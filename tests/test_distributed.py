"""Distributed == local engine equality, executed in a subprocess with
forced host devices (the parent test process must keep 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import Engine
    from repro.data import make_dataset
    from repro.partition import partition, STRATEGIES
    from repro.algorithms import (pagerank_spec, pagerank_entropy_spec,
        label_propagation_spec, shortest_paths_spec, random_walk_spec,
        connected_components_spec)

    mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
    hg = make_dataset('apache', scale=0.04, seed=3)
    specs = {
      'pagerank': pagerank_spec(hg, iters=6),
      'pr_entropy': pagerank_entropy_spec(hg, iters=6),
      'labelprop': label_propagation_spec(hg, iters=8),
      'sssp': shortest_paths_spec(hg, source=1, max_iters=16),
      'randwalk': random_walk_spec(hg, iters=6),
      'cc': connected_components_spec(hg, max_iters=32),
    }
    failures = []
    for strat in ['random_vertex_cut', 'random_both_cut',
                  'hybrid_hyperedge_cut', 'greedy_vertex_cut']:
        kw = {'chunk': 32} if 'greedy' in strat else {}
        plan = partition(strat, hg, 8, **kw)
        for name, spec in specs.items():
            ref = Engine(representation='bipartite',
                         backend='local').run(spec).value
            for backend in ['replicated', 'sharded']:
                got = Engine(plan=plan, mesh=mesh,
                             representation='bipartite',
                             backend=backend).run(spec).value
                ok = jax.tree.all(jax.tree.map(
                    lambda a, b: np.allclose(np.asarray(a), np.asarray(b),
                                             rtol=1e-5, atol=1e-5,
                                             equal_nan=True), ref, got))
                if not bool(ok):
                    failures.append((strat, name, backend))
    assert not failures, failures
    print('ALL_MATCH')
""")


@pytest.mark.slow
def test_distributed_matches_local_all_algorithms():
    # Inherit the environment: dropping JAX_PLATFORMS makes jax probe for
    # accelerator platforms, stalling the child for minutes.
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=1200,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL_MATCH" in proc.stdout
