"""Property tests for the sparse substrate (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sparse import (
    MONOIDS,
    embedding_bag,
    segment_mean,
    segment_softmax,
    segment_std,
    segment_reduce,
)
from repro.sparse.embedding_bag import embedding_bag_dense

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def segmented_data(draw):
    n_seg = draw(st.integers(1, 12))
    n = draw(st.integers(1, 64))
    ids = draw(
        st.lists(st.integers(0, n_seg - 1), min_size=n, max_size=n)
    )
    vals = draw(
        st.lists(
            st.floats(-100, 100, allow_nan=False, width=32),
            min_size=n, max_size=n,
        )
    )
    return np.array(ids, np.int32), np.array(vals, np.float32), n_seg


@given(segmented_data(), st.sampled_from(["sum", "max", "min", "prod"]))
def test_segment_reduce_matches_fold(data, monoid_name):
    """segment(x, ids)[i] == fold(combine, identity, values of segment i)
    — the monoid law that makes pre-aggregation before the network legal."""
    ids, vals, n_seg = data
    monoid = MONOIDS[monoid_name]
    got = np.asarray(
        segment_reduce(jnp.asarray(vals), jnp.asarray(ids), n_seg,
                       monoid_name)
    )
    for s in range(n_seg):
        members = vals[ids == s]
        ident = float(monoid.identity(np.float32))
        expect = ident
        for m in members:
            expect = float(monoid.combine(jnp.float32(expect),
                                          jnp.float32(m)))
        if len(members) == 0 and monoid_name in ("max", "min"):
            continue  # XLA empty-segment convention (±inf) — skip
        np.testing.assert_allclose(got[s], expect, rtol=2e-5, atol=1e-4)


@given(segmented_data())
def test_segment_softmax_normalizes(data):
    ids, vals, n_seg = data
    p = np.asarray(
        segment_softmax(jnp.asarray(vals), jnp.asarray(ids), n_seg)
    )
    sums = np.zeros(n_seg)
    np.add.at(sums, ids, p)
    present = np.unique(ids)
    np.testing.assert_allclose(sums[present], 1.0, rtol=1e-5)
    assert (p >= 0).all()


@given(segmented_data())
def test_segment_mean_std(data):
    ids, vals, n_seg = data
    mean = np.asarray(segment_mean(jnp.asarray(vals), jnp.asarray(ids),
                                   n_seg))
    std = np.asarray(segment_std(jnp.asarray(vals), jnp.asarray(ids),
                                 n_seg))
    for s in np.unique(ids):
        m = vals[ids == s]
        np.testing.assert_allclose(mean[s], m.mean(), rtol=2e-4, atol=1e-4)
        np.testing.assert_allclose(
            std[s], np.sqrt(m.var() + 1e-5), rtol=2e-3, atol=1e-3
        )


@given(
    st.integers(2, 20), st.integers(1, 8), st.integers(1, 30),
    st.sampled_from(["sum", "mean", "max"]),
)
def test_embedding_bag_matches_loop(vocab, dim, nnz, mode):
    rng = np.random.default_rng(0)
    table = rng.standard_normal((vocab, dim)).astype(np.float32)
    idx = rng.integers(0, vocab, nnz).astype(np.int32)
    bags = np.sort(rng.integers(0, 4, nnz)).astype(np.int32)
    got = np.asarray(
        embedding_bag(jnp.asarray(table), jnp.asarray(idx),
                      jnp.asarray(bags), 4, mode=mode)
    )
    for b in range(4):
        rows = table[idx[bags == b]]
        if len(rows) == 0:
            np.testing.assert_allclose(got[b], 0.0, atol=1e-6)
            continue
        expect = {
            "sum": rows.sum(0), "mean": rows.mean(0), "max": rows.max(0)
        }[mode]
        np.testing.assert_allclose(got[b], expect, rtol=1e-5, atol=1e-5)


def test_embedding_bag_dense_matches_ragged():
    rng = np.random.default_rng(1)
    table = rng.standard_normal((50, 8)).astype(np.float32)
    idx = rng.integers(1, 50, (6, 5)).astype(np.int32)
    idx[2, 3:] = 0  # PAD
    dense = np.asarray(
        embedding_bag_dense(jnp.asarray(table), jnp.asarray(idx),
                            mode="sum", pad_id=0)
    )
    flat = idx.reshape(-1)
    bags = np.repeat(np.arange(6), 5).astype(np.int32)
    keep = flat != 0
    ragged = np.asarray(
        embedding_bag(jnp.asarray(table), jnp.asarray(flat[keep]),
                      jnp.asarray(bags[keep]), 6, mode="sum")
    )
    np.testing.assert_allclose(dense, ragged, rtol=1e-5, atol=1e-5)
