"""Validation against the paper's own claims (EXPERIMENTS.md §Paper-claims).

The paper reports scalability/flexibility results, not accuracy; the
reproducible claims at CI scale are:

  C1  (Fig 7 / Table I) clique expansion explodes for heavy-tailed
      hypergraphs and stays moderate for apache-like ones.
  C2  (Figs 8-11) no single partitioner dominates: the best strategy
      differs across dataset regimes, tracking the V:E ratio.
  C3  (§IV-B) greedy (holistic) partitioning cuts replication vs random.
  C4  (Table II) the system core stays within the paper's MESH-vs-HyperX
      LOC envelope (~5x smaller than a specialized build).
  C5  message combining: sum-decomposed == Seq-combined results
      (pre-aggregation is lossless) — covered in test_algorithms.py.
"""
import os

import numpy as np
import pytest

from repro.core import clique_expansion_size
from repro.data import make_dataset
from repro.partition import STRATEGIES, partition


def test_c1_clique_expansion_blowup():
    apache = make_dataset("apache", scale=0.05, seed=0)
    orkut = make_dataset("orkut", scale=0.0005, seed=0)
    ratio_apache = clique_expansion_size(apache) / apache.nnz
    ratio_orkut = clique_expansion_size(orkut) / orkut.nnz
    # heavy-tailed cardinalities blow up quadratically; apache stays small
    assert ratio_orkut > 3 * ratio_apache


def test_c2_no_partitioner_dominates():
    """Rank partitioners by projected sync bytes per regime; the argmin
    must differ across regimes (the paper's flexibility argument)."""
    winners = {}
    for regime, scale in [("friendster", 0.0008), ("orkut", 0.0003),
                          ("dblp", 0.002)]:
        hg = make_dataset(regime, scale=scale, seed=0)
        best, best_cost = None, np.inf
        for strat in STRATEGIES:
            kw = {"chunk": 256} if "greedy" in strat else {}
            plan = partition(strat, hg, 8, **kw)
            # paper's execution-time drivers: sync volume + load balance
            cost = plan.stats.sync_bytes_per_dim * plan.stats.edge_balance
            if cost < best_cost:
                best, best_cost = strat, cost
        winners[regime] = best
    assert len(set(winners.values())) >= 2, winners


def test_c3_greedy_beats_random_on_replication():
    hg = make_dataset("dblp", scale=0.004, seed=1)
    r = partition("random_vertex_cut", hg, 8)
    g = partition("greedy_vertex_cut", hg, 8, chunk=256)
    assert (
        g.stats.vertex_replication < r.stats.vertex_replication
    )


def test_c4_loc_envelope():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def loc(path):
        total = 0
        for base, _, files in os.walk(os.path.join(root, path)):
            for f in files:
                if f.endswith(".py"):
                    with open(os.path.join(base, f)) as fh:
                        total += sum(
                            1 for ln in fh
                            if ln.strip() and not ln.strip().startswith("#")
                        )
        return total

    core = loc("src/repro/core") + loc("src/repro/partition")
    apps = loc("src/repro/algorithms")
    # paper: MESH total system 795 LOC vs HyperX 4050. Our JAX port spends
    # more lines (distributed executor is explicit, not inherited from
    # GraphX) but must stay well under the specialized-system scale.
    assert core < 4050, core
    # applications stay tens-of-lines each (7 algorithms)
    assert apps / 7 < 120, apps
