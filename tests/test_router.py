"""Multi-replica serving: the Router's failover contract, chaos-tested.

The PR 9 invariant crossed the process boundary: every request admitted
by the ``Router`` resolves — a value or a typed error — no matter which
replicas die, when, or how (kill -9, wedged-without-exiting, broken
pipe).  Asserted at three depths:

* fake-clock unit tests against in-memory ``FakeReplica`` handles: no
  processes, no threads, no sleeps — heartbeat expiry, bounded failover
  (``ReplicaLost`` after ``MAX_FAILOVERS``), load shedding
  (``Overloaded``), ``close()`` draining (``FrontendClosed``),
  affinity/least-loaded routing, respawn, the ``router.route`` fault
  point;
* a chaos property: random kill schedules x arrival orders x completion
  interleavings — every future resolves, successes equal the
  deterministic sequential value, ``in_flight == 0`` at drain;
* slow subprocess integration: real replica processes over the real
  shared disk store, one killed -9 mid-replay — survivors' results
  bitwise equal the parent's sequential runs and the respawn boots from
  disk with zero retraces.  Plus the cross-process ``cache.lock`` store
  stress (two simultaneous ``serve.warm`` on one empty dir).
"""
import os
import subprocess
import sys
import textwrap
import threading
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.faults import (
    FaultInjector,
    FaultPlan,
    FaultRule,
    FrontendClosed,
    InjectedFault,
    Overloaded,
    ReplicaLost,
)
from repro.obs.metrics import MetricsRegistry
from repro.serve.router import MAX_FAILOVERS, Router

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------
# fakes: a replica handle and a clock, both fully deterministic
# --------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


class FakeReplica:
    """In-memory stand-in for ``ProcessReplica``: the router sends
    requests in, the test decides when (and whether) results come back.
    Deterministic execution model: ``value = f"v:{key}:{query}"``."""

    def __init__(self, index):
        self.index = index
        self.outbox = [("ready", {"index": index, "boot_s": 0.0,
                                  "traces": 0, "from_disk": 1,
                                  "compiled": 0})]
        self.inbox = []          # ("req", id, key, query, hg, deadline)
        self.sent_stop = False
        self._alive = True
        self._broken = False
        self.connection = None

    # -- the ProcessReplica interface -------------------------------------
    def poll_messages(self):
        out, self.outbox = self.outbox, []
        return out

    def send(self, msg):
        if self._broken or not self._alive:
            raise BrokenPipeError(f"fake replica {self.index} down")
        if msg[0] == "stop":
            self.sent_stop = True
        else:
            self.inbox.append(msg)

    def alive(self):
        return self._alive and not self._broken

    def kill(self):
        self._alive = False

    def stop(self, force=False, join_s=None):
        self._alive = False

    # -- test controls -----------------------------------------------------
    def heartbeat(self):
        self.outbox.append(("hb", {"received": len(self.inbox)}))

    def complete(self, n=None):
        """Answer the oldest ``n`` queued requests (all by default)."""
        done = 0
        while self.inbox and (n is None or done < n):
            _, req_id, key, query, _hg, _dl = self.inbox.pop(0)
            self.outbox.append(("res", req_id, f"v:{key}:{query}"))
            done += 1
        return done

    def fail_one(self, err):
        _, req_id, *_ = self.inbox.pop(0)
        self.outbox.append(("err", req_id, err))

    def die(self):
        """Process exit: poll_messages still drains what was written."""
        self._alive = False

    def break_pipe(self):
        self._broken = True


def make_router(n=2, clock=None, registry=None, **kw):
    clock = clock or FakeClock()
    replicas = []

    def factory(i):
        r = FakeReplica(i)
        replicas.append(r)
        return r

    kw.setdefault("heartbeat_timeout_ms", 1000.0)
    kw.setdefault("boot_timeout_s", 100.0)
    router = Router(factory, n, clock=clock,
                    registry=registry or MetricsRegistry(), **kw)
    router.pump(clock.now)      # drain the ready messages
    return router, replicas, clock


def expected(key, query):
    return f"v:{key}:{query}"


# --------------------------------------------------------------------------
# fake-clock units
# --------------------------------------------------------------------------

def test_routes_completes_and_counts():
    router, reps, clock = make_router(2)
    futs = [(k, q, router.submit(k, query=q))
            for k, q in [("sssp", 1), ("ppr", 2), ("sssp", 3), ("ppr", 4)]]
    assert router.in_flight() == 4
    for r in reps:
        r.complete()
    router.pump(clock.now)
    for k, q, f in futs:
        assert f.result(timeout=1) == expected(k, q)
    st_ = router.stats()
    assert st_["served"] == 4 and st_["in_flight"] == 0
    assert st_["deaths"] == 0 and st_["failovers"] == 0


def test_affinity_pins_key_to_home_replica():
    router, reps, clock = make_router(2)
    for q in range(4):
        router.submit("sssp", query=q)
    homes = {i for i, r in enumerate(reps) if r.inbox}
    # All four go to ONE home replica (load within affinity_slack=2 of
    # the empty peer only holds for the first few; 4 - 0 > 2 spills).
    assert len(reps[min(homes)].inbox) >= 3


def test_least_loaded_takes_spill():
    router, reps, clock = make_router(2, affinity_slack=0)
    keys = [("sssp", q) for q in range(6)]
    for k, q in keys:
        router.submit(k, query=q)
    # slack 0: any imbalance spills to the least-loaded peer
    assert abs(len(reps[0].inbox) - len(reps[1].inbox)) <= 1


def test_heartbeat_expiry_fails_over_and_respawns():
    reg = MetricsRegistry()
    router, reps, clock = make_router(2, registry=reg)
    f = router.submit("sssp", query=7)
    serving = next(r for r in reps if r.inbox)
    other = next(r for r in reps if r is not serving)
    # The wedged replica stops heartbeating; the healthy one keeps going.
    for _ in range(3):
        clock.advance(0.5)
        other.heartbeat()
        router.pump(clock.now)
    # > heartbeat_timeout since `serving` last spoke: declared dead, its
    # in-flight request failed over to `other`, and a respawn appeared.
    assert not serving.alive()
    assert len(reps) == 3                      # the respawned instance
    assert any(m[0] == "req" for m in other.inbox)
    other.complete()
    router.pump(clock.now)
    assert f.result(timeout=1) == expected("sssp", 7)
    assert reg.counter("faults.replica.deaths").value == 1
    assert reg.counter("faults.replica.failovers").value == 1
    assert reg.counter("faults.replica.respawns").value == 1


def test_failover_budget_exhausts_to_replica_lost():
    reg = MetricsRegistry()
    router, reps, clock = make_router(2, registry=reg)
    f = router.submit("sssp", query=1)
    deaths = 0
    while not f.done():
        serving = next((r for r in reps if r.inbox and r.alive()), None)
        assert serving is not None, "request parked with no serving replica"
        serving.die()
        deaths += 1
        clock.advance(0.01)
        router.pump(clock.now)
        assert deaths <= MAX_FAILOVERS + 2, "future never resolved"
    with pytest.raises(ReplicaLost):
        f.result(timeout=1)
    # budget: MAX_FAILOVERS re-routes then lost on the next death
    assert deaths == MAX_FAILOVERS + 1
    assert reg.counter("faults.replica.lost").value == 1
    assert router.in_flight() == 0


def test_close_drains_queued_and_in_flight_typed():
    router, reps, clock = make_router(1, max_in_flight=1)
    f1 = router.submit("sssp", query=1)          # dispatched
    f2 = router.submit("sssp", query=2)          # parked (cap 1)
    router.close()
    with pytest.raises(FrontendClosed):
        f1.result(timeout=1)
    with pytest.raises(FrontendClosed):
        f2.result(timeout=1)
    f3 = router.submit("sssp", query=3)          # after close
    with pytest.raises(FrontendClosed):
        f3.result(timeout=1)
    assert router.in_flight() == 0


def test_overload_sheds_typed():
    reg = MetricsRegistry()
    router, reps, clock = make_router(1, max_queue_depth=2, registry=reg)
    keep = [router.submit("sssp", query=q) for q in range(2)]
    shed = router.submit("sssp", query=99)
    with pytest.raises(Overloaded):
        shed.result(timeout=1)
    assert reg.counter("serve.router.shed").value == 1
    reps[0].complete()
    router.pump(clock.now)
    for q, f in enumerate(keep):
        assert f.result(timeout=1) == expected("sssp", q)


def test_route_fault_point_resolves_typed():
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule(point="router.route", trigger="nth", n=2, error="fatal"),
    )))
    router, reps, clock = make_router(2, fault_injector=inj)
    f1 = router.submit("sssp", query=1)
    f2 = router.submit("sssp", query=2)          # nth=2: injected
    with pytest.raises(InjectedFault):
        f2.result(timeout=1)
    for r in reps:
        r.complete()
    router.pump(clock.now)
    assert f1.result(timeout=1) == expected("sssp", 1)
    assert inj.snapshot()["never_fired"] == []


def test_broken_pipe_at_send_fails_over():
    router, reps, clock = make_router(2)
    reps[0].break_pipe()
    futs = [router.submit("sssp", query=q) for q in range(3)]
    router.pump(clock.now)
    alive = [r for r in reps if r.alive()]
    for r in alive:
        r.complete()
    router.pump(clock.now)
    for q, f in enumerate(futs):
        assert f.result(timeout=1) == expected("sssp", q)


def test_all_dead_without_respawn_resolves_replica_lost():
    router, reps, clock = make_router(2, respawn=False)
    futs = [router.submit("sssp", query=q) for q in range(4)]
    for r in reps:
        r.die()
    clock.advance(0.01)
    router.pump(clock.now)
    for f in futs:
        with pytest.raises(ReplicaLost):
            f.result(timeout=1)
    # admission after total loss fails immediately, typed
    with pytest.raises(ReplicaLost):
        router.submit("sssp", query=9).result(timeout=1)


def test_boot_timeout_declares_dead():
    clock = FakeClock()
    spawned = []

    def factory(i):
        r = FakeReplica(i)
        r.outbox.clear()                 # never says ready
        spawned.append(r)
        return r

    router = Router(factory, 1, boot_timeout_s=5.0, max_respawns=1,
                    clock=clock, registry=MetricsRegistry())
    f = router.submit("sssp", query=1)
    clock.advance(6.0)
    router.pump(clock.now)               # boot timeout -> dead -> respawn
    assert len(spawned) == 2
    clock.advance(6.0)
    router.pump(clock.now)               # respawn also times out; budget 1
    with pytest.raises(ReplicaLost):
        f.result(timeout=1)


def test_max_in_flight_caps_dispatch():
    router, reps, clock = make_router(1, max_in_flight=2)
    futs = [router.submit("sssp", query=q) for q in range(5)]
    assert len(reps[0].inbox) == 2
    assert router.stats()["pending"] == 3
    reps[0].complete()
    router.pump(clock.now)
    assert len(reps[0].inbox) == 2       # refilled from pending
    while router.stats()["pending"] or router.in_flight():
        reps[0].complete()
        router.pump(clock.now)
    for q, f in enumerate(futs):
        assert f.result(timeout=1) == expected("sssp", q)


def test_stats_provider_registered():
    reg = MetricsRegistry()
    router, reps, clock = make_router(2, registry=reg)
    router.submit("sssp", query=1)
    snap = reg.snapshot()
    assert snap["serve.router"]["replicas"] == 2
    assert snap["serve.router"]["in_flight"] == 1


# --------------------------------------------------------------------------
# the chaos property: random kill schedules x arrival orders
# --------------------------------------------------------------------------

@given(
    st.lists(st.integers(min_value=0, max_value=5), min_size=4,
             max_size=24),                       # per-step arrivals (key id)
    st.lists(st.integers(min_value=0, max_value=30), min_size=0,
             max_size=6),                        # kill steps
    st.integers(min_value=1, max_value=3),       # completions per step
)
@settings(max_examples=60, deadline=None)
def test_chaos_every_request_resolves(arrivals, kill_steps, per_step):
    router, reps, clock = make_router(
        2, max_respawns=50, heartbeat_timeout_ms=1000.0)
    kills = sorted(set(kill_steps))
    futs = []
    step = 0
    pending_arrivals = list(enumerate(arrivals))
    # Run until every future resolves (bounded: the failover budget plus
    # respawns guarantee progress; 500 steps is far beyond worst case).
    while pending_arrivals or not all(f.done() for _, _, f in futs):
        assert step < 500, "chaos schedule failed to drain"
        if pending_arrivals:
            q, key_id = pending_arrivals.pop(0)
            key = f"k{key_id}"
            futs.append((key, q, router.submit(key, query=q)))
        if step in kills:
            live = [r for r in reps if r.alive() and r.inbox]
            if not live:
                live = [r for r in reps if r.alive()]
            if live:
                live[step % len(live)].die()
        for r in reps:
            if r.alive():
                r.complete(per_step)
                r.heartbeat()
        clock.advance(0.05)
        router.pump(clock.now)
        step += 1
    ok = lost = 0
    for key, q, f in futs:
        try:
            # == the deterministic sequential value, per request
            assert f.result(timeout=0) == expected(key, q)
            ok += 1
        except ReplicaLost:
            lost += 1
    assert ok + lost == len(futs)        # nothing hangs, nothing vanishes
    assert router.in_flight() == 0
    assert router.stats()["pending"] == 0
    if not kills:
        assert lost == 0                 # fault-free: every value lands


# --------------------------------------------------------------------------
# cache.lock: cross-thread contention unit (cross-process stress is slow)
# --------------------------------------------------------------------------

def test_disk_lock_contention_counts_waits(tmp_path):
    from repro.serve import DiskExecutableCache

    cache = DiskExecutableCache(str(tmp_path))
    inside = threading.Event()
    release = threading.Event()
    entered = []

    def holder():
        with cache.lock("k"):
            inside.set()
            release.wait(5)

    def contender():
        inside.wait(5)
        with cache.lock("k"):
            entered.append(True)

    t1 = threading.Thread(target=holder)
    t2 = threading.Thread(target=contender)
    t1.start(); t2.start()
    inside.wait(5)
    time.sleep(0.05)                     # let the contender hit the lock
    release.set()
    t1.join(5); t2.join(5)
    assert entered == [True]
    assert cache.stats()["disk_lock_waits"] >= 1


# --------------------------------------------------------------------------
# slow: real processes over the real shared store
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_pool_survives_kill9_midreplay(tmp_path):
    """Kill -9 one of two real replicas mid-replay: every request
    resolves, survivors' values are bitwise equal to the parent's
    sequential runs, and the respawn boots from disk with zero traces."""
    import numpy as np

    import jax
    from repro import algorithms as alg
    from repro.core import Engine
    from repro.data import make_dataset
    from repro.serve import (
        DiskExecutableCache,
        ProcessReplica,
        ReplicaConfig,
        Router,
        warm,
    )

    cache_dir = str(tmp_path / "store")
    hg = make_dataset("dblp", scale=0.003, seed=0)
    engine = Engine(disk_cache=DiskExecutableCache(cache_dir))
    specs = {
        "sssp": alg.shortest_paths_spec(hg, source=0, max_iters=12),
        "ppr": alg.random_walk_spec(hg, iters=12),
    }
    warm(engine, list(specs.values()), batch_sizes=(8,), queries=[0, 0])

    cfg = ReplicaConfig(
        builder="repro.launch.serve_hypergraph:build_paths",
        kwargs={"regime": "dblp", "scale": 0.003, "seed": 0, "iters": 12},
        cache_dir=cache_dir, max_batch=8, require_no_retrace=True,
    )
    router = Router(lambda i: ProcessReplica(i, cfg), 2,
                    heartbeat_timeout_ms=2000.0, max_in_flight=8,
                    registry=MetricsRegistry()).start()
    try:
        router.wait_ready(timeout_s=180)
        trace = [("sssp" if q % 2 else "ppr", q % hg.n_vertices)
                 for q in range(40)]
        futs = [(k, q, router.submit(k, query=q)) for k, q in trace]
        # kill -9 one replica while the batch is mid-flight
        victim = router.slots[0].handle
        os.kill(victim.pid, 9)
        values, lost = {}, 0
        for k, q, f in futs:
            try:
                values[(k, q)] = f.result(timeout=300)
            except (ReplicaLost, FrontendClosed):
                lost += 1
        assert len(values) + lost == len(trace)      # all resolved
        assert len(values) >= len(trace) - MAX_FAILOVERS  # almost all land
        assert router.in_flight() == 0
        stats = router.stats()
        assert stats["deaths"] >= 1 and stats["respawns"] >= 1
        # the respawned instance booted from disk, zero retraces
        router.wait_ready(timeout_s=180)
        reborn = router.stats()["per_replica"][0]["boot"]
        assert reborn["traces"] == 0 and reborn["from_disk"] > 0
        # bitwise vs the parent's sequential fault-free path
        for (k, q), served in list(values.items())[:8]:
            seq = engine.compile(specs[k]).run(query=q)
            for a, b in zip(jax.tree.leaves(seq.value),
                            jax.tree.leaves(served.value)):
                assert np.array_equal(np.asarray(a), np.asarray(b),
                                      equal_nan=True)
    finally:
        router.close()


CONCURRENT_WARM_CHILD = textwrap.dedent("""
    import os, sys, time
    from repro.core import Engine
    from repro import algorithms as alg
    from repro.data import make_dataset
    from repro.serve import DiskExecutableCache, warm

    cache_dir, barrier = sys.argv[1], sys.argv[2]
    hg = make_dataset("dblp", scale=0.003, seed=0)
    specs = [alg.shortest_paths_spec(hg, source=0, max_iters=8)]
    # barrier: both children reach here, then compile simultaneously
    open(barrier + "." + str(os.getpid()), "w").close()
    deadline = time.time() + 60
    while len([f for f in os.listdir(os.path.dirname(barrier))
               if os.path.basename(barrier) + "." in f]) < 2:
        assert time.time() < deadline, "peer never arrived"
        time.sleep(0.01)
    eng = Engine(disk_cache=DiskExecutableCache(cache_dir))
    report = warm(eng, specs, batch_sizes=(8,), queries=[0])
    res = eng.compile(specs[0]).run(query=0)
    import jax
    import numpy as np
    total = sum(float(np.asarray(x).sum())
                for x in jax.tree.leaves(res.value))
    print("OK", report["traces"], total)
""")


@pytest.mark.slow
def test_concurrent_warm_on_one_empty_store(tmp_path):
    """Two processes ``serve.warm`` the SAME empty store simultaneously:
    the advisory lock serializes compile-and-store, both exit clean, and
    both serve identical results."""
    cache_dir = str(tmp_path / "store")
    barrier = str(tmp_path / "barrier")
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", CONCURRENT_WARM_CHILD, cache_dir,
             barrier],
            env=env, cwd=REPO, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True,
        )
        for _ in range(2)
    ]
    outs = []
    for p in procs:
        out, err = p.communicate(timeout=600)
        assert p.returncode == 0, f"child failed:\n{err}\n{out}"
        outs.append([ln for ln in out.splitlines() if ln.startswith("OK")][0])
    sums = {o.split()[-1] for o in outs}
    assert len(sums) == 1, f"divergent results: {outs}"
    # the store holds each signature once (no torn/duplicate publish)
    from repro.serve import DiskExecutableCache

    cache = DiskExecutableCache(cache_dir)
    assert cache.stats()["entries"] >= 1
