"""BERT4Rec smoke: cloze training, serving, retrieval scoring."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.recsys import bert4rec as b4r
from repro.train import AdamWConfig, init_train_state, make_train_step

SPEC = get_config("bert4rec", smoke=True)
CFG = SPEC.model


def _batch(key, batch=4, n_masked=3):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    items = jax.random.randint(k1, (batch, CFG.max_seq), 1, CFG.n_items)
    masked_pos = jax.random.randint(
        k2, (batch, n_masked), 0, CFG.max_seq
    )
    labels = jnp.take_along_axis(items, masked_pos, axis=1)
    items = jnp.stack([
        items[i].at[masked_pos[i]].set(CFG.mask_id)
        for i in range(batch)
    ])
    negatives = jax.random.randint(k4, (64,), 1, CFG.n_items)
    return {
        "items": items, "masked_pos": masked_pos, "labels": labels,
        "negatives": negatives,
    }


def test_cloze_training_decreases_loss():
    params = b4r.init_params(jax.random.PRNGKey(0), CFG)
    step = make_train_step(
        lambda p, b: b4r.loss_sampled(p, CFG, b),
        AdamWConfig(lr=1e-3, total_steps=20),
    )
    state = init_train_state(params)
    batch = _batch(jax.random.PRNGKey(1))
    losses = []
    for _ in range(5):
        state, m = jax.jit(step)(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_serve_scores_full_catalog():
    params = b4r.init_params(jax.random.PRNGKey(0), CFG)
    items = jax.random.randint(
        jax.random.PRNGKey(2), (3, CFG.max_seq), 1, CFG.n_items
    )
    scores = b4r.serve_score(params, CFG, items)
    assert scores.shape == (3, CFG.vocab)
    assert not bool(jnp.isnan(scores).any())


def test_retrieval_matches_full_scoring():
    """Scoring a candidate subset must agree with the corresponding
    entries of the full-catalog scores (blocked dot == gather of full)."""
    params = b4r.init_params(jax.random.PRNGKey(0), CFG)
    items = jax.random.randint(
        jax.random.PRNGKey(3), (1, CFG.max_seq), 1, CFG.n_items
    )
    cand = jax.random.randint(jax.random.PRNGKey(4), (100,), 1,
                              CFG.n_items)
    sub = b4r.retrieval_score(params, CFG, items, cand)
    full = b4r.serve_score(params, CFG, items)[0]
    np.testing.assert_allclose(
        np.asarray(sub), np.asarray(full)[np.asarray(cand)],
        rtol=1e-5, atol=1e-5,
    )


def test_pad_vocab_is_lane_aligned():
    assert get_config("bert4rec").model.vocab % 512 == 0
