"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash.ops import flash_attention
from repro.kernels.flash.ref import attention_ref
from repro.kernels.isect.ops import pair_intersect_bitset
from repro.kernels.isect.ref import pair_intersect_ref
from repro.kernels.segsum.ops import segment_sum_mxu
from repro.kernels.segsum.ref import segment_sum_ref


@pytest.mark.parametrize("e,n,d", [
    (256, 64, 32), (1000, 300, 64), (512, 128, 128), (77, 13, 8),
    (2048, 17, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segsum_sweep(e, n, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(e + n))
    msgs = jax.random.normal(k1, (e, d), dtype)
    dst = jax.random.randint(k2, (e,), 0, n)
    got = segment_sum_mxu(msgs, dst, n, block_n=64, block_e=128,
                          interpret=True)
    want = segment_sum_ref(msgs, dst, n)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    # bf16 rounding error grows with per-segment accumulation depth
    # (~e/n addends); near-zero sums of ~120 N(0,1) values cancel
    # catastrophically, so the floor must scale with sqrt(depth).
    atol = tol * 10 * max(1.0, (e / n) ** 0.5 / 3.0)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=atol,
    )


def test_segsum_empty_and_single_segment():
    msgs = jnp.ones((128, 8), jnp.float32)
    dst = jnp.zeros((128,), jnp.int32)
    got = segment_sum_mxu(msgs, dst, 4, block_n=64, block_e=128,
                          interpret=True)
    np.testing.assert_allclose(got[0], 128.0)
    np.testing.assert_allclose(got[1:], 0.0)


@pytest.mark.parametrize("e,n,d", [
    (256, 64, 32), (1000, 300, 8), (77, 13, 8), (512, 17, 16),
])
def test_segsum_sorted_block_skip(e, n, d):
    """dst-SORTED inputs through the block-sparse skip (per-tile CSR
    block bounds, scalar-prefetched) == the full-sweep fallback == the
    jnp oracle."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(e * n))
    msgs = jax.random.normal(k1, (e, d), jnp.float32)
    dst = jnp.sort(jax.random.randint(k2, (e,), 0, n))
    got = segment_sum_mxu(msgs, dst, n, sorted_dst=True,
                          block_n=64, block_e=128, interpret=True)
    full = segment_sum_mxu(msgs, dst, n, sorted_dst=False,
                           block_n=64, block_e=128, interpret=True)
    want = segment_sum_ref(msgs, dst, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    # skip path == sweep path exactly (same blocks, same order)
    assert np.array_equal(np.asarray(got), np.asarray(full))


@pytest.mark.parametrize("b,h,s,d", [
    (2, 3, 256, 64), (1, 2, 128, 32), (2, 2, 384, 64), (1, 1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(b, h, s, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * h + s), 3)
    q = (jax.random.normal(k1, (b, h, s, d)) * 0.3).astype(dtype)
    k = (jax.random.normal(k2, (b, h, s, d)) * 0.3).astype(dtype)
    v = jax.random.normal(k3, (b, h, s, d)).astype(dtype)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_unpadded_vs_padded_sequence():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (1, 2, 200, 32)) * 0.3
    k = jax.random.normal(k2, (1, 2, 200, 32)) * 0.3
    v = jax.random.normal(k3, (1, 2, 200, 32))
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("fused", [True, False])
@pytest.mark.parametrize("n_pairs,n_edges,n_vertices", [
    (100, 40, 64), (1000, 300, 500), (37, 5, 2000), (513, 64, 31),
])
def test_isect_bitset_sweep(n_pairs, n_edges, n_vertices, fused):
    """Blocked AND+popcount pair-intersection kernel vs the
    population_count oracle (and the SWAR popcount inside it), in both
    forms: in-kernel scalar-prefetch row gather (fused) and the
    pre-gathered reference."""
    from repro.data import powerlaw_hypergraph
    from repro.motifs import build_index

    hg = powerlaw_hypergraph(
        n_vertices, n_edges, mean_cardinality=4,
        seed=n_pairs + n_edges,
    )
    bits = build_index(hg, "bitset").data
    k1, k2 = jax.random.split(jax.random.PRNGKey(n_pairs))
    ea = jax.random.randint(k1, (n_pairs,), 0, n_edges)
    eb = jax.random.randint(k2, (n_pairs,), 0, n_edges)
    got = pair_intersect_bitset(
        bits, ea, eb, block_p=128, block_w=4, fused=fused,
        interpret=True,
    )
    want = pair_intersect_ref(bits, ea, eb)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_isect_fused_skewed_hot_rows():
    """Skewed pair batches (every pair hits the same hot rows) — the
    fused gather's motivating regime."""
    from repro.data import powerlaw_hypergraph
    from repro.motifs import build_index

    hg = powerlaw_hypergraph(300, 64, mean_cardinality=6, seed=9)
    bits = build_index(hg, "bitset").data
    ea = jnp.zeros((700,), jnp.int32)          # one hot row vs all
    eb = jnp.arange(700, dtype=jnp.int32) % 64
    got = pair_intersect_bitset(
        bits, ea, eb, block_p=128, block_w=4, fused=True, interpret=True
    )
    want = pair_intersect_ref(bits, ea, eb)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_isect_empty_and_identical_pairs():
    from repro.data import powerlaw_hypergraph
    from repro.motifs import build_index

    hg = powerlaw_hypergraph(50, 10, mean_cardinality=4, seed=0)
    index = build_index(hg, "bitset")
    ids = jnp.arange(10)
    got = pair_intersect_bitset(index.data, ids, ids, interpret=True)
    # e ∩ e == |e|
    assert np.array_equal(np.asarray(got), index.cardinalities())
    empty = pair_intersect_bitset(
        index.data, jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
        interpret=True,
    )
    assert empty.shape == (0,)
