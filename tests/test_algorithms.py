"""Algorithm correctness against independent oracles (networkx / numpy)."""
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.algorithms import (
    connected_components,
    label_propagation,
    pagerank,
    pagerank_entropy,
    pagerank_entropy_seq,
    random_walk,
    shortest_paths,
)
from repro.core import HyperGraph
from repro.data import powerlaw_hypergraph

FIG1 = [[0, 1], [0, 1, 2, 3], [0, 3, 4], [2, 3]]


def bipartite_nx(hg):
    g = nx.Graph()
    src = np.asarray(hg.src)
    dst = np.asarray(hg.dst)
    for v in range(hg.n_vertices):
        g.add_node(("v", v))
    for e in range(hg.n_hyperedges):
        g.add_node(("e", e))
    for s, d in zip(src, dst):
        g.add_edge(("v", int(s)), ("e", int(d)))
    return g


@pytest.fixture(params=[0, 1])
def hyper(request):
    if request.param == 0:
        return HyperGraph.from_hyperedge_lists(FIG1, n_vertices=5)
    return powerlaw_hypergraph(60, 40, mean_cardinality=4, seed=3)


def test_sssp_matches_networkx(hyper):
    vd, hed = shortest_paths(hyper, source=0, max_iters=64)
    g = bipartite_nx(hyper)
    lengths = nx.single_source_shortest_path_length(g, ("v", 0))
    for v in range(hyper.n_vertices):
        nx_d = lengths.get(("v", v), np.inf)
        # hyperedge hops = bipartite hops / 2
        expect = nx_d / 2 if np.isfinite(nx_d) else np.inf
        got = float(vd[v])
        assert got == expect, (v, got, expect)


def test_connected_components_match_networkx(hyper):
    vc, hec = connected_components(hyper)
    g = bipartite_nx(hyper)
    for comp in nx.connected_components(g):
        vs = [n[1] for n in comp if n[0] == "v"]
        if not vs:
            continue
        labels = {int(vc[v]) for v in vs}
        assert len(labels) == 1
        assert labels.pop() == min(vs)
    # isolated vertices keep their own id
    iso = set(range(hyper.n_vertices)) - {
        int(s) for s in np.asarray(hyper.src)
    }
    for v in iso:
        assert int(vc[v]) == v


def test_label_propagation_converges_to_component_max(hyper):
    vl, hel = label_propagation(hyper, iters=64)
    g = bipartite_nx(hyper)
    for comp in nx.connected_components(g):
        vs = [n[1] for n in comp if n[0] == "v"]
        if not vs:
            continue
        labels = {int(vl[v]) for v in vs}
        assert labels == {max(vs)}


def test_pagerank_against_dense_oracle():
    hg = HyperGraph.from_hyperedge_lists(FIG1, n_vertices=5)
    vr, her = pagerank(hg, iters=25, alpha=0.15)
    # dense power iteration of the same update equations
    H = np.zeros((4, 5))
    for e, members in enumerate(FIG1):
        H[e, members] = 1.0
    card = H.sum(1)
    v_rank = np.ones(5)
    tw = np.ones(5)
    for _ in range(25):
        # one (vertex, hyperedge) superstep pair, in engine order:
        # the vertex attr after iteration k is new_rank computed from the
        # hyperedge broadcast of iteration k-1.
        new_rank = 0.15 + 0.85 * v_rank
        he_rank = H @ (new_rank / np.maximum(tw, 1e-12))
        v_rank = H.T @ (he_rank / card)
        tw = H.T @ np.ones(4)
    np.testing.assert_allclose(vr, new_rank, rtol=1e-4)
    np.testing.assert_allclose(her, he_rank, rtol=1e-4)


def test_pagerank_entropy_decomposition_matches_seq_oracle(hyper):
    """The distributable sum-decomposed entropy equals the literal
    Seq-combiner port — the system's key message-combining claim."""
    v1, he1, ent1 = pagerank_entropy(hyper, iters=10)
    v2, he2, ent2 = pagerank_entropy_seq(hyper, iters=10)
    np.testing.assert_allclose(v1, v2, rtol=1e-4)
    np.testing.assert_allclose(he1, he2, rtol=1e-4)
    np.testing.assert_allclose(ent1, ent2, rtol=1e-3, atol=1e-4)


def test_entropy_bounds(hyper):
    _, _, ent = pagerank_entropy(hyper, iters=8)
    card = np.asarray(hyper.cardinalities())
    ent = np.asarray(ent)
    live = card > 0
    assert (ent[live] <= np.log2(np.maximum(card[live], 1)) + 1e-3).all()
    assert (ent[live] >= -1e-4).all()


def test_random_walk_is_distribution(hyper):
    p = random_walk(hyper, iters=40)
    assert abs(float(jnp.sum(p)) - 1.0) < 1e-3
    assert float(jnp.min(p)) >= 0.0
