"""Compile-once serve-many: the executable cache and batched execution.

The tentpole contracts, asserted:

* ``Engine.compile(spec).run(hg)`` equals ``Engine.run(spec)`` exactly
  (padding to a shape bucket must be invisible in results AND stats);
* a second hypergraph in the same shape bucket is served by the cached
  executable with ZERO retracing (trace-counter assertion);
* dtype / design-point changes miss the cache (new executable);
* ``run_batch`` over 8 SSSP sources agrees bitwise with 8 sequential
  runs — in-process on the local backend, and in a forced-host-device
  subprocess on the sharded/replicated backends.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import (
    label_propagation_spec,
    pagerank_spec,
    random_walk_spec,
    shortest_paths_spec,
)
from repro.core import Engine, bucket_dim
from repro.data import powerlaw_hypergraph


def same_bucket_pair(nv=47, ne=33, nv2=52, ne2=36):
    """Two structurally different hypergraphs landing in one shape
    bucket (nv/ne/nnz all quantize identically)."""
    hg = powerlaw_hypergraph(nv, ne, mean_cardinality=4, seed=0)
    want = (bucket_dim(nv), bucket_dim(ne), bucket_dim(hg.nnz))
    for seed in range(1, 60):
        hg2 = powerlaw_hypergraph(nv2, ne2, mean_cardinality=4, seed=seed)
        got = (bucket_dim(nv2), bucket_dim(ne2), bucket_dim(hg2.nnz))
        if got == want:
            return hg, hg2
    raise AssertionError("no same-bucket draw found (adjust sizes)")


# --------------------------------------------------------------------------
# compiled == run
# --------------------------------------------------------------------------

@pytest.mark.parametrize("make_spec", [
    lambda hg: pagerank_spec(hg, iters=6),
    lambda hg: shortest_paths_spec(hg, 0, 12),
    lambda hg: label_propagation_spec(hg, iters=6),
])
def test_compiled_run_matches_engine_run(make_spec):
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    spec = make_spec(hg)
    eng = Engine()
    ref = eng.run(spec).value
    got = eng.compile(spec).run().value
    for a, b in zip(ref, got):
        assert np.array_equal(
            np.asarray(a), np.asarray(b), equal_nan=True
        )


def test_compiled_stats_mask_bucket_padding():
    """Padding entities must not leak into activity stats: the compiled
    (padded) pagerank reports exactly n_vertices active, not the bucket
    size."""
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    assert bucket_dim(hg.n_vertices) > hg.n_vertices  # padding exists
    eng = Engine(collect_stats=True)
    spec = pagerank_spec(hg, iters=4)
    ref = eng.run(spec)
    got = eng.compile(spec).run()
    for r, g in zip(ref.superstep_stats, got.superstep_stats):
        assert np.array_equal(np.asarray(r), np.asarray(g))
    assert int(np.asarray(got.superstep_stats[0])[0]) == hg.n_vertices


# --------------------------------------------------------------------------
# the executable cache: hits, zero retraces, misses
# --------------------------------------------------------------------------

def test_same_bucket_second_hypergraph_zero_retraces(no_retrace):
    hg, hg2 = same_bucket_pair()
    eng = Engine()
    compiled = eng.compile(shortest_paths_spec(hg, 0, 12))
    compiled.run()
    stats = eng.cache_stats()
    assert stats["misses"] == 1 and stats["traces"] == 1

    # same bucket, different structure: cache hit, NO retrace
    with no_retrace(eng, label="same-bucket serve"):
        got = compiled.run(hg2).value
    assert eng.cache_stats()["hits"] >= 1

    # ... and the served result is exactly a fresh run on hg2
    ref = eng.run(shortest_paths_spec(hg2, 0, 12)).value
    for a, b in zip(ref, got):
        assert np.array_equal(
            np.asarray(a), np.asarray(b), equal_nan=True
        )


def test_second_compile_of_same_spec_hits_cache(no_retrace):
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine()
    spec = shortest_paths_spec(hg, 0, 12)
    eng.compile(spec).run()
    assert eng.cache_stats()["misses"] == 1
    with no_retrace(eng, label="second compile of same spec"):
        eng.compile(spec).run()  # same programs, same bucket -> hit
    stats = eng.cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1


def test_query_change_never_recompiles(no_retrace):
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine()
    compiled = eng.compile(shortest_paths_spec(hg, 0, 12))
    with no_retrace(eng, allow=1, label="query sweep"):
        for s in (0, 3, 11, 46):
            compiled.run(query=s)
    assert eng.cache_stats()["traces"] == 1


def test_dtype_change_misses():
    """Same bucket, different attribute dtype -> different executable."""
    import dataclasses

    hg, hg2 = same_bucket_pair()
    hg = dataclasses.replace(
        hg, e_attr=jnp.ones((hg.nnz,), jnp.float32)
    )
    # hg2 carries an int32 incidence attribute instead of float32
    hg2 = dataclasses.replace(
        hg2, e_attr=jnp.ones((hg2.nnz,), jnp.int32)
    )
    eng = Engine()
    compiled = eng.compile(shortest_paths_spec(hg, 0, 8))
    compiled.run()
    compiled.run(hg2)
    stats = eng.cache_stats()
    assert stats["misses"] == 2 and stats["traces"] == 2


def test_initial_msg_change_misses():
    """Regression: initial_msg is baked into the executable as a traced
    constant, so swapping it via _replace must MISS the cache (the
    programs' identities don't change)."""
    hg = powerlaw_hypergraph(30, 20, mean_cardinality=3, seed=0)
    eng = Engine()
    spec = shortest_paths_spec(hg, 0, 8)
    ref = eng.compile(spec).run().value
    spec2 = spec._replace(initial_msg=jnp.float32(0.0))
    got = eng.compile(spec2).run().value
    assert eng.cache_stats()["misses"] == 2
    # 0-distance initial messages collapse every distance to 0 — results
    # must reflect the NEW spec, not the cached executable's constants.
    assert not np.array_equal(
        np.asarray(ref[0]), np.asarray(got[0]), equal_nan=True
    )
    assert float(np.asarray(got[0]).max()) == 0.0


def test_seeded_random_walk_serves_new_hypergraph():
    """Regression: a seeded spec's restart set must survive
    re-initialization on a second hypergraph (it once silently reverted
    to the uniform walk)."""
    hg, hg2 = same_bucket_pair()
    eng = Engine()
    seeds = jnp.asarray([3, 7])
    compiled = eng.compile(random_walk_spec(hg, seeds=seeds, iters=8))
    got = compiled.run(hg2).value
    ref = eng.run(random_walk_spec(hg2, seeds=seeds, iters=8)).value
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_design_point_change_misses():
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine()
    spec = shortest_paths_spec(hg, 0, 12)
    eng.compile(spec).run()
    eng.compile(spec, max_iters=6).run()        # different design point
    eng.compile(spec, collect_stats=True).run()
    stats = eng.cache_stats()
    assert stats["misses"] == 3 and stats["entries"] == 3


def test_cache_is_lru_bounded():
    hg = powerlaw_hypergraph(30, 20, mean_cardinality=3, seed=0)
    eng = Engine(exec_cache_size=2)
    for iters in (2, 3, 4):
        eng.compile(shortest_paths_spec(hg, 0, iters)).run()
    stats = eng.cache_stats()
    assert stats["entries"] == 2 and stats["misses"] == 3


def test_compile_rejects_clique_and_analytics():
    from repro.core import AnalyticsSpec
    from repro.algorithms import vertex_pagerank_spec

    hg = powerlaw_hypergraph(20, 12, seed=0)
    with pytest.raises(ValueError, match="bipartite"):
        Engine(representation="clique").compile(
            vertex_pagerank_spec(hg, iters=2)
        )
    with pytest.raises(TypeError, match="AlgorithmSpec"):
        Engine().compile(AnalyticsSpec(hg))


# --------------------------------------------------------------------------
# batched multi-query execution (local backend; sharded in subprocess)
# --------------------------------------------------------------------------

def test_run_batch_matches_sequential_local():
    """8 SSSP sources through one vmapped executable == 8 sequential
    runs, bitwise."""
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine()
    compiled = eng.compile(shortest_paths_spec(hg, 0, 16))
    sources = np.arange(8, dtype=np.int32)
    vb, heb = compiled.run_batch(sources).value
    assert vb.shape == (8, hg.n_vertices)
    assert heb.shape == (8, hg.n_hyperedges)
    for i, s in enumerate(sources):
        ref = eng.run(shortest_paths_spec(hg, int(s), 16)).value
        assert np.array_equal(
            np.asarray(ref[0]), np.asarray(vb[i]), equal_nan=True
        )
        assert np.array_equal(
            np.asarray(ref[1]), np.asarray(heb[i]), equal_nan=True
        )


def test_run_batch_bucket_shares_executable_across_batch_sizes(no_retrace):
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine()
    compiled = eng.compile(shortest_paths_spec(hg, 0, 8))
    compiled.run_batch(np.arange(8, dtype=np.int32))
    with no_retrace(eng, label="B=5 pads into B=8"):
        out = compiled.run_batch(np.arange(5, dtype=np.int32)).value
    assert out[0].shape == (5, hg.n_vertices)


def test_run_batch_personalized_random_walk():
    """Batched seeds == per-seed specs (personalized restart)."""
    hg = powerlaw_hypergraph(40, 28, mean_cardinality=4, seed=2)
    eng = Engine()
    seeds = np.asarray([3, 17, 29], np.int32)
    batch = eng.compile(random_walk_spec(hg, iters=12)).run_batch(
        seeds
    ).value
    for i, s in enumerate(seeds):
        ref = eng.run(
            random_walk_spec(hg, seeds=jnp.asarray([s]), iters=12)
        ).value
        np.testing.assert_array_equal(
            np.asarray(ref), np.asarray(batch[i])
        )


def test_run_batch_batch_aware_halting():
    """The batched executable's scan sits OUTSIDE the query vmap, so
    halting is a real ``cond`` on ``all(halted)``: a skewed-convergence
    batch executes exactly as many superstep pairs as its slowest query
    needs — not ``max_iters`` — while staying bitwise-equal to
    sequential runs (results AND stats)."""
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    max_iters = 24
    eng = Engine(collect_stats=True)
    sources = np.arange(8, dtype=np.int32)

    # sequential convergence profile: first zero-activity iteration + 1
    # (the halting superstep itself reports zero and flips the flag)
    seq = [
        eng.run(shortest_paths_spec(hg, int(s), max_iters))
        for s in sources
    ]
    def halt_iter(stats):
        total = np.asarray(stats[0]) + np.asarray(stats[1])
        zeros = np.flatnonzero(total == 0)
        return (zeros[0] + 1) if len(zeros) else max_iters
    slowest = max(halt_iter(r.superstep_stats) for r in seq)
    assert slowest < max_iters, "pick a larger max_iters for this test"

    compiled = eng.compile(shortest_paths_spec(hg, 0, max_iters))
    res = compiled.run_batch(sources)
    executed = int(np.asarray(res.supersteps_executed))
    assert executed == slowest, (executed, slowest)
    assert executed < max_iters

    vb, heb = res.value
    for i, r in enumerate(seq):
        assert np.array_equal(
            np.asarray(r.value[0]), np.asarray(vb[i]), equal_nan=True
        )
        assert np.array_equal(
            np.asarray(r.value[1]), np.asarray(heb[i]), equal_nan=True
        )
        # per-query stats match the sequential trace bit for bit
        for k in (0, 1):
            assert np.array_equal(
                np.asarray(r.superstep_stats[k]),
                np.asarray(res.superstep_stats[k][i]),
            )


def test_unbatched_run_reports_no_executed_count():
    hg = powerlaw_hypergraph(30, 20, mean_cardinality=3, seed=0)
    res = Engine().compile(shortest_paths_spec(hg, 0, 8)).run()
    assert res.supersteps_executed is None


def test_run_batch_requires_query_axis():
    hg = powerlaw_hypergraph(20, 12, seed=0)
    compiled = Engine().compile(pagerank_spec(hg, iters=2))
    with pytest.raises(ValueError, match="bind_query"):
        compiled.run_batch(np.arange(4))


def test_every_builtin_spec_serves_new_hypergraphs():
    """Regression: every iterative spec declares init, so a compiled
    handle can re-initialize a second hypergraph (label_propagation
    once forgot to wire its init in)."""
    hg, hg2 = same_bucket_pair()
    eng = Engine()
    for make in (pagerank_spec, label_propagation_spec,
                 lambda h, iters: random_walk_spec(h, iters=iters),
                 lambda h, iters: shortest_paths_spec(h, 0, iters)):
        spec = make(hg, 4)
        ref = eng.run(make(hg2, 4)).value
        got = eng.compile(spec).run(hg2).value
        for a, b in zip(
            ref if isinstance(ref, tuple) else (ref,),
            got if isinstance(got, tuple) else (got,),
        ):
            assert np.array_equal(
                np.asarray(a), np.asarray(b), equal_nan=True
            ), make


def test_wrapper_query_argument_conflicts_raise():
    from repro.algorithms import random_walk, shortest_paths

    hg = powerlaw_hypergraph(20, 12, seed=0)
    with pytest.raises(ValueError, match="not both"):
        shortest_paths(hg, source=3, sources=[1, 2])
    with pytest.raises(ValueError, match="not both"):
        random_walk(hg, seeds=jnp.asarray([1]), seed_batch=[1, 2])


# --------------------------------------------------------------------------
# sharded/replicated serving (subprocess: needs forced host devices)
# --------------------------------------------------------------------------

SHARDED_SERVING = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import Engine, bucket_dim
    from repro.data import powerlaw_hypergraph
    from repro.algorithms import shortest_paths_spec

    mesh = Mesh(np.array(jax.devices()).reshape(4), ('data',))
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    sources = np.arange(8, dtype=np.int32)

    for backend in ('replicated', 'sharded'):
        eng = Engine(mesh=mesh, backend=backend)
        spec = shortest_paths_spec(hg, 0, 12)
        compiled = eng.compile(spec)
        res = compiled.run_batch(sources)
        vb, heb = res.value
        # batched == sequential, bitwise, against the LOCAL engine
        local = Engine()
        for i, s in enumerate(sources):
            ref = local.run(shortest_paths_spec(hg, int(s), 12)).value
            assert np.array_equal(np.asarray(ref[0]), np.asarray(vb[i]),
                                  equal_nan=True), (backend, i)
            assert np.array_equal(np.asarray(ref[1]), np.asarray(heb[i]),
                                  equal_nan=True), (backend, i)
        # batch-aware halting on the distributed scan: the executed
        # count is a real cond on all(halted) inside shard_map, agrees
        # with the local backend and undercuts max_iters
        lexec = int(np.asarray(
            local.compile(spec).run_batch(sources).supersteps_executed))
        dexec = int(np.asarray(res.supersteps_executed))
        assert dexec == lexec, (backend, dexec, lexec)
        assert dexec < 12, (backend, dexec)
        # same-bucket second hypergraph: zero retraces on the
        # distributed executable (plan rebuilt host-side, shapes cached)
        want = (bucket_dim(hg.n_vertices), bucket_dim(hg.n_hyperedges),
                bucket_dim(hg.nnz))
        hg2 = None
        for seed in range(1, 60):
            cand = powerlaw_hypergraph(52, 36, mean_cardinality=4,
                                       seed=seed)
            got = (bucket_dim(52), bucket_dim(36), bucket_dim(cand.nnz))
            if got == want:
                hg2 = cand
                break
        assert hg2 is not None
        from repro.analysis.retrace import assert_no_retrace
        with assert_no_retrace(eng, label=backend + ' same-bucket'):
            out2 = compiled.run_batch(sources, hg=hg2).value
        ref2 = local.run(shortest_paths_spec(hg2, 0, 12)).value
        assert np.array_equal(np.asarray(ref2[0]), np.asarray(out2[0][0]),
                              equal_nan=True), (backend, 'hg2')
    print('SERVING_AGREES')
""")


def test_distributed_serving_subprocess():
    # Inherit the full environment (dropping JAX_PLATFORMS makes jax
    # probe for accelerator platforms — minutes of stall per child).
    proc = subprocess.run(
        [sys.executable, "-c", SHARDED_SERVING],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SERVING_AGREES" in proc.stdout
