import os
import sys

# Property tests are written against the real ``hypothesis`` API.  When the
# package is missing (minimal images without network access) fall back to
# the vendored shim so the properties still *run* instead of erroring at
# collection.  Must happen before test modules import, hence conftest.
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    _src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    if _src not in sys.path:
        sys.path.insert(0, _src)
    from repro._vendor import hypothesis_shim

    sys.modules["hypothesis"] = hypothesis_shim
    sys.modules["hypothesis.strategies"] = hypothesis_shim.strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running subprocess/integration tests"
    )


import pytest  # noqa: E402


@pytest.fixture
def no_retrace():
    """The retrace sentinel as a fixture: ``with no_retrace(engine):``
    asserts the engine's trace counter doesn't move inside the block
    (``allow=`` budgets expected compiles).  Replaces the hand-rolled
    before/after ``cache_stats()["traces"]`` assertions."""
    from repro.analysis.retrace import assert_no_retrace

    return assert_no_retrace
