"""Neighbor sampler + clique expansion correctness."""
import numpy as np
import pytest

from repro.core import HyperGraph, clique_expansion_size, to_graph
from repro.data import powerlaw_hypergraph
from repro.sparse import NeighborSampler, build_csr

FIG1 = [[0, 1], [0, 1, 2, 3], [0, 3, 4], [2, 3]]


def test_clique_expansion_fig1():
    hg = HyperGraph.from_hyperedge_lists(FIG1, n_vertices=5)
    g = to_graph(hg)
    # unique unordered pairs of Fig 3(a): 8, symmetrized to 16
    assert g.src.shape[0] == 16
    assert clique_expansion_size(hg) == 8
    pairs = set(zip(np.asarray(g.src).tolist(), np.asarray(g.dst).tolist()))
    assert (0, 1) in pairs and (1, 0) in pairs
    assert (1, 4) not in pairs  # v1 and v4 never share a hyperedge
    # shared-count edge attr: v0-v1 share he0+he1 => weight 2
    idx = [i for i, (a, b) in enumerate(
        zip(np.asarray(g.src), np.asarray(g.dst))) if (a, b) == (0, 1)]
    assert float(np.asarray(g.e_attr)[idx[0]]) == 2.0


def test_clique_estimate_huge_regime_is_upper_bound_only():
    hg = powerlaw_hypergraph(5000, 3000, mean_cardinality=12,
                             max_cardinality=2000, seed=0)
    est = clique_expansion_size(hg)
    assert est > hg.nnz  # expansion blows up vs bipartite edges


def _toy_graph():
    #  0 <- 1, 0 <- 2, 1 <- 2, 3 isolated-in
    src = np.array([1, 2, 2, 0], np.int32)
    dst = np.array([0, 0, 1, 3], np.int32)
    return build_csr(src, dst, 4)


def test_csr_build():
    indptr, indices = _toy_graph()
    assert indptr.tolist() == [0, 2, 3, 3, 4]
    assert sorted(indices[0:2].tolist()) == [1, 2]
    assert indices[3] == 0


def test_sampler_static_shapes_and_validity():
    rng = np.random.default_rng(0)
    n = 500
    src = rng.integers(0, n, 4000).astype(np.int32)
    dst = rng.integers(0, n, 4000).astype(np.int32)
    indptr, indices = build_csr(src, dst, n)
    sampler = NeighborSampler(indptr, indices, fanouts=(5, 3), seed=1)
    n_nodes_max, n_edges_max = sampler.padded_block_shape(8)
    for seed_batch in range(3):
        seeds = rng.integers(0, n, 8).astype(np.int32)
        block = sampler.sample_padded(seeds)
        assert block.nodes.shape == (n_nodes_max + 1,)
        assert block.edge_src.shape == (n_edges_max,)
        # seeds occupy the first rows
        assert set(block.nodes[: len(set(seeds.tolist()))]) <= set(
            seeds.tolist()
        )
        live = block.edge_mask > 0
        # every live edge is a real graph edge
        edge_set = set(zip(src.tolist(), dst.tolist()))
        for s_loc, d_loc in zip(block.edge_src[live],
                                block.edge_dst[live]):
            gs = int(block.nodes[s_loc])
            gd = int(block.nodes[d_loc])
            assert (gs, gd) in edge_set


def test_sampler_zero_degree_masked():
    # node 0 has no in-neighbors
    src = np.array([0, 0], np.int32)
    dst = np.array([1, 2], np.int32)
    indptr, indices = build_csr(src, dst, 3)
    sampler = NeighborSampler(indptr, indices, fanouts=(4,), seed=0)
    block = sampler.sample_padded(np.array([0], np.int32))
    assert float(block.edge_mask.sum()) == 0.0
