"""End-to-end behaviour tests for the MESH hypergraph system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HyperGraph, Program, ProcedureOut, compute
from repro.data import make_dataset

# The paper's Fig. 1 hypergraph: 4 groups over 5 vertices.
FIG1 = [[0, 1], [0, 1, 2, 3], [0, 3, 4], [2, 3]]


@pytest.fixture()
def fig1():
    hg = HyperGraph.from_hyperedge_lists(FIG1, n_vertices=5)
    hg.validate()
    return hg


def test_degrees_and_cardinalities(fig1):
    np.testing.assert_array_equal(fig1.degrees(), [3, 2, 2, 3, 1])
    np.testing.assert_array_equal(fig1.cardinalities(), [2, 4, 3, 2])


def test_compute_alternates_supersteps(fig1):
    """Vertex step sees even steps, hyperedge step odd steps."""
    seen = []

    def vertex(step, ids, attr, msg, deg):
        return ProcedureOut(
            attr=attr + 1,
            msg=jnp.full((5,), step, jnp.float32),
        )

    def hyperedge(step, ids, attr, msg, card):
        return ProcedureOut(attr=jnp.maximum(attr, msg), msg=msg)

    hg = fig1.with_attrs(
        v_attr=jnp.zeros((5,), jnp.int32),
        he_attr=jnp.zeros((4,), jnp.float32),
    )
    out = compute(
        hg, max_iters=3, initial_msg=jnp.float32(0),
        v_program=Program(procedure=vertex, combiner="max"),
        he_program=Program(procedure=hyperedge, combiner="max"),
    )
    # 3 iterations -> vertex attr incremented 3x
    np.testing.assert_array_equal(out.v_attr, [3] * 5)
    # hyperedge saw the max broadcast step (= 4, the last vertex step)
    assert float(out.he_attr.max()) == 4.0


def test_message_combining_is_preaggregated(fig1):
    """Sum-combined messages equal the dense incidence-matrix product."""

    def vertex(step, ids, attr, msg, deg):
        return ProcedureOut(attr=msg, msg=ids.astype(jnp.float32) + 1.0)

    def hyperedge(step, ids, attr, msg, card):
        return ProcedureOut(attr=msg, msg=msg)

    hg = fig1.with_attrs(
        v_attr=jnp.zeros((5,)), he_attr=jnp.zeros((4,))
    )
    out = compute(
        hg, max_iters=1, initial_msg=jnp.float32(0),
        v_program=Program(procedure=vertex, combiner="sum"),
        he_program=Program(procedure=hyperedge, combiner="sum"),
    )
    # incidence matrix H [he, v]
    H = np.zeros((4, 5))
    for e, members in enumerate(FIG1):
        H[e, members] = 1.0
    expect = H @ (np.arange(5) + 1.0)
    np.testing.assert_allclose(out.he_attr, expect, rtol=1e-6)


def test_sub_hypergraph(fig1):
    sub = fig1.sub_hypergraph(v_pred=np.array([1, 1, 1, 1, 0], bool))
    assert sub.nnz == fig1.nnz - 1  # v4 appears once
    sub.validate()


def test_sub_hypergraph_drops_masked_incidences(fig1):
    """Regression: padding incidences (e_mask 0) must not be resurrected
    as live rows of the sub-hypergraph."""
    import dataclasses

    mask = np.ones(fig1.nnz, np.float32)
    mask[2] = 0.0  # kill one real incidence
    masked = dataclasses.replace(fig1, e_mask=jnp.asarray(mask))
    sub = masked.sub_hypergraph(
        v_pred=np.ones(fig1.n_vertices, bool)
    )
    assert sub.nnz == fig1.nnz - 1  # dead row stays dead
    sub.validate()
    # degrees computed from the sub-hypergraph match the masked original
    np.testing.assert_array_equal(
        np.asarray(sub.degrees()), np.asarray(masked.degrees())
    )


def test_dataset_generator_regimes():
    hg = make_dataset("orkut", scale=0.001, seed=0)
    assert hg.n_hyperedges > hg.n_vertices  # E >> V regime preserved
    hg2 = make_dataset("friendster", scale=0.001, seed=0)
    assert hg2.n_vertices > hg2.n_hyperedges  # V >> E regime preserved
    for g in (hg, hg2):
        g.validate()
        assert int(g.cardinalities().max()) > int(
            np.median(np.asarray(g.cardinalities()))
        )  # heavy tail
