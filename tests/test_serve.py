"""The serving tier: coalescing front-end + persistent executable cache.

The tentpole contracts, asserted:

* **coalescing is invisible in the numbers**: any arrival order, mixed
  signatures, deadline-forced partial flushes and duplicate in-flight
  queries — every request's resolved value is bitwise identical to a
  sequential ``CompiledAlgorithm.run(query=...)`` of the same query
  (jit-free property tests on the pure batcher + fake-clock front-end,
  plus real-jax integration on the local backend and a sharded-backend
  subprocess);
* **boot-from-disk never retraces**: a second Engine — and, in the slow
  suite, a second *process* — on the same cache dir reaches warm-path
  serving with the trace counter pinned at zero;
* ``bucket_dim`` / batch-bucket edge cases (n=0, exact powers of two,
  floor boundaries) behave (the satellite property tests);
* ``cache_stats`` reports evictions and per-entry bucket shapes.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Engine, bucket_dim
from repro.core.serving import BATCH_FLOOR, BUCKET_FLOOR
from repro.data import powerlaw_hypergraph
from repro.serve import (
    CoalescingBatcher,
    DiskExecutableCache,
    Frontend,
    LatencyHistogram,
    warm,
)
from repro.serve.cache import stable_digest


# --------------------------------------------------------------------------
# bucket_dim edge cases (the bucketing contract the batcher leans on)
# --------------------------------------------------------------------------

def test_bucket_dim_edges():
    assert bucket_dim(0) == BUCKET_FLOOR
    assert bucket_dim(1) == BUCKET_FLOOR
    assert bucket_dim(BUCKET_FLOOR) == BUCKET_FLOOR
    assert bucket_dim(BUCKET_FLOOR + 1) == 2 * BUCKET_FLOOR
    assert bucket_dim(0, floor=BATCH_FLOOR) == BATCH_FLOOR
    # exact powers of two are their own bucket (no gratuitous doubling)
    for p in (8, 16, 64, 1024):
        if p >= BATCH_FLOOR:
            assert bucket_dim(p, floor=BATCH_FLOOR) == p


@given(st.integers(min_value=0, max_value=1 << 20),
       st.sampled_from([1, 2, 8, 64, 128]))
@settings(max_examples=200, deadline=None)
def test_bucket_dim_properties(n, floor):
    b = bucket_dim(n, floor=floor)
    assert b >= n and b >= floor
    # power-of-two multiple of the floor
    assert b % floor == 0 and (b // floor) & (b // floor - 1) == 0
    # minimal: halving (where legal) undershoots n
    if b > floor:
        assert b // 2 < n
    # monotone
    assert bucket_dim(n + 1, floor=floor) >= b


# --------------------------------------------------------------------------
# the pure batcher (fake clock, no jax)
# --------------------------------------------------------------------------

def test_batcher_full_flush_takes_exactly_capacity():
    b = CoalescingBatcher(capacity=4)
    for i in range(6):
        b.submit("g", i, now=0.0, deadline_s=10.0)
    f = b.poll(0.0)
    assert f is not None and f.reason == "full"
    assert [r.query for r in f.requests] == [0, 1, 2, 3]
    assert b.pending_count() == 2
    # remainder is not due until its deadline
    assert b.poll(1.0) is None
    f2 = b.poll(10.5)
    assert f2.reason == "deadline"
    assert [r.query for r in f2.requests] == [4, 5]
    assert b.pending_count() == 0


def test_batcher_deadline_ordering_and_fairness():
    b = CoalescingBatcher(capacity=8)
    b.submit("late", 0, now=0.0, deadline_s=5.0)
    b.submit("early", 1, now=0.0, deadline_s=1.0)
    assert b.next_deadline() == 1.0
    assert b.poll(0.5) is None
    f = b.poll(6.0)  # both expired: oldest deadline first
    assert f.group == "early"
    assert b.poll(6.0).group == "late"


def test_batcher_rejects_mixed_hypergraph_in_group():
    b = CoalescingBatcher(capacity=8)
    hg1, hg2 = object(), object()
    b.submit("g", 0, now=0.0, deadline_s=1.0, hg=hg1)
    with pytest.raises(ValueError, match="different hypergraph"):
        b.submit("g", 1, now=0.0, deadline_s=1.0, hg=hg2)


@given(st.lists(
    st.tuples(
        st.sampled_from(["a", "b", "c"]),     # group
        st.integers(0, 99),                   # query (duplicates likely)
        st.floats(0.0, 4.0),                  # inter-arrival delta
        st.floats(0.001, 2.0),                # deadline_s
        st.booleans(),                        # poll after this arrival?
    ),
    min_size=1, max_size=60,
))
@settings(max_examples=100, deadline=None)
def test_batcher_flushes_every_request_exactly_once(events):
    """Any arrival order / mixed groups / deadline-forced partial
    flushes / duplicate in-flight queries: each request flushed exactly
    once, FIFO within its group, never above capacity, group-pure."""
    b = CoalescingBatcher(capacity=4)
    now = 0.0
    submitted, flushes = [], []
    for group, query, dt, deadline_s, do_poll in events:
        now += dt
        submitted.append(b.submit(group, query, now=now,
                                  deadline_s=deadline_s))
        if do_poll:
            while (f := b.poll(now)) is not None:
                flushes.append(f)
    flushes.extend(b.drain())
    assert b.pending_count() == 0

    flushed = [r for f in flushes for r in f.requests]
    assert len(flushed) == len(submitted)
    assert {r.seq for r in flushed} == {r.seq for r in submitted}
    per_group_seqs: dict = {}
    for f in flushes:
        assert 1 <= len(f.requests) <= 4
        assert f.reason in ("full", "deadline", "drain")
        for r in f.requests:
            assert r.group == f.group
            per_group_seqs.setdefault(f.group, []).append(r.seq)
    for seqs in per_group_seqs.values():
        assert seqs == sorted(seqs)  # FIFO within a group


# --------------------------------------------------------------------------
# front-end coalescing == sequential (fake compiled, fake clock, no jax)
# --------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class FakeResult:
    def __init__(self, value):
        self.value = value
        self.supersteps_executed = None


class FakeCompiled:
    """``run_batch`` double: value rows are a pure function of the query
    (plus a per-instance salt, so mixed signatures can't alias)."""

    def __init__(self, salt):
        self.salt = salt
        self.batch_sizes = []

    def _one(self, q):
        return {"out": np.asarray([q * 2 + self.salt, q], np.int64)}

    def run(self, query=None, hg=None):
        return FakeResult(self._one(int(query)))

    def run_batch(self, queries, hg=None):
        qs = np.asarray(queries["q"] if isinstance(queries, dict)
                        else queries)
        self.batch_sizes.append(len(qs))
        rows = [self._one(int(q)) for q in qs]
        return FakeResult({
            "out": np.stack([r["out"] for r in rows]),
        })


@given(st.lists(
    st.tuples(
        st.sampled_from(["sssp", "ppr"]),   # signature
        st.integers(0, 30),                 # query (duplicates likely)
        st.floats(0.0, 0.01),               # inter-arrival
        st.booleans(),                      # pump mid-stream?
    ),
    min_size=1, max_size=50,
))
@settings(max_examples=60, deadline=None)
def test_frontend_coalescing_matches_sequential(events):
    clock = FakeClock()
    eng = Engine()  # unused by the fakes; supplies stats plumbing
    fe = Frontend(eng, max_batch=4, max_delay_ms=5.0, clock=clock)
    fakes = {"sssp": FakeCompiled(1000), "ppr": FakeCompiled(7000)}
    for key, fake in fakes.items():
        fe.register(key, fake)

    futs = []
    for key, query, dt, do_pump in events:
        clock.t += dt
        futs.append((key, query, fe.submit(key, query=query)))
        if do_pump:
            fe.pump()
    clock.t += 10.0  # expire every deadline
    fe.pump(drain=True)

    for key, query, fut in futs:
        assert fut.done()
        served = fut.result(timeout=0)
        expected = fakes[key].run(query=query).value
        np.testing.assert_array_equal(served.value["out"],
                                      expected["out"])
        assert served.batch_size <= 4
        assert served.flush_reason in ("full", "deadline", "drain")
    st_ = fe.stats()
    assert st_["submitted"] == st_["completed"] == len(futs)
    assert st_["errors"] == 0
    for fake in fakes.values():
        assert all(b <= 4 for b in fake.batch_sizes)


def test_frontend_error_fans_out_to_futures():
    class Broken:
        def run_batch(self, queries, hg=None):
            raise RuntimeError("boom")

    fe = Frontend(Engine(), max_batch=4, clock=FakeClock())
    fe.register("bad", Broken())
    f1, f2 = fe.submit("bad", query=1), fe.submit("bad", query=2)
    fe.pump(drain=True)
    for f in (f1, f2):
        with pytest.raises(RuntimeError, match="boom"):
            f.result(timeout=0)
    assert fe.stats()["errors"] == 2


def test_frontend_unknown_key_and_queryless_spec():
    fe = Frontend(Engine(), clock=FakeClock())
    with pytest.raises(KeyError, match="register"):
        fe.submit("nope", query=0)
    from repro.algorithms import pagerank_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    with pytest.raises(ValueError, match="bind_query"):
        fe.register("pr", pagerank_spec(hg, iters=4))


# --------------------------------------------------------------------------
# front-end integration: real jax, worker thread, bitwise vs sequential
# --------------------------------------------------------------------------

def test_frontend_threaded_bitwise_local_backend():
    import jax

    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine()
    fe = Frontend(eng, max_batch=8, max_delay_ms=2.0)
    fe.register("sssp", shortest_paths_spec(hg, 0, 12))
    rng = np.random.default_rng(0)
    sources = rng.integers(0, hg.n_vertices, size=13).astype(np.int32)
    with fe:
        futs = [fe.submit("sssp", query=int(s)) for s in sources]
        results = [f.result(timeout=300) for f in futs]
    comp = fe.compiled("sssp")
    for s, served in zip(sources, results):
        ref = comp.run(query=int(s)).value
        for a, b in zip(jax.tree.leaves(ref),
                        jax.tree.leaves(served.value)):
            assert np.array_equal(np.asarray(a), np.asarray(b),
                                  equal_nan=True), int(s)
    snap = fe.stats()
    assert snap["completed"] == len(sources)
    assert snap["queue_wait"]["count"] == len(sources)
    assert snap["engine_cache"]["entries"] >= 1


# --------------------------------------------------------------------------
# persistent executable cache
# --------------------------------------------------------------------------

def test_stable_digest_is_stable_across_spec_instances():
    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    s1 = shortest_paths_spec(hg, 0, 12)
    s2 = shortest_paths_spec(hg, 0, 12)
    # Program dataclasses hold closures: identity differs, digest must not
    assert s1.v_program is not s2.v_program
    assert stable_digest(s1.v_program) == stable_digest(s2.v_program)
    assert stable_digest(s1.he_program) == stable_digest(s2.he_program)
    # a different closed-over constant MUST change the digest
    s3 = shortest_paths_spec(hg, 0, 13)
    key = (s1.v_program, s1.he_program, 12)
    assert stable_digest(key) != stable_digest(
        (s3.v_program, s3.he_program, 13)
    )


def test_disk_cache_zero_retrace_second_engine(tmp_path, no_retrace):
    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng1 = Engine(disk_cache=DiskExecutableCache(tmp_path))
    rep1 = warm(eng1, [shortest_paths_spec(hg, 0, 12)], batch_sizes=(8,))
    assert rep1["traces"] > 0 and rep1["from_disk"] == 0
    r1 = eng1.compile(shortest_paths_spec(hg, 0, 12)).run_batch(
        np.arange(8, dtype=np.int32)
    )

    # a fresh Engine + fresh spec objects on the same store: no retrace
    # (require_no_retrace raises from inside warm — the runtime guard a
    # booting replica uses to fail fast instead of eating compiles)
    eng2 = Engine(disk_cache=DiskExecutableCache(tmp_path))
    rep2 = warm(eng2, [shortest_paths_spec(hg, 0, 12)], batch_sizes=(8,),
                require_no_retrace=True)
    assert rep2["from_disk"] == 2  # single + batch8 paths
    with no_retrace(eng2, label="first replay after disk boot"):
        r2 = eng2.compile(shortest_paths_spec(hg, 0, 12)).run_batch(
            np.arange(8, dtype=np.int32)
        )
    for a, b in zip(r1.value, r2.value):
        assert np.array_equal(np.asarray(a), np.asarray(b),
                              equal_nan=True)


def test_disk_cache_respects_env_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "envroot"))
    cache = DiskExecutableCache()
    assert str(cache.root) == str(tmp_path / "envroot")
    monkeypatch.delenv("REPRO_CACHE_DIR")
    assert str(DiskExecutableCache().root) == ".repro_cache"


def test_disk_cache_corrupt_blob_degrades_to_miss(tmp_path):
    cache = DiskExecutableCache(tmp_path)
    key = ("k",)
    cache.dir.mkdir(parents=True, exist_ok=True)
    with open(cache._path(stable_digest(key)), "wb") as f:
        f.write(b"not a pickle")
    assert cache.load(key) is None
    assert cache.stats()["disk_errors"] == 1


def test_warm_requires_example_query_for_query0_free_spec(tmp_path):
    from repro.algorithms import random_walk_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine()
    # the unbatched path warms fine without a query...
    rep = warm(eng, [random_walk_spec(hg, iters=4)])
    assert rep["paths"]["0:random_walk"]["single"]["source"] in (
        "aot", "jit"
    )
    # ...but a batched path needs an example (query0 is unset)
    with pytest.raises(ValueError, match="query"):
        warm(eng, [random_walk_spec(hg, iters=4)], batch_sizes=(8,))


# --------------------------------------------------------------------------
# cache_stats: evictions + per-entry bucket shapes
# --------------------------------------------------------------------------

def test_cache_stats_evictions_and_entry_shapes():
    eng = Engine(exec_cache_size=2)
    for i in range(4):
        eng._executable_for(("k", i), lambda: (lambda *a: None),
                            meta={"algorithm": f"alg{i}"})
    s = eng.cache_stats()
    assert s["entries"] == 2 and s["capacity"] == 2
    assert s["evictions"] == 2
    assert [m["algorithm"] for m in s["entry_shapes"]] == ["alg2", "alg3"]
    # hits don't evict
    eng._executable_for(("k", 3), lambda: (lambda *a: None))
    assert eng.cache_stats()["evictions"] == 2
    assert eng.cache_stats()["hits"] == 1


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------

def test_latency_histogram_quantiles():
    h = LatencyHistogram()
    assert h.snapshot()["p99_s"] == 0.0
    for ms in [1.0] * 98 + [100.0, 1000.0]:
        h.record(ms / 1e3)
    snap = h.snapshot()
    assert snap["count"] == 100
    # bin upper bounds: p50 covers 1ms, p99 covers the 100ms outlier
    assert 1e-3 <= snap["p50_s"] < 2e-3
    assert 0.1 <= snap["p99_s"] < 0.2
    assert snap["p999_s"] >= 1.0
    assert snap["max_s"] == 1.0


def test_serve_metrics_occupancy_split():
    from repro.serve import ServeMetrics

    m = ServeMetrics()
    m.note_submit(6)
    m.note_flush("sssp", "full", 4, 4, [0.001] * 4, 0.010)
    m.note_flush("sssp", "deadline", 2, 4, [0.005] * 2, 0.010)
    snap = m.snapshot()
    assert snap["completed"] == 6 and snap["in_flight"] == 0
    assert snap["flush_reasons"] == {"full": 1, "deadline": 1}
    b = snap["buckets"]["sssp/b4"]
    assert b["flushes"] == 2 and b["requests"] == 6
    assert b["mean_occupancy"] == pytest.approx(0.75)
    assert snap["queue_wait"]["count"] == 6


# --------------------------------------------------------------------------
# cross-process boot + distributed front-end (slow: subprocesses)
# --------------------------------------------------------------------------

BOOT_CHILD = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.core import Engine
    from repro.data import powerlaw_hypergraph
    from repro.algorithms import shortest_paths_spec, random_walk_spec
    from repro.serve import DiskExecutableCache, warm

    phase = sys.argv[1]
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine(disk_cache=DiskExecutableCache(sys.argv[2]))
    specs = [shortest_paths_spec(hg, 0, 12),
             random_walk_spec(hg, iters=6)]
    # replay boots under the runtime retrace guard: RetraceError here
    # means the store missed across the process boundary
    rep = warm(eng, specs, batch_sizes=(8,), queries=[0, 0],
               require_no_retrace=(phase != 'populate'))
    if phase == 'populate':
        assert rep['traces'] > 0, rep
        assert rep['compiled'] == 4, rep
    else:
        assert rep['from_disk'] == 4, rep
    res = eng.compile(specs[0]).run_batch(np.arange(8, dtype=np.int32))
    if phase != 'populate':
        assert eng.cache_stats()['traces'] == 0, eng.cache_stats()
    np.save(sys.argv[3], np.asarray(res.value[0]))
    print('BOOT_OK', rep['traces'], rep['from_disk'])
""")


@pytest.mark.slow
def test_second_process_boots_from_disk_cache(tmp_path):
    def child(phase, out):
        proc = subprocess.run(
            [sys.executable, "-c", BOOT_CHILD, phase, str(tmp_path),
             str(out)],
            capture_output=True, text=True, timeout=600,
            env={**os.environ, "PYTHONPATH": "src"},
            cwd=__file__.rsplit("/tests/", 1)[0],
        )
        assert proc.returncode == 0, proc.stderr[-3000:]
        assert "BOOT_OK" in proc.stdout
        return proc.stdout

    child("populate", tmp_path / "a.npy")
    out = child("boot", tmp_path / "b.npy")
    assert "BOOT_OK 0 4" in out
    np.testing.assert_array_equal(np.load(tmp_path / "a.npy"),
                                  np.load(tmp_path / "b.npy"))


SHARDED_FRONTEND = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import Engine
    from repro.data import powerlaw_hypergraph
    from repro.algorithms import shortest_paths_spec
    from repro.serve import Frontend

    mesh = Mesh(np.array(jax.devices()).reshape(4), ('data',))
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine(mesh=mesh, backend='sharded')
    fe = Frontend(eng, max_batch=8, max_delay_ms=2.0)
    fe.register('sssp', shortest_paths_spec(hg, 0, 12))
    sources = np.arange(11, dtype=np.int32) % hg.n_vertices
    with fe:
        futs = [fe.submit('sssp', query=int(s)) for s in sources]
        results = [f.result(timeout=300) for f in futs]
    comp = fe.compiled('sssp')
    for s, served in zip(sources, results):
        ref = comp.run(query=int(s)).value
        for a, b in zip(jax.tree.leaves(ref),
                        jax.tree.leaves(served.value)):
            assert np.array_equal(np.asarray(a), np.asarray(b),
                                  equal_nan=True), int(s)
    print('FRONTEND_SHARDED_AGREES')
""")


@pytest.mark.slow
def test_frontend_sharded_backend_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", SHARDED_FRONTEND],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "FRONTEND_SHARDED_AGREES" in proc.stdout


# --------------------------------------------------------------------------
# adaptive flush deadline (the bounded EWMA controller; off by default)
# --------------------------------------------------------------------------

@given(
    st.lists(
        st.tuples(
            st.floats(0.0, 0.2),                      # execute_s
            st.floats(0.0, 1.0),                      # occupancy
            st.sampled_from(["full", "deadline", "drain"]),
        ),
        min_size=1, max_size=60,
    ),
    st.floats(1e-5, 1.0),                             # initial delay
)
@settings(max_examples=80, deadline=None)
def test_adaptive_delay_always_in_bounds(stream, d0):
    from repro.serve import AdaptiveDelay

    ad = AdaptiveDelay(d0, lo_s=1e-3, hi_s=2e-2)
    for execute_s, occupancy, reason in stream:
        d = ad.observe(
            execute_s=execute_s, occupancy=occupancy, reason=reason
        )
        assert 1e-3 <= d <= 2e-2
        assert d == ad.delay_s
    assert ad.observations == len(stream)
    snap = ad.snapshot()
    assert snap["lo_s"] == 1e-3 and snap["hi_s"] == 2e-2


def test_adaptive_delay_converges_down_under_full_flushes():
    from repro.serve import AdaptiveDelay

    ad = AdaptiveDelay(0.02, lo_s=1e-3, hi_s=2e-2)
    for _ in range(50):
        ad.observe(execute_s=0.005, occupancy=1.0, reason="full")
    assert ad.delay_s <= 1.2e-3  # geometrically onto the floor


def test_adaptive_delay_grows_toward_execute_cost_when_starved():
    from repro.serve import AdaptiveDelay

    ad = AdaptiveDelay(0.002, lo_s=1e-3, hi_s=5e-2)
    # mostly-empty deadline flushes with a 30ms execute: waiting up to
    # one execute is worth it, so the delay climbs toward 30ms.
    for _ in range(60):
        ad.observe(execute_s=0.03, occupancy=0.1, reason="deadline")
    assert ad.delay_s == pytest.approx(0.03, rel=0.1)
    # well-filled deadline flushes hold rather than drift
    held = ad.delay_s
    for _ in range(10):
        ad.observe(execute_s=0.03, occupancy=0.9, reason="deadline")
    assert ad.delay_s == pytest.approx(held, rel=1e-6)


def test_adaptive_delay_validates_parameters():
    from repro.serve import AdaptiveDelay

    with pytest.raises(ValueError, match="lo_s"):
        AdaptiveDelay(0.01, lo_s=0.0)
    with pytest.raises(ValueError, match="lo_s"):
        AdaptiveDelay(0.01, lo_s=0.1, hi_s=0.01)
    with pytest.raises(ValueError, match="gain"):
        AdaptiveDelay(0.01, gain=0.0)


def test_frontend_adaptive_delay_shrinks_on_full_traffic():
    clock = FakeClock()
    fe = Frontend(
        Engine(), max_batch=4, max_delay_ms=20.0, clock=clock,
        adaptive_delay=True, min_delay_ms=1.0,
    )
    fe.register("sssp", FakeCompiled(1000))
    assert fe.current_delay_ms == pytest.approx(20.0)
    for _ in range(20):  # every flush full: waiting buys nothing
        for q in range(4):
            fe.submit("sssp", query=q)
        fe.pump(drain=True)
    assert fe.current_delay_ms < 2.0
    snap = fe.stats()["adaptive_delay"]
    assert snap is not None and snap["observations"] == 20
    # error flushes must not feed the controller
    class Broken:
        def run_batch(self, queries, hg=None):
            raise RuntimeError("boom")

    fe.register("bad", Broken())
    fe.submit("bad", query=1)
    fe.pump(drain=True)
    assert fe.stats()["adaptive_delay"]["observations"] == 20


def test_frontend_adaptive_delay_off_by_default():
    fe = Frontend(Engine(), max_batch=4, max_delay_ms=7.0,
                  clock=FakeClock())
    assert fe.stats()["adaptive_delay"] is None
    assert fe.current_delay_ms == pytest.approx(7.0)


# --------------------------------------------------------------------------
# warmup-record fallback: platforms where serialize_executable fails
# --------------------------------------------------------------------------

def test_disk_cache_warmup_record_fallback(tmp_path, monkeypatch):
    """When ``serialize_executable.serialize`` raises (platforms that
    cannot round-trip executables), ``store`` degrades to a warmup
    record, boot still works, and a second replica re-traces instead of
    crashing on the record."""
    from jax.experimental import serialize_executable as se
    from repro.algorithms import shortest_paths_spec

    def boom(compiled):
        raise RuntimeError("platform cannot serialize executables")

    monkeypatch.setattr(se, "serialize", boom)
    hg = powerlaw_hypergraph(61, 37, mean_cardinality=4, seed=1)
    spec = shortest_paths_spec(hg, 0, 6)

    eng1 = Engine(disk_cache=DiskExecutableCache(tmp_path))
    report = warm(eng1, [spec], batch_sizes=(4,), queries=[0])
    s1 = eng1.disk_cache.stats()
    assert report["from_disk"] == 0
    assert s1["disk_stores"] == 0          # nothing fully serialized
    assert s1["disk_errors"] >= 1          # every store degraded
    assert s1["entries"] >= 1              # ... to on-disk warmup records
    res1 = eng1.compile(spec).run_batch(np.asarray([0, 1], np.int32))
    assert res1.value is not None

    # second replica, same dir: loads see warmup records (not payloads),
    # recompile, and still serve.
    eng2 = Engine(disk_cache=DiskExecutableCache(tmp_path))
    report2 = warm(eng2, [spec], batch_sizes=(4,), queries=[0])
    s2 = eng2.disk_cache.stats()
    assert report2["from_disk"] == 0
    assert s2["warm_records"] >= 1
    assert s2["disk_hits"] == 0
    res2 = eng2.compile(spec).run_batch(np.asarray([0, 1], np.int32))
    import jax

    for a, b in zip(jax.tree.leaves(res1.value),
                    jax.tree.leaves(res2.value)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
