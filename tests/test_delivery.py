"""Fused incidence delivery: degree-class layout, kernels, engine seam,
serving.

The tentpole contracts, asserted:

* **Kernel parity** (property-tested): both fused lowerings — the
  sliced-ELL + sorted-COO XLA form and the per-class Pallas kernels in
  interpret mode — equal the reference gather/mask/segment path across
  monoids (sum, min, max, or, prod), dtypes, dead-row masks, dynamic
  activity, empty segments and padded buckets.  Equality is BITWISE:
  order-insensitive monoids (min/max/or) on arbitrary values, sum/prod
  on integer-valued payloads where every association order is exact.
  (Float sums across different reduce algorithms differ by
  reassociation; the tight-allclose case is covered separately.)
* **Planner** (property-tested): the vectorized
  ``plan_ell_width``/class-planner overflow stats agree with a naive
  per-width rescan loop; class plans are deterministic in the degree
  histogram and structurally sound (ascending pow2 widths, rows
  conserved, residual == spill past the last width).
* **Pathological degree histograms**: single mega-hub, uniform, empty,
  all-overflow (forced width-1 plan), hub-on-shard-boundary — both
  lowerings, all monoids, bitwise vs the reference; shard-harmonized
  class plans in the subprocess distributed suite.
* **Engine seam**: ``delivery='pallas_fused'`` matches ``'xla'``
  end-to-end through ``Engine.run`` and ``Engine.compile``; ``auto``
  resolves via the cost model and reports its reasoning; non-monoid
  specs fall back (auto) or raise (explicit).
* **Distributed**: fused == reference on the replicated AND sharded
  backends, padded (serving) and unpadded (one-shot), in a
  forced-host-device subprocess — including a mega-hub destination
  whose id sits exactly on a shard boundary.
* **Batch-aware halting**: ``run_batch`` stops at the slowest query's
  convergence — fewer supersteps than ``max_iters``, bitwise-equal
  results, on the local backend (``tests/test_compile.py``) AND the
  distributed backends (the serving subprocess there asserts
  ``supersteps_executed`` agrees with the local backend).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algorithms import (
    label_propagation_spec,
    pagerank_spec,
    shortest_paths_spec,
)
from repro.core import Engine
from repro.core.api import Program
from repro.core.engine import deliver
from repro.core.executor import select_delivery
from repro.data import powerlaw_hypergraph
from repro.kernels.deliver import (
    ClassPlan,
    build_delivery_layout,
    classify_degrees,
    fused_deliver,
    layout_pair,
    plan_degree_classes,
    plan_ell_width,
)
from repro.kernels.deliver.layout import (
    CLASS_K_CAP,
    ELL_K_CAP,
    ELL_REMAINDER_FRACTION,
    MAX_CLASSES,
)

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")

MONOIDS_UNDER_TEST = ("sum", "min", "max", "or", "prod")


@st.composite
def incidence_case(draw):
    """A random incidence list + messages: the deliver() input space."""
    n_src = draw(st.integers(1, 60))
    n_dst = draw(st.integers(1, 50))
    nnz = draw(st.integers(0, 220))
    seed = draw(st.integers(0, 100_000))
    monoid = draw(st.sampled_from(MONOIDS_UNDER_TEST))
    dtype = draw(st.sampled_from(["float32", "int32"]))
    width = draw(st.sampled_from([(), (3,), (2, 2)]))
    with_mask = draw(st.booleans())
    with_active = draw(st.booleans())
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_src, nnz).astype(np.int32)
    dst = rng.integers(0, n_dst, nnz).astype(np.int32)
    mask = (
        (rng.random(nnz) > 0.25).astype(np.float32) if with_mask else None
    )
    if monoid == "or":
        msg = rng.random((n_src,) + width) > 0.5
    elif dtype == "int32":
        msg = rng.integers(-4, 5, (n_src,) + width).astype(np.int32)
    else:
        # Integer-valued float32: every association order is exact, so
        # sum/prod parity is bitwise (the contract under test is the
        # data path — which rows combine where — not fp rounding).
        msg = rng.integers(-4, 5, (n_src,) + width).astype(np.float32)
    active = rng.random(n_src) > 0.3 if with_active else None
    return (src, dst, mask, n_src, n_dst, monoid, msg, active)


@given(incidence_case())
def test_fused_delivery_bitwise_equals_reference(case):
    src, dst, mask, n_src, n_dst, monoid, msg, active = case
    prog = Program(procedure=lambda *a: None, combiner=monoid)
    act_j = jnp.asarray(active) if active is not None else None
    ref = deliver(
        jnp.asarray(msg), act_j, jnp.asarray(src), jnp.asarray(dst),
        n_dst, prog,
        e_mask=jnp.asarray(mask) if mask is not None else None,
    )
    layout = build_delivery_layout(
        src, dst, mask, n_src, n_dst, block_n=8, block_e=16
    )
    for lowering in ("ell", "pallas_interpret"):
        got = fused_deliver(
            jnp.asarray(msg), act_j, layout, prog, lowering=lowering
        )
        assert np.array_equal(
            np.asarray(ref), np.asarray(got), equal_nan=True
        ), (monoid, lowering, msg.dtype)


@given(incidence_case())
def test_fused_delivery_padded_layout_invariance(case):
    """Forcing larger per-class row/edge/remainder pads (the shard
    harmonization path) must not change any result, on either
    lowering."""
    src, dst, mask, n_src, n_dst, monoid, msg, active = case
    prog = Program(procedure=lambda *a: None, combiner=monoid)
    act_j = jnp.asarray(active) if active is not None else None
    base = build_delivery_layout(
        src, dst, mask, n_src, n_dst, block_n=8, block_e=16
    )
    padded = build_delivery_layout(
        src, dst, mask, n_src, n_dst, block_n=8, block_e=16,
        plan=ClassPlan(
            widths=base.class_widths,
            rows=tuple(int(r) for r in base.class_rows),
            residual=base.rem_nnz,
        ),
        class_rows_pad=tuple(r + 24 for r in base.class_rows),
        class_nnz_pad=tuple(
            int(a.shape[0]) + 37 for a in base.class_src
        ),
        rem_pad_to=base.rem_len + 19,
    )
    a = fused_deliver(jnp.asarray(msg), act_j, base, prog, lowering="ell")
    for lowering in ("ell", "pallas_interpret"):
        b = fused_deliver(
            jnp.asarray(msg), act_j, padded, prog, lowering=lowering
        )
        assert np.array_equal(
            np.asarray(a), np.asarray(b), equal_nan=True
        ), lowering


def test_fused_float_sum_within_reassociation_tolerance():
    """Arbitrary float sums: the fused dense reduce reassociates, so
    parity is tight-allclose, not bitwise."""
    rng = np.random.default_rng(7)
    n_src, n_dst, nnz = 200, 90, 4000
    src = rng.integers(0, n_src, nnz).astype(np.int32)
    dst = rng.integers(0, n_dst, nnz).astype(np.int32)
    msg = rng.standard_normal((n_src, 4)).astype(np.float32)
    prog = Program(procedure=lambda *a: None, combiner="sum")
    ref = deliver(
        jnp.asarray(msg), None, jnp.asarray(src), jnp.asarray(dst),
        n_dst, prog,
    )
    layout = build_delivery_layout(src, dst, None, n_src, n_dst)
    for lowering in ("ell", "pallas_interpret"):
        got = fused_deliver(
            jnp.asarray(msg), None, layout, prog, lowering=lowering
        )
        np.testing.assert_allclose(
            np.asarray(ref), np.asarray(got), rtol=1e-5, atol=1e-5
        )


def test_plan_ell_width_remainder_rule():
    deg = np.array([1, 1, 2, 40])
    k, rem = plan_ell_width(deg, int(deg.sum()))
    # k grows until <= 25% of incidences overflow (cap 64)
    assert rem <= 0.25 * deg.sum()
    assert k & (k - 1) == 0  # power of two
    k_uniform, rem_uniform = plan_ell_width(np.full(16, 4), 64)
    assert (k_uniform, rem_uniform) == (4, 0)


# --------------------------------------------------------------------------
# planners: vectorized histogram stats vs the naive loop; class plans
# --------------------------------------------------------------------------

def _loop_plan_ell_width(degrees, nnz):
    """The pre-vectorization reference: rescan the degree array at every
    doubling of k."""
    if nnz <= 0 or degrees.size == 0:
        return 1, 0
    k = 1
    while True:
        remainder = int(np.maximum(degrees - k, 0).sum())
        if remainder <= ELL_REMAINDER_FRACTION * nnz or k >= ELL_K_CAP:
            return k, remainder
        k *= 2


@st.composite
def degree_case(draw):
    n = draw(st.integers(0, 200))
    seed = draw(st.integers(0, 100_000))
    profile = draw(st.sampled_from(["uniform", "zipfish", "hub", "zero"]))
    rng = np.random.default_rng(seed)
    if profile == "uniform":
        deg = rng.integers(0, 9, n)
    elif profile == "zipfish":
        deg = (rng.pareto(1.2, n) * 3).astype(np.int64)
    elif profile == "hub":
        deg = rng.integers(0, 4, n)
        if n:
            deg[rng.integers(0, n)] = draw(st.integers(100, 200_000))
    else:
        deg = np.zeros(n, np.int64)
    return deg.astype(np.int64)


@given(degree_case())
def test_vectorized_plan_ell_width_agrees_with_loop(deg):
    nnz = int(deg.sum())
    assert plan_ell_width(deg, nnz) == _loop_plan_ell_width(deg, nnz)


@given(degree_case())
def test_class_plan_structurally_sound(deg):
    nnz = int(deg.sum())
    plan = plan_degree_classes(deg, nnz)
    widths = plan.widths
    # 1..MAX_CLASSES ascending power-of-two widths, capped
    assert 1 <= len(widths) <= MAX_CLASSES
    assert all(k & (k - 1) == 0 for k in widths)
    assert list(widths) == sorted(set(widths))
    assert widths[-1] <= CLASS_K_CAP
    # rows conserved: every positive-degree destination sits in exactly
    # one class; residual is exactly the spill past the last width
    cls = classify_degrees(deg, widths)
    assert sum(plan.rows) == int((deg > 0).sum())
    for c, r in enumerate(plan.rows):
        assert int((cls == c).sum()) == r
    spill = int(np.maximum(deg - widths[-1], 0).sum())
    assert plan.residual == spill
    # the plan's weighted objective never exceeds the single-ELL plan's
    # (the DP considers the single class as a candidate)
    k1, rem1 = plan_ell_width(deg, nnz)
    if nnz and widths[-1] >= k1:
        from repro.kernels.deliver.layout import RESIDUAL_WEIGHT
        single = int((deg > 0).sum()) * k1 + RESIDUAL_WEIGHT * rem1
        assert plan.weighted_work <= single + 1e-9
    # deterministic in the histogram
    assert plan == plan_degree_classes(deg.copy(), nnz)


# --------------------------------------------------------------------------
# pathological degree histograms, both lowerings, all monoids
# --------------------------------------------------------------------------

def _assert_fused_matches_reference(src, dst, mask, n_src, n_dst,
                                    layout=None, **build_kw):
    rng = np.random.default_rng(7)
    if layout is None:
        layout = build_delivery_layout(
            src, dst, mask, n_src, n_dst, block_n=8, block_e=16,
            **build_kw,
        )
    for monoid in MONOIDS_UNDER_TEST:
        if monoid == "or":
            msg = rng.random((n_src, 2)) > 0.5
        else:
            msg = rng.integers(-4, 5, (n_src, 2)).astype(np.float32)
        prog = Program(procedure=lambda *a: None, combiner=monoid)
        active = rng.random(n_src) > 0.3
        ref = deliver(
            jnp.asarray(msg), jnp.asarray(active), jnp.asarray(src),
            jnp.asarray(dst), n_dst, prog,
            e_mask=jnp.asarray(mask) if mask is not None else None,
        )
        for lowering in ("ell", "pallas_interpret"):
            got = fused_deliver(
                jnp.asarray(msg), jnp.asarray(active), layout, prog,
                lowering=lowering,
            )
            assert np.array_equal(
                np.asarray(ref), np.asarray(got), equal_nan=True
            ), (monoid, lowering)
    return layout


def test_pathological_single_mega_hub():
    """One destination absorbs ~95% of the incidences: the hub must land
    in its own wide class (dense), not the residual scatter."""
    rng = np.random.default_rng(0)
    n_src, n_dst, nnz = 64, 50, 3000
    dst = np.where(
        rng.random(nnz) < 0.95, 7, rng.integers(0, n_dst, nnz)
    ).astype(np.int32)
    src = rng.integers(0, n_src, nnz).astype(np.int32)
    layout = _assert_fused_matches_reference(src, dst, None, n_src, n_dst)
    hub_deg = int((dst == 7).sum())
    assert layout.class_widths[-1] >= hub_deg  # hub fully dense
    assert layout.rem_nnz == 0
    assert len(layout.class_widths) >= 2  # tail kept narrow


def test_pathological_uniform_degrees_collapse_to_one_class():
    rng = np.random.default_rng(1)
    n, nnz = 100, 800
    dst = np.repeat(np.arange(n), 8).astype(np.int32)  # exactly deg 8
    src = rng.integers(0, n, nnz).astype(np.int32)
    layout = _assert_fused_matches_reference(src, dst, None, n, n)
    assert layout.class_widths == (8,)
    assert layout.rem_nnz == 0


def test_pathological_empty_structures():
    # no incidences at all
    _assert_fused_matches_reference(
        np.zeros(0, np.int32), np.zeros(0, np.int32), None, 5, 4
    )
    # incidences exist but every one statically dead
    rng = np.random.default_rng(2)
    src = rng.integers(0, 6, 20).astype(np.int32)
    dst = rng.integers(0, 5, 20).astype(np.int32)
    layout = _assert_fused_matches_reference(
        src, dst, np.zeros(20, np.float32), 6, 5
    )
    assert layout.ell_slots >= 0 and layout.rem_nnz == 0
    # zero destinations
    lay = build_delivery_layout(
        np.zeros(0, np.int32), np.zeros(0, np.int32), None, 3, 0,
        block_n=8, block_e=16,
    )
    prog = Program(procedure=lambda *a: None, combiner="sum")
    out = fused_deliver(
        jnp.ones((3, 2), jnp.float32), None, lay, prog, lowering="ell"
    )
    assert out.shape == (0, 2)


def test_pathological_all_overflow_forced_plan():
    """A forced width-1 plan pushes nearly every incidence through the
    residual sorted-COO path — the XLA lowering's worst case — while
    the Pallas CSR form absorbs it densely.  Both stay bitwise."""
    rng = np.random.default_rng(3)
    n_src, n_dst, nnz = 40, 30, 900
    src = rng.integers(0, n_src, nnz).astype(np.int32)
    dst = rng.integers(0, n_dst, nnz).astype(np.int32)
    layout = build_delivery_layout(
        src, dst, None, n_src, n_dst, block_n=8, block_e=16,
        plan=ClassPlan(widths=(1,), rows=(n_dst,), residual=nnz - n_dst),
    )
    assert layout.rem_nnz > 0.9 * nnz
    _assert_fused_matches_reference(
        src, dst, None, n_src, n_dst, layout=layout
    )


def test_pathological_zero_degree_destinations_read_identity():
    """Bucket padding: destinations with no live incidence own no ELL
    rows at all and read the identity through ``inv_perm``."""
    src = np.array([0, 1, 2, 3], np.int32)
    dst = np.array([2, 2, 2, 2], np.int32)  # only dst 2 is live
    n_src, n_dst = 4, 9
    layout = build_delivery_layout(
        src, dst, None, n_src, n_dst, block_n=8, block_e=16
    )
    # every empty destination shares the single identity slot
    inv = np.asarray(layout.inv_perm)
    assert (inv[np.arange(n_dst) != 2] == layout.n_slots).all()
    prog = Program(procedure=lambda *a: None, combiner="min")
    msg = jnp.arange(4, dtype=jnp.float32)
    for lowering in ("ell", "pallas_interpret"):
        out = np.asarray(fused_deliver(msg, None, layout, prog,
                                       lowering=lowering))
        assert out[2] == 0.0
        assert np.isposinf(out[np.arange(n_dst) != 2]).all()


def test_shard_harmonized_class_plans_stack():
    """``build_shard_delivery``: one merged-histogram plan, per-class
    pads harmonized to maxima — layouts stack, and a hub destination on
    the shard boundary stays dense on every shard that sees it."""
    from repro.core.distributed import build_shard_delivery

    rng = np.random.default_rng(4)
    n_parts, shard_len = 4, 256
    nv = ne = 64
    hub = 16  # == ne_pad/n_parts: first id of shard 1's range
    dst = np.where(
        rng.random((n_parts, shard_len)) < 0.7, hub,
        rng.integers(0, ne, (n_parts, shard_len)),
    ).astype(np.int32)
    src = rng.integers(0, nv, (n_parts, shard_len)).astype(np.int32)
    mask = (rng.random((n_parts, shard_len)) > 0.1).astype(np.float32)
    fwd, bwd = build_shard_delivery(src, dst, mask, nv, ne)
    for lay in (fwd, bwd):
        # stacked: every child gained one [n_parts] leading dim, with
        # identical per-class shapes across shards
        assert lay.inv_perm.shape[0] == n_parts
        for c in range(lay.n_classes):
            assert lay.class_ell[c].shape[0] == n_parts
            assert lay.class_ell[c].shape[2] == lay.class_widths[c]
            assert lay.class_src[c].shape[0] == n_parts
    # the hub's shard-local degree fits its class width on every shard
    live = np.asarray(mask) != 0
    for p in range(n_parts):
        hub_deg = int(((dst[p] == hub) & live[p]).sum())
        assert fwd.class_widths[-1] >= hub_deg
    assert fwd.rem_nnz == 0


# --------------------------------------------------------------------------
# the Engine seam
# --------------------------------------------------------------------------

def medium_hypergraph():
    # Large enough to clear the cost model's FUSED_MIN_NNZ floor.
    return powerlaw_hypergraph(1400, 1000, mean_cardinality=7, seed=3)


@pytest.mark.parametrize("make_spec,bitwise", [
    (lambda hg: shortest_paths_spec(hg, 0, 12), True),
    (lambda hg: label_propagation_spec(hg, iters=6), True),
    (lambda hg: pagerank_spec(hg, iters=6), False),
])
def test_engine_run_fused_matches_xla(make_spec, bitwise):
    hg = medium_hypergraph()
    eng = Engine()
    spec = make_spec(hg)
    ref = eng.run(spec, delivery="xla").value
    got = eng.run(spec, delivery="pallas_fused").value
    for a, b in zip(ref, got):
        a, b = np.asarray(a), np.asarray(b)
        if bitwise:
            assert np.array_equal(a, b, equal_nan=True)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_compiled_fused_matches_xla_and_masks_padding():
    hg = medium_hypergraph()
    eng = Engine(collect_stats=True)
    spec = shortest_paths_spec(hg, 0, 12)
    ref = eng.compile(spec, delivery="xla").run()
    got = eng.compile(spec, delivery="pallas_fused").run()
    for a, b in zip(ref.value, got.value):
        assert np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
    # bucket padding must stay invisible in stats on the fused path too
    for r, g in zip(ref.superstep_stats, got.superstep_stats):
        assert np.array_equal(np.asarray(r), np.asarray(g))


def test_delivery_auto_resolves_and_reports():
    """The cost model picks fused for a large narrow-message hypergraph
    and reports the numbers it decided on."""
    hg = medium_hypergraph()
    eng = Engine()
    cfg, _, decision = eng.resolve(shortest_paths_spec(hg, 0, 8))
    why = decision["delivery"]
    assert cfg.delivery == "pallas_fused", why
    assert why["lowering"] in ("ell", "pallas", "pallas_interpret")
    assert "reason" in why and "message_width_bytes" in why

    # tiny structures stay on the reference path (overhead-dominated)
    tiny = powerlaw_hypergraph(30, 20, mean_cardinality=3, seed=0)
    cfg2, _, dec2 = eng.resolve(shortest_paths_spec(tiny, 0, 8))
    assert cfg2.delivery == "xla"


def test_delivery_auto_rejects_wide_messages_on_ell():
    """Wide message rows flip the ELL cost model back to the reference
    path (the dense reduce's padding outweighs the scatter win)."""
    hg = medium_hypergraph()
    spec = pagerank_spec(hg, iters=4)
    wide = spec._replace(initial_msg=jnp.zeros((64,), jnp.float32))
    choice, why = select_delivery(wide, hg)
    assert choice == "xla"
    assert "wide" in why["reason"]


def test_non_monoid_spec_falls_back_and_explicit_raises():
    hg = powerlaw_hypergraph(60, 40, mean_cardinality=4, seed=1)
    spec = pagerank_spec(hg, iters=4)
    # graft a custom (Seq) reducer onto the vertex program: the fused
    # path must refuse it — reducers consume materialized rows.
    seq_reducer = lambda rows, dst, n, live: jax.tree.map(
        lambda r: jax.ops.segment_sum(r, dst, n), rows
    )
    import dataclasses as dc

    spec = spec._replace(
        v_program=dc.replace(spec.v_program, reducer=seq_reducer)
    )
    eng = Engine()
    cfg, _, decision = eng.resolve(spec)
    assert cfg.delivery == "xla"
    assert "non-monoid" in decision["delivery"]["reason"]
    with pytest.raises(ValueError, match="monoid"):
        eng.resolve(spec, delivery="pallas_fused")


def test_delivery_layouts_cached_per_structure():
    hg = medium_hypergraph()
    eng = Engine()
    spec = shortest_paths_spec(hg, 0, 8)
    eng.run(spec, delivery="pallas_fused")
    lay1 = eng._delivery_layouts(hg)
    eng.run(spec, delivery="pallas_fused")
    assert eng._delivery_layouts(hg) is lay1  # identity-cached


def test_layout_pair_directions():
    hg = powerlaw_hypergraph(50, 30, mean_cardinality=4, seed=2)
    fwd, bwd = layout_pair(
        hg.src, hg.dst, hg.e_mask, hg.n_vertices, hg.n_hyperedges
    )
    assert (fwd.n_src, fwd.n_dst) == (hg.n_vertices, hg.n_hyperedges)
    assert (bwd.n_src, bwd.n_dst) == (hg.n_hyperedges, hg.n_vertices)
    assert fwd.nnz == bwd.nnz == hg.nnz


# --------------------------------------------------------------------------
# distributed: fused == reference on both backends (subprocess)
# --------------------------------------------------------------------------

DISTRIBUTED_FUSED = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import Engine
    from repro.core.hypergraph import HyperGraph
    from repro.data import powerlaw_hypergraph
    from repro.algorithms import shortest_paths_spec, pagerank_spec

    mesh = Mesh(np.array(jax.devices()).reshape(4), ('data',))
    hg = powerlaw_hypergraph(90, 70, mean_cardinality=5, seed=0)

    # Mega-hub hyperedge whose id sits exactly on a shard boundary
    # (ne_pad=72, he_block=18 -> id 18 opens shard 1's range), plus a
    # mega-hub vertex on a boundary: the shard-harmonized class plans
    # must keep both dense on every shard that sees a piece of them.
    rng = np.random.default_rng(1)
    nv, ne, nnz = 90, 70, 2600
    dst = np.where(rng.random(nnz) < 0.6, 18,
                   rng.integers(0, ne, nnz)).astype(np.int32)
    src = np.where(rng.random(nnz) < 0.4, 23,
                   rng.integers(0, nv, nnz)).astype(np.int32)
    hub = HyperGraph.from_coo(src, dst, nv, ne)

    local = Engine()
    for backend in ('replicated', 'sharded'):
        eng = Engine(mesh=mesh, backend=backend)
        for graph, tag in ((hg, 'powerlaw'), (hub, 'boundary-hub')):
            # min monoid: one-shot (unpadded) run, bitwise vs local xla
            ref = local.run(shortest_paths_spec(graph, 1, 12),
                            delivery='xla')
            got = eng.run(shortest_paths_spec(graph, 1, 12),
                          delivery='pallas_fused')
            for a, b in zip(ref.value, got.value):
                assert np.array_equal(np.asarray(a), np.asarray(b),
                                      equal_nan=True), (backend, tag)
            # sum monoid: reassociation tolerance
            refp = local.run(pagerank_spec(graph, iters=6),
                             delivery='xla')
            gotp = eng.run(pagerank_spec(graph, iters=6),
                           delivery='pallas_fused')
            for a, b in zip(refp.value, gotp.value):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
        # the harmonized shard plans really did keep the boundary hub
        # dense (no residual scatter lanes anywhere)
        from repro.core.distributed import build_shard_delivery, _pad_to
        plan, _ = eng._cached_plan(hub, 4, 'auto')
        fwd, bwd = build_shard_delivery(
            plan.shard_src, plan.shard_dst, plan.shard_mask,
            _pad_to(nv, 4), _pad_to(ne, 4))
        hub_deg = int((np.asarray(plan.shard_dst) == 18)[
            np.asarray(plan.shard_mask) != 0].sum())
        assert fwd.class_widths[-1] >= hub_deg // 4, fwd.class_widths
        assert fwd.rem_nnz == 0, 'boundary hub spilled to the residual'
        # compiled (bucket-PADDED) fused serving, batched: bitwise vs
        # sequential local, and executed on the distributed executable
        compiled = eng.compile(shortest_paths_spec(hg, 0, 12),
                               delivery='pallas_fused')
        sources = np.arange(6, dtype=np.int32)
        vb, heb = compiled.run_batch(sources).value
        for i, s in enumerate(sources):
            r = local.run(shortest_paths_spec(hg, int(s), 12)).value
            assert np.array_equal(np.asarray(r[0]), np.asarray(vb[i]),
                                  equal_nan=True), (backend, i)
            assert np.array_equal(np.asarray(r[1]), np.asarray(heb[i]),
                                  equal_nan=True), (backend, i)
    print('FUSED_DISTRIBUTED_AGREES')
""")


def test_distributed_fused_subprocess():
    # Inherit the full environment (dropping JAX_PLATFORMS makes jax
    # probe for accelerator platforms — minutes of stall per child).
    proc = subprocess.run(
        [sys.executable, "-c", DISTRIBUTED_FUSED],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "FUSED_DISTRIBUTED_AGREES" in proc.stdout
