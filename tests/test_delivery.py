"""Fused incidence delivery: layout, kernels, engine seam, serving.

The tentpole contracts, asserted:

* **Kernel parity** (property-tested): both fused lowerings — the ELL +
  sorted-COO XLA form and the Pallas kernel in interpret mode — equal
  the reference gather/mask/segment path across monoids (sum, min, max,
  or, prod), dtypes, dead-row masks, dynamic activity, empty segments
  and padded buckets.  Equality is BITWISE: order-insensitive monoids
  (min/max/or) on arbitrary values, sum/prod on integer-valued payloads
  where every association order is exact.  (Float sums across different
  reduce algorithms differ by reassociation; the tight-allclose case is
  covered separately.)
* **Engine seam**: ``delivery='pallas_fused'`` matches ``'xla'``
  end-to-end through ``Engine.run`` and ``Engine.compile``; ``auto``
  resolves via the cost model and reports its reasoning; non-monoid
  specs fall back (auto) or raise (explicit).
* **Distributed**: fused == reference on the replicated AND sharded
  backends, padded (serving) and unpadded (one-shot), in a
  forced-host-device subprocess.
* **Batch-aware halting**: ``run_batch`` stops at the slowest query's
  convergence — fewer supersteps than ``max_iters``, bitwise-equal
  results (asserted in ``tests/test_compile.py``).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algorithms import (
    label_propagation_spec,
    pagerank_spec,
    shortest_paths_spec,
)
from repro.core import Engine
from repro.core.api import Program
from repro.core.engine import deliver
from repro.core.executor import select_delivery
from repro.data import powerlaw_hypergraph
from repro.kernels.deliver import (
    build_delivery_layout,
    fused_deliver,
    layout_pair,
    plan_ell_width,
)

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")

MONOIDS_UNDER_TEST = ("sum", "min", "max", "or", "prod")


@st.composite
def incidence_case(draw):
    """A random incidence list + messages: the deliver() input space."""
    n_src = draw(st.integers(1, 60))
    n_dst = draw(st.integers(1, 50))
    nnz = draw(st.integers(0, 220))
    seed = draw(st.integers(0, 100_000))
    monoid = draw(st.sampled_from(MONOIDS_UNDER_TEST))
    dtype = draw(st.sampled_from(["float32", "int32"]))
    width = draw(st.sampled_from([(), (3,), (2, 2)]))
    with_mask = draw(st.booleans())
    with_active = draw(st.booleans())
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_src, nnz).astype(np.int32)
    dst = rng.integers(0, n_dst, nnz).astype(np.int32)
    mask = (
        (rng.random(nnz) > 0.25).astype(np.float32) if with_mask else None
    )
    if monoid == "or":
        msg = rng.random((n_src,) + width) > 0.5
    elif dtype == "int32":
        msg = rng.integers(-4, 5, (n_src,) + width).astype(np.int32)
    else:
        # Integer-valued float32: every association order is exact, so
        # sum/prod parity is bitwise (the contract under test is the
        # data path — which rows combine where — not fp rounding).
        msg = rng.integers(-4, 5, (n_src,) + width).astype(np.float32)
    active = rng.random(n_src) > 0.3 if with_active else None
    return (src, dst, mask, n_src, n_dst, monoid, msg, active)


@given(incidence_case())
def test_fused_delivery_bitwise_equals_reference(case):
    src, dst, mask, n_src, n_dst, monoid, msg, active = case
    prog = Program(procedure=lambda *a: None, combiner=monoid)
    act_j = jnp.asarray(active) if active is not None else None
    ref = deliver(
        jnp.asarray(msg), act_j, jnp.asarray(src), jnp.asarray(dst),
        n_dst, prog,
        e_mask=jnp.asarray(mask) if mask is not None else None,
    )
    layout = build_delivery_layout(
        src, dst, mask, n_src, n_dst, block_n=8, block_e=16
    )
    for lowering in ("ell", "pallas_interpret"):
        got = fused_deliver(
            jnp.asarray(msg), act_j, layout, prog, lowering=lowering
        )
        assert np.array_equal(
            np.asarray(ref), np.asarray(got), equal_nan=True
        ), (monoid, lowering, msg.dtype)


@given(incidence_case())
def test_fused_delivery_padded_bucket_invariance(case):
    """Padding the sorted lanes to a larger bucket (the serving path's
    ``pad_sorted_to``) must not change any result."""
    src, dst, mask, n_src, n_dst, monoid, msg, active = case
    prog = Program(procedure=lambda *a: None, combiner=monoid)
    act_j = jnp.asarray(active) if active is not None else None
    base = build_delivery_layout(
        src, dst, mask, n_src, n_dst, block_n=8, block_e=16
    )
    padded = build_delivery_layout(
        src, dst, mask, n_src, n_dst, block_n=8, block_e=16,
        pad_sorted_to=len(src) + 37,
    )
    a = fused_deliver(jnp.asarray(msg), act_j, base, prog, lowering="ell")
    b = fused_deliver(jnp.asarray(msg), act_j, padded, prog, lowering="ell")
    assert np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)


def test_fused_float_sum_within_reassociation_tolerance():
    """Arbitrary float sums: the fused dense reduce reassociates, so
    parity is tight-allclose, not bitwise."""
    rng = np.random.default_rng(7)
    n_src, n_dst, nnz = 200, 90, 4000
    src = rng.integers(0, n_src, nnz).astype(np.int32)
    dst = rng.integers(0, n_dst, nnz).astype(np.int32)
    msg = rng.standard_normal((n_src, 4)).astype(np.float32)
    prog = Program(procedure=lambda *a: None, combiner="sum")
    ref = deliver(
        jnp.asarray(msg), None, jnp.asarray(src), jnp.asarray(dst),
        n_dst, prog,
    )
    layout = build_delivery_layout(src, dst, None, n_src, n_dst)
    for lowering in ("ell", "pallas_interpret"):
        got = fused_deliver(
            jnp.asarray(msg), None, layout, prog, lowering=lowering
        )
        np.testing.assert_allclose(
            np.asarray(ref), np.asarray(got), rtol=1e-5, atol=1e-5
        )


def test_plan_ell_width_remainder_rule():
    deg = np.array([1, 1, 2, 40])
    k, rem = plan_ell_width(deg, int(deg.sum()))
    # k grows until <= 25% of incidences overflow (cap 64)
    assert rem <= 0.25 * deg.sum()
    assert k & (k - 1) == 0  # power of two
    k_uniform, rem_uniform = plan_ell_width(np.full(16, 4), 64)
    assert (k_uniform, rem_uniform) == (4, 0)


# --------------------------------------------------------------------------
# the Engine seam
# --------------------------------------------------------------------------

def medium_hypergraph():
    # Large enough to clear the cost model's FUSED_MIN_NNZ floor.
    return powerlaw_hypergraph(1400, 1000, mean_cardinality=7, seed=3)


@pytest.mark.parametrize("make_spec,bitwise", [
    (lambda hg: shortest_paths_spec(hg, 0, 12), True),
    (lambda hg: label_propagation_spec(hg, iters=6), True),
    (lambda hg: pagerank_spec(hg, iters=6), False),
])
def test_engine_run_fused_matches_xla(make_spec, bitwise):
    hg = medium_hypergraph()
    eng = Engine()
    spec = make_spec(hg)
    ref = eng.run(spec, delivery="xla").value
    got = eng.run(spec, delivery="pallas_fused").value
    for a, b in zip(ref, got):
        a, b = np.asarray(a), np.asarray(b)
        if bitwise:
            assert np.array_equal(a, b, equal_nan=True)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_compiled_fused_matches_xla_and_masks_padding():
    hg = medium_hypergraph()
    eng = Engine(collect_stats=True)
    spec = shortest_paths_spec(hg, 0, 12)
    ref = eng.compile(spec, delivery="xla").run()
    got = eng.compile(spec, delivery="pallas_fused").run()
    for a, b in zip(ref.value, got.value):
        assert np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
    # bucket padding must stay invisible in stats on the fused path too
    for r, g in zip(ref.superstep_stats, got.superstep_stats):
        assert np.array_equal(np.asarray(r), np.asarray(g))


def test_delivery_auto_resolves_and_reports():
    """The cost model picks fused for a large narrow-message hypergraph
    and reports the numbers it decided on."""
    hg = medium_hypergraph()
    eng = Engine()
    cfg, _, decision = eng.resolve(shortest_paths_spec(hg, 0, 8))
    why = decision["delivery"]
    assert cfg.delivery == "pallas_fused", why
    assert why["lowering"] in ("ell", "pallas", "pallas_interpret")
    assert "reason" in why and "message_width_bytes" in why

    # tiny structures stay on the reference path (overhead-dominated)
    tiny = powerlaw_hypergraph(30, 20, mean_cardinality=3, seed=0)
    cfg2, _, dec2 = eng.resolve(shortest_paths_spec(tiny, 0, 8))
    assert cfg2.delivery == "xla"


def test_delivery_auto_rejects_wide_messages_on_ell():
    """Wide message rows flip the ELL cost model back to the reference
    path (the dense reduce's padding outweighs the scatter win)."""
    hg = medium_hypergraph()
    spec = pagerank_spec(hg, iters=4)
    wide = spec._replace(initial_msg=jnp.zeros((64,), jnp.float32))
    choice, why = select_delivery(wide, hg)
    assert choice == "xla"
    assert "wide" in why["reason"]


def test_non_monoid_spec_falls_back_and_explicit_raises():
    hg = powerlaw_hypergraph(60, 40, mean_cardinality=4, seed=1)
    spec = pagerank_spec(hg, iters=4)
    # graft a custom (Seq) reducer onto the vertex program: the fused
    # path must refuse it — reducers consume materialized rows.
    seq_reducer = lambda rows, dst, n, live: jax.tree.map(
        lambda r: jax.ops.segment_sum(r, dst, n), rows
    )
    import dataclasses as dc

    spec = spec._replace(
        v_program=dc.replace(spec.v_program, reducer=seq_reducer)
    )
    eng = Engine()
    cfg, _, decision = eng.resolve(spec)
    assert cfg.delivery == "xla"
    assert "non-monoid" in decision["delivery"]["reason"]
    with pytest.raises(ValueError, match="monoid"):
        eng.resolve(spec, delivery="pallas_fused")


def test_delivery_layouts_cached_per_structure():
    hg = medium_hypergraph()
    eng = Engine()
    spec = shortest_paths_spec(hg, 0, 8)
    eng.run(spec, delivery="pallas_fused")
    lay1 = eng._delivery_layouts(hg)
    eng.run(spec, delivery="pallas_fused")
    assert eng._delivery_layouts(hg) is lay1  # identity-cached


def test_layout_pair_directions():
    hg = powerlaw_hypergraph(50, 30, mean_cardinality=4, seed=2)
    fwd, bwd = layout_pair(
        hg.src, hg.dst, hg.e_mask, hg.n_vertices, hg.n_hyperedges
    )
    assert (fwd.n_src, fwd.n_dst) == (hg.n_vertices, hg.n_hyperedges)
    assert (bwd.n_src, bwd.n_dst) == (hg.n_hyperedges, hg.n_vertices)
    assert fwd.nnz == bwd.nnz == hg.nnz


# --------------------------------------------------------------------------
# distributed: fused == reference on both backends (subprocess)
# --------------------------------------------------------------------------

DISTRIBUTED_FUSED = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import Engine
    from repro.data import powerlaw_hypergraph
    from repro.algorithms import shortest_paths_spec, pagerank_spec

    mesh = Mesh(np.array(jax.devices()).reshape(4), ('data',))
    hg = powerlaw_hypergraph(90, 70, mean_cardinality=5, seed=0)
    local = Engine()
    for backend in ('replicated', 'sharded'):
        eng = Engine(mesh=mesh, backend=backend)
        # min monoid: one-shot (unpadded) run, bitwise vs local xla
        ref = local.run(shortest_paths_spec(hg, 1, 12), delivery='xla')
        got = eng.run(shortest_paths_spec(hg, 1, 12),
                      delivery='pallas_fused')
        for a, b in zip(ref.value, got.value):
            assert np.array_equal(np.asarray(a), np.asarray(b),
                                  equal_nan=True), backend
        # sum monoid: reassociation tolerance
        refp = local.run(pagerank_spec(hg, iters=6), delivery='xla')
        gotp = eng.run(pagerank_spec(hg, iters=6),
                       delivery='pallas_fused')
        for a, b in zip(refp.value, gotp.value):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        # compiled (bucket-PADDED) fused serving, batched: bitwise vs
        # sequential local, and executed on the distributed executable
        compiled = eng.compile(shortest_paths_spec(hg, 0, 12),
                               delivery='pallas_fused')
        sources = np.arange(6, dtype=np.int32)
        vb, heb = compiled.run_batch(sources).value
        for i, s in enumerate(sources):
            r = local.run(shortest_paths_spec(hg, int(s), 12)).value
            assert np.array_equal(np.asarray(r[0]), np.asarray(vb[i]),
                                  equal_nan=True), (backend, i)
            assert np.array_equal(np.asarray(r[1]), np.asarray(heb[i]),
                                  equal_nan=True), (backend, i)
    print('FUSED_DISTRIBUTED_AGREES')
""")


def test_distributed_fused_subprocess():
    # Inherit the full environment (dropping JAX_PLATFORMS makes jax
    # probe for accelerator platforms — minutes of stall per child).
    proc = subprocess.run(
        [sys.executable, "-c", DISTRIBUTED_FUSED],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "FUSED_DISTRIBUTED_AGREES" in proc.stdout
