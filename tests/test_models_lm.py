"""Per-arch LM smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs; prefill/decode agreement.

Marked slow: the per-arch compiles push the suite past the tier-1 wall
clock; run with ``-m slow`` (or ``-m ""`` for everything).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import (
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    serve_step,
)
from repro.train import AdamWConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow

LM_ARCHS = [a for a in ARCH_IDS
            if get_config(a, smoke=True).family == "lm"]


def _fp32(cfg):
    return dataclasses.replace(cfg, compute_dtype=jnp.float32)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch):
    spec = get_config(arch, smoke=True)
    cfg = spec.model
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    logits, aux = forward(params, cfg, toks)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    step = make_train_step(
        lambda p, b: loss_fn(p, cfg, b), AdamWConfig(total_steps=10)
    )
    state = init_train_state(params)
    batch = {"tokens": toks, "labels": toks}
    state, m1 = jax.jit(step)(state, batch)
    state, m2 = jax.jit(step)(state, batch)
    assert np.isfinite(float(m1["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5  # moving, not NaN


@pytest.mark.parametrize("arch", ["gemma3-12b", "llama4-maverick-400b-a17b",
                                  "command-r-plus-104b"])
def test_prefill_matches_decode(arch):
    """Token-by-token decode must reproduce prefill's last-token logits —
    cache update + window/chunked attention consistency.  MoE configs get
    an unbounded capacity factor: capacity drops legitimately differ
    between a 32-token prefill batch and per-token decode."""
    spec = get_config(arch, smoke=True)
    cfg = _fp32(spec.model)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
        )
    params = init_params(jax.random.PRNGKey(0), cfg)
    s = 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, s), 0, cfg.vocab)
    last_logits, _cache = prefill(params, cfg, toks)
    cache = init_cache(cfg, 2, s, dtype=jnp.float32)
    for t in range(s):
        logits, cache = serve_step(params, cfg, cache, toks[:, t],
                                   jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(last_logits), rtol=2e-4, atol=2e-4
    )


def test_grad_accumulation_matches_full_batch():
    spec = get_config("llama3.2-1b", smoke=True)
    cfg = _fp32(spec.model)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    s1 = init_train_state(params)
    s2 = init_train_state(params)
    step1 = make_train_step(lambda p, b: loss_fn(p, cfg, b),
                            AdamWConfig(), accum_steps=1)
    step2 = make_train_step(lambda p, b: loss_fn(p, cfg, b),
                            AdamWConfig(), accum_steps=2)
    _, m1 = jax.jit(step1)(s1, batch)
    _, m2 = jax.jit(step2)(s2, batch)
    # micro-batch CE means averaged over same-size chunks == full mean
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)


def test_local_global_pattern_shapes():
    spec = get_config("gemma3-12b", smoke=True)
    cfg = spec.model
    assert cfg.period == 6
    kinds = cfg.layer_kinds
    assert [k[0] for k in kinds] == [True] * 5 + [False]


def test_moe_interleave_pattern():
    spec = get_config("llama4-maverick-400b-a17b")
    kinds = spec.model.layer_kinds
    assert [k[1] for k in kinds] == [False, True, False, True]
    assert [k[0] for k in kinds] == [True, True, True, False]
