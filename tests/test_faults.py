"""Fault-tolerance suite: injection, serve resilience, checkpoint/resume.

The tentpole invariant, asserted three ways:

* **everything resolves** — under ANY injected fault plan, every
  submitted request's future resolves (a result or a typed
  ``FaultError``); nothing hangs, nothing is silently dropped
  (the chaos property test);
* **bitwise on success** — every successfully served value equals the
  sequential fault-free path exactly;
* **resume == uninterrupted** — a checkpointed run killed mid-algorithm
  and resumed produces bitwise-identical results to a run that was
  never interrupted (local here; sharded subprocess in the slow suite).

Plus the unit contracts of each resilience mechanism: deterministic
trigger schedules, retry-with-backoff, batch bisect poison isolation,
circuit breaker, worker supervisor, disk-cache quarantine + checksum
migration, and the closed-front-end guarantees.
"""
import os
import pickle
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Engine
from repro.data import powerlaw_hypergraph
from repro.faults import (
    CircuitOpen,
    DeadlineExceeded,
    FaultError,
    FaultInjector,
    FaultPlan,
    FaultRule,
    FrontendClosed,
    InjectedFault,
    PoisonQuery,
    TransientExecuteError,
    is_transient,
)
from repro.serve import DiskExecutableCache, Frontend, warm
from repro.serve.cache import stable_digest


def _tree_equal(a, b) -> bool:
    import jax

    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(la, lb)
    )


# --------------------------------------------------------------------------
# FaultPlan: schedules + JSON round trip
# --------------------------------------------------------------------------

def test_plan_json_round_trip():
    plan = FaultPlan((
        FaultRule(point="execute", trigger="nth", n=3, error="fatal"),
        FaultRule(point="serve.flush", trigger="prob", p=0.25, seed=7,
                  times=2),
        FaultRule(point="disk.read", trigger="every", n=2,
                  error="corrupt"),
    ))
    assert FaultPlan.from_json(plan.to_json()) == plan
    # dict / list forms are accepted too
    assert FaultPlan.from_json({"rules": [r.to_dict() for r in plan.rules]}) \
        == plan
    assert FaultPlan.from_json([r.to_dict() for r in plan.rules]) == plan


def test_rule_validation():
    with pytest.raises(ValueError, match="unknown trigger"):
        FaultRule(point="execute", trigger="sometimes")
    with pytest.raises(ValueError, match="needs n"):
        FaultRule(point="execute", trigger="nth")
    with pytest.raises(ValueError, match="needs p"):
        FaultRule(point="execute", trigger="prob")
    with pytest.raises(ValueError, match="unknown error kind"):
        FaultRule(point="execute", error="explosive")
    with pytest.raises(ValueError, match="unknown FaultRule fields"):
        FaultRule.from_dict({"point": "execute", "when": "later"})


def test_plan_validate_flags_unknown_points():
    plan = FaultPlan((
        FaultRule(point="execute"),
        FaultRule(point="warp.core"),
    ))
    warnings = plan.validate()
    assert len(warnings) == 1 and "warp.core" in warnings[0]


def test_plan_validate_lists_point_inventory():
    # the warning alone is enough to fix a typo'd plan: it quotes the
    # full instrumented-point inventory
    from repro.faults import FAULT_POINTS

    warnings = FaultPlan((FaultRule(point="warp.core"),)).validate()
    for point in FAULT_POINTS:
        assert point in warnings[0]
    assert "replica.crash" in warnings[0]       # the new replica points
    assert "router.route" in warnings[0]


# --------------------------------------------------------------------------
# FaultInjector: deterministic triggers, taxonomy mapping
# --------------------------------------------------------------------------

def _fire_pattern(inj: FaultInjector, point: str, n: int) -> list:
    out = []
    for _ in range(n):
        try:
            inj.maybe_raise(point)
            out.append(None)
        except FaultError as err:
            out.append(type(err).__name__)
    return out


def test_injector_always_nth_every_times():
    inj = FaultInjector(FaultPlan((
        FaultRule(point="a", trigger="always", times=2),
        FaultRule(point="b", trigger="nth", n=3),
        FaultRule(point="c", trigger="every", n=2),
    )))
    t = "TransientExecuteError"
    assert _fire_pattern(inj, "a", 4) == [t, t, None, None]
    assert _fire_pattern(inj, "b", 4) == [None, None, t, None]
    assert _fire_pattern(inj, "c", 5) == [None, t, None, t, None]
    # untargeted points never fire, but calls are still counted
    assert _fire_pattern(inj, "z", 2) == [None, None]
    snap = inj.snapshot()
    assert snap["calls"] == {"a": 4, "b": 4, "c": 5, "z": 2}
    assert snap["fired"] == {"a": 2, "b": 1, "c": 2}
    assert snap["never_fired"] == []         # every planned point fired


def test_snapshot_reports_never_fired_points():
    # a plan whose rule never triggers (nth call never reached) shows up
    # in never_fired — chaos CI asserts on this to prove the plan
    # actually exercised its scheduled failures
    inj = FaultInjector(FaultPlan((
        FaultRule(point="a", trigger="always", times=1),
        FaultRule(point="b", trigger="nth", n=100),
    )))
    _fire_pattern(inj, "a", 2)
    _fire_pattern(inj, "b", 2)
    snap = inj.snapshot()
    assert snap["never_fired"] == ["b"]


def test_injector_prob_is_deterministic_per_seed():
    plan = FaultPlan((
        FaultRule(point="x", trigger="prob", p=0.4, seed=11),
    ))
    p1 = _fire_pattern(FaultInjector(plan), "x", 64)
    p2 = _fire_pattern(FaultInjector(plan), "x", 64)
    assert p1 == p2                      # same plan, same traffic, same faults
    assert any(p1) and not all(p1)       # p=0.4 over 64 draws: mixed
    reseeded = FaultPlan((
        FaultRule(point="x", trigger="prob", p=0.4, seed=12),
    ))
    assert _fire_pattern(FaultInjector(reseeded), "x", 64) != p1


def test_injector_error_kinds_map_to_taxonomy():
    inj = FaultInjector(FaultPlan((
        FaultRule(point="t", error="transient"),
        FaultRule(point="f", error="fatal"),
        FaultRule(point="c", error="corrupt"),
    )))
    with pytest.raises(TransientExecuteError) as e1:
        inj.maybe_raise("t")
    assert is_transient(e1.value)
    with pytest.raises(InjectedFault) as e2:
        inj.maybe_raise("f")
    assert not is_transient(e2.value) and e2.value.point == "f"
    with pytest.raises(FaultError, match="corrupt"):
        inj.maybe_raise("c")
    # every taxonomy error is a RuntimeError: pre-taxonomy callers work
    with pytest.raises(RuntimeError):
        inj.maybe_raise("f")


# --------------------------------------------------------------------------
# serve-tier resilience (fake compiled, fake clock — no jax dispatch)
# --------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class FakeResult:
    def __init__(self, value):
        self.value = value
        self.supersteps_executed = None


class FakeCompiled:
    """``run_batch`` double: rows are a pure function of the query."""

    def __init__(self, salt):
        self.salt = salt

    def _one(self, q):
        return {"out": np.asarray([q * 2 + self.salt, q], np.int64)}

    def run(self, query=None, hg=None):
        return FakeResult(self._one(int(query)))

    def run_batch(self, queries, hg=None):
        qs = np.asarray(queries)
        rows = [self._one(int(q)) for q in qs]
        return FakeResult({"out": np.stack([r["out"] for r in rows])})


class FlakyCompiled(FakeCompiled):
    """Fails transiently the first ``fail_first`` run_batch calls."""

    def __init__(self, salt, fail_first):
        super().__init__(salt)
        self.fail_first = fail_first
        self.calls = 0

    def run_batch(self, queries, hg=None):
        self.calls += 1
        if self.calls <= self.fail_first:
            raise TransientExecuteError(f"flaky call #{self.calls}")
        return super().run_batch(queries, hg=hg)


class PoisonCompiled(FakeCompiled):
    """Deterministically fails any batch containing ``poison``."""

    def __init__(self, salt, poison):
        super().__init__(salt)
        self.poison = poison

    def run_batch(self, queries, hg=None):
        if self.poison in set(np.asarray(queries).tolist()):
            raise RuntimeError(f"poisoned by {self.poison}")
        return super().run_batch(queries, hg=hg)


def _frontend(compiled, **kw):
    kw.setdefault("clock", FakeClock())
    kw.setdefault("max_batch", 4)
    kw.setdefault("retry_backoff_ms", 0.0)
    fe = Frontend(Engine(), **kw)
    fe._sleep = lambda s: None   # retries without wall-clock waits
    fe.register("k", compiled)
    return fe


def _counter(fe, name):
    return fe.metrics.registry.counter(name).value


def test_closed_frontend_fails_queued_and_rejects_new():
    fe = _frontend(FakeCompiled(10))
    f1, f2 = fe.submit("k", query=1), fe.submit("k", query=2)
    fe.close()
    for f in (f1, f2):
        assert f.done()
        with pytest.raises(FrontendClosed, match="still queued"):
            f.result(timeout=0)
    with pytest.raises(FrontendClosed):
        fe.submit("k", query=3)
    with pytest.raises(FrontendClosed):
        fe.register("k2", FakeCompiled(11))
    snap = fe.stats()
    assert snap["errors"] == 2 and snap["in_flight"] == 0


def test_deadline_exceeded_resolves_typed():
    fe = _frontend(FakeCompiled(10))
    late = fe.submit("k", query=1, timeout_ms=5.0)
    ok = fe.submit("k", query=2)
    fe.clock.t += 1.0              # blow way past the 5ms hard deadline
    fe.pump(drain=True)
    with pytest.raises(DeadlineExceeded, match="past its deadline"):
        late.result(timeout=0)
    assert ok.result(timeout=0).value["out"][1] == 2
    assert fe.stats()["in_flight"] == 0


def test_retry_serves_after_transient_failures():
    flaky = FlakyCompiled(10, fail_first=2)
    fe = _frontend(flaky, max_retries=2)
    before = _counter(fe, "faults.serve.retries")
    fut = fe.submit("k", query=5)
    fe.pump(drain=True)
    assert fut.result(timeout=0).value["out"][0] == 20
    assert flaky.calls == 3
    assert _counter(fe, "faults.serve.retries") - before == 2


def test_retry_gives_up_past_max_retries():
    flaky = FlakyCompiled(10, fail_first=10)
    fe = _frontend(flaky, max_retries=2)
    fut = fe.submit("k", query=5)
    fe.pump(drain=True)
    with pytest.raises(TransientExecuteError):
        fut.result(timeout=0)
    assert flaky.calls == 3        # 1 attempt + 2 retries, then surface


def test_bisect_isolates_poison_query():
    fe = _frontend(PoisonCompiled(10, poison=2))
    before = _counter(fe, "faults.serve.bisects")
    futs = {q: fe.submit("k", query=q) for q in (0, 1, 2, 3)}
    fe.pump(drain=True)
    for q, fut in futs.items():
        if q == 2:
            with pytest.raises(PoisonQuery, match="poisoned") as exc:
                fut.result(timeout=0)
            assert "poisoned by 2" in str(exc.value.__cause__)
        else:
            assert fut.result(timeout=0).value["out"][1] == q
    assert _counter(fe, "faults.serve.bisects") - before >= 1
    snap = fe.stats()
    assert snap["completed"] == 3 and snap["errors"] == 1
    assert snap["in_flight"] == 0


def test_circuit_breaker_trips_cools_down_and_probes():
    class Togglable(FakeCompiled):
        broken = True

        def run_batch(self, queries, hg=None):
            if self.broken:
                raise RuntimeError("hard down")
            return super().run_batch(queries, hg=hg)

    dbl = Togglable(10)
    fe = _frontend(dbl, breaker_threshold=2, breaker_cooldown_ms=1000.0)
    trips0 = _counter(fe, "faults.serve.breaker_trips")
    for _ in range(2):             # two consecutive failures: trip
        fut = fe.submit("k", query=1)
        fe.pump(drain=True)
        with pytest.raises(RuntimeError, match="hard down"):
            fut.result(timeout=0)
    assert _counter(fe, "faults.serve.breaker_trips") - trips0 == 1
    # open: fail fast, the (still broken) executable is not even called
    fast = fe.submit("k", query=1)
    fe.pump(drain=True)
    with pytest.raises(CircuitOpen, match="circuit open"):
        fast.result(timeout=0)
    # cooldown elapses; the half-open probe reaches a now-healthy path
    dbl.broken = False
    fe.clock.t += 2.0
    probe = fe.submit("k", query=7)
    fe.pump(drain=True)
    assert probe.result(timeout=0).value["out"][1] == 7
    assert fe.stats()["in_flight"] == 0


def test_worker_supervisor_restarts_and_requeues():
    inj = FaultInjector(FaultPlan((
        FaultRule(point="serve.worker", trigger="nth", n=1),
    )))
    fe = Frontend(Engine(), max_batch=4, max_delay_ms=1.0,
                  fault_injector=inj)
    fake = FakeCompiled(100)
    fe.register("k", fake)
    restarts0 = fe.metrics.registry.counter(
        "faults.serve.worker_restarts").value
    with fe:
        futs = [fe.submit("k", query=q) for q in (3, 4, 5)]
        results = [f.result(timeout=120) for f in futs]
    for q, served in zip((3, 4, 5), results):
        assert _tree_equal(served.value, fake.run(query=q).value)
    assert fe.metrics.registry.counter(
        "faults.serve.worker_restarts").value - restarts0 >= 1
    assert inj.fired("serve.worker") == 1
    assert fe.stats()["in_flight"] == 0


def test_repeated_worker_crash_bounds_requeues():
    inj = FaultInjector(FaultPlan((
        FaultRule(point="serve.worker", trigger="always", error="fatal"),
    )))
    fe = Frontend(Engine(), max_batch=4, max_delay_ms=1.0,
                  fault_injector=inj)
    fe.register("k", FakeCompiled(100))
    with fe:
        fut = fe.submit("k", query=1)
        # the supervisor gives up after MAX_REQUEUES: the future resolves
        # with the crash instead of looping forever
        with pytest.raises(InjectedFault, match="serve.worker"):
            fut.result(timeout=120)
    assert fe.stats()["in_flight"] == 0


# --------------------------------------------------------------------------
# chaos property: random fault plans x arrival orders — everything
# resolves; successes are bitwise-equal to the sequential path
# --------------------------------------------------------------------------

_CHAOS_RULE = st.tuples(
    st.sampled_from(["serve.flush", "serve.flush", "execute"]),
    st.sampled_from(["always", "nth", "every", "prob"]),
    st.integers(1, 3),                    # n (nth / every)
    st.floats(0.0, 0.6),                  # p (prob)
    st.integers(0, 99),                   # seed
    st.sampled_from([1, 2, 3, None]),     # times
    st.sampled_from(["transient", "transient", "fatal"]),
)

_CHAOS_TRAFFIC = st.lists(
    st.tuples(
        st.sampled_from(["sssp", "ppr"]),   # signature
        st.integers(0, 30),                 # query
        st.floats(0.0, 0.01),               # inter-arrival
        st.booleans(),                      # pump mid-stream?
    ),
    min_size=1, max_size=40,
)


@given(st.lists(_CHAOS_RULE, min_size=0, max_size=3), _CHAOS_TRAFFIC)
@settings(max_examples=40, deadline=None)
def test_chaos_every_request_resolves_bitwise_on_success(raw_rules, events):
    rules = tuple(
        FaultRule(point=point, trigger=trigger, n=n, p=p, seed=seed,
                  times=times, error=error)
        for point, trigger, n, p, seed, times, error in raw_rules
    )
    inj = FaultInjector(FaultPlan(rules))
    clock = FakeClock()
    fe = Frontend(Engine(), max_batch=4, max_delay_ms=5.0, clock=clock,
                  retry_backoff_ms=0.0, fault_injector=inj)
    fe._sleep = lambda s: None
    fakes = {"sssp": FakeCompiled(1000), "ppr": FakeCompiled(7000)}
    for key, fake in fakes.items():
        fe.register(key, fake)

    futs = []
    for key, query, dt, do_pump in events:
        clock.t += dt
        futs.append((key, query, fe.submit(key, query=query)))
        if do_pump:
            fe.pump()
    clock.t += 10.0
    fe.pump(drain=True)

    served_ok = 0
    for key, query, fut in futs:
        assert fut.done()        # NOTHING hangs, whatever the plan did
        err = fut.exception(timeout=0)
        if err is None:
            served = fut.result(timeout=0)
            expected = fakes[key].run(query=query).value
            np.testing.assert_array_equal(served.value["out"],
                                          expected["out"])
            served_ok += 1
        else:
            assert isinstance(err, RuntimeError)   # typed, catchable
    snap = fe.stats()
    assert snap["submitted"] == len(futs)
    assert snap["completed"] == served_ok
    assert snap["in_flight"] == 0
    if not rules:
        assert served_ok == len(futs)   # fault-free plans serve everything


# --------------------------------------------------------------------------
# disk-cache integrity: quarantine, checksum, migration
# --------------------------------------------------------------------------

def test_cache_quarantines_garbage_file(tmp_path):
    cache = DiskExecutableCache(tmp_path)
    key = ("unit", "garbage")
    path = cache._path(stable_digest(key))
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(b"not a pickle at all")
    assert cache.load(key) is None
    st_ = cache.stats()
    assert st_["disk_errors"] == 1 and st_["disk_quarantined"] == 1
    assert not path.exists()
    assert path.with_name(path.name + ".corrupt").exists()
    # quarantined: the next load is a clean miss, not another error
    assert cache.load(key) is None
    assert cache.stats()["disk_errors"] == 1


def test_cache_rejects_unknown_format(tmp_path):
    cache = DiskExecutableCache(tmp_path)
    key = ("unit", "foreign")
    path = cache._path(stable_digest(key))
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(pickle.dumps({"format": "alien", "serialized": b""}))
    assert cache.load(key) is None
    assert cache.stats()["disk_quarantined"] == 1


def test_cache_checksum_detects_bitrot_and_migrates_legacy(tmp_path):
    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    eng = Engine(disk_cache=DiskExecutableCache(tmp_path))
    rep = warm(eng, [shortest_paths_spec(hg, 0, 12)], batch_sizes=(8,))
    assert rep["traces"] > 0
    entries = sorted(tmp_path.rglob("*.jexe"))
    assert entries
    victim = entries[0]
    payload = pickle.loads(victim.read_bytes())
    assert payload["format"] == "xla-executable"
    assert payload.get("checksum")       # stores are checksummed now

    # Bit-rot: flip bytes but keep the recorded checksum
    rotten = dict(payload)
    rotten["serialized"] = b"\x00" * 16 + payload["serialized"][16:]
    victim.write_bytes(pickle.dumps(rotten))
    cache2 = DiskExecutableCache(tmp_path)
    eng2 = Engine(disk_cache=cache2)
    rep2 = warm(eng2, [shortest_paths_spec(hg, 0, 12)], batch_sizes=(8,))
    st2 = cache2.stats()
    assert st2["disk_quarantined"] >= 1
    assert victim.with_name(victim.name + ".corrupt").exists()
    assert rep2["traces"] > 0            # recompiled past the rot
    # the recompile re-published a GOOD entry in the victim's place
    # (fresh serialized bytes, so a fresh — but self-consistent — sum)
    from repro.serve.cache import _checksum

    republished = pickle.loads(victim.read_bytes())
    assert _checksum(republished["serialized"]) == republished["checksum"]

    # Legacy migration: strip a checksum; the next load verifies the
    # round-trip, serves the hit, and upgrades the entry in place
    other = sorted(tmp_path.rglob("*.jexe"))[-1]
    legacy = pickle.loads(other.read_bytes())
    legacy.pop("checksum")
    other.write_bytes(pickle.dumps(legacy))
    cache3 = DiskExecutableCache(tmp_path)
    eng3 = Engine(disk_cache=cache3)
    warm(eng3, [shortest_paths_spec(hg, 0, 12)], batch_sizes=(8,))
    st3 = cache3.stats()
    assert st3["disk_hits"] >= 1 and st3["disk_migrated"] >= 1
    assert pickle.loads(other.read_bytes()).get("checksum")


# --------------------------------------------------------------------------
# graceful degradation: fused failures fall back to xla delivery
# --------------------------------------------------------------------------

def test_execute_fault_degrades_fused_to_xla_bitwise():
    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    spec = shortest_paths_spec(hg, 0, 12)
    ref = Engine().compile(spec, delivery="xla").run(query=3)

    inj = FaultInjector(FaultPlan((
        FaultRule(point="execute", trigger="nth", n=1, error="fatal"),
    )))
    eng = Engine(fault_injector=inj)
    comp = eng.compile(spec, delivery="pallas_fused")
    degraded0 = eng.metrics.counter("faults.delivery_degraded").value
    got = comp.run(query=3)
    assert _tree_equal(got.value, ref.value)
    assert got.decision.get("degraded_from") == "pallas_fused"
    assert eng.metrics.counter(
        "faults.delivery_degraded").value - degraded0 == 1
    # degradation is per-request, not sticky: the injector's nth=1 rule
    # is spent, so the next run serves fused again — same numbers
    again = comp.run(query=3)
    assert _tree_equal(again.value, ref.value)
    assert "degraded_from" not in again.decision


def test_layout_fault_degrades_fused_to_xla():
    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    spec = shortest_paths_spec(hg, 0, 12)
    ref = Engine().compile(spec, delivery="xla").run(query=5)
    inj = FaultInjector(FaultPlan((
        FaultRule(point="layout.build", trigger="always", error="fatal"),
    )))
    eng = Engine(fault_injector=inj)
    got = eng.compile(spec, delivery="pallas_fused").run(query=5)
    assert _tree_equal(got.value, ref.value)
    assert got.decision.get("degraded_from") == "pallas_fused"


# --------------------------------------------------------------------------
# checkpoint/resume: chunked == uninterrupted, bitwise
# --------------------------------------------------------------------------

def test_checkpointed_run_bitwise_equals_plain(tmp_path):
    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    spec = shortest_paths_spec(hg, 0, 8)
    eng = Engine()
    plain = eng.run(spec, max_iters=8)
    ck = eng.run(spec, max_iters=8, checkpoint_every=3,
                 checkpoint_dir=str(tmp_path / "ck"))
    assert _tree_equal(ck.value, plain.value)
    steps = sorted(p.name for p in (tmp_path / "ck").iterdir())
    assert steps and steps[0] == "step_00000003"


def test_kill_and_resume_bitwise_equals_uninterrupted(tmp_path):
    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    spec = shortest_paths_spec(hg, 0, 8)
    baseline = Engine().run(spec, max_iters=8)

    ckdir = str(tmp_path / "ck")
    inj = FaultInjector(FaultPlan((
        FaultRule(point="checkpoint.chunk", trigger="nth", n=1,
                  error="fatal"),
    )))
    dead = Engine(fault_injector=inj)
    with pytest.raises(InjectedFault, match="checkpoint.chunk"):
        dead.run(spec, max_iters=8, checkpoint_every=3,
                 checkpoint_dir=ckdir)
    # the first chunk's snapshot survived the crash
    assert (tmp_path / "ck" / "step_00000003").exists()

    fresh = Engine()
    restored0 = fresh.metrics.counter("faults.checkpoint.restored").value
    resumed = fresh.run(spec, max_iters=8, checkpoint_every=3,
                        checkpoint_dir=ckdir)
    assert fresh.metrics.counter(
        "faults.checkpoint.restored").value - restored0 == 1
    assert _tree_equal(resumed.value, baseline.value)


def test_corrupt_checkpoint_degrades_to_fresh_start(tmp_path):
    from repro.algorithms import shortest_paths_spec

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    spec = shortest_paths_spec(hg, 0, 8)
    baseline = Engine().run(spec, max_iters=8)

    junk = tmp_path / "ck" / "step_00000003"
    junk.mkdir(parents=True)
    (junk / "manifest.json").write_text("{ not json")
    eng = Engine()
    failed0 = eng.metrics.counter(
        "faults.checkpoint.restore_failed").value
    res = eng.run(spec, max_iters=8, checkpoint_every=3,
                  checkpoint_dir=str(tmp_path / "ck"))
    assert eng.metrics.counter(
        "faults.checkpoint.restore_failed").value - failed0 == 1
    assert _tree_equal(res.value, baseline.value)


# --------------------------------------------------------------------------
# sharded kill-and-resume (subprocess: forced host devices) — slow suite
# --------------------------------------------------------------------------

_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import Engine
    from repro.data import powerlaw_hypergraph
    from repro.algorithms import shortest_paths_spec
    from repro.faults import FaultInjector, FaultPlan, FaultRule, \\
        InjectedFault
    from repro.partition import partition

    ckdir = sys.argv[1]
    phase = sys.argv[2]
    hg = powerlaw_hypergraph(61, 41, mean_cardinality=4, seed=1)
    spec = shortest_paths_spec(hg, 0, 8)
    mesh = Mesh(np.array(jax.devices()).reshape(4), ('data',))
    plan = partition('random_vertex_cut', hg, 4)

    if phase == 'kill':
        inj = FaultInjector(FaultPlan((
            FaultRule(point='checkpoint.chunk', trigger='nth', n=1,
                      error='fatal'),
        )))
        eng = Engine(plan=plan, mesh=mesh, backend='sharded',
                     fault_injector=inj)
        try:
            eng.run(spec, max_iters=8, checkpoint_every=3,
                    checkpoint_dir=ckdir)
        except InjectedFault:
            print('KILLED_AFTER_CHUNK')
            sys.exit(0)
        sys.exit(3)  # the fault did not fire
    else:
        eng = Engine(plan=plan, mesh=mesh, backend='sharded')
        resumed = eng.run(spec, max_iters=8, checkpoint_every=3,
                          checkpoint_dir=ckdir)
        local = Engine().run(spec, max_iters=8)
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
            for a, b in zip(jax.tree.leaves(resumed.value),
                            jax.tree.leaves(local.value))
        )
        restored = eng.metrics.counter(
            'faults.checkpoint.restored').value
        assert restored == 1, restored
        print('RESUMED_BITWISE' if ok else 'MISMATCH')
""")


@pytest.mark.slow
def test_sharded_kill_and_resume_bitwise(tmp_path):
    env = {**os.environ, "PYTHONPATH": "src"}
    cwd = __file__.rsplit("/tests/", 1)[0]
    ckdir = str(tmp_path / "ck")
    p1 = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, ckdir, "kill"],
        capture_output=True, text=True, timeout=900, env=env, cwd=cwd,
    )
    assert p1.returncode == 0, p1.stderr[-3000:]
    assert "KILLED_AFTER_CHUNK" in p1.stdout
    assert (tmp_path / "ck" / "step_00000003").exists()
    p2 = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, ckdir, "resume"],
        capture_output=True, text=True, timeout=900, env=env, cwd=cwd,
    )
    assert p2.returncode == 0, p2.stderr[-3000:]
    assert "RESUMED_BITWISE" in p2.stdout
