"""Observability tier: tracer, metrics registry, explain/calibrate.

The tentpole contracts, asserted:

* ``Tracer`` spans nest per thread, the ring keeps the newest spans
  (counting the dropped rest), and the export is schema-valid
  Chrome-trace JSON (``ph: "X"`` complete events, microsecond fields);
* ``MetricsRegistry`` get-or-creates typed instruments, suffixes
  colliding provider names, prunes dead weakref providers, and keeps
  snapshotting through a provider that throws;
* ``Engine.explain`` reports per-candidate predicted costs WITHOUT
  executing (trace counter pinned at zero) and its winners match what
  ``resolve``/``run`` of the same inputs picks — axis for axis, also
  as a property over axis overrides;
* ``Engine.run`` enriches ``Result.decision["measured"]`` (wall split,
  executed supersteps, per-class delivery bytes on the fused path);
* ``obs.calibrate`` arithmetic (traffic models, superstep counting,
  log2 residuals, the bench_delivery calibration record);
* ``tools/bench_check.py`` fails only on >2x ratio-metric regressions
  and warns on host-dependent drift.
"""
import gc
import importlib.util
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import algorithms as alg
from repro.core import AnalyticsSpec, Engine
from repro.data import powerlaw_hypergraph
from repro.kernels.deliver import build_delivery_layout
from repro.obs import (
    MetricsRegistry,
    Tracer,
    decision_residuals,
    delivery_calibration,
    executed_supersteps,
    fused_traffic,
    maybe_span,
    reference_traffic,
    reset_default_registry,
    residual_log2,
    weak_provider,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# Tracer: nesting, ring eviction, Chrome-trace schema
# --------------------------------------------------------------------------

def test_span_nesting_and_durations_fake_clock():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("outer", cat="execute", k=1) as outer:
        clock.t = 1.0
        with tr.span("inner", cat="compile") as inner:
            clock.t = 3.0
        clock.t = 10.0
    spans = tr.spans()
    # completion order: inner closes first
    assert [s.name for s in spans] == ["inner", "outer"]
    assert inner.depth == 1 and outer.depth == 0
    assert inner.dur_s == pytest.approx(2.0)
    assert outer.dur_s == pytest.approx(10.0)
    assert outer.args["k"] == 1
    # siblings after the nest go back to depth 0
    with tr.span("next") as nxt:
        pass
    assert nxt.depth == 0


def test_ring_eviction_keeps_newest_and_counts_dropped():
    tr = Tracer(capacity=4, clock=FakeClock())
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    spans = tr.spans()
    assert len(spans) == 4
    assert [s.name for s in spans] == ["s6", "s7", "s8", "s9"]
    assert tr.dropped == 6
    assert tr.total == 10
    tr.clear()
    assert tr.spans() == [] and tr.dropped == 0


def test_chrome_trace_schema_and_export(tmp_path):
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("compile", cat="compile", key="k"):
        clock.t = 0.5
        with tr.span("execute", cat="execute"):
            clock.t = 0.75
    doc = tr.chrome_trace()
    assert doc["otherData"]["dropped_spans"] == 0
    events = doc["traceEvents"]
    assert len(events) == 2
    for ev in events:
        for field in ("name", "cat", "ph", "ts", "dur", "pid", "tid"):
            assert field in ev
        assert ev["ph"] == "X"
        assert ev["dur"] >= 0.0
        assert "depth" in ev["args"]
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        reloaded = json.load(f)
    assert reloaded["traceEvents"] == json.loads(json.dumps(events))


def test_maybe_span_is_noop_without_tracer():
    with maybe_span(None, "anything", cat="execute", k=2) as sp:
        assert sp is None


def test_tracer_block_records_device_wait():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("x") as sp:
        clock.t = 1.0
        out = tr.block(sp, np.zeros(3))  # numpy value: no-op block
    assert out.shape == (3,)
    assert sp.args["device_wait_s"] == pytest.approx(0.0)


# --------------------------------------------------------------------------
# MetricsRegistry
# --------------------------------------------------------------------------

def test_registry_instruments_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc()
    c.inc(3)
    reg.gauge("g").set(2.5)
    reg.histogram("h").record(0.01)
    assert reg.counter("n") is c  # get-or-create
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("n")
    snap = reg.snapshot()
    assert snap["n"] == 4
    assert snap["g"] == 2.5
    assert snap["h"]["count"] == 1


def test_registry_provider_collision_suffix_and_errors():
    reg = MetricsRegistry()
    n1 = reg.register_provider("cache", lambda: {"a": 1})
    n2 = reg.register_provider("cache", lambda: {"b": 2})
    assert (n1, n2) == ("cache", "cache#2")

    def boom():
        raise RuntimeError("nope")

    reg.register_provider("bad", boom)
    snap = reg.snapshot()
    assert snap["cache"] == {"a": 1}
    assert snap["cache#2"] == {"b": 2}
    assert "error" in snap["bad"]


def test_registry_prunes_dead_weak_providers():
    class Owner:
        def stats(self):
            return {"alive": True}

    reg = MetricsRegistry()
    o = Owner()
    reg.register_provider("owner", weak_provider(o.stats))
    assert reg.snapshot()["owner"] == {"alive": True}
    del o
    gc.collect()
    snap = reg.snapshot()
    assert "owner" not in snap
    assert "owner" not in reg._providers  # pruned, not just skipped


def test_latency_histogram_is_shared_between_obs_and_serve():
    import repro.obs.metrics as obs_metrics
    import repro.serve as serve
    import repro.serve.metrics as serve_metrics

    assert serve.LatencyHistogram is obs_metrics.LatencyHistogram
    assert serve_metrics.LatencyHistogram is obs_metrics.LatencyHistogram


def test_frontend_stats_merges_registry_sections():
    from repro.serve import Frontend

    reset_default_registry()
    eng = Engine()
    fe = Frontend(eng, max_batch=4, max_delay_ms=1.0, clock=FakeClock())
    snap = fe.stats()["registry"]
    assert "engine.exec_cache" in snap
    assert "serve.frontend" in snap
    assert snap["engine.exec_cache"]["entries"] == 0
    reset_default_registry()


def test_delivery_layout_builder_reports_into_registry():
    reg = reset_default_registry()
    rng = np.random.default_rng(0)
    src = rng.integers(0, 64, 512).astype(np.int32)
    dst = rng.integers(0, 64, 512).astype(np.int32)
    layout = build_delivery_layout(src, dst, None, 64, 64)
    snap = reg.snapshot()
    assert snap["delivery.layouts_built"] == 1
    assert snap["delivery.ell_slots"] == layout.ell_slots
    assert snap["delivery.build_s"]["count"] == 1
    reset_default_registry()


# --------------------------------------------------------------------------
# Engine.explain: candidates without executing, agreement with run
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(400, 180, mean_cardinality=5, seed=3)


def test_explain_reports_candidates_without_executing(hg):
    eng = Engine()
    spec = alg.pagerank_spec(hg, iters=4)
    ex = eng.explain(spec)
    # no compile, no device work
    assert eng.cache_stats()["traces"] == 0
    assert eng.cache_stats()["entries"] == 0
    axes = ex["axes"]
    assert set(axes) == {
        "representation", "backend", "partition", "delivery",
    }
    for axis, info in axes.items():
        assert "winner" in info and "candidates" in info, axis
    d = axes["delivery"]["candidates"]
    assert d["xla"]["eligible"] is True
    assert d["xla"]["predicted_hbm_bytes"] > 0
    assert "eligible" in d["pallas_fused"]
    r = axes["representation"]["candidates"]
    assert r["bipartite"]["predicted_cost_edges"] == hg.nnz


def test_explain_config_matches_run(hg):
    eng = Engine(collect_stats=True)
    spec = alg.pagerank_spec(hg, iters=4)
    ex = eng.explain(spec)
    res = eng.run(spec)
    assert ex["config"] == res.config
    assert ex["axes"]["representation"]["winner"] == res.representation
    assert ex["axes"]["backend"]["winner"] == res.backend
    assert ex["axes"]["delivery"]["winner"] == res.config.delivery


@given(
    st.sampled_from(["auto", "bipartite"]),
    st.sampled_from(["auto", "xla", "pallas_fused"]),
    st.sampled_from(["auto", "local"]),
)
@settings(max_examples=12, deadline=None)
def test_explain_matches_resolve_under_overrides(
    representation, delivery, backend
):
    # the agreement property: explain is BUILT on resolve, so for any
    # pinning of the axes the explained config IS the resolved config.
    hg = powerlaw_hypergraph(120, 60, mean_cardinality=4, seed=7)
    eng = Engine()
    spec = alg.shortest_paths_spec(hg, 0, 3)
    overrides = dict(
        representation=representation, delivery=delivery, backend=backend,
    )
    ex = eng.explain(spec, **overrides)
    resolved, _, decision = eng.resolve(spec, **overrides)
    assert ex["config"] == resolved
    assert ex["decision"].keys() == decision.keys()
    for axis in ("representation", "backend", "delivery"):
        assert ex["axes"][axis]["winner"] == getattr(
            resolved,
            axis if axis != "backend" else "backend",
        )
    assert eng.cache_stats()["traces"] == 0


def test_explain_analytics_axes(hg):
    eng = Engine()
    ex = eng.explain(AnalyticsSpec(hg, mode="auto"))
    axes = ex["axes"]
    assert {"kernel", "representation", "backend", "mode"} <= set(axes)
    k = axes["kernel"]["candidates"]
    assert k["merge"]["eligible"] is True
    assert k["merge"]["predicted_ops_per_pair"] > 0
    res = eng.analyze(AnalyticsSpec(hg, mode="auto"))
    assert axes["kernel"]["winner"] == res.kernel
    assert axes["mode"]["winner"] == res.mode


def test_run_enriches_decision_with_measured(hg):
    eng = Engine(collect_stats=True)
    res = eng.run(alg.pagerank_spec(hg, iters=4))
    m = res.decision["measured"]
    assert m["wall_s"] >= m["device_wait_s"] >= 0.0
    assert m["max_iters"] == 4
    assert 0 <= m["supersteps"] <= 4


def test_run_measured_delivery_bytes_on_fused_path(hg):
    eng = Engine(delivery="pallas_fused")
    res = eng.run(alg.pagerank_spec(hg, iters=3))
    md = res.decision["measured"]["delivery"]
    assert md["total_bytes"] > 0
    assert md["fwd"]["nnz"] == hg.nnz
    assert md["total_bytes"] == pytest.approx(
        md["fwd"]["total_bytes"] + md["bwd"]["total_bytes"]
    )
    assert md["reference_total_bytes"] > 0
    # the residual record built from the same enriched decision
    rr = decision_residuals(res.decision)
    if "delivery" in rr:
        assert rr["delivery"]["built_work_slots"] > 0


# --------------------------------------------------------------------------
# obs.calibrate arithmetic
# --------------------------------------------------------------------------

def test_reference_and_fused_traffic_models():
    assert reference_traffic(100, 10, 4.0) == 100 * (12 + 8) + 40
    rng = np.random.default_rng(1)
    src = rng.integers(0, 32, 256).astype(np.int32)
    dst = rng.integers(0, 32, 256).astype(np.int32)
    layout = build_delivery_layout(src, dst, None, 32, 32)
    t = fused_traffic(layout, 4.0)
    assert t["total_bytes"] == pytest.approx(
        sum(t["per_class_bytes"]) + t["residual_bytes"] + t["output_bytes"]
    )
    assert t["nnz"] == 256


def test_executed_supersteps_counts_active_pairs():
    assert executed_supersteps(([3, 2, 0, 0], [1, 0, 0, 0])) == 2
    assert executed_supersteps(([1, 0, 0, 0], [0, 0, 0, 0])) == 1
    # batched stats: the slowest query wins
    v = np.array([[3, 2, 0], [1, 0, 0]])
    he = np.zeros_like(v)
    assert executed_supersteps((v, he)) == 2
    assert executed_supersteps((v, he), max_iters=1) == 1
    assert executed_supersteps(None) is None


def test_residual_log2_and_delivery_calibration():
    assert residual_log2(2.0, 1.0) == pytest.approx(1.0)
    assert residual_log2(1.0, 1.0) == pytest.approx(0.0)
    regimes = {
        "perfect": {
            "model_traffic_ratio": 2.0, "fused_speedup": 2.0,
            "auto_picks": "pallas_fused",
        },
        "off": {
            "model_traffic_ratio": 0.5, "fused_speedup": 0.8,
            "auto_picks": "xla",
        },
    }
    cal = delivery_calibration(regimes)
    assert cal["regimes"]["perfect"]["residual_log2"] == pytest.approx(0.0)
    assert cal["regimes"]["perfect"]["decision_agrees"] is True
    assert cal["regimes"]["off"]["measured_winner"] == "xla"
    assert cal["regimes"]["off"]["decision_agrees"] is True
    s = cal["summary"]
    assert s["decision_accuracy"] == 1.0
    assert s["mean_abs_residual_log2"] == pytest.approx(
        abs(np.log2(0.5 / 0.8)) / 2
    )
    assert s["suggested_model_scale"] > 1.0  # model under-predicted "off"


# --------------------------------------------------------------------------
# tools/bench_check.py
# --------------------------------------------------------------------------

def _bench_check():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "bench_check", os.path.join(root, "tools", "bench_check.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_check_leaf_flattening_and_markers():
    bc = _bench_check()
    leaves = bc.numeric_leaves(
        {"a": {"b": 1, "skip": True}, "xs": [{"y": 2.5}, 3]}
    )
    assert leaves == {"a.b": 1.0, "xs[0].y": 2.5, "xs[1]": 3.0}
    assert bc.is_ratio_metric("regimes.n.fused_speedup")
    assert bc.is_ratio_metric("overhead.traced_over_untraced")
    assert bc.is_ratio_metric("summary.decision_accuracy")
    assert not bc.is_ratio_metric("regimes.n.xla_s")


def test_bench_check_fails_only_on_ratio_regression():
    bc = _bench_check()
    baseline = {"fused_speedup": 2.0, "xla_s": 1.0}
    # >2x ratio regression -> failure
    fails, warns = bc.compare(
        {"fused_speedup": 0.9, "xla_s": 1.0}, baseline, 0.5
    )
    assert len(fails) == 1 and "fused_speedup" in fails[0]
    # big timing drift -> warning only
    fails, warns = bc.compare(
        {"fused_speedup": 2.0, "xla_s": 5.0}, baseline, 0.5
    )
    assert fails == []
    assert any("xla_s" in w for w in warns)
    # in-band run -> clean
    fails, warns = bc.compare(
        {"fused_speedup": 1.9, "xla_s": 1.2}, baseline, 0.5
    )
    assert fails == [] and warns == []


def test_bench_check_main_and_update(tmp_path):
    bc = _bench_check()
    fresh = tmp_path / "fresh"
    base = tmp_path / "base"
    fresh.mkdir()
    (fresh / "BENCH_x.json").write_text(
        json.dumps({"speedup": 1.0, "wall_s": 2.0})
    )
    # no baseline yet: skipped, exit 0; --update seeds it
    assert bc.main(
        ["--fresh-dir", str(fresh), "--baseline-dir", str(base)]
    ) == 0
    assert bc.main(
        ["--fresh-dir", str(fresh), "--baseline-dir", str(base),
         "--update"]
    ) == 0
    assert bc.main(
        ["--fresh-dir", str(fresh), "--baseline-dir", str(base)]
    ) == 0
    # regress the ratio metric past 2x -> exit 1
    (fresh / "BENCH_x.json").write_text(
        json.dumps({"speedup": 0.4, "wall_s": 2.0})
    )
    assert bc.main(
        ["--fresh-dir", str(fresh), "--baseline-dir", str(base)]
    ) == 1
    # empty fresh dir -> usage error
    empty = tmp_path / "empty"
    empty.mkdir()
    assert bc.main(
        ["--fresh-dir", str(empty), "--baseline-dir", str(base)]
    ) == 2


# --------------------------------------------------------------------------
# traced execution end-to-end (real jax, local backend)
# --------------------------------------------------------------------------

def test_traced_compile_and_serve_records_phases(hg):
    tr = Tracer()
    # pin the fused path so the delivery-layout build span is in play
    eng = Engine(tracer=tr, delivery="pallas_fused")
    compiled = eng.compile(alg.shortest_paths_spec(hg, 0, 4))
    compiled.run_batch(np.asarray([0, 1, 2], np.int32))
    names = {s.name for s in tr.spans()}
    assert "engine.build_executable" in names
    assert "engine.execute" in names
    assert "serve.layout_build" in names or "engine.layout_build" in names
    ex_spans = [s for s in tr.spans() if s.name == "engine.execute"]
    assert ex_spans and "device_wait_s" in ex_spans[0].args
    # measured enrichment rides the traced serve path
    res = compiled.run_batch(np.asarray([3, 4], np.int32))
    assert "measured" in res.decision
    assert res.decision["measured"]["wall_s"] > 0


def test_untraced_serve_skips_measured_enrichment(hg):
    eng = Engine()
    compiled = eng.compile(alg.shortest_paths_spec(hg, 0, 4))
    res = compiled.run_batch(np.asarray([0, 1], np.int32))
    assert "measured" not in res.decision  # zero-overhead contract


# --------------------------------------------------------------------------
# registry under concurrency (the serve tier is multi-threaded)
# --------------------------------------------------------------------------

def test_registry_concurrent_registration_snapshot_and_pruning():
    """Registration, owned-instrument writes, weakref pruning, and
    snapshots racing from many threads — including a REAL ``Frontend``
    worker thread serving submits — must neither raise nor corrupt the
    snapshot (every value a snapshot reports is internally consistent)."""
    import threading

    from repro.obs.metrics import (
        MetricsRegistry,
        reset_default_registry,
        weak_provider,
    )
    from repro.serve import Frontend

    reg = reset_default_registry()
    assert isinstance(reg, MetricsRegistry)
    errors: list[BaseException] = []
    stop = threading.Event()

    def snapshotter():
        while not stop.is_set():
            try:
                snap = reg.snapshot()
                # pruning must never surface a dead provider as None
                assert all(v is not None for v in snap.values())
            except BaseException as err:  # noqa: BLE001
                errors.append(err)
                return

    def churn(k):
        # short-lived owners: their weak providers go dead mid-run and
        # must be pruned by concurrent snapshots without KeyErrors
        class Owner:
            def __init__(self, i):
                self.i = i

            def stats(self):
                return {"i": self.i}

        try:
            for i in range(300):
                o = Owner(i)
                reg.register_provider(f"churn{k}", weak_provider(o.stats))
                reg.counter(f"count{k}").inc()
                reg.gauge(f"gauge{k}").set(i)
                reg.histogram(f"hist{k}").record(1e-4 * (i + 1))
                del o
        except BaseException as err:  # noqa: BLE001
            errors.append(err)

    # a real Frontend: its ServeMetrics registers a provider into the
    # default registry and its worker thread completes futures while
    # the snapshotters race
    class Fake:
        def run_batch(self, queries, hg=None):
            import numpy as _np

            class R:
                value = {"out": _np.asarray(queries)}
                supersteps_executed = None

            return R()

    fe = Frontend(Engine(), max_batch=4, max_delay_ms=0.5)
    fe.register("sig", Fake())

    threads = [threading.Thread(target=snapshotter) for _ in range(3)]
    threads += [threading.Thread(target=churn, args=(k,)) for k in range(4)]
    with fe:
        for t in threads:
            t.start()
        futs = [fe.submit("sig", query=q) for q in range(64)]
        for f in futs:
            f.result(timeout=30)
        stop.set()
        for t in threads:
            t.join(timeout=30)
    assert not errors, errors[:3]

    gc.collect()
    snap = reg.snapshot()     # post-churn: dead churn providers pruned
    snap2 = reg.snapshot()
    assert not any(k.startswith("churn") for k in snap2)
    for k in range(4):
        assert snap[f"count{k}"] == 300
        assert snap[f"hist{k}"]["count"] == 300
    assert snap["serve.frontend"]["completed"] == 64
