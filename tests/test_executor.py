"""The Engine facade: one API, every design point agrees.

Three layers of coverage:

* in-process properties (hypothesis): the facade's local backend equals
  the raw single-device engine (``compute``), config resolution reports
  the chosen design point, representation auto-selection enforces the
  paper's constant-folding precondition, ``submit`` dispatches on spec
  type;
* the backend cost model (``select_backend``) picks ``sharded`` when the
  plan's projected sync volume beats full replication and ``replicated``
  when the cut replicates everything anyway — pure decisions, no mesh;
* a subprocess with forced host devices runs the three backends on random
  hypergraphs through ``Engine`` and asserts agreement: bit-for-bit for
  min/max monoids (label propagation), fp32 round-off only (~1 ulp,
  reduction reassociation across partitions) for sum monoids (pagerank),
  plus end-to-end ``backend="auto"`` picks on engineered plans.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algorithms import (
    label_propagation_spec,
    pagerank_spec,
    vertex_pagerank_spec,
)
from repro.algorithms.graph_pagerank import graph_pagerank
from repro.core import (
    Engine,
    ExecutionConfig,
    compute,
    select_backend,
    select_representation,
    to_graph,
)
from repro.data import powerlaw_hypergraph
from repro.partition import partition
from repro.partition.base import build_plan

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


@st.composite
def small_hypergraph(draw):
    nv = draw(st.integers(5, 40))
    ne = draw(st.integers(2, 30))
    seed = draw(st.integers(0, 1000))
    return powerlaw_hypergraph(nv, ne, mean_cardinality=3, seed=seed)


# --------------------------------------------------------------------------
# local backend == the raw single-device engine (facade plumbing)
# --------------------------------------------------------------------------

def _raw_compute(spec):
    """The pre-facade execution: ``compute`` + the spec's extract."""
    out = compute(
        spec.hg0,
        max_iters=spec.max_iters,
        initial_msg=spec.initial_msg,
        v_program=spec.v_program,
        he_program=spec.he_program,
    )
    return spec.extract(out)


@given(small_hypergraph(), st.integers(2, 8))
def test_engine_local_matches_raw_compute(hg, iters):
    spec = pagerank_spec(hg, iters=iters)
    res = Engine(backend="local").run(spec)
    raw = _raw_compute(spec)
    for a, b in zip(res.value, raw):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert res.backend == "local"
    assert res.representation == "bipartite"


@given(small_hypergraph())
def test_engine_jit_matches_eager(hg):
    spec = label_propagation_spec(hg, iters=6)
    eager = Engine(backend="local", jit=False).run(spec).value
    jitted = Engine(backend="local", jit=True).run(spec).value
    for a, b in zip(eager, jitted):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_legacy_entry_points_removed():
    """PR-1 migration is finished: the deprecated shims are gone."""
    with pytest.raises(ImportError):
        from repro.algorithms import run_local  # noqa: F401
    with pytest.raises(ImportError):
        from repro.algorithms import run_distributed  # noqa: F401


def test_submit_dispatches_on_spec_type():
    """Engine.submit is THE entry point: AlgorithmSpec -> iterative run,
    AnalyticsSpec -> batch analytics, anything else -> TypeError."""
    from repro.core import AnalyticsSpec

    hg = powerlaw_hypergraph(20, 12, seed=1)
    run_res = Engine().submit(pagerank_spec(hg, iters=3))
    assert run_res.backend == "local"
    ana_res = Engine().submit(AnalyticsSpec(hg))
    assert ana_res.kernel in ("bitset", "merge")
    with pytest.raises(TypeError, match="AlgorithmSpec or AnalyticsSpec"):
        Engine().submit(hg)


# --------------------------------------------------------------------------
# config resolution / result reporting
# --------------------------------------------------------------------------

def test_result_reports_resolved_config_and_stats():
    hg = powerlaw_hypergraph(20, 12, seed=1)
    res = Engine().run(
        pagerank_spec(hg, iters=9), collect_stats=True, max_iters=4
    )
    assert res.config.representation == "bipartite"
    assert res.config.backend == "local"
    assert res.config.max_iters == 4
    v_act, he_act = res.superstep_stats
    assert v_act.shape == (4,) and he_act.shape == (4,)
    assert int(v_act[0]) == hg.n_vertices  # pagerank never deactivates


def test_invalid_config_rejected():
    with pytest.raises(ValueError, match="representation"):
        ExecutionConfig(representation="adjacency")
    with pytest.raises(ValueError, match="backend"):
        ExecutionConfig(backend="tpu")
    hg = powerlaw_hypergraph(10, 6, seed=0)
    with pytest.raises(ValueError, match="mesh"):
        Engine(backend="sharded").run(pagerank_spec(hg, iters=2))


# --------------------------------------------------------------------------
# representation selection (the paper's constant-folding precondition)
# --------------------------------------------------------------------------

@given(small_hypergraph())
def test_auto_refuses_clique_for_hyperedge_state_specs(hg):
    """Specs that touch hyperedge state must never constant-fold, no
    matter how cheap the expansion is (MESH §IV-A1)."""
    spec = pagerank_spec(hg, iters=4)  # extracts hyperedge ranks
    rep, why = select_representation(spec, hg, edge_budget=1e9)
    assert rep == "bipartite"
    assert why["touches_hyperedge_state"] is True
    res = Engine(representation="auto").run(spec)
    assert res.representation == "bipartite"


@given(small_hypergraph())
def test_explicit_clique_raises_for_hyperedge_state_specs(hg):
    with pytest.raises(ValueError, match="hyperedge state"):
        Engine(representation="clique").run(pagerank_spec(hg, iters=4))


def test_auto_picks_clique_when_cheap_and_legal():
    # Fig. 1's expansion (16 directed edges) is within the default budget
    # of its 11 incidences; powerlaw regimes blow past it (test below).
    from repro.core import HyperGraph

    hg = HyperGraph.from_hyperedge_lists(
        [[0, 1], [0, 1, 2, 3], [0, 3, 4], [2, 3]], n_vertices=5
    )
    spec = vertex_pagerank_spec(hg, iters=8)
    res = Engine(representation="auto").run(spec)
    assert res.representation == "clique"
    expect = graph_pagerank(to_graph(hg), iters=8)
    np.testing.assert_allclose(
        np.asarray(res.value), np.asarray(expect), rtol=1e-6
    )


def test_explicit_bipartite_pins_raw_compute_numbers():
    """representation='bipartite' must reproduce the raw bipartite
    ``compute`` numbers even for specs the auto-selector would
    constant-fold (clique is a *different* design point numerically)."""
    from repro.core import HyperGraph

    hg = HyperGraph.from_hyperedge_lists(
        [[0, 1], [0, 1, 2, 3], [0, 3, 4], [2, 3]], n_vertices=5
    )
    spec = vertex_pagerank_spec(hg, iters=10)
    raw = _raw_compute(spec)
    bipartite = Engine(representation="bipartite").run(spec).value
    assert np.array_equal(np.asarray(raw), np.asarray(bipartite))


def test_explicit_requests_beat_clique_auto_selection():
    """Explicit distributed backend or max_iters override pins bipartite
    (auto) or raises (explicit clique) — never silently dropped."""
    from repro.core import HyperGraph

    hg = HyperGraph.from_hyperedge_lists(
        [[0, 1], [0, 1, 2, 3], [0, 3, 4], [2, 3]], n_vertices=5
    )
    spec = vertex_pagerank_spec(hg, iters=6)
    # auto would pick clique (see test above); an explicit distributed
    # backend forces bipartite resolution first...
    rep, why = Engine(backend="replicated")._resolve_representation(
        spec, ExecutionConfig(backend="replicated")
    )
    assert rep == "bipartite"
    # ...and still fails loudly on the missing mesh, instead of quietly
    # running the clique program locally.
    with pytest.raises(ValueError, match="mesh"):
        Engine(backend="replicated").run(spec)
    with pytest.raises(ValueError, match="cannot honor"):
        Engine(representation="clique", backend="sharded").run(spec)
    with pytest.raises(ValueError, match="max_iters"):
        Engine(representation="clique").run(spec, max_iters=3)
    # explicit clique + a mesh: loud conflict, not a silent local run.
    import jax
    from jax.sharding import Mesh

    mesh1 = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    with pytest.raises(ValueError, match="mesh"):
        Engine(mesh=mesh1, representation="clique").run(spec)
    # auto + mesh: bipartite (distributed intent), never clique.
    rep, why = Engine(mesh=mesh1)._resolve_representation(
        spec, ExecutionConfig()
    )
    assert rep == "bipartite" and "mesh" in why["reason"]
    # max_iters override + auto: honored, on bipartite.
    res = Engine().run(spec, max_iters=3)
    assert res.representation == "bipartite"
    assert res.config.max_iters == 3


def test_auto_falls_back_to_bipartite_when_expansion_blows_up():
    # One giant hyperedge -> quadratic expansion; budget forces bipartite.
    hg = powerlaw_hypergraph(
        200, 40, mean_cardinality=8, max_cardinality=150, seed=2
    )
    spec = vertex_pagerank_spec(hg, iters=4)
    rep, why = select_representation(spec, hg, edge_budget=1.0)
    assert rep == "bipartite"
    assert why["clique_edges"] > why["bipartite_edges"]


# --------------------------------------------------------------------------
# backend cost model: sync_bytes_per_dim decides replicated vs sharded
# --------------------------------------------------------------------------

def _full_replication_plan(n: int = 8, p: int = 8):
    """Complete bipartite incidence spread so every entity is replicated
    on every partition — the cut buys nothing over full replication."""
    src, dst = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    src, dst = src.ravel().astype(np.int32), dst.ravel().astype(np.int32)
    edge_part = ((src + dst) % p).astype(np.int32)
    return build_plan("adversarial", src, dst, n, n, edge_part, p)


def test_auto_backend_picks_sharded_when_sync_favors_it():
    """The acceptance check: a well-cut plan's projected sync volume is
    far below the full-replication bound, so auto picks sharded."""
    hg = powerlaw_hypergraph(60, 40, mean_cardinality=4, seed=3)
    plan = partition("random_hyperedge_cut", hg, 4)  # vertices whole
    backend, why = select_backend(plan, hg.n_vertices, hg.n_hyperedges)
    assert backend == "sharded"
    assert (
        why["sync_bytes_per_dim"]
        < 0.5 * why["full_replication_sync_bytes"]
    )


def test_auto_backend_picks_replicated_when_cut_replicates_everything():
    plan = _full_replication_plan()
    backend, why = select_backend(plan, 8, 8)
    assert backend == "replicated"
    assert (
        why["sync_bytes_per_dim"]
        >= 0.5 * why["full_replication_sync_bytes"]
    )


def test_single_partition_prefers_replicated():
    hg = powerlaw_hypergraph(20, 12, seed=0)
    plan = partition("random_vertex_cut", hg, 1)
    backend, _ = select_backend(plan, hg.n_vertices, hg.n_hyperedges)
    assert backend == "replicated"


def _hyperedge_replicating_plan(nv=80, ne=8, p=4):
    """Every hyperedge spans all partitions (he_extra = (p-1)*ne);
    every vertex lives on exactly one (v_extra = 0)."""
    members_per_he = p
    src = np.arange(ne * members_per_he, dtype=np.int32) % nv
    dst = np.repeat(np.arange(ne, dtype=np.int32), members_per_he)
    edge_part = (np.arange(ne * members_per_he) % p).astype(np.int32)
    return build_plan("he_replicating", src, dst, nv, ne, edge_part, p)


def test_select_backend_folds_state_width_in():
    """ROADMAP open item: bytes/dim must NOT cancel out — a wide
    hyperedge state makes the hyperedge-replicating cut pay for every
    replica, flipping the decision replicated-wards while a scalar
    state stays sharded."""
    plan = _hyperedge_replicating_plan()
    assert plan.stats.v_extra_replicas == 0.0
    assert plan.stats.he_extra_replicas == 3 * 8  # (p-1) * ne

    narrow, why_n = select_backend(plan, 80, 8)
    assert narrow == "sharded"
    wide, why_w = select_backend(plan, 80, 8, he_state_bytes=256.0)
    assert wide == "replicated"
    # the widths are visible in the decision record
    assert why_w["he_state_bytes"] == 256.0
    assert why_w["sharded_sync_bytes"] > why_n["sharded_sync_bytes"]


def test_state_width_bytes_measures_pytrees():
    import jax.numpy as jnp
    from repro.core.executor import state_width_bytes

    assert state_width_bytes(None, 10) == 4.0  # no state: one f32 dim
    assert state_width_bytes(jnp.zeros((10,), jnp.float32), 10) == 4.0
    assert state_width_bytes(jnp.zeros((10, 64), jnp.float32), 10) == 256.0
    tree = {"a": jnp.zeros((10, 2), jnp.float32),
            "b": jnp.zeros((10,), jnp.int32)}
    assert state_width_bytes(tree, 10) == 12.0


def test_engine_passes_state_widths_to_backend_decision():
    """The resolved decision must carry the spec's measured widths (the
    seam select_backend consumes)."""
    hg = powerlaw_hypergraph(60, 40, mean_cardinality=4, seed=3)
    spec = pagerank_spec(hg, iters=2)
    from repro.core.executor import state_width_bytes

    v_w = state_width_bytes(spec.hg0.v_attr, hg.n_vertices)
    he_w = state_width_bytes(spec.hg0.he_attr, hg.n_hyperedges)
    plan = partition("random_hyperedge_cut", hg, 4)
    _, why = select_backend(
        plan, hg.n_vertices, hg.n_hyperedges,
        v_state_bytes=v_w, he_state_bytes=he_w,
    )
    assert why["v_state_bytes"] == v_w
    assert why["he_state_bytes"] == he_w


# --------------------------------------------------------------------------
# three backends agree (subprocess: needs forced host devices)
# --------------------------------------------------------------------------

BACKEND_AGREEMENT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import Engine
    from repro.data import powerlaw_hypergraph
    from repro.partition import partition
    from repro.algorithms import pagerank_spec, label_propagation_spec

    mesh = Mesh(np.array(jax.devices()).reshape(4), ('data',))
    # odd sizes: state padding slots exist, so the activity stats must
    # prove they exclude them.
    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    plan = partition('random_vertex_cut', hg, 4)
    from repro.algorithms import shortest_paths_spec
    specs = [(label_propagation_spec(hg, 6), True),
             (pagerank_spec(hg, 6), False),
             # dynamic activation + halting: the stats trace actually
             # varies per superstep (and the min monoid exercises the
             # all_to_all reduce-scatter on the sharded backend).
             (shortest_paths_spec(hg, 0, 8), True)]
    for spec, exact in specs:
        ref = Engine(backend='local').run(spec, collect_stats=True)
        for backend in ('replicated', 'sharded'):
            got = Engine(plan=plan, mesh=mesh, backend=backend).run(
                spec, collect_stats=True)
            for a, b in zip(ref.value, got.value):
                a, b = np.asarray(a), np.asarray(b)
                if exact:
                    assert np.array_equal(a, b), (spec.name, backend)
                else:
                    # sum monoid: partition partials reassociate fp32
                    # adds -> round-off only, everything else exact.
                    np.testing.assert_allclose(
                        a, b, rtol=2e-6, atol=1e-7,
                        err_msg=f'{spec.name} {backend}')
            # distributed superstep stats == local, bit for bit (the
            # shard_map out_specs threading).
            for r, g in zip(ref.superstep_stats, got.superstep_stats):
                assert np.array_equal(np.asarray(r), np.asarray(g)), (
                    spec.name, backend, r, g)

    # batch analytics: the sharded backend (pair blocks tiled across
    # the mesh) equals the local census bitwise.
    from repro.core import AnalyticsSpec
    aspec = AnalyticsSpec(hg)
    a_local = Engine().analyze(aspec)
    a_shard = Engine(mesh=mesh).analyze(aspec)
    assert a_shard.backend == 'sharded', a_shard.backend
    assert np.array_equal(a_local.value.counts, a_shard.value.counts)

    # end-to-end auto decision through Engine.run: same plan + iters as
    # the sharded run above, so the compile cache is warm and the only
    # new work is the decision itself.
    res = Engine(plan=plan, mesh=mesh, backend='auto').run(
        label_propagation_spec(hg, 6))
    assert res.backend == 'sharded', res.backend
    assert res.decision['backend']['sync_bytes_per_dim'] < 0.5 * (
        res.decision['backend']['full_replication_sync_bytes'])

    # the adversarial fully-replicating cut flips the decision; assert
    # via Engine.resolve (no execution needed).
    from repro.partition.base import build_plan
    from repro.core import HyperGraph
    src, dst = np.meshgrid(np.arange(8), np.arange(8), indexing='ij')
    src, dst = src.ravel().astype(np.int32), dst.ravel().astype(np.int32)
    adv = build_plan('adversarial', src, dst, 8, 8,
                     ((src + dst) % 4).astype(np.int32), 4)
    dense = HyperGraph.from_coo(src, dst, 8, 8)
    resolved, _, why = Engine(plan=adv, mesh=mesh, backend='auto').resolve(
        label_propagation_spec(dense, 4))
    assert resolved.backend == 'replicated', resolved.backend
    print('BACKENDS_AGREE')
""")


def test_three_backends_agree_subprocess():
    # Inherit the full environment (dropping JAX_PLATFORMS in particular
    # makes jax probe for accelerator platforms — minutes of stall).
    proc = subprocess.run(
        [sys.executable, "-c", BACKEND_AGREEMENT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "BACKENDS_AGREE" in proc.stdout
