"""The static-analysis suite checks the checkers.

Positive direction: the four passes run clean on the repo as committed
(that is CI's job — here we pin the machinery).  Negative direction
(the acceptance bar): every pass must catch a deliberately injected
violation —

* lint rules on synthetic sources (traced-cond, host-sync with hot-path
  classification, static-arg-array, tracer-gate), plus suppression and
  baseline-diff semantics;
* the retrace sentinel raising ``RetraceError`` on a forced compile
  (and staying quiet on the warm path), including the ``serve.warm``
  runtime guard;
* the digest audit flagging an injected collision and an injected
  identity leak;
* the shape audit flagging an injected lowering disagreement, and the
  VMEM model rejecting the worst-geometry wide-row tile (the ROADMAP
  D>8 caveat, now a checked constraint).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.analysis import (
    Finding,
    RetraceError,
    assert_no_retrace,
    diff_baseline,
    lint_file,
)


def _lint_source(tmp_path, source, rel="pkg/mod.py"):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return lint_file(path, root=tmp_path)


# --------------------------------------------------------------------------
# lint rules on synthetic sources
# --------------------------------------------------------------------------

def test_traced_cond_flags_if_and_while_in_traced_regions(tmp_path):
    found = _lint_source(tmp_path, """
        import functools
        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("flag",))
        def f(x, flag=True):
            y = jnp.abs(x)
            if y > 0:            # traced -> finding
                return y
            if flag:             # static arg -> fine
                return -y
            return x

        def g(x):
            while x < 3:         # traced via the jit call below
                x = x + 1
            return x

        jax.jit(g)(1)

        def cold(x):
            if x > 0:            # not a traced region
                return x
            return -x
    """)
    rules = [(f.rule, f.scope) for f in found
             if f.classification == "finding"]
    assert ("traced-cond", "f") in rules
    assert ("traced-cond", "g") in rules
    assert not any(s == "cold" for _, s in rules)


def test_traced_cond_skips_static_tests(tmp_path):
    found = _lint_source(tmp_path, """
        import jax
        import jax.numpy as jnp

        def body(carry, x):
            a, b = carry
            if a is None:              # identity test: fine
                a = x
            if isinstance(b, tuple):   # static predicate: fine
                b = b[0]
            if x.shape[0] > 2:         # shape read: fine
                pass
            return (a, b), x

        jax.lax.scan(body, (None, 0), jnp.arange(3))
    """)
    assert not [f for f in found if f.rule == "traced-cond"]


def test_host_sync_classifies_hot_guarded_cold(tmp_path):
    # the file's suffix places it on the serve hot-path inventory
    found = _lint_source(tmp_path, """
        import numpy as np

        def _stack(queries, tracer=None):
            rows = [np.asarray(q) for q in queries]     # hot finding
            if tracer is not None:
                tracer.note(float(rows[0].sum()))       # guarded
            return rows

        def boot_helper(x):
            return np.asarray(x)                        # cold path
    """, rel="serve/frontend.py")
    by = {(f.scope, f.classification) for f in found
          if f.rule == "host-sync"}
    assert ("_stack", "finding") in by
    assert ("_stack", "guarded") in by
    assert ("boot_helper", "cold-path") in by


def test_host_sync_early_tracer_return_guards_rest_of_function(tmp_path):
    found = _lint_source(tmp_path, """
        import numpy as np

        def _block(value, tracer):
            if tracer is None:
                return value
            return np.asarray(value)    # only runs traced: guarded
    """, rel="serve/frontend.py")
    syncs = [f for f in found if f.rule == "host-sync"]
    assert [f.classification for f in syncs] == ["guarded"]


def test_static_arg_array_default_and_call_site(tmp_path):
    found = _lint_source(tmp_path, """
        import functools
        import jax
        import numpy as np

        @functools.partial(jax.jit, static_argnames=("w",))
        def f(x, w=np.asarray([1.0])):   # default -> finding
            return x * w

        def g(x, w):
            return x * w

        jax.jit(g, static_argnames=("w",))
        g(w=np.asarray([2.0]))           # call site -> finding
        g(w=1.0)                         # scalar: hashable, fine
    """)
    hits = [f for f in found if f.rule == "static-arg-array"]
    assert len(hits) == 2
    assert {f.scope for f in hits} == {"f", "<module>"}


def test_tracer_gate_requires_none_branch(tmp_path):
    found = _lint_source(tmp_path, """
        def bad(x, tracer=None):
            with tracer.span("a"):
                return x

        def good(x, tracer=None):
            if tracer is None:
                return x
            with tracer.span("a"):
                return x

        def also_good(x, tracer=None):
            from repro.obs import maybe_span
            with maybe_span(tracer, "a"):
                return x
    """)
    gates = [f.scope for f in found if f.rule == "tracer-gate"]
    assert gates == ["bad"]


def test_swallowed_error_hot_routed_narrow_and_cold(tmp_path):
    found = _lint_source(tmp_path, """
        def _stack(reqs):
            try:
                work()
            except Exception:            # hot + discarded -> finding
                pass

        def _unstack(reqs):
            try:
                work()
            except Exception as err:     # routed: error reaches a future
                reqs[0].future.set_exception(err)

        def _block(value):
            try:
                work()
            except ValueError:           # narrow: names the real failure
                pass
            try:
                work()
            except Exception:            # routed: re-raised
                raise

        def boot_helper(x):
            try:
                work()
            except:                      # bare, but off the hot path
                pass
    """, rel="serve/frontend.py")
    hits = {(f.scope, f.classification) for f in found
            if f.rule == "swallowed-error"}
    assert hits == {("_stack", "finding"), ("boot_helper", "cold-path")}


def test_inline_suppression_same_line_and_block_above(tmp_path):
    found = _lint_source(tmp_path, """
        import numpy as np

        def _stack(x):
            a = np.asarray(x)  # analysis: ignore[host-sync]
            # analysis: ignore[host-sync] — rationale text here,
            # continuing onto a second comment line
            b = np.asarray(x)
            c = np.asarray(x)  # analysis: ignore[traced-cond] wrong rule
            return a, b, c
    """, rel="serve/frontend.py")
    syncs = {f.line: f.classification for f in found
             if f.rule == "host-sync"}
    assert sorted(syncs.values()) == ["finding", "suppressed",
                                      "suppressed"]


def test_baseline_diff_budgets_counts_and_reports_stale():
    f1 = Finding("host-sync", "a.py", 3, "f", "m")
    f2 = Finding("host-sync", "a.py", 9, "f", "m2")
    f3 = Finding("traced-cond", "b.py", 1, "g", "m3")
    baseline = {"host-sync:a.py:f": 1, "retrace:gone.py:h": 1}
    fresh, stale = diff_baseline([f1, f2, f3], baseline)
    # one host-sync covered by the budget, the second resurfaces
    assert [f.message for f in fresh] == ["m2", "m3"]
    assert stale == ["retrace:gone.py:h"]


def test_repo_lint_is_clean_and_inventory_classified():
    """The committed tree has NO unsuppressed hot-path findings, and the
    host-sync inventory is fully classified (the ISSUE's ~83+ sites all
    land in a bucket)."""
    from repro.analysis.lint import lint_tree

    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    found = lint_tree(root)
    fresh = [f for f in found if f.classification == "finding"]
    assert fresh == [], [f.format(explain=False) for f in fresh]
    sync = [f for f in found if f.rule == "host-sync"]
    assert len(sync) > 80
    assert {f.classification for f in sync} <= {
        "cold-path", "guarded", "suppressed"
    }


# --------------------------------------------------------------------------
# retrace sentinel
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def compiled_pair():
    from repro.algorithms import shortest_paths_spec
    from repro.core import Engine
    from repro.data import powerlaw_hypergraph

    hg = powerlaw_hypergraph(30, 20, mean_cardinality=3, seed=0)
    eng = Engine()
    compiled = eng.compile(shortest_paths_spec(hg, 0, 6))
    compiled.run()
    return eng, compiled


def test_sentinel_quiet_on_warm_path(compiled_pair):
    eng, compiled = compiled_pair
    with assert_no_retrace(eng) as delta:
        compiled.run(query=3)
        assert delta() == 0


def test_sentinel_raises_on_forced_retrace(compiled_pair):
    eng, compiled = compiled_pair
    with pytest.raises(RetraceError, match="design-point change"):
        with assert_no_retrace(eng, label="design-point change"):
            # a new design point misses the cache -> compiles
            eng.compile(compiled.spec, collect_stats=True).run()


def test_sentinel_allow_budget(compiled_pair):
    eng, compiled = compiled_pair
    with assert_no_retrace(eng, allow=1):
        eng.compile(compiled.spec, max_iters=3).run()


def test_warm_runtime_guard_raises_without_disk_store():
    from repro.algorithms import shortest_paths_spec
    from repro.core import Engine
    from repro.data import powerlaw_hypergraph
    from repro.serve import warm

    hg = powerlaw_hypergraph(30, 20, mean_cardinality=3, seed=0)
    with pytest.raises(RetraceError, match="serve.warm"):
        warm(Engine(), [shortest_paths_spec(hg, 0, 6)],
             require_no_retrace=True)


# --------------------------------------------------------------------------
# digest audit
# --------------------------------------------------------------------------

def test_digest_audit_clean_in_process():
    from repro.analysis.digest import audit

    assert audit(cross_process=False) == []


def test_digest_audit_catches_injected_collision():
    from repro.analysis.digest import audit

    found = audit(digest_fn=lambda key: "constant", cross_process=False)
    assert any(f.rule == "digest-collision" for f in found)


def test_digest_audit_catches_identity_leak():
    from repro.analysis.digest import audit
    from repro.serve.cache import stable_digest

    # id() varies between the two in-process grid builds: the exact
    # failure mode of hashing an object by repr/address
    found = audit(digest_fn=lambda key: stable_digest((id(key), )),
                  cross_process=False)
    assert any(f.rule == "digest-identity" for f in found)


@pytest.mark.slow
def test_digest_stable_across_process_boundary():
    """The cross-process half, against a REAL child interpreter with
    randomized hashing — the regression the disk cache depends on."""
    from repro.analysis.digest import grid_digests

    here = grid_digests()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ, "PYTHONPATH": src, "PYTHONHASHSEED": "random"}
    out = subprocess.run(
        [sys.executable, "-c",
         "import json, sys; from repro.analysis.digest import "
         "grid_digests; json.dump(grid_digests(), sys.stdout)"],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-500:]
    assert json.loads(out.stdout) == here


# --------------------------------------------------------------------------
# shape agreement + VMEM budget
# --------------------------------------------------------------------------

def test_shape_agreement_clean():
    from repro.analysis.shapes import check_shapes

    assert check_shapes() == []


def test_shape_audit_catches_injected_lowering_disagreement():
    from repro.analysis.shapes import check_shapes
    from repro.kernels.deliver import _pallas_leaf

    def wrong_dtype(m, layout, monoid, active):
        out = _pallas_leaf(m, layout, monoid, active, interpret=True)
        return out.astype(np.int8)         # dtype drift

    found = check_shapes(fused_leaf=wrong_dtype, widths=(1,),
                         monoids=("min",))
    assert found and all(f.rule == "shape-mismatch" for f in found)

    def wrong_shape(m, layout, monoid, active):
        out = _pallas_leaf(m, layout, monoid, active, interpret=True)
        return out[:-1]                    # drops a destination row

    found = check_shapes(fused_leaf=wrong_shape, widths=(1,),
                         monoids=("min",))
    assert found and all(f.rule == "shape-mismatch" for f in found)


def test_vmem_model_passes_auto_selectable_widths():
    from repro.analysis.shapes import check_width_gate, shape_vmem_audit

    assert check_width_gate() == []
    assert shape_vmem_audit() == []


def test_vmem_model_rejects_wide_rows_at_worst_geometry():
    """The ROADMAP 'VMEM-check [block_n, block_e, D] at D > 8' caveat as
    a checked constraint: D=32 fp32 on the hub-class tile cap violates
    the 16 MiB budget; D=16 (the widest the auto path selects) fits."""
    import types

    from repro.analysis.shapes import check_vmem, check_width_gate

    hub = types.SimpleNamespace(
        class_block_e=(1024,), block_n=128, n_src=4096,
    )
    assert check_vmem(hub, 16, 4) == []
    bad = check_vmem(hub, 32, 4)
    assert bad and bad[0].rule == "vmem-budget"
    assert "16 MiB" in bad[0].message
    # a hypothetical wider auto gate would be caught by the gate check
    assert check_width_gate(width_budget_bytes=256.0) != []


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_cli_lint_pass_exits_clean_and_explains(capsys):
    from repro.analysis.__main__ import main

    rc = main(["--passes", "lint"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "OK: no new findings vs baseline" in out


def test_cli_reports_new_finding_with_rationale(tmp_path, capsys):
    """A repo-shaped tree with an injected violation exits 1 and prints
    the clickable ``file:line: [rule]`` + rationale format."""
    from repro.analysis.__main__ import main

    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(textwrap.dedent("""
        import jax
        import jax.numpy as jnp

        def f(x):
            y = jnp.abs(x)
            if y > 0:
                return y
            return x

        jax.jit(f)(1.0)
    """))
    (tmp_path / "pyproject.toml").write_text("")
    rc = main(["--passes", "lint", "--root", str(tmp_path),
               "--baseline", "baseline.json"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "bad.py:7: [traced-cond]" in out
    assert "why: " in out
