"""Fault tolerance: checkpoint round-trip, corruption detection,
bit-exact resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.train import synthetic_batch
from repro.models.transformer import init_params, loss_fn
from repro.train import (
    AdamWConfig,
    init_train_state,
    latest_checkpoint,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)


@pytest.fixture()
def setup(tmp_path):
    spec = get_config("llama3.2-1b", smoke=True)
    cfg = spec.model
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    step = jax.jit(make_train_step(
        lambda p, b: loss_fn(p, cfg, b), AdamWConfig(total_steps=20)
    ))
    return cfg, state, step, str(tmp_path)


def _trees_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_roundtrip_bit_exact(setup):
    cfg, state, step, d = setup
    path = save_checkpoint(d, 3, state)
    restored, s = restore_checkpoint(path, state)
    assert s == 3
    assert _trees_equal(state, restored)


def test_latest_checkpoint_ordering(setup):
    cfg, state, step, d = setup
    save_checkpoint(d, 1, state)
    save_checkpoint(d, 12, state)
    save_checkpoint(d, 3, state)
    assert latest_checkpoint(d).endswith("step_00000012")


def test_corruption_detected(setup):
    cfg, state, step, d = setup
    path = save_checkpoint(d, 1, state)
    victim = os.path.join(path, "leaf_00000.npy")
    arr = np.load(victim)
    arr_flat = arr.reshape(-1)
    arr_flat[0] = arr_flat[0] + 1.0 if arr.dtype.kind == "f" else 1
    np.save(victim, arr)
    with pytest.raises(IOError, match="corrupt"):
        restore_checkpoint(path, state)


def test_shape_mismatch_rejected(setup):
    cfg, state, step, d = setup
    path = save_checkpoint(d, 1, state)
    bad_template = jax.tree.map(
        lambda x: jnp.zeros(x.shape + (1,), x.dtype), state
    )
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(path, bad_template, verify=False)


def test_resume_is_bit_exact(setup):
    """Crash/restart at step 2 of 4 reproduces the uninterrupted run —
    the deterministic data pipeline + checkpoint contract."""
    cfg, state0, step, d = setup

    def run(state, lo, hi):
        for i in range(lo, hi):
            batch = synthetic_batch(cfg.vocab, 2, 16, i)
            state, _ = step(state, batch)
        return state

    straight = run(state0, 0, 4)

    half = run(state0, 0, 2)
    path = save_checkpoint(d, 2, half)
    recovered, s = restore_checkpoint(path, half)
    resumed = run(recovered, s, 4)
    assert _trees_equal(straight, resumed)


def test_atomic_write_no_partial(setup, tmp_path):
    cfg, state, step, d = setup
    # a .tmp directory must never be picked up as a checkpoint
    os.makedirs(os.path.join(d, "step_00000099.tmp"), exist_ok=True)
    save_checkpoint(d, 5, state)
    assert latest_checkpoint(d).endswith("step_00000005")
