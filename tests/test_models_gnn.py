"""GNN smoke + equivariance property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.gnn import GraphBatch, random_graph
from repro.models.gnn import equivariant, gat, pna
from repro.models.gnn.irreps import (
    _random_rotation,
    allowed_paths,
    real_cg,
    sph_harm_np,
    wigner_d_np,
)
from repro.train import AdamWConfig, init_train_state, make_train_step


def test_cg_paths_are_equivariant():
    rng = np.random.default_rng(5)
    for (l1, l2, l3) in allowed_paths(2):
        rot = _random_rotation(rng)
        d1, d2, d3 = (wigner_d_np(l, rot) for l in (l1, l2, l3))
        c = real_cg(l1, l2, l3)
        a = rng.standard_normal(2 * l1 + 1)
        b = rng.standard_normal(2 * l2 + 1)
        out1 = np.einsum("ijk,i,j->k", c, d1 @ a, d2 @ b)
        out2 = d3 @ np.einsum("ijk,i,j->k", c, a, b)
        np.testing.assert_allclose(out1, out2, atol=1e-8)


def test_cg_1_1_1_is_cross_product():
    c = real_cg(1, 1, 1)
    # antisymmetric part only — the cross-product intertwiner that
    # sphere-quadrature Gaunt coefficients would miss entirely.
    np.testing.assert_allclose(c, -np.transpose(c, (1, 0, 2)), atol=1e-8)


def test_wigner_d_consistency():
    rng = np.random.default_rng(2)
    pts = rng.standard_normal((12, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    rot = _random_rotation(rng)
    for l in range(4):
        d = wigner_d_np(l, rot)
        np.testing.assert_allclose(
            sph_harm_np(l, pts @ rot.T), sph_harm_np(l, pts) @ d.T,
            atol=1e-8,
        )
        # D is orthogonal (real irrep)
        np.testing.assert_allclose(d @ d.T, np.eye(2 * l + 1), atol=1e-8)


@pytest.mark.parametrize("arch", ["nequip", "mace"])
def test_energy_is_e3_invariant(arch):
    """E(R x + t) == E(x): rotations + translations leave energies
    unchanged (forces are then equivariant by construction)."""
    spec = get_config(arch, smoke=True)
    cfg = spec.model
    g = random_graph(24, 80, with_positions=True,
                     n_species=cfg.n_species, seed=3)
    params = equivariant.init_params(jax.random.PRNGKey(0), cfg)
    e0 = equivariant.forward(params, cfg, g)
    rng = np.random.default_rng(4)
    rot = jnp.asarray(_random_rotation(rng), jnp.float32)
    t = jnp.asarray(rng.standard_normal(3), jnp.float32)
    g2 = dataclasses.replace(g, positions=g.positions @ rot.T + t)
    e1 = equivariant.forward(params, cfg, g2)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               rtol=2e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["nequip", "mace"])
def test_equivariant_train_step(arch):
    spec = get_config(arch, smoke=True)
    cfg = spec.model
    g = random_graph(20, 60, with_positions=True,
                     n_species=cfg.n_species, seed=1)
    g = dataclasses.replace(g, labels=jnp.zeros((1,), jnp.float32))
    params = equivariant.init_params(jax.random.PRNGKey(0), cfg)
    step = make_train_step(
        lambda p, b: equivariant.loss_fn(p, cfg, b),
        AdamWConfig(lr=1e-3, total_steps=10),
    )
    state = init_train_state(params)
    losses = []
    for _ in range(3):
        state, m = jax.jit(step)(state, g)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]  # MSE to zero target decreases


def test_forces_rotate_with_input():
    spec = get_config("nequip", smoke=True)
    cfg = spec.model
    g = random_graph(16, 40, with_positions=True,
                     n_species=cfg.n_species, seed=6)
    params = equivariant.init_params(jax.random.PRNGKey(0), cfg)
    f0 = equivariant.forces(params, cfg, g)
    rng = np.random.default_rng(8)
    rot = jnp.asarray(_random_rotation(rng), jnp.float32)
    g2 = dataclasses.replace(g, positions=g.positions @ rot.T)
    f1 = equivariant.forces(params, cfg, g2)
    np.testing.assert_allclose(
        np.asarray(f1), np.asarray(f0 @ rot.T), rtol=2e-2, atol=2e-3
    )


@pytest.mark.parametrize("arch", ["gat-cora", "pna"])
def test_message_passing_smoke(arch):
    spec = get_config(arch, smoke=True)
    cfg = spec.model
    mod = gat if arch == "gat-cora" else pna
    g = random_graph(30, 90, d_feat=cfg.d_in, n_classes=cfg.n_classes,
                     seed=2)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    out = mod.forward(params, cfg, g)
    assert out.shape == (30, cfg.n_classes)
    assert not bool(jnp.isnan(out).any())
    step = make_train_step(
        lambda p, b: mod.loss_fn(p, cfg, b),
        AdamWConfig(lr=1e-2, total_steps=10),
    )
    state = init_train_state(params)
    l0 = None
    for _ in range(4):
        state, m = jax.jit(step)(state, g)
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0


def test_edge_mask_kills_messages():
    """Fully-masked edge sets must be interchangeable: the output cannot
    depend on WHICH dead edges exist (padding invariance)."""
    spec = get_config("pna", smoke=True)
    cfg = spec.model
    g1 = random_graph(10, 20, d_feat=cfg.d_in, seed=0)
    g2 = random_graph(10, 20, d_feat=cfg.d_in, seed=99)
    params = pna.init_params(jax.random.PRNGKey(0), cfg)
    dead1 = dataclasses.replace(
        g1, edge_mask=jnp.zeros_like(g1.edge_mask)
    )
    dead2 = dataclasses.replace(
        g1, edge_src=g2.edge_src, edge_dst=g2.edge_dst,
        edge_mask=jnp.zeros_like(g1.edge_mask),
    )
    out1 = pna.forward(params, cfg, dead1)
    out2 = pna.forward(params, cfg, dead2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
