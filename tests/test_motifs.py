"""The batch analytics subsystem: intersection kernels + h-motif census.

Four layers of coverage:

* the h-motif class tables: exactly 26 classes (Lee et al. 2020),
  permutation-invariant classification;
* kernel correctness: both intersection paths (bitset / merge) against
  a python-set oracle, pairs and triples, property-tested;
* the census: exact census cross-checked **bitwise** against an
  O(E^3)-over-pairs brute-force reference on ≤ 64-hyperedge random
  hypergraphs; the sampled estimator's error/CI behavior on a 10x
  larger graph;
* the Engine seam: ``Engine.analyze`` design-point resolution (kernel /
  representation / backend / mode cost models), task outputs, and
  config validation.
"""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import AnalyticsSpec, Engine, ExecutionConfig
from repro.data import make_dataset, powerlaw_hypergraph
from repro.motifs import (
    CLASS_OF_PATTERN,
    N_HMOTIF_CLASSES,
    batch_intersections,
    build_index,
    exact_census,
    materialize_pair_sizes,
    overlap_pairs,
    sampled_census,
    select_intersect_kernel,
)

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


@st.composite
def small_hypergraph(draw):
    nv = draw(st.integers(5, 48))
    ne = draw(st.integers(3, 64))
    card = draw(st.integers(2, 5))
    seed = draw(st.integers(0, 10_000))
    return powerlaw_hypergraph(nv, ne, mean_cardinality=card, seed=seed)


def member_sets(hg):
    src, dst = np.asarray(hg.src), np.asarray(hg.dst)
    return [set(src[dst == e].tolist()) for e in range(hg.n_hyperedges)]


def brute_force_census(hg):
    """O(E^3) python-set reference: every unordered triple, connectivity
    by pairwise overlap, classification via the 7 Venn regions."""
    sets = member_sets(hg)
    counts = np.zeros(N_HMOTIF_CLASSES, np.int64)
    n_dup = 0
    for a, b, c in itertools.combinations(range(hg.n_hyperedges), 3):
        sa, sb, sc = sets[a], sets[b], sets[c]
        links = (
            bool(sa & sb) + bool(sb & sc) + bool(sc & sa)
        )
        if links < 2:
            continue
        regions = [
            sa - sb - sc, sb - sa - sc, (sa & sb) - sc,
            sc - sa - sb, (sa & sc) - sb, (sb & sc) - sa,
            sa & sb & sc,
        ]
        pattern = sum((len(r) > 0) << i for i, r in enumerate(regions))
        cls = CLASS_OF_PATTERN[pattern]
        if cls < 0:
            n_dup += 1
        else:
            counts[cls] += 1
    return counts, n_dup


# --------------------------------------------------------------------------
# class tables
# --------------------------------------------------------------------------

def test_exactly_26_hmotif_classes():
    """Lee et al. 2020: 26 h-motifs for connected triples of distinct
    hyperedges — our table is derived programmatically and must land on
    the published count."""
    assert N_HMOTIF_CLASSES == 26
    assert set(CLASS_OF_PATTERN[CLASS_OF_PATTERN >= 0]) == set(range(26))


def test_classification_is_permutation_invariant():
    rng = np.random.default_rng(0)
    from repro.motifs import classify_patterns

    for _ in range(50):
        # random region sizes -> a consistent profile for each of the 6
        # orderings of (a, b, c) must classify identically.
        r = rng.integers(0, 3, size=7)  # a,b,c,ab,bc,ca,abc region sizes
        a_, b_, c_, ab_, bc_, ca_, abc_ = r
        size = {
            0: a_ + ab_ + ca_ + abc_,
            1: b_ + ab_ + bc_ + abc_,
            2: c_ + bc_ + ca_ + abc_,
        }
        pair = {
            frozenset((0, 1)): ab_ + abc_,
            frozenset((1, 2)): bc_ + abc_,
            frozenset((2, 0)): ca_ + abc_,
        }
        out = set()
        for p in itertools.permutations(range(3)):
            x, y, z = p
            out.add(int(classify_patterns(
                size[x], size[y], size[z],
                pair[frozenset((x, y))], pair[frozenset((y, z))],
                pair[frozenset((z, x))], abc_,
            )))
        assert len(out) == 1, (r, out)


# --------------------------------------------------------------------------
# intersection kernels
# --------------------------------------------------------------------------

@given(small_hypergraph(), st.integers(0, 2**31 - 1))
def test_both_kernel_paths_match_set_oracle(hg, seed):
    sets = member_sets(hg)
    rng = np.random.default_rng(seed)
    n = 64
    ea = rng.integers(0, hg.n_hyperedges, n)
    eb = rng.integers(0, hg.n_hyperedges, n)
    ec = rng.integers(0, hg.n_hyperedges, n)
    ref_pair = np.array([len(sets[a] & sets[b]) for a, b in zip(ea, eb)])
    ref_tri = np.array(
        [len(sets[a] & sets[b] & sets[c]) for a, b, c in zip(ea, eb, ec)]
    )
    for kernel in ("bitset", "merge"):
        index = build_index(hg, kernel)
        got_pair = batch_intersections(index, ea, eb, tile=16)
        got_tri = batch_intersections(index, ea, eb, ec, tile=16)
        assert np.array_equal(got_pair, ref_pair), kernel
        assert np.array_equal(got_tri, ref_tri), kernel


def test_kernel_cost_model_flips_on_vocabulary_size():
    small = powerlaw_hypergraph(200, 64, mean_cardinality=4, seed=0)
    k_small, why_small = select_intersect_kernel(small)
    assert k_small == "bitset"
    large = powerlaw_hypergraph(
        300_000, 64, mean_cardinality=3, max_cardinality=16, seed=0
    )
    k_large, why_large = select_intersect_kernel(large)
    assert k_large == "merge"
    assert (
        why_large["bitset_words_per_pair"]
        > why_large["merge_ops_per_pair"]
    )


def test_overlap_pairs_match_set_oracle():
    hg = powerlaw_hypergraph(40, 30, mean_cardinality=4, seed=5)
    sets = member_sets(hg)
    ref = {
        (a, b)
        for a, b in itertools.combinations(range(hg.n_hyperedges), 2)
        if sets[a] & sets[b]
    }
    got = {tuple(p) for p in overlap_pairs(hg)}
    assert got == ref


# --------------------------------------------------------------------------
# census: brute-force cross-check (the acceptance criterion)
# --------------------------------------------------------------------------

@given(small_hypergraph())
def test_exact_census_matches_brute_force_bitwise(hg):
    ref, ref_dup = brute_force_census(hg)
    for kernel in ("bitset", "merge"):
        census = exact_census(hg, kernel=kernel)
        assert np.array_equal(census.counts, ref), kernel
        assert census.n_duplicate_triples == ref_dup
    # the materialized-pair (dual clique expansion) path too
    census = exact_census(
        hg, kernel="bitset", pair_sizes=materialize_pair_sizes(hg)
    )
    assert np.array_equal(census.counts, ref)


def test_sampled_estimator_error_bounds():
    """On a ~10x larger graph than the brute-force regime: fixed-seed
    relative error on the total, CI coverage of the exact per-class
    counts, and CI width shrinking with the sample count."""
    hg = powerlaw_hypergraph(600, 400, mean_cardinality=4, seed=11)
    exact = exact_census(hg)
    assert exact.total > 10_000  # meaningfully larger than the 64-E regime

    est = sampled_census(hg, 1500, seed=3)
    rel_err = abs(est.total - exact.total) / exact.total
    assert rel_err < 0.10, (est.total, exact.total)
    covered = (
        (exact.counts >= est.ci_low) & (exact.counts <= est.ci_high)
    ).mean()
    assert covered >= 0.75, covered  # 95% nominal, normal approx

    wide = sampled_census(hg, 150, seed=3)
    assert (wide.ci_high - wide.ci_low).sum() > (
        est.ci_high - est.ci_low
    ).sum()


def test_sampled_estimator_is_unbiased_across_seeds():
    hg = powerlaw_hypergraph(300, 150, mean_cardinality=4, seed=2)
    exact = exact_census(hg)
    totals = [sampled_census(hg, 300, seed=s).total for s in range(12)]
    assert abs(np.mean(totals) - exact.total) / exact.total < 0.08


# --------------------------------------------------------------------------
# the Engine seam
# --------------------------------------------------------------------------

def test_engine_analyze_exact_census_and_decision():
    hg = powerlaw_hypergraph(150, 100, mean_cardinality=4, seed=3)
    res = Engine().analyze(AnalyticsSpec(hg))
    assert res.mode == "exact"
    assert res.backend == "local"
    assert res.kernel in ("bitset", "merge")
    assert {"kernel", "representation", "backend", "mode"} <= set(
        res.decision
    )
    assert res.value.total == res.value.n_triples > 0
    # explicit kernels agree with auto
    for kernel in ("bitset", "merge"):
        forced = Engine(intersect_kernel=kernel).analyze(AnalyticsSpec(hg))
        assert forced.kernel == kernel
        assert np.array_equal(forced.value.counts, res.value.counts)


def test_engine_analyze_mode_auto_flips_on_pair_budget():
    hg = powerlaw_hypergraph(150, 100, mean_cardinality=4, seed=3)
    exact_cfg, mode, _ = Engine().resolve_analytics(AnalyticsSpec(hg))
    assert mode == "exact"
    _, mode, why = Engine().resolve_analytics(
        AnalyticsSpec(hg, exact_pair_budget=1)
    )
    assert mode == "sample"
    assert why["mode"]["n_overlap_pairs"] > 1


def test_engine_analyze_representation_cost_model():
    # dense small graph: few overlap pairs relative to nnz -> clique
    # (materialized pair intersections); blow the budget -> bipartite.
    hg = powerlaw_hypergraph(200, 40, mean_cardinality=3, seed=1)
    res = Engine().analyze(AnalyticsSpec(hg))
    resolved, _, why = Engine().resolve_analytics(
        AnalyticsSpec(hg), clique_edge_budget=1e-6
    )
    assert resolved.representation == "bipartite"
    forced = Engine(representation="bipartite").analyze(AnalyticsSpec(hg))
    assert np.array_equal(forced.value.counts, res.value.counts)


def test_engine_analyze_pair_intersections_task():
    hg = powerlaw_hypergraph(60, 40, mean_cardinality=4, seed=9)
    sets = member_sets(hg)
    res = Engine().analyze(AnalyticsSpec(hg, task="pair_intersections"))
    pairs, sizes = res.value
    assert len(pairs) == len(sizes) and len(pairs) > 0
    for (a, b), s in zip(pairs[:50], sizes[:50]):
        assert len(sets[a] & sets[b]) == s
    # explicit pair list, including self-pairs (|e ∩ e| = |e|), which
    # must agree across the materialized-clique and kernel paths.
    ea, eb = np.array([0, 1, 2, 4]), np.array([1, 2, 3, 4])
    ref = [len(sets[a] & sets[b]) for a, b in zip(ea, eb)]
    for representation in ("auto", "clique", "bipartite"):
        res = Engine(representation=representation).analyze(
            AnalyticsSpec(hg, task="pair_intersections", pairs=(ea, eb))
        )
        _, sizes = res.value
        assert np.array_equal(sizes, ref), representation


def test_engine_analyze_invalid_configs_rejected():
    hg = powerlaw_hypergraph(20, 10, seed=0)
    with pytest.raises(ValueError, match="task"):
        AnalyticsSpec(hg, task="clustering")
    with pytest.raises(ValueError, match="mode"):
        AnalyticsSpec(hg, mode="guess")
    with pytest.raises(ValueError, match="intersect_kernel"):
        ExecutionConfig(intersect_kernel="gpu_hash")
    with pytest.raises(ValueError, match="replicated"):
        Engine(backend="replicated").analyze(AnalyticsSpec(hg))
    with pytest.raises(ValueError, match="mesh"):
        Engine(backend="sharded").analyze(AnalyticsSpec(hg))


def test_engine_analyze_large_vocab_regime_picks_merge():
    hg = make_dataset("friendster", scale=0.0005, seed=0)
    big = powerlaw_hypergraph(
        300_000, 200, mean_cardinality=3, max_cardinality=16, seed=0
    )
    resolved, _, _ = Engine().resolve_analytics(AnalyticsSpec(big))
    assert resolved.intersect_kernel == "merge"
    resolved, _, _ = Engine().resolve_analytics(AnalyticsSpec(hg))
    assert resolved.intersect_kernel == "bitset"
