"""Edge-sharded GNN executor == plain step (subprocess, 8 host devices).

The MESH replicated backend applied to GNN training (§Perf H2): gradients
are taken THROUGH shard_map, so param updates must match the unsharded
step bit-for-bit (sum-aggregation models; PNA's min/max aggregators hit a
known JAX shard_map-linearization limitation and stay on the pjit path).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import jax, numpy as np, dataclasses
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.models.gnn import random_graph
    from repro.models.gnn import gat, equivariant
    from repro.launch.gnn_sharded import make_edge_sharded_step
    from repro.train import AdamWConfig, init_train_state, make_train_step

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ('data', 'model'))
    for arch, mod in [('gat-cora', gat), ('mace', equivariant),
                      ('nequip', equivariant)]:
        spec = get_config(arch, smoke=True)
        cfg = spec.model
        if arch in ('mace', 'nequip'):
            g = random_graph(24, 80, with_positions=True,
                             n_species=cfg.n_species, seed=3)
            g = dataclasses.replace(g, labels=jnp.zeros((1,), jnp.float32))
        else:
            g = random_graph(24, 80, d_feat=cfg.d_in,
                             n_classes=cfg.n_classes, seed=3)
        params = mod.init_params(jax.random.PRNGKey(0), cfg)
        state0 = init_train_state(params)
        ref_step = jax.jit(make_train_step(
            lambda p, b: mod.loss_fn(p, cfg, b), AdamWConfig()))
        s_ref, m_ref = ref_step(state0, g)
        sh_step = make_edge_sharded_step(mod, cfg, mesh)
        with mesh:
            s_sh, m_sh = jax.jit(sh_step)(state0, g)
        dl = abs(float(m_ref['loss']) - float(m_sh['loss']))
        errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(s_ref.params),
                                jax.tree.leaves(s_sh.params))]
        assert dl < 5e-4 and max(errs) < 5e-4, (arch, dl, max(errs))
    print('SHARDED_GNN_MATCH')
""")


@pytest.mark.slow
def test_edge_sharded_gnn_matches_plain():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=1200,
        # Inherit the environment (JAX_PLATFORMS in particular: without
        # it jax probes for accelerator platforms and stalls for minutes).
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_GNN_MATCH" in proc.stdout
