"""Partitioner invariants + properties (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import HyperGraph
from repro.data import powerlaw_hypergraph
from repro.partition import STRATEGIES, partition

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@st.composite
def small_hypergraph(draw):
    nv = draw(st.integers(4, 40))
    ne = draw(st.integers(2, 30))
    seed = draw(st.integers(0, 1000))
    return powerlaw_hypergraph(nv, ne, mean_cardinality=3, seed=seed)


@given(small_hypergraph(), st.sampled_from(sorted(STRATEGIES)),
       st.sampled_from([2, 4, 8]))
def test_plan_reconstructs_edge_list(hg, strategy, n_parts):
    """Shards + masks must be a permutation of the incidence list —
    no edge lost, none duplicated, padding properly dead."""
    kw = {"chunk": 16} if "greedy" in strategy else {}
    plan = partition(strategy, hg, n_parts, **kw)
    live = plan.shard_mask > 0
    pairs = set()
    for p in range(n_parts):
        for s, d in zip(plan.shard_src[p][live[p]],
                        plan.shard_dst[p][live[p]]):
            pairs.add((int(s), int(d)))
    expect = set(
        zip(np.asarray(hg.src).tolist(), np.asarray(hg.dst).tolist())
    )
    assert pairs == expect
    assert int(live.sum()) == hg.nnz


@given(small_hypergraph(), st.sampled_from([2, 8]))
def test_vertex_cut_keeps_hyperedges_whole(hg, n_parts):
    plan = partition("random_vertex_cut", hg, n_parts)
    # every hyperedge's incidences in exactly one partition
    assert plan.stats.hyperedge_replication == pytest.approx(1.0)


@given(small_hypergraph(), st.sampled_from([2, 8]))
def test_hyperedge_cut_keeps_vertices_whole(hg, n_parts):
    plan = partition("random_hyperedge_cut", hg, n_parts)
    assert plan.stats.vertex_replication == pytest.approx(1.0)


def test_hybrid_cutoff_differentiates():
    """Low-cardinality hyperedges stay whole; only heavy ones get cut."""
    hg = powerlaw_hypergraph(200, 100, mean_cardinality=4,
                             max_cardinality=150, seed=7)
    plan = partition("hybrid_vertex_cut", hg, 8, cutoff=10)
    card = np.bincount(np.asarray(hg.dst), minlength=hg.n_hyperedges)
    dst = np.asarray(hg.dst)
    for e in range(hg.n_hyperedges):
        parts = set(plan.edge_part[dst == e].tolist())
        if card[e] <= 10 and card[e] > 0:
            assert len(parts) == 1, (e, card[e], parts)


def test_greedy_reduces_replication_vs_random():
    hg = powerlaw_hypergraph(500, 400, mean_cardinality=4, seed=11)
    rnd = partition("random_vertex_cut", hg, 8)
    greedy = partition("greedy_vertex_cut", hg, 8, chunk=1)
    assert (
        greedy.stats.vertex_replication
        <= rnd.stats.vertex_replication + 1e-9
    )
    # greedy balances load explicitly
    assert greedy.stats.edge_balance <= rnd.stats.edge_balance + 0.5


def test_greedy_rejects_wide_meshes():
    hg = powerlaw_hypergraph(30, 20, seed=0)
    with pytest.raises(ValueError, match="bitmask"):
        partition("greedy_vertex_cut", hg, 128)


def test_partition_time_recorded():
    hg = powerlaw_hypergraph(100, 80, seed=2)
    plan = partition("random_both_cut", hg, 4)
    assert plan.partition_time_s >= 0.0
    assert plan.stats.pad_fraction < 0.9
