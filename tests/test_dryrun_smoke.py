"""The dry-run harness itself, exercised in CI (smoke configs, subprocess
with 512 forced host devices — the parent test process keeps 1 device)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_smoke_single_and_multi():
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "llama3.2-1b", "--arch", "gat-cora",
            "--arch", "bert4rec",
            "--shape", "train_4k", "--shape", "molecule",
            "--shape", "serve_p99",
            "--mesh", "both", "--smoke", "--no-roofline",
        ],
        capture_output=True, text=True, timeout=1500,
        # Inherit the environment (JAX_PLATFORMS in particular: without
        # it jax probes for accelerator platforms and stalls for minutes).
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    ok_lines = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("[ok")]
    assert len(ok_lines) == 6, proc.stdout  # 3 cells x 2 meshes
