"""End-to-end distributed hypergraph analytics — the paper's flagship
scenario: generate an orkut-like hypergraph, evaluate every partitioning
strategy, pick the best by projected sync volume, and run Label
Propagation on the distributed engine over host devices.

Run: PYTHONPATH=src python examples/hypergraph_analytics.py
(spawns 8 forced host devices; set REPRO_DEVICES to change)
"""
import os

N_DEV = int(os.environ.get("REPRO_DEVICES", "8"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.algorithms import label_propagation_spec, run_distributed, \
    run_local  # noqa: E402
from repro.data import make_dataset  # noqa: E402
from repro.partition import STRATEGIES, partition  # noqa: E402

hg = make_dataset("orkut", scale=0.0005, seed=0)
print(f"hypergraph: {hg.n_vertices} vertices, {hg.n_hyperedges} "
      f"hyperedges, {hg.nnz} incidences (orkut regime: E >> V)")

plans = {}
for strat in STRATEGIES:
    kw = {"chunk": 256} if "greedy" in strat else {}
    plans[strat] = partition(strat, hg, N_DEV, **kw)
    s = plans[strat].stats
    print(f"  {strat:22s} t={plans[strat].partition_time_s:6.2f}s "
          f"vrep={s.vertex_replication:4.2f} "
          f"herep={s.hyperedge_replication:4.2f} "
          f"bal={s.edge_balance:4.2f} "
          f"sync={s.sync_bytes_per_dim / 1e6:6.2f} MB/dim")

best = min(plans, key=lambda k: plans[k].stats.sync_bytes_per_dim)
print(f"\nselected strategy (min projected sync): {best}")

mesh = Mesh(np.array(jax.devices()[:N_DEV]).reshape(N_DEV), ("data",))
spec = label_propagation_spec(hg, iters=16)
v_dist, he_dist = run_distributed(
    spec, plans[best], mesh, backend="sharded"
)
v_local, he_local = run_local(spec)
match = bool(np.array_equal(np.asarray(v_dist), np.asarray(v_local)))
print(f"distributed == local: {match}")
print(f"communities found: {len(np.unique(np.asarray(v_dist)))}")
