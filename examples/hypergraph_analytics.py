"""End-to-end distributed hypergraph analytics — the paper's flagship
scenario: generate an orkut-like hypergraph and run Label Propagation
distributed over host devices, letting the ``Engine`` facade pick the
partitioning strategy (min projected sync volume) and the backend
(replicated vs sharded by the sync cost model) automatically.

Then the *batch* mode on the same facade: ``Engine.analyze`` runs the
h-motif census (connected 3-hyperedge overlap patterns, Lee et al.
2020), picking the intersection-kernel path (bitset word lanes vs
sorted-merge) and tiling hyperedge-pair blocks across the same mesh.

Run: PYTHONPATH=src python examples/hypergraph_analytics.py
(spawns 8 forced host devices; set REPRO_DEVICES to change)
"""
import os

N_DEV = int(os.environ.get("REPRO_DEVICES", "8"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.algorithms import label_propagation_spec  # noqa: E402
from repro.core import Engine  # noqa: E402
from repro.data import make_dataset  # noqa: E402

hg = make_dataset("orkut", scale=0.0005, seed=0)
print(f"hypergraph: {hg.n_vertices} vertices, {hg.n_hyperedges} "
      f"hyperedges, {hg.nnz} incidences (orkut regime: E >> V)")

mesh = Mesh(np.array(jax.devices()[:N_DEV]).reshape(N_DEV), ("data",))
spec = label_propagation_spec(hg, iters=16)

# One call: the Engine partitions with every registered strategy, keeps
# the plan with minimum projected sync volume, sizes replicated-vs-sharded
# with the same stats, and runs the superstep scan under shard_map.
engine = Engine(mesh=mesh)  # everything else "auto"
res = engine.run(spec)

part_why = res.decision["partition"]
print("\nstrategy sync bytes/dim (Engine's selection table):")
for name, cost in sorted(part_why["sync_bytes_by_strategy"].items(),
                         key=lambda kv: kv[1]):
    marker = " <- selected" if name == res.partition else ""
    print(f"  {name:22s} {cost / 1e6:8.3f} MB{marker}")
print(f"\nselected design point: partition={res.partition} "
      f"backend={res.backend} ({res.decision['backend']['reason']})")

v_local, _ = Engine(backend="local").run(spec).value
v_dist, _ = res.value
match = bool(np.array_equal(np.asarray(v_dist), np.asarray(v_local)))
print(f"distributed == local: {match}")
print(f"communities found: {len(np.unique(np.asarray(v_dist)))}")

# -- batch analytics on the same facade: the h-motif census --------------
from repro.core import AnalyticsSpec  # noqa: E402

ares = engine.analyze(AnalyticsSpec(hg))
census = ares.value
print(f"\nh-motif census: representation={ares.representation} "
      f"kernel={ares.kernel} backend={ares.backend} mode={ares.mode}")
for axis, why in ares.decision.items():
    reason = why.get("reason") if isinstance(why, dict) else why
    print(f"  {axis}: {reason}")
counts = census.counts
print(f"  {census.total:.0f} connected 3-hyperedge patterns over "
      f"{census.n_pairs} overlapping pairs; top classes: "
      + ", ".join(f"m{m}={counts[m]:.0f}"
                  for m in np.argsort(counts)[::-1][:4]))

# exact and sharded-vs-local agreement, same invariant as the iterative
# path: every design point returns the same numbers.
a_local = Engine().analyze(AnalyticsSpec(hg))
print("sharded census == local census: "
      f"{bool(np.array_equal(counts, a_local.value.counts))}")
