"""Quickstart: the MESH API on the paper's Fig. 1 hypergraph.

Run: PYTHONPATH=src python examples/quickstart.py

One API, many design points: every built-in application is a thin wrapper
over ``Engine.run(spec)``; construct your own ``Engine`` to pin or
auto-select the representation / partitioning / backend design axes.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, HyperGraph, Program, ProcedureOut
from repro.algorithms import (
    AlgorithmSpec,
    connected_components,
    label_propagation,
    pagerank,
    pagerank_entropy,
    pagerank_spec,
    shortest_paths,
    vertex_pagerank_spec,
)

# The paper's Fig. 1: four groups over five users.
hg = HyperGraph.from_hyperedge_lists(
    [[0, 1], [0, 1, 2, 3], [0, 3, 4], [2, 3]], n_vertices=5
)
print("degrees      ", np.asarray(hg.degrees()))
print("cardinalities", np.asarray(hg.cardinalities()))

# Built-in applications (each a ~20-line Program pair; see
# src/repro/algorithms/).  Wrappers construct a default local Engine.
vr, her = pagerank(hg, iters=20)
print("pagerank v   ", np.round(np.asarray(vr), 3))
print("pagerank he  ", np.round(np.asarray(her), 3))

_, _, entropy = pagerank_entropy(hg, iters=20)
print("he entropy   ", np.round(np.asarray(entropy), 3))

vl, _ = label_propagation(hg, iters=10)
print("communities  ", np.asarray(vl))

vd, _ = shortest_paths(hg, source=4)
print("hops from v4 ", np.asarray(vd))

vc, _ = connected_components(hg)
print("components   ", np.asarray(vc))

# The Engine facade directly: the Result reports the design point chosen
# and per-superstep activity when asked.
engine = Engine()
res = engine.run(pagerank_spec(hg, iters=20), collect_stats=True)
print("engine ran   ", res.representation, "/", res.backend,
      "| active trace:", np.asarray(res.superstep_stats[0])[:3], "...")

# Representation auto-selection: the vertex-only PageRank spec satisfies
# the clique precondition (no hyperedge state), and Fig. 1's expansion is
# tiny, so "auto" constant-folds hyperedges away.
res = engine.run(vertex_pagerank_spec(hg, iters=20))
print("auto rep     ", res.representation, "->",
      np.round(np.asarray(res.value), 3))

# A custom "think like a vertex or hyperedge" program through the same
# facade: count 2-hop neighbors through groups (vertex -> he -> vertex).
def vertex(step, ids, attr, msg, deg):
    return ProcedureOut(attr=msg, msg=jnp.ones_like(attr))

def hyperedge(step, ids, attr, msg, card):
    return ProcedureOut(attr=msg, msg=msg)

spec = AlgorithmSpec(
    hg0=hg.with_attrs(
        v_attr=jnp.zeros((5,), jnp.float32),
        he_attr=jnp.zeros((4,), jnp.float32),
    ),
    initial_msg=jnp.float32(0),
    v_program=Program(procedure=vertex, combiner="sum"),
    he_program=Program(procedure=hyperedge, combiner="sum"),
    max_iters=2,  # 2nd vertex step consumes the hyperedge broadcast
    extract=lambda out: out.v_attr,
    name="two_hop_mass",
)
print("2-hop mass   ", np.asarray(engine.run(spec).value))
