"""Quickstart: the MESH API on the paper's Fig. 1 hypergraph.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import HyperGraph, Program, ProcedureOut, compute
from repro.algorithms import (
    connected_components,
    label_propagation,
    pagerank,
    pagerank_entropy,
    shortest_paths,
)

# The paper's Fig. 1: four groups over five users.
hg = HyperGraph.from_hyperedge_lists(
    [[0, 1], [0, 1, 2, 3], [0, 3, 4], [2, 3]], n_vertices=5
)
print("degrees      ", np.asarray(hg.degrees()))
print("cardinalities", np.asarray(hg.cardinalities()))

# Built-in applications (each a ~20-line Program pair; see
# src/repro/algorithms/).
vr, her = pagerank(hg, iters=20)
print("pagerank v   ", np.round(np.asarray(vr), 3))
print("pagerank he  ", np.round(np.asarray(her), 3))

_, _, entropy = pagerank_entropy(hg, iters=20)
print("he entropy   ", np.round(np.asarray(entropy), 3))

vl, _ = label_propagation(hg, iters=10)
print("communities  ", np.asarray(vl))

vd, _ = shortest_paths(hg, source=4)
print("hops from v4 ", np.asarray(vd))

vc, _ = connected_components(hg)
print("components   ", np.asarray(vc))

# A custom "think like a vertex or hyperedge" program: count 2-hop
# neighbors through groups (vertex -> hyperedge -> vertex).
def vertex(step, ids, attr, msg, deg):
    return ProcedureOut(attr=msg, msg=jnp.ones_like(attr))

def hyperedge(step, ids, attr, msg, card):
    return ProcedureOut(attr=msg, msg=msg)

out = compute(
    hg.with_attrs(
        v_attr=jnp.zeros((5,), jnp.float32),
        he_attr=jnp.zeros((4,), jnp.float32),
    ),
    max_iters=2,  # 2nd vertex step consumes the hyperedge broadcast
    initial_msg=jnp.float32(0),
    v_program=Program(procedure=vertex, combiner="sum"),
    he_program=Program(procedure=hyperedge, combiner="sum"),
)
print("2-hop mass   ", np.asarray(out.v_attr))
