"""Serve a small LM with batched requests: prefill + decode loop.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys

# The serving driver lives in the launch layer; this example invokes it the
# way an operator would.
if __name__ == "__main__":
    sys.exit(subprocess.call([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "gemma3-12b", "--smoke",
        "--batch", "4", "--prompt-len", "24", "--gen", "12",
    ]))
