"""Train an LM end-to-end with checkpoint/restart.

Default is the CPU-sized smoke config; ``--size 100m`` trains a ~100M-param
llama-family model for a few hundred steps (the deliverable driver — run it
on real accelerators; on this CPU container expect ~minutes/step).

Run: PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + sys.argv[1:]

import jax  # noqa: E402

from repro.models.transformer import LMConfig, init_params, loss_fn  # noqa: E402
from repro.train import (  # noqa: E402
    AdamWConfig,
    init_train_state,
    latest_checkpoint,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.launch.train import synthetic_batch  # noqa: E402


def config_for(size: str) -> LMConfig:
    if size == "100m":
        return LMConfig(
            name="llama-100m", n_layers=14, d_model=640, n_heads=10,
            n_kv_heads=5, head_dim=64, d_ff=2560, vocab=32_000,
            remat=False,
        )
    return LMConfig(
        name="lm-tiny", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab=1024, remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_for(args.size)
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        )
    )
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    start = 0
    path = latest_checkpoint(args.ckpt_dir)
    if path:
        state, start = restore_checkpoint(path, state)
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(
        lambda p, b: loss_fn(p, cfg, b),
        AdamWConfig(lr=3e-4, total_steps=args.steps),
    ))
    for step in range(start, args.steps):
        batch = synthetic_batch(cfg.vocab, args.batch, args.seq, step)
        state, m = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f}")
        if (step + 1) % 25 == 0:
            save_checkpoint(args.ckpt_dir, step + 1, state)
    save_checkpoint(args.ckpt_dir, args.steps, state)
    print("training complete; checkpoint saved")


if __name__ == "__main__":
    main()
