"""Paper Figs. 12-14: strong scaling with worker count.

On this CPU host all "devices" share one core, so wall time cannot show
real speedup; what scales — and what we measure — is the *per-partition
work* (edges/shard) and the projected sync volume, the quantities that
govern Fig. 12-14 on real hardware.  Wall time is reported for reference.

Each (regime, P) cell also reports the backend the Engine facade's cost
model (``select_backend``) picks at that scale — the replicated->sharded
crossover as P grows is the design-point flexibility the facade automates.

The distributed executor itself runs under forced host devices in the
separate dry-run/regression entries (tests/test_distributed.py,
tests/test_executor.py).
"""
from __future__ import annotations

from repro.core import select_backend
from repro.data import make_dataset
from repro.partition import partition

from benchmarks.common import SCALE, row


def run() -> None:
    for regime, base_scale in [("orkut", 0.0004), ("friendster", 0.001),
                               ("dblp", 0.003), ("apache", 0.05)]:
        hg = make_dataset(regime, scale=base_scale * SCALE, seed=0)
        for n_parts in (2, 4, 8, 16, 32, 64):
            plan = partition("random_both_cut", hg, n_parts)
            s = plan.stats
            per_shard = plan.shard_len
            backend, _ = select_backend(
                plan, hg.n_vertices, hg.n_hyperedges
            )
            row(
                f"scaling/{regime}/p{n_parts}/edges_per_shard",
                float(per_shard),
                f"vrep={s.vertex_replication:.2f};"
                f"herep={s.hyperedge_replication:.2f};"
                f"sync_bytes={s.sync_bytes_per_dim:.0f};"
                f"pad={s.pad_fraction:.3f};"
                f"auto_backend={backend}",
            )


if __name__ == "__main__":
    run()
