"""Paper Figs. 12-14: strong scaling with worker count.

On this CPU host all "devices" share one core, so wall time cannot show
real speedup; what scales — and what we measure — is the *per-partition
work* (edges/shard for the iterative workloads, hyperedge-pair blocks
per device for the motif census) and the projected sync volume, the
quantities that govern Fig. 12-14 on real hardware.  Wall time is
reported for reference.

Each (regime, P) cell also reports the backend the Engine facade's cost
model (``select_backend``) picks at that scale — the replicated->sharded
crossover as P grows is the design-point flexibility the facade automates.

The motif census rides the same device sweep (ROADMAP open item): its
sharded backend tiles pair blocks of ``tile`` rows across the mesh, so
the per-device quantity is the padded pair-block length — reported per
(regime, P) next to the auto-picked intersection kernel.

The distributed executor itself runs under forced host devices in the
separate dry-run/regression entries (tests/test_distributed.py,
tests/test_executor.py).
"""
from __future__ import annotations

from repro.core import select_backend
from repro.data import make_dataset
from repro.motifs import overlap_pairs, select_intersect_kernel
from repro.partition import partition

from benchmarks.common import SCALE, row

# pair-batch tile the sharded intersection kernel uses (AnalyticsSpec
# default); per-device blocks are padded to a multiple of it.
MOTIF_TILE = 2048

DEVICE_SWEEP = (2, 4, 8, 16, 32, 64)


def iterative_rows(regime: str, hg) -> None:
    for n_parts in DEVICE_SWEEP:
        plan = partition("random_both_cut", hg, n_parts)
        s = plan.stats
        per_shard = plan.shard_len
        backend, _ = select_backend(
            plan, hg.n_vertices, hg.n_hyperedges
        )
        row(
            f"scaling/{regime}/p{n_parts}/edges_per_shard",
            float(per_shard),
            f"vrep={s.vertex_replication:.2f};"
            f"herep={s.hyperedge_replication:.2f};"
            f"sync_bytes={s.sync_bytes_per_dim:.0f};"
            f"pad={s.pad_fraction:.3f};"
            f"auto_backend={backend}",
        )


def motif_rows(regime: str, hg, tile: int = MOTIF_TILE) -> None:
    """The census's device-count scaling curve: per-device pair-block
    length under the sharded tiling of ``repro.motifs.batch_intersections``
    (blocks are padded to ``tile`` multiples, mirroring edge-shard
    padding), plus the kernel the cost model picks for this regime."""
    n_pairs = len(overlap_pairs(hg))
    kernel, _ = select_intersect_kernel(hg)
    for n_parts in DEVICE_SWEEP:
        block = -(-n_pairs // (n_parts * tile)) * tile
        pad = 1.0 - n_pairs / max(n_parts * block, 1)
        row(
            f"scaling/{regime}/p{n_parts}/pairs_per_shard",
            float(block),
            f"n_pairs={n_pairs};pad={pad:.3f};kernel={kernel}",
        )


def run() -> None:
    for regime, base_scale in [("orkut", 0.0004), ("friendster", 0.001),
                               ("dblp", 0.003), ("apache", 0.05)]:
        hg = make_dataset(regime, scale=base_scale * SCALE, seed=0)
        iterative_rows(regime, hg)
        motif_rows(regime, hg)


if __name__ == "__main__":
    run()
