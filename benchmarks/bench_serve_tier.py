"""The serving tier end-to-end: coalescing front-end + persistent cache.

Extends ``bench_serving`` (raw ``run_batch`` cold/warm) up one layer to
the full request path — ``Frontend.submit`` -> coalescing batcher ->
``run_batch`` -> future fan-out — and down one layer to the disk store:

* **cold boot**: fresh process-state analogue (empty disk cache):
  ``serve.warm`` pays AOT trace + XLA compile for every path, then a
  mixed SSSP/PPR trace replays through the front-end;
* **warm serve**: the same trace again on the hot executables — the
  sustained q/s the tier holds once booted (gate: ≥ 5x the cold
  replay, which amortizes the compiles);
* **disk-warmed boot**: a second Engine on the same cache dir —
  ``serve.warm`` must deserialize every executable (ZERO retraces,
  asserted) and its first replay must already run at warm q/s (gate:
  ≥ 5x cold replay — no compile hiding in the first flush).

* **replica pool scaling**: the same trace through the multi-replica
  ``Router`` at 1 / 2 / 4 worker processes, all booted from the shared
  disk store — aggregate q/s per pool size (gate on multicore hosts:
  2 replicas ≥ 1.5x one), plus a kill -9 run measuring failover
  recovery time (kill → pool back to full ready strength) with every
  request still resolving.

Reports the latency split (queue-wait vs execute p50/p99), per-bucket
occupancy and boot times; writes ``BENCH_serve_tier.json`` (uploaded
by the nightly CI job).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.algorithms import random_walk_spec, shortest_paths_spec
from repro.core import Engine
from repro.data import make_dataset
from repro.serve import DiskExecutableCache, Frontend, warm

from benchmarks.common import SCALE, emit_json, row

REQUESTS = 96
MAX_BATCH = 16
MAX_DELAY_MS = 5.0
ITERS = 8
SSSP_MIX = 0.6


def _specs(hg):
    return {
        "sssp": shortest_paths_spec(hg, 0, ITERS),
        "ppr": random_walk_spec(hg, iters=ITERS),
    }


def _trace(hg, rng):
    return [
        ("sssp" if rng.random() < SSSP_MIX else "ppr",
         int(rng.integers(0, hg.n_vertices)))
        for _ in range(REQUESTS)
    ]


def _replay(engine, hg, trace, resilience=True) -> tuple[float, dict]:
    """One front-end lifetime serving ``trace``; (wall_s, stats)."""
    fe = Frontend(engine, max_batch=MAX_BATCH, max_delay_ms=MAX_DELAY_MS,
                  resilience=resilience)
    for key, spec in _specs(hg).items():
        fe.register(key, spec)
    t0 = time.perf_counter()
    with fe:
        futs = [fe.submit(key, query=q) for key, q in trace]
        for f in futs:
            f.result()
    return time.perf_counter() - t0, fe.stats()


def run() -> None:
    hg = make_dataset("dblp", scale=0.002 * SCALE, seed=0)
    rng = np.random.default_rng(0)
    trace = _trace(hg, rng)
    cache_dir = tempfile.mkdtemp(prefix="repro-serve-tier-")

    # -- cold boot: empty disk cache, compiles all the way down ----------
    eng_cold = Engine(disk_cache=DiskExecutableCache(cache_dir))
    t0 = time.perf_counter()
    boot_cold = warm(eng_cold, list(_specs(hg).values()),
                     batch_sizes=(MAX_BATCH,), queries=[0, 0])
    cold_boot_s = time.perf_counter() - t0
    cold_wall_s, _ = _replay(eng_cold, hg, trace)
    cold_qps = REQUESTS / (cold_boot_s + cold_wall_s)
    row("serve_tier/cold_boot", cold_boot_s * 1e6,
        f"traces={boot_cold['traces']};stored={boot_cold['compiled']}")
    row(f"serve_tier/cold_replay{REQUESTS}",
        (cold_boot_s + cold_wall_s) * 1e6, f"qps={cold_qps:.1f}")

    # -- warm serve: same engine, hot executables ------------------------
    warm_wall_s, warm_stats = _replay(eng_cold, hg, trace)
    warm_qps = REQUESTS / warm_wall_s
    row(f"serve_tier/warm_replay{REQUESTS}", warm_wall_s * 1e6,
        f"qps={warm_qps:.1f};"
        f"wait_p99={warm_stats['queue_wait']['p99_s'] * 1e3:.2f}ms;"
        f"exec_p99={warm_stats['execute']['p99_s'] * 1e3:.2f}ms")
    speedup = warm_qps / cold_qps
    assert speedup >= 5.0, (
        f"warm q/s only {speedup:.1f}x cold (< 5x): serve-tier compile "
        "amortization regressed"
    )

    # -- fault-free overhead: resilient default vs resilience=False ------
    # The zero-overhead-when-healthy contract of the fault-tolerance
    # layer: deadline/breaker/retry checks on the warm path must cost
    # < 2% q/s vs a front-end with every resilience mechanism compiled
    # out.  Best-of-3 each side to shed scheduler noise.
    plain_wall_s = min(
        _replay(eng_cold, hg, trace, resilience=False)[0]
        for _ in range(3)
    )
    resil_wall_s = min(
        _replay(eng_cold, hg, trace, resilience=True)[0]
        for _ in range(3)
    )
    plain_qps = REQUESTS / plain_wall_s
    resil_qps = REQUESTS / resil_wall_s
    overhead = resil_wall_s / plain_wall_s - 1.0
    row(f"serve_tier/faultfree_plain{REQUESTS}", plain_wall_s * 1e6,
        f"qps={plain_qps:.1f}")
    row(f"serve_tier/faultfree_resilient{REQUESTS}", resil_wall_s * 1e6,
        f"qps={resil_qps:.1f};overhead={overhead * 100:+.2f}%")
    assert resil_qps >= 0.98 * plain_qps, (
        f"resilient warm q/s {resil_qps:.1f} < 98% of plain "
        f"{plain_qps:.1f}: the fault-tolerance layer is taxing the "
        "fault-free hot path"
    )

    # -- disk-warmed boot: new replica, same cache dir -------------------
    eng_disk = Engine(disk_cache=DiskExecutableCache(cache_dir))
    t0 = time.perf_counter()
    boot_disk = warm(eng_disk, list(_specs(hg).values()),
                     batch_sizes=(MAX_BATCH,), queries=[0, 0])
    disk_boot_s = time.perf_counter() - t0
    assert boot_disk["traces"] == 0, (
        f"disk-warmed boot retraced {boot_disk['traces']}x — "
        "persistent executable cache regression"
    )
    disk_wall_s, disk_stats = _replay(eng_disk, hg, trace)
    disk_qps = REQUESTS / disk_wall_s
    retraces = eng_disk.cache_stats()["traces"]
    assert retraces == 0, (
        f"disk-warmed serve retraced {retraces}x"
    )
    # the first flush already runs warm: the whole first replay of a
    # disk-booted replica must clear the same >= 5x-cold gate.
    disk_speedup = disk_qps / cold_qps
    assert disk_speedup >= 5.0, (
        f"disk-warmed replay only {disk_speedup:.1f}x cold (< 5x): "
        "boot-from-disk is not reaching warm q/s in its first flushes"
    )
    row("serve_tier/disk_boot", disk_boot_s * 1e6,
        f"from_disk={boot_disk['from_disk']};retraces=0;"
        f"boot_speedup={cold_boot_s / disk_boot_s:.1f}x")
    row(f"serve_tier/disk_replay{REQUESTS}", disk_wall_s * 1e6,
        f"qps={disk_qps:.1f}")

    # -- replica pool scaling + failover recovery ------------------------
    # All pools boot require_no_retrace from the store the sections
    # above populated; q/s is aggregate across the pool.
    from repro.obs.metrics import MetricsRegistry
    from repro.serve import ProcessReplica, ReplicaConfig, Router

    cfg = ReplicaConfig(
        builder="repro.launch.serve_hypergraph:build_paths",
        kwargs={"regime": "dblp", "scale": 0.002 * SCALE, "seed": 0,
                "iters": ITERS},
        cache_dir=cache_dir, max_batch=MAX_BATCH,
        max_delay_ms=MAX_DELAY_MS, require_no_retrace=True,
    )

    def _pool_replay(n: int, kill_one: bool = False) -> dict:
        router = Router(
            lambda i: ProcessReplica(i, cfg), n,
            heartbeat_timeout_ms=2000.0, max_in_flight=2 * MAX_BATCH,
            registry=MetricsRegistry(),
        ).start()
        try:
            router.wait_ready(timeout_s=300)
            t0 = time.perf_counter()
            futs = [router.submit(k, query=q) for k, q in trace]
            recovery_s = None
            if kill_one:
                os.kill(router.slots[0].handle.pid, 9)
                tk = time.perf_counter()
                # recovery = kill -> death detected -> respawn booted
                # from disk -> pool back at full ready strength
                while router.stats()["ready"] >= n:
                    time.sleep(0.005)
                    assert time.perf_counter() - tk < 300, \
                        "router never noticed the kill -9"
                router.wait_ready(min_ready=n, timeout_s=300)
                recovery_s = time.perf_counter() - tk
            ok = err = 0
            for f in futs:
                try:
                    f.result(timeout=600)
                    ok += 1
                except Exception:
                    err += 1
            wall_s = time.perf_counter() - t0
            stats = router.stats()
        finally:
            router.close()
        return {"wall_s": wall_s, "ok": ok, "err": err,
                "qps": ok / wall_s, "recovery_s": recovery_s,
                "stats": stats}

    pool_qps = {}
    for n in (1, 2, 4):
        r = _pool_replay(n)
        assert r["ok"] == REQUESTS and r["err"] == 0, (
            f"fault-free {n}-replica pool dropped requests: {r}"
        )
        pool_qps[n] = r["qps"]
        row(f"serve_tier/pool{n}_replay{REQUESTS}", r["wall_s"] * 1e6,
            f"qps={r['qps']:.1f}")

    pool2_over_pool1 = pool_qps[2] / pool_qps[1]
    cpus = os.cpu_count() or 1
    if cpus >= 2:
        scaling_note = f"gated on {cpus} cpus"
        assert pool2_over_pool1 >= 1.5, (
            f"2-replica pool only {pool2_over_pool1:.2f}x one replica "
            "(< 1.5x): pool parallelism regressed"
        )
    else:
        # one core can't run two replicas concurrently; record the
        # ratio, gate only where the hardware can express scaling.
        scaling_note = "scaling gate skipped: single-cpu host"
    row("serve_tier/pool_scaling_2x", pool2_over_pool1 * 1e6,
        f"ratio={pool2_over_pool1:.2f};{scaling_note}")

    killed = _pool_replay(2, kill_one=True)
    assert killed["ok"] + killed["err"] == REQUESTS, (
        f"kill -9 replay lost track of requests: {killed}"
    )
    assert killed["stats"]["deaths"] >= 1
    assert killed["stats"]["respawns"] >= 1
    assert killed["err"] <= 2, (  # failover budget keeps losses ~zero
        f"{killed['err']} requests lost to one kill -9"
    )
    row(f"serve_tier/pool2_kill9_replay{REQUESTS}",
        killed["wall_s"] * 1e6,
        f"qps={killed['qps']:.1f};"
        f"recovery={killed['recovery_s'] * 1e3:.0f}ms;"
        f"failovers={killed['stats']['failovers']};"
        f"lost={killed['stats']['lost']}")

    occupancy = {
        bucket: s["mean_occupancy"]
        for bucket, s in warm_stats["buckets"].items()
    }
    emit_json("serve_tier", {
        "n_vertices": hg.n_vertices,
        "n_hyperedges": hg.n_hyperedges,
        "nnz": hg.nnz,
        "requests": REQUESTS,
        "max_batch": MAX_BATCH,
        "max_delay_ms": MAX_DELAY_MS,
        "sssp_mix": SSSP_MIX,
        "cold_boot_s": cold_boot_s,
        "cold_qps": cold_qps,
        "warm_qps": warm_qps,
        "warm_over_cold": speedup,
        "disk_boot_s": disk_boot_s,
        "disk_boot_traces": boot_disk["traces"],
        "disk_qps": disk_qps,
        "disk_over_cold": disk_speedup,
        "faultfree_plain_qps": plain_qps,
        "faultfree_resilient_qps": resil_qps,
        "faultfree_overhead_ratio": resil_wall_s / plain_wall_s,
        "queue_wait": warm_stats["queue_wait"],
        "execute": warm_stats["execute"],
        "flush_reasons": warm_stats["flush_reasons"],
        "occupancy": occupancy,
        "disk_cache": eng_disk.disk_cache.stats(),
        "pool_qps": {str(n): q for n, q in pool_qps.items()},
        "pool2_over_pool1": pool2_over_pool1,
        "pool_scaling_note": scaling_note,
        "pool_kill9_qps": killed["qps"],
        "pool_kill9_recovery_ms": killed["recovery_s"] * 1e3,
        "pool_kill9_lost": killed["stats"]["lost"],
        "pool_kill9_failovers": killed["stats"]["failovers"],
    })


if __name__ == "__main__":
    run()
