"""Paper Table II: lines-of-code accounting.

The paper's claim: layering on a mature engine keeps the system ~5x
smaller than a from-scratch build (MESH 795 vs HyperX 4050 LOC), and
applications stay tens of lines.  We report our own subsystem LOC next to
the paper's numbers for both systems.
"""
from __future__ import annotations

import os

from benchmarks.common import row

GROUPS = {
    "system_core": ["src/repro/core", "src/repro/sparse"],
    "partition": ["src/repro/partition"],
    "algorithms": ["src/repro/algorithms"],
}

PAPER = {
    "system_core": {"mesh": 630, "hyperx": 2620},
    "partition": {"mesh": 30 + 40, "hyperx": 1295 + 60},
    "algorithms": {"mesh": 35 + 40, "hyperx": 50 + 75},
}


def _loc(path: str) -> int:
    total = 0
    for base, _, files in os.walk(path):
        for f in files:
            if f.endswith(".py"):
                with open(os.path.join(base, f)) as fh:
                    total += sum(
                        1 for line in fh
                        if line.strip() and not line.strip().startswith("#")
                    )
    return total


def run() -> None:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for group, paths in GROUPS.items():
        ours = sum(_loc(os.path.join(root, p)) for p in paths)
        paper = PAPER[group]
        row(
            f"loc/{group}", float(ours),
            f"paper_mesh={paper['mesh']};paper_hyperx={paper['hyperx']}",
        )


if __name__ == "__main__":
    run()
