"""Paper Figs. 8-11: partitioning time + execution time for all seven
strategies across dataset regimes x four applications.

The paper's headline result — no single partitioner dominates; the winner
tracks the vertex:hyperedge ratio and skew — is asserted by
tests/test_paper_claims.py over the stats this harness emits.

Everything executes through the ``Engine`` facade; each partition row also
reports which distributed backend the Engine's cost model would pick for
that plan (``select_backend`` on the plan's projected sync volume).
"""
from __future__ import annotations

from repro.algorithms import (
    label_propagation_spec,
    pagerank_entropy_spec,
    pagerank_spec,
    shortest_paths_spec,
)
from repro.core import Engine, select_backend
from repro.data import make_dataset
from repro.partition import STRATEGIES, partition

from benchmarks.common import SCALE, row, timed

APPS = {
    "labelprop": lambda hg: label_propagation_spec(hg, iters=8),
    "pagerank": lambda hg: pagerank_spec(hg, iters=8),
    "pagerank_entropy": lambda hg: pagerank_entropy_spec(hg, iters=8),
    "sssp": lambda hg: shortest_paths_spec(hg, 0, max_iters=16),
}

REGIMES = {
    "dblp": 0.003,
    "friendster": 0.001,
    "orkut": 0.0004,
}


def run(n_parts: int = 8) -> None:
    engine = Engine(backend="local")
    for regime, base_scale in REGIMES.items():
        hg = make_dataset(regime, scale=base_scale * SCALE, seed=0)
        for strat in STRATEGIES:
            kw = {"chunk": 256} if "greedy" in strat else {}
            plan = partition(strat, hg, n_parts, **kw)
            s = plan.stats
            backend, _ = select_backend(
                plan, hg.n_vertices, hg.n_hyperedges
            )
            row(
                f"partition/{regime}/{strat}/partition_time",
                plan.partition_time_s * 1e6,
                f"vrep={s.vertex_replication:.2f};"
                f"herep={s.hyperedge_replication:.2f};"
                f"bal={s.edge_balance:.2f};"
                f"sync_bytes={s.sync_bytes_per_dim:.0f};"
                f"auto_backend={backend}",
            )
        for app, make_spec in APPS.items():
            t, _ = timed(
                lambda: engine.run(make_spec(hg)).value, repeats=2
            )
            row(f"partition/{regime}/{app}/exec_time", t * 1e6,
                f"nv={hg.n_vertices};ne={hg.n_hyperedges};nnz={hg.nnz}")


if __name__ == "__main__":
    run()
