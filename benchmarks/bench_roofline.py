"""§Roofline table: render the dry-run JSON into the per-cell report."""
from __future__ import annotations

import json
import os

from benchmarks.common import row


def run(path: str = "reports/dryrun_full.json") -> None:
    if not os.path.exists(path):
        row("roofline/missing", 0.0,
            f"run `python -m repro.launch.dryrun --all --mesh both "
            f"--out {path}` first")
        return
    with open(path) as f:
        results = json.load(f)
    for r in results:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        row(
            f"roofline/{r['cell']}",
            rf["compute_s"] * 1e6,
            f"mem_us={rf['memory_s'] * 1e6:.1f};"
            f"coll_us={rf['collective_s'] * 1e6:.1f};"
            f"dom={rf['dominant']};"
            f"useful={rf['useful_ratio']:.3f};"
            f"frac={rf['roofline_fraction']:.3f}",
        )


if __name__ == "__main__":
    run()
