"""Batch analytics under the Engine: intersection kernels + h-motif census.

Measures, per dataset regime:

* pair-intersections/sec for BOTH kernel paths (bitset word lanes vs
  sorted-merge ``searchsorted``) over the same overlapping-pair batch —
  the quantity the ``select_intersect_kernel`` cost model trades off;
* exact census wall-time through ``Engine.analyze`` (``mode="exact"``),
  and the sampled estimator's wall-time + relative error against it;
* which kernel ``intersect_kernel="auto"`` picks — asserted to flip
  between the small-vocab and large-vocab inputs (the acceptance check
  of the motif subsystem).

Emits CSV rows to stdout plus a ``BENCH_motifs.json`` artifact (the
nightly CI job uploads these).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import AnalyticsSpec, Engine
from repro.data import make_dataset, powerlaw_hypergraph
from repro.motifs import (
    batch_intersections,
    build_index,
    overlap_pairs,
    select_intersect_kernel,
)

from benchmarks.common import SCALE, emit_json, row, timed


def bench_kernels(name: str, hg, results: dict) -> None:
    pairs = overlap_pairs(hg)
    ea, eb = pairs[:, 0].astype(np.int32), pairs[:, 1].astype(np.int32)
    auto_pick, why = select_intersect_kernel(hg)
    entry = results.setdefault(name, {
        "n_vertices": hg.n_vertices,
        "n_hyperedges": hg.n_hyperedges,
        "nnz": hg.nnz,
        "n_overlap_pairs": int(len(pairs)),
        "auto_kernel": auto_pick,
        "auto_reason": why["reason"],
    })
    for kernel in ("bitset", "merge"):
        index = build_index(hg, kernel)
        t, _ = timed(lambda: batch_intersections(index, ea, eb))
        rate = len(pairs) / max(t, 1e-12)
        entry[f"{kernel}_pairs_per_sec"] = rate
        entry[f"{kernel}_index_bytes"] = index.nbytes
        row(
            f"motifs/{name}/intersect_{kernel}", t * 1e6,
            f"pairs={len(pairs)};pairs_per_s={rate:.3g};"
            f"auto={auto_pick}",
        )


def bench_census(name: str, hg, results: dict) -> None:
    engine = Engine()
    spec = AnalyticsSpec(hg)
    t0 = time.perf_counter()
    res = engine.analyze(spec, intersect_kernel="auto")
    exact_s = time.perf_counter() - t0
    census = res.value
    entry = results[name]
    entry.update(
        census_total=int(census.total),
        census_wall_s=exact_s,
        census_kernel=res.kernel,
        census_representation=res.representation,
    )
    row(
        f"motifs/{name}/census_exact", exact_s * 1e6,
        f"total={census.total};triples={census.n_triples};"
        f"kernel={res.kernel};representation={res.representation}",
    )
    t0 = time.perf_counter()
    est = engine.analyze(
        AnalyticsSpec(hg, mode="sample", n_samples=2000, seed=1)
    ).value
    sample_s = time.perf_counter() - t0
    rel_err = abs(est.total - census.total) / max(census.total, 1)
    entry.update(sample_wall_s=sample_s, sample_rel_err=float(rel_err))
    row(
        f"motifs/{name}/census_sampled", sample_s * 1e6,
        f"total~{est.total:.0f};rel_err={rel_err:.3f};"
        f"samples={est.n_samples}",
    )


def run() -> None:
    results: dict = {}
    # Small vocabulary: bitset word lanes win.  dblp-regime at CI scale.
    small = make_dataset("dblp", scale=0.004 * SCALE, seed=0)
    # Large vocabulary, small cardinalities: sorted-merge wins (word
    # count scales with |V|, merge work with max cardinality only).
    large = powerlaw_hypergraph(
        int(400_000 * SCALE), int(3_000 * SCALE),
        mean_cardinality=3.0, max_cardinality=24, seed=0,
    )
    bench_kernels("small_vocab", small, results)
    bench_kernels("large_vocab", large, results)
    picks = {results["small_vocab"]["auto_kernel"],
             results["large_vocab"]["auto_kernel"]}
    assert picks == {"bitset", "merge"}, (
        f"auto must pick different kernels for small vs large "
        f"vocabularies, got {picks}"
    )
    bench_census("small_vocab", small, results)
    bench_census("large_vocab", large, results)
    emit_json("motifs", results)


if __name__ == "__main__":
    run()
