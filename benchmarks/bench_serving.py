"""Compile-once serve-many: cold vs warm queries/sec through
``Engine.compile`` (the serving-path canary).

Measures, on one dblp-regime hypergraph:

* **cold**: ``Engine.compile`` + the first ``run_batch`` of B SSSP
  sources — pays design-point resolution, tracing and XLA compilation;
* **warm**: subsequent ``run_batch`` calls with fresh source batches —
  the shape-bucketed executable cache must serve them with ZERO
  retracing (asserted via ``Engine.cache_stats()``'s trace counter);
* **same-bucket serve**: a second hypergraph padded into the same shape
  bucket, served by the cached executable (again zero retraces);
* single-query warm latency through ``CompiledAlgorithm.run(query=s)``.

Asserts warm-cache throughput ≥ 5x cold (the cheap CI canary against
cache regressions — in practice the gap is orders of magnitude) and
writes ``BENCH_serving.json`` (uploaded by the nightly CI job).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.algorithms import shortest_paths_spec
from repro.core import Engine
from repro.data import make_dataset

from benchmarks.common import SCALE, emit_json, row

BATCH = 8
ITERS = 8
WARM_REPEATS = 5


def _serve(compiled, queries, hg=None) -> float:
    t0 = time.perf_counter()
    res = compiled.run_batch(queries, hg=hg)
    jax.block_until_ready(res.value)
    return time.perf_counter() - t0


def run() -> None:
    hg = make_dataset("dblp", scale=0.002 * SCALE, seed=0)
    rng = np.random.default_rng(0)
    engine = Engine()
    spec = shortest_paths_spec(hg, 0, ITERS)

    # -- cold: compile + first batch (trace + XLA compile + execute) ------
    t0 = time.perf_counter()
    compiled = engine.compile(spec)
    _serve(compiled, rng.integers(0, hg.n_vertices, BATCH).astype(np.int32))
    cold_s = time.perf_counter() - t0
    cold_qps = BATCH / cold_s
    row(f"serving/cold_batch{BATCH}", cold_s * 1e6,
        f"qps={cold_qps:.1f};cache={engine.cache_stats()}")

    # -- warm: fresh source batches, cached executable --------------------
    traces_before = engine.cache_stats()["traces"]
    warm_times = [
        _serve(
            compiled,
            rng.integers(0, hg.n_vertices, BATCH).astype(np.int32),
        )
        for _ in range(WARM_REPEATS)
    ]
    warm_s = sorted(warm_times)[len(warm_times) // 2]
    warm_qps = BATCH / warm_s
    retraces = engine.cache_stats()["traces"] - traces_before
    assert retraces == 0, (
        f"warm batches retraced {retraces}x — executable cache regression"
    )
    row(f"serving/warm_batch{BATCH}", warm_s * 1e6,
        f"qps={warm_qps:.1f};retraces={retraces}")

    # -- second hypergraph served by the same compiled handle -------------
    # (retraces reported, not asserted: a seed-1 regime draw usually —
    # but not provably — lands in the seed-0 shape bucket)
    hg2 = make_dataset("dblp", scale=0.002 * SCALE, seed=1)
    traces_before = engine.cache_stats()["traces"]
    bucket_s = _serve(
        compiled,
        rng.integers(0, hg2.n_vertices, BATCH).astype(np.int32),
        hg=hg2,
    )
    same_bucket_retraces = engine.cache_stats()["traces"] - traces_before
    row(f"serving/second_hg_batch{BATCH}", bucket_s * 1e6,
        f"qps={BATCH / bucket_s:.1f};retraces={same_bucket_retraces}")

    # -- single-query warm latency ----------------------------------------
    times = []
    for s in rng.integers(0, hg.n_vertices, 5):
        t0 = time.perf_counter()
        res = compiled.run(query=int(s))
        jax.block_until_ready(res.value)
        times.append(time.perf_counter() - t0)
    single_s = sorted(times)[len(times) // 2]
    row("serving/warm_single", single_s * 1e6,
        f"qps={1.0 / single_s:.1f}")

    speedup = warm_qps / cold_qps
    assert speedup >= 5.0, (
        f"warm throughput only {speedup:.1f}x cold (< 5x): compile "
        "amortization regressed"
    )
    emit_json("serving", {
        "n_vertices": hg.n_vertices,
        "n_hyperedges": hg.n_hyperedges,
        "nnz": hg.nnz,
        "batch": BATCH,
        "iters": ITERS,
        "cold_s": cold_s,
        "cold_qps": cold_qps,
        "warm_s": warm_s,
        "warm_qps": warm_qps,
        "warm_over_cold": speedup,
        "warm_single_s": single_s,
        "same_bucket_s": bucket_s,
        "same_bucket_retraces": int(same_bucket_retraces),
        "cache_stats": engine.cache_stats(),
    })


if __name__ == "__main__":
    run()
