"""Paper Fig. 15 + Table II: MESH vs a specialized implementation.

HyperX does not exist in this environment; the comparison target is a
hand-specialized Label Propagation written directly against the incidence
arrays with zero framework machinery — the same flexibility-vs-
specialization axis the paper probes.  We report wall time of both and the
LOC comparison (bench_loc).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms import label_propagation
from repro.data import make_dataset

from benchmarks.common import SCALE, row, timed


from functools import partial


@partial(jax.jit, static_argnums=(2, 3))
def _specialized_lp(src, dst, nv: int, ne: int, v0, he0):
    """Direct label propagation: no Program/engine indirection."""

    def body(carry, _):
        v, he = carry
        he2 = jnp.maximum(
            he, jax.ops.segment_max(v[src], dst, num_segments=ne)
        )
        v2 = jnp.maximum(
            v, jax.ops.segment_max(he2[dst], src, num_segments=nv)
        )
        return (v2, he2), None

    (v, he), _ = jax.lax.scan(body, (v0, he0), None, length=8)
    return v, he


def run() -> None:
    for regime, base_scale in [("dblp", 0.003), ("orkut", 0.0004)]:
        hg = make_dataset(regime, scale=base_scale * SCALE, seed=0)
        t_mesh, (v_mesh, _) = timed(label_propagation, hg, 8, repeats=2)
        v0 = jnp.arange(hg.n_vertices, dtype=jnp.int32)
        he0 = jnp.full((hg.n_hyperedges,), -1, jnp.int32)
        t_spec, (v_spec, _) = timed(
            _specialized_lp, hg.src, hg.dst, hg.n_vertices,
            hg.n_hyperedges, v0, he0, repeats=2,
        )
        agree = bool(jnp.array_equal(v_mesh, v_spec))
        row(
            f"vs_specialized/{regime}/mesh_api", t_mesh * 1e6,
            f"agree={agree}",
        )
        row(
            f"vs_specialized/{regime}/specialized", t_spec * 1e6,
            f"overhead={t_mesh / max(t_spec, 1e-9):.2f}x",
        )


if __name__ == "__main__":
    run()
