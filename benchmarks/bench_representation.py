"""Paper Fig. 7 + Table I: bipartite vs clique-expanded representation.

Measures (a) representation build + partition time, (b) PageRank execution
time on each representation, (c) edge counts — including the
clique-infeasibility of the friendster/orkut regimes (Table I's "10.3
billion (approximate)" entries), reproduced via the closed-form estimator
without materializing.

Both representations run through the ``Engine`` facade on the same
``vertex_pagerank_spec`` — ``representation="bipartite"`` vs ``"clique"``
is exactly the design axis the Engine exposes; each row also reports which
representation ``"auto"`` would pick for that dataset.
"""
from __future__ import annotations

import time

from repro.algorithms import vertex_pagerank_spec
from repro.core import (
    Engine,
    clique_expansion_size,
    select_representation,
    to_graph,
)
from repro.data import make_dataset

from benchmarks.common import SCALE, row, timed


def run() -> None:
    eng_bip = Engine(representation="bipartite")
    eng_clq = Engine(representation="clique")
    for name, scale in [("apache", 0.05 * SCALE), ("dblp", 0.004 * SCALE)]:
        hg = make_dataset(name, scale=scale, seed=0)
        spec = vertex_pagerank_spec(hg, iters=10)
        t0 = time.perf_counter()
        g = to_graph(hg)  # build cost is a measured quantity (Fig. 7)
        build_s = time.perf_counter() - t0
        t_bip, _ = timed(lambda: eng_bip.run(spec).value)
        # Exec-only timing on the prebuilt graph (Engine.run would fold
        # the expansion build into every repeat); one facade run keeps
        # the representation="clique" path itself exercised.
        eng_clq.run(spec)
        t_clq, _ = timed(lambda: spec.clique_program(g))
        auto_pick, _ = select_representation(spec, hg)
        row(
            f"representation/{name}/bipartite_exec", t_bip * 1e6,
            f"edges={hg.nnz};auto={auto_pick}",
        )
        row(
            f"representation/{name}/clique_exec", t_clq * 1e6,
            f"edges={int(g.src.shape[0])};build_s={build_s:.3f};"
            f"auto={auto_pick}",
        )
    # Table I scale estimates: the clique expansion of the heavy regimes
    # is orders of magnitude larger -> not materializable (paper §V-B).
    for name, scale in [("friendster", 0.002 * SCALE),
                        ("orkut", 0.001 * SCALE)]:
        hg = make_dataset(name, scale=scale, seed=0)
        est = clique_expansion_size(hg)
        auto_pick, _ = select_representation(
            vertex_pagerank_spec(hg, iters=2), hg
        )
        row(
            f"representation/{name}/clique_edges_estimate", 0.0,
            f"bipartite={hg.nnz};clique~{est};"
            f"ratio={est / max(hg.nnz, 1):.1f}x;auto={auto_pick}",
        )


if __name__ == "__main__":
    run()
