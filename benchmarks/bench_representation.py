"""Paper Fig. 7 + Table I: bipartite vs clique-expanded representation.

Measures (a) representation build + partition time, (b) PageRank execution
time on each representation, (c) edge counts — including the
clique-infeasibility of the friendster/orkut regimes (Table I's "10.3
billion (approximate)" entries), reproduced via the closed-form estimator
without materializing.
"""
from __future__ import annotations

import time

from repro.algorithms import graph_pagerank, pagerank
from repro.core import clique_expansion_size, to_graph
from repro.data import make_dataset

from benchmarks.common import SCALE, row, timed


def run() -> None:
    for name, scale in [("apache", 0.05 * SCALE), ("dblp", 0.004 * SCALE)]:
        hg = make_dataset(name, scale=scale, seed=0)
        t0 = time.perf_counter()
        g = to_graph(hg)
        build_s = time.perf_counter() - t0
        t_bip, _ = timed(pagerank, hg, 10)
        t_clq, _ = timed(graph_pagerank, g, 10)
        row(
            f"representation/{name}/bipartite_exec", t_bip * 1e6,
            f"edges={hg.nnz}",
        )
        row(
            f"representation/{name}/clique_exec", t_clq * 1e6,
            f"edges={int(g.src.shape[0])};build_s={build_s:.3f}",
        )
    # Table I scale estimates: the clique expansion of the heavy regimes
    # is orders of magnitude larger -> not materializable (paper §V-B).
    for name, scale in [("friendster", 0.002 * SCALE),
                        ("orkut", 0.001 * SCALE)]:
        hg = make_dataset(name, scale=scale, seed=0)
        est = clique_expansion_size(hg)
        row(
            f"representation/{name}/clique_edges_estimate", 0.0,
            f"bipartite={hg.nnz};clique~{est};ratio={est / max(hg.nnz, 1):.1f}x",
        )


if __name__ == "__main__":
    run()
