"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.row).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_delivery,
        bench_loc,
        bench_motifs,
        bench_obs,
        bench_partitioning,
        bench_representation,
        bench_roofline,
        bench_scaling,
        bench_serve_tier,
        bench_serving,
        bench_vs_specialized,
    )

    suites = [
        ("loc (Table II)", bench_loc.run),
        ("representation (Fig 7, Table I)", bench_representation.run),
        ("partitioning (Figs 8-11)", bench_partitioning.run),
        ("scaling (Figs 12-14)", bench_scaling.run),
        ("vs_specialized (Fig 15)", bench_vs_specialized.run),
        ("roofline (EXPERIMENTS §Roofline)", bench_roofline.run),
        ("motifs (batch analytics)", bench_motifs.run),
        ("serving (compile-once serve-many)", bench_serving.run),
        ("serve_tier (front-end + persistent cache)", bench_serve_tier.run),
        ("delivery (fused superstep data path)", bench_delivery.run),
        ("obs (trace coverage + overhead)", bench_obs.run),
    ]
    failures = 0
    print("name,us_per_call,derived")
    for label, fn in suites:
        print(f"# --- {label} ---", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
