"""Observability tier: trace coverage + the zero-overhead contract.

Two claims the obs tentpole makes about itself, measured:

* **trace coverage** — a traced end-to-end serve run (Engine(tracer=)
  + Frontend, synchronous ``pump`` mode) exports valid Chrome-trace
  JSON whose top-level (depth-0) spans account for the serve wall time
  within 20%.  A tracer that drops the compile or misattributes the
  execute would show up here as a coverage hole.
* **zero overhead untraced** — steady-state ``run_batch`` through an
  Engine WITHOUT a tracer must cost the same as one WITH a tracer to
  within noise (interleaved rounds, median of per-round ratios — the
  ``bench_delivery`` discipline for this drifting shared host).  The
  hot paths branch on ``tracer is None``; this is the canary that a
  future edit doesn't move span bookkeeping onto the untraced path.

Writes ``BENCH_obs.json`` (uploaded by the nightly CI job).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.algorithms import random_walk_spec, shortest_paths_spec
from repro.core import Engine
from repro.data import make_dataset
from repro.obs import Tracer
from repro.serve import Frontend

from benchmarks.common import SCALE, emit_json, row

REQUESTS = 32
MAX_BATCH = 16
ITERS = 8
ROUNDS = 9
COVERAGE_BAND = 0.20        # depth-0 span sum within ±20% of wall
OVERHEAD_CEILING = 1.30     # traced/untraced median ratio (noise incl.)


def _specs(hg):
    return {
        "sssp": shortest_paths_spec(hg, 0, ITERS),
        "ppr": random_walk_spec(hg, iters=ITERS),
    }


def _traced_serve(hg) -> dict:
    tracer = Tracer()
    engine = Engine(tracer=tracer)
    fe = Frontend(engine, max_batch=MAX_BATCH, max_delay_ms=5.0)
    for key, spec in _specs(hg).items():
        fe.register(key, spec)
    rng = np.random.default_rng(0)
    trace = [
        ("sssp" if rng.random() < 0.6 else "ppr",
         int(rng.integers(0, hg.n_vertices)))
        for _ in range(REQUESTS)
    ]
    t0 = time.perf_counter()
    futs = [fe.submit(key, query=q) for key, q in trace]
    fe.pump(drain=True)
    for f in futs:
        f.result()
    wall_s = time.perf_counter() - t0

    spans = tracer.spans()
    top_s = sum(sp.dur_s for sp in spans if sp.depth == 0)
    coverage = top_s / max(wall_s, 1e-12)
    by_cat: dict = {}
    for sp in spans:
        by_cat[sp.cat] = by_cat.get(sp.cat, 0) + 1

    # exported artifact must be loadable Chrome-trace JSON.
    path = os.path.join(
        tempfile.mkdtemp(prefix="repro-bench-obs-"), "serve.trace.json"
    )
    tracer.export(path)
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert events, "traced serve run exported no events"
    for ev in events:
        for field in ("name", "cat", "ph", "ts", "dur", "pid", "tid"):
            assert field in ev, f"Chrome-trace event missing {field}: {ev}"
        assert ev["ph"] == "X", ev

    assert abs(coverage - 1.0) <= COVERAGE_BAND, (
        f"depth-0 span coverage {coverage:.2f} of serve wall "
        f"(outside ±{COVERAGE_BAND:.0%}): the tracer is losing or "
        "double-counting phases"
    )
    row(f"obs/traced_serve{REQUESTS}", wall_s * 1e6,
        f"coverage={coverage:.3f};spans={len(spans)};"
        f"dropped={tracer.dropped}")
    return {
        "wall_s": wall_s,
        "coverage": coverage,
        "n_spans": len(spans),
        "dropped": tracer.dropped,
        "spans_by_cat": by_cat,
        "trace_events": len(events),
    }


def _overhead(hg) -> dict:
    """Interleaved steady-state run_batch: traced vs untraced engine."""
    spec = shortest_paths_spec(hg, 0, ITERS)
    queries = np.arange(MAX_BATCH, dtype=np.int32) % hg.n_vertices
    plain = Engine().compile(spec)
    traced_eng = Engine(tracer=Tracer(capacity=16))
    traced = traced_eng.compile(spec)
    for c in (plain, traced):  # warm both executables
        jax.block_until_ready(c.run_batch(queries).value)
    ratios = []
    t_plain = []
    for _ in range(ROUNDS):
        t0 = time.perf_counter()
        jax.block_until_ready(plain.run_batch(queries).value)
        dt_plain = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(traced.run_batch(queries).value)
        dt_traced = time.perf_counter() - t0
        t_plain.append(dt_plain)
        ratios.append(dt_traced / dt_plain)
    ratios.sort()
    t_plain.sort()
    ratio = ratios[len(ratios) // 2]
    assert ratio <= OVERHEAD_CEILING, (
        f"traced run_batch {ratio:.2f}x untraced "
        f"(> {OVERHEAD_CEILING}x): span bookkeeping leaked onto the "
        "hot path"
    )
    row("obs/untraced_run_batch", t_plain[len(t_plain) // 2] * 1e6,
        f"traced_over_untraced={ratio:.3f}")
    return {
        "untraced_s": t_plain[len(t_plain) // 2],
        "traced_over_untraced": ratio,
        "rounds": ROUNDS,
    }


def run() -> None:
    hg = make_dataset("dblp", scale=0.002 * SCALE, seed=0)
    results = {
        "scale": SCALE,
        "n_vertices": hg.n_vertices,
        "n_hyperedges": hg.n_hyperedges,
        "nnz": hg.nnz,
        "requests": REQUESTS,
        "traced_serve": _traced_serve(hg),
        "overhead": _overhead(hg),
    }
    emit_json("obs", results)


if __name__ == "__main__":
    run()
