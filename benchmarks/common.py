"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

import jax

# Global scale knob: 1.0 reproduces paper-sized ratios at CI-feasible size;
# raise on beefier hosts (paper-scale needs a real cluster).
SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))


def timed(fn, *args, repeats: int = 3, **kw):
    """Median wall time; blocks on jax outputs."""
    times = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def emit_json(name: str, payload: dict) -> str:
    """Write a machine-readable benchmark artifact ``BENCH_<name>.json``
    (the nightly CI job uploads these) next to the CSV rows on stdout.

    ``BENCH_OUTPUT_DIR`` overrides the destination directory."""
    out_dir = os.environ.get("BENCH_OUTPUT_DIR", ".")
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
    print(f"wrote {path}", flush=True)
    return path
