"""Fused vs reference delivery: rows/sec + modeled HBM traffic across
skew regimes (the tentpole's perf canary).

The deliver/combine data path dominates every MESH superstep.  This
bench times one half-superstep — combine ``[nnz]`` incidences into
``n_dst`` destinations — through both delivery design points:

* ``xla``: the reference gather -> ``where`` mask -> segment reduce
  (materializes ``[nnz, D]`` in HBM, re-reads it, serialized scatter);
* ``pallas_fused``: the dst-sorted fused layout
  (``repro.kernels.deliver``; the layout precompute is paid ONCE, as in
  ``Engine.compile``, and excluded from the steady-state timing).

Three regimes probe the cost model's axes (message width, degree skew):

* ``narrow_lowskew`` — scalar messages, bounded degrees: the SSSP /
  components / labelprop shape, and the fused path's home turf on XLA
  hosts (dense ELL reduce vs serialized scatter).  Asserted ≥ 1.5x
  rows/sec over the reference AND picked by ``delivery='auto'``.
* ``narrow_highskew`` — zipf destination popularity: the capped ELL
  absorbs the bulk and the heavy tails ride the dst-sorted overflow —
  still a measured fused win (~3x), so ``auto`` must pick fused here
  too (asserted, with a looser floor).
* ``wide_lowskew`` — 64-lane float rows: the reference gather/scatter
  already vectorizes; ``auto`` must keep the reference path (asserted).

On a native-Pallas host (TPU) the fused kernel's block-sparse skip
changes the picture — the wide/high-skew regimes become fused wins too
(the ``[nnz, D]`` intermediate is 3x traffic regardless of skew); the
cost model is platform-aware via ``select_lowering``.  Asserts here are
calibrated for the XLA (ELL) lowering CI actually runs.

Writes ``BENCH_delivery.json`` (uploaded by the nightly CI job).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.algorithms.spec import AlgorithmSpec
from repro.core.api import Program
from repro.core.engine import deliver
from repro.core.executor import select_delivery
from repro.core.hypergraph import HyperGraph
from repro.kernels.deliver import build_delivery_layout, fused_deliver

from benchmarks.common import SCALE, emit_json, row, timed

REGIMES = {
    # (nnz, n_dst, width, zipf_skew)
    "narrow_lowskew": (200_000, 8192, (), False),
    "narrow_highskew": (200_000, 8192, (), True),
    "wide_lowskew": (200_000, 8192, (64,), False),
}
FUSED_SPEEDUP_FLOOR = 1.5  # acceptance: fused >= 1.5x in its regime


def _make_regime(nnz, n_dst, width, skew, seed=0):
    rng = np.random.default_rng(seed)
    nnz = max(int(nnz * SCALE), 4096)
    n_dst = max(int(n_dst * SCALE), 256)
    n_src = n_dst
    if skew:
        p = 1.0 / np.arange(1, n_dst + 1)
        dst = rng.choice(n_dst, size=nnz, p=p / p.sum()).astype(np.int32)
    else:
        dst = rng.integers(0, n_dst, nnz).astype(np.int32)
    src = rng.integers(0, n_src, nnz).astype(np.int32)
    msg = rng.standard_normal((n_src,) + width).astype(np.float32)
    return src, dst, msg, n_src, n_dst, nnz


def _traffic_model(layout, nnz, n_dst, width_bytes):
    """Effective HBM bytes per half-superstep, both paths.

    Reference: read ids, gather+write the [nnz, D] rows array, re-read
    it for the masked scatter, write the output.  Fused: read the
    layout ids once, read each gathered row once, write the output —
    the intermediate never exists.
    """
    ref = nnz * (3 * width_bytes + 2 * 4) + n_dst * width_bytes
    ell_rows = layout.ell_idx.size + layout.rem_len
    fused = ell_rows * (width_bytes + 4) + n_dst * width_bytes
    return ref, fused


def run() -> None:
    results: dict = {"regimes": {}, "scale": SCALE}
    prog = Program(procedure=lambda *a: None, combiner="sum")

    for name, (nnz0, n_dst0, width, skew) in REGIMES.items():
        src, dst, msg, n_src, n_dst, nnz = _make_regime(
            nnz0, n_dst0, width, skew
        )
        msg_j = jnp.asarray(msg)
        src_j, dst_j = jnp.asarray(src), jnp.asarray(dst)

        ref_fn = jax.jit(
            lambda m, s, d: deliver(m, None, s, d, n_dst, prog)
        )
        t_ref, _ = timed(ref_fn, msg_j, src_j, dst_j, repeats=5)

        layout = build_delivery_layout(src, dst, None, n_src, n_dst)
        # layout rides as an operand (as in the engine path) — closed
        # over, XLA constant-folds the gathers and skews the timing.
        fused_fn = jax.jit(
            lambda m, lay: fused_deliver(m, None, lay, prog)
        )
        t_fused, _ = timed(fused_fn, msg_j, layout, repeats=5)

        speedup = t_ref / t_fused
        width_bytes = float(
            np.prod(width, dtype=np.int64) * 4 if width else 4
        )
        ref_bytes, fused_bytes = _traffic_model(
            layout, nnz, n_dst, width_bytes
        )

        # what would auto do here? (a minimal monoid spec carrying the
        # regime's message width)
        hg = HyperGraph.from_coo(src, dst, n_src, n_dst)
        spec = AlgorithmSpec(
            hg0=hg,
            initial_msg=jnp.zeros(width, jnp.float32),
            v_program=prog,
            he_program=prog,
            max_iters=1,
            extract=lambda out: out,
            name=f"bench_{name}",
        )
        auto_choice, why = select_delivery(spec, hg)

        results["regimes"][name] = {
            "nnz": nnz,
            "n_dst": n_dst,
            "width_bytes": width_bytes,
            "skew": skew,
            "xla_s": t_ref,
            "fused_s": t_fused,
            "xla_rows_per_s": nnz / t_ref,
            "fused_rows_per_s": nnz / t_fused,
            "fused_speedup": speedup,
            "model_xla_hbm_bytes": ref_bytes,
            "model_fused_hbm_bytes": fused_bytes,
            "model_traffic_ratio": ref_bytes / max(fused_bytes, 1.0),
            "ell_k": layout.k,
            "ell_remainder": layout.rem_len,
            "auto_picks": auto_choice,
            "auto_reason": why.get("reason"),
        }
        row(
            f"delivery/{name}/xla", t_ref * 1e6,
            f"rows_per_s={nnz / t_ref:.0f}",
        )
        row(
            f"delivery/{name}/pallas_fused", t_fused * 1e6,
            f"rows_per_s={nnz / t_fused:.0f};speedup={speedup:.2f};"
            f"auto={auto_choice}",
        )

    r = results["regimes"]
    # The cost model must track the measured winner per regime...
    assert r["narrow_lowskew"]["auto_picks"] == "pallas_fused", (
        "auto must pick the fused path in its winning regime",
        r["narrow_lowskew"],
    )
    assert r["narrow_highskew"]["auto_picks"] == "pallas_fused", (
        "narrow messages win fused even under zipf skew (capped ELL + "
        "sorted overflow); auto must follow",
        r["narrow_highskew"],
    )
    assert r["wide_lowskew"]["auto_picks"] == "xla", (
        "wide rows must keep auto on the reference path (ELL lowering)",
        r["wide_lowskew"],
    )
    # ... and the fused path must actually deliver where auto sends it
    # (the tentpole's acceptance floor; skew gets a looser bar — the
    # overflow scatter claws back part of the win).
    measured = r["narrow_lowskew"]["fused_speedup"]
    assert measured >= FUSED_SPEEDUP_FLOOR, (
        f"fused delivery only {measured:.2f}x the XLA path "
        f"(< {FUSED_SPEEDUP_FLOOR}x) in the narrow/low-skew regime"
    )
    # noisy-host tolerance: under skew the win ranges ~1.15-3x run to
    # run; the canary only demands fused never LOSES where auto sends it
    assert r["narrow_highskew"]["fused_speedup"] >= 1.0, (
        "fused delivery lost under skew",
        r["narrow_highskew"],
    )
    emit_json("delivery", results)


if __name__ == "__main__":
    run()
