"""Fused vs reference delivery: rows/sec + modeled HBM traffic across
skew regimes (the delivery tentpoles' perf canary).

The deliver/combine data path dominates every MESH superstep.  This
bench times one half-superstep — combine ``[nnz]`` incidences into
``n_dst`` destinations — through the delivery design points:

* ``xla``: the reference gather -> ``where`` mask -> segment reduce
  (materializes ``[nnz, D]`` in HBM, re-reads it, serialized scatter);
* ``pallas_fused``: the dst-sorted degree-class (sliced-ELL) layout
  (``repro.kernels.deliver``; the layout precompute is paid ONCE, as in
  ``Engine.compile``, and excluded from the steady-state timing);
* ``single_ell`` (skewed regimes): the SAME fused lowering over a
  forced single-class plan — the PR-4 packing, whose capped width
  spills hub incidences into the overflow scatter.  The degree-class
  acceptance floors are measured against THIS, isolating what the
  class planner buys on skewed inputs.

Contenders are timed INTERLEAVED (round-robin, median of per-round
ratios) so the 2-3x load drift of this shared CPU host cancels out of
every ratio instead of landing on whichever contender ran last.

Five regimes probe the cost model's axes (message width, degree skew):

* ``narrow_lowskew`` — scalar messages, bounded degrees: the SSSP /
  components / labelprop shape.  Fused ≥ 1.5x rows/sec over the
  reference AND picked by ``delivery='auto'`` (asserted).
* ``narrow_highskew`` — scalar messages, zipf destination popularity:
  per-class widths keep hubs dense, so the win no longer bleeds into
  an overflow scatter.  ``auto`` must pick fused and the class layout
  must beat the single-ELL packing ≥ 2x (asserted; typ. 3.5-4.6x).
* ``mid_highskew`` — 4-lane (16-byte) rows under zipf: the scatter
  still pays per lane, so the class win persists into multi-lane
  messages.  Same floors as narrow_highskew (typ. 3.5-4.1x).
* ``wide_highskew`` — 16-lane (64-byte, the cost model's width cap)
  rows under zipf: the boundary regime the class layout FLIPPED.  The
  PR-4 single-ELL packing measures a ~2x loss to the reference here —
  so its cost model's fused pick was wrong exactly where skew met
  width.  Per-class widths win the regime back: ``auto`` must keep
  fused, fused must hold parity-or-better with the reference, and the
  class layout must beat single-ELL ≥ 1.2x (asserted; the 64-byte
  scatter amortizes per lane, so the margin is structural, not 2x).
* ``wide_lowskew`` — 64-lane (256-byte) rows, bounded degrees: the
  reference gather/scatter already vectorizes and dense-table row
  traffic multiplies with width; ``auto`` must keep the reference
  path, and the class layout must not regress the single-ELL packing
  (asserted).

On a native-Pallas host (TPU) the per-class grids change the picture
further (class-local ``max_blocks`` stops tail tiles from paying hub
grid extents); asserts here are calibrated for the XLA (ELL) lowering
CI actually runs.

Writes ``BENCH_delivery.json`` (uploaded by the nightly CI job).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.algorithms.spec import AlgorithmSpec
from repro.core.api import Program
from repro.core.engine import deliver
from repro.core.executor import select_delivery
from repro.core.hypergraph import HyperGraph
from repro.kernels.deliver import (
    build_delivery_layout,
    fused_deliver,
    plan_ell_width,
)
from repro.kernels.deliver.layout import ClassPlan
from repro.obs import delivery_calibration

from benchmarks.common import SCALE, emit_json, row

REGIMES = {
    # (nnz, n_dst, width, zipf_skew)
    "narrow_lowskew": (200_000, 8192, (), False),
    "narrow_highskew": (200_000, 8192, (), True),
    "mid_highskew": (200_000, 8192, (4,), True),
    "wide_highskew": (200_000, 8192, (16,), True),
    "wide_lowskew": (200_000, 8192, (64,), False),
}
ROUNDS = 7                  # interleaved timing rounds per regime
FUSED_SPEEDUP_FLOOR = 1.5   # fused >= 1.5x reference in its home regime
CLASS_SPEEDUP_FLOOR = 2.0   # class >= 2x single-ELL, narrow/mid skew
# The 64-byte boundary regime: scatter amortizes per lane, so the class
# margin over single-ELL is structural (typ. 1.4-2.1x), and parity with
# the reference is the flip being defended (typ. 1.0-1.45x).
WIDE_CLASS_FLOOR = 1.2
WIDE_PARITY_FLOOR = 0.9


def _make_regime(nnz, n_dst, width, skew, seed=0):
    rng = np.random.default_rng(seed)
    nnz = max(int(nnz * SCALE), 4096)
    n_dst = max(int(n_dst * SCALE), 256)
    n_src = n_dst
    if skew:
        p = 1.0 / np.arange(1, n_dst + 1)
        dst = rng.choice(n_dst, size=nnz, p=p / p.sum()).astype(np.int32)
    else:
        dst = rng.integers(0, n_dst, nnz).astype(np.int32)
    src = rng.integers(0, n_src, nnz).astype(np.int32)
    msg = rng.standard_normal((n_src,) + width).astype(np.float32)
    return src, dst, msg, n_src, n_dst, nnz


def _interleaved_times(fns_args, rounds=ROUNDS):
    """Round-robin timing: per contender, the list of per-round wall
    times (one untimed warmup each).  Ratios between contenders should
    be taken per round and medianed — host load drift then hits every
    contender of a round roughly equally."""
    for fn, args in fns_args:
        jax.block_until_ready(fn(*args))
    times = [[] for _ in fns_args]
    for _ in range(rounds):
        for i, (fn, args) in enumerate(fns_args):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            times[i].append(time.perf_counter() - t0)
    return times


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def _median_ratio(num, den):
    return _median([n / d for n, d in zip(num, den)])


def _single_ell_plan(dst, n_dst, nnz) -> ClassPlan:
    """The PR-4 packing as a forced plan: ONE class at the capped
    single-ELL width; everything past it overflows."""
    deg = np.bincount(dst, minlength=n_dst)
    k, rem = plan_ell_width(deg, nnz)
    return ClassPlan(
        widths=(k,), rows=(int((deg > 0).sum()),), residual=int(rem)
    )


def _layout_stats(layout, nnz):
    ell_slots = layout.ell_slots
    return {
        "class_widths": list(layout.class_widths),
        "class_rows": list(layout.class_rows),
        "ell_slots": ell_slots,
        "padding_fraction": (ell_slots + layout.rem_nnz) / max(nnz, 1) - 1.0,
        "residual_nnz": layout.rem_nnz,
    }


def _traffic_model(layout, nnz, n_dst, width_bytes):
    """Effective HBM bytes per half-superstep, both paths.

    Reference: read ids, gather+write the [nnz, D] rows array, re-read
    it for the masked scatter, write the output.  Fused: read the
    layout ids once, read each gathered row once, write the output —
    the intermediate never exists.
    """
    ref = nnz * (3 * width_bytes + 2 * 4) + n_dst * width_bytes
    ell_rows = layout.ell_slots + layout.rem_len
    fused = ell_rows * (width_bytes + 4) + n_dst * width_bytes
    return ref, fused


def run() -> None:
    results: dict = {"regimes": {}, "scale": SCALE}
    prog = Program(procedure=lambda *a: None, combiner="sum")

    for name, (nnz0, n_dst0, width, skew) in REGIMES.items():
        src, dst, msg, n_src, n_dst, nnz = _make_regime(
            nnz0, n_dst0, width, skew
        )
        msg_j = jnp.asarray(msg)
        src_j, dst_j = jnp.asarray(src), jnp.asarray(dst)

        ref_fn = jax.jit(
            lambda m, s, d: deliver(m, None, s, d, n_dst, prog)
        )
        layout = build_delivery_layout(src, dst, None, n_src, n_dst)
        # The PR-4 single-ELL packing through the same lowering: the
        # degree-class acceptance baseline (skewed regimes are where
        # they diverge; low-skew plans collapse to ~one class anyway).
        single = build_delivery_layout(
            src, dst, None, n_src, n_dst,
            plan=_single_ell_plan(dst, n_dst, nnz),
        )
        # layouts ride as operands (as in the engine path) — closed
        # over, XLA constant-folds the gathers and skews the timing.
        fused_fn = jax.jit(
            lambda m, lay: fused_deliver(m, None, lay, prog)
        )
        times = _interleaved_times([
            (ref_fn, (msg_j, src_j, dst_j)),
            (fused_fn, (msg_j, layout)),
            (fused_fn, (msg_j, single)),
        ])
        t_ref, t_fused, t_single = map(_median, times)
        speedup = _median_ratio(times[0], times[1])
        class_vs_single = _median_ratio(times[2], times[1])
        width_bytes = float(
            np.prod(width, dtype=np.int64) * 4 if width else 4
        )
        ref_bytes, fused_bytes = _traffic_model(
            layout, nnz, n_dst, width_bytes
        )

        # what would auto do here? (a minimal monoid spec carrying the
        # regime's message width)
        hg = HyperGraph.from_coo(src, dst, n_src, n_dst)
        spec = AlgorithmSpec(
            hg0=hg,
            initial_msg=jnp.zeros(width, jnp.float32),
            v_program=prog,
            he_program=prog,
            max_iters=1,
            extract=lambda out: out,
            name=f"bench_{name}",
        )
        auto_choice, why = select_delivery(spec, hg)

        results["regimes"][name] = {
            "nnz": nnz,
            "n_dst": n_dst,
            "width_bytes": width_bytes,
            "skew": skew,
            "xla_s": t_ref,
            "fused_s": t_fused,
            "single_ell_s": t_single,
            "xla_rows_per_s": nnz / t_ref,
            "fused_rows_per_s": nnz / t_fused,
            "fused_speedup": speedup,
            "class_vs_single_ell": class_vs_single,
            "model_xla_hbm_bytes": ref_bytes,
            "model_fused_hbm_bytes": fused_bytes,
            "model_traffic_ratio": ref_bytes / max(fused_bytes, 1.0),
            "class_layout": _layout_stats(layout, nnz),
            "single_ell_layout": _layout_stats(single, nnz),
            "auto_picks": auto_choice,
            "auto_reason": why.get("reason"),
            "auto_skew_gain": why.get("skew_gain"),
        }
        row(
            f"delivery/{name}/xla", t_ref * 1e6,
            f"rows_per_s={nnz / t_ref:.0f}",
        )
        row(
            f"delivery/{name}/pallas_fused", t_fused * 1e6,
            f"rows_per_s={nnz / t_fused:.0f};speedup={speedup:.2f};"
            f"vs_single_ell={class_vs_single:.2f};auto={auto_choice}",
        )

    r = results["regimes"]
    # The cost model must track the measured winner per regime...
    for regime in (
        "narrow_lowskew", "narrow_highskew", "mid_highskew",
        "wide_highskew",
    ):
        assert r[regime]["auto_picks"] == "pallas_fused", (
            "auto must pick the fused path in its winning regime",
            regime, r[regime],
        )
    assert r["wide_lowskew"]["auto_picks"] == "xla", (
        "wide rows on low-skew degrees must keep auto on the reference "
        "path (ELL lowering)",
        r["wide_lowskew"],
    )
    # ... the fused path must actually deliver where auto sends it
    # (noisy-host tolerance: floors sit below the typical interleaved
    # medians) ...
    measured = r["narrow_lowskew"]["fused_speedup"]
    assert measured >= FUSED_SPEEDUP_FLOOR, (
        f"fused delivery only {measured:.2f}x the XLA path "
        f"(< {FUSED_SPEEDUP_FLOOR}x) in the narrow/low-skew regime"
    )
    for regime in ("narrow_highskew", "mid_highskew"):
        assert r[regime]["fused_speedup"] >= 1.0, (
            "fused delivery lost to the reference where auto sends it",
            regime, r[regime],
        )
        # ... the degree-class acceptance floor: ≥ 2x the PR-4
        # single-ELL packing exactly where skew used to claw it back.
        got = r[regime]["class_vs_single_ell"]
        assert got >= CLASS_SPEEDUP_FLOOR, (
            f"degree-class layout only {got:.2f}x the single-ELL "
            f"packing (< {CLASS_SPEEDUP_FLOOR}x) in {regime}"
        )
    # ... the flipped boundary regime holds its ground ...
    assert r["wide_highskew"]["fused_speedup"] >= WIDE_PARITY_FLOOR, (
        "fused delivery fell below parity in the flipped 64-byte zipf "
        "regime",
        r["wide_highskew"],
    )
    assert r["wide_highskew"]["class_vs_single_ell"] >= WIDE_CLASS_FLOOR, (
        "degree-class layout lost its structural margin over single-ELL "
        "in the 64-byte zipf regime",
        r["wide_highskew"],
    )
    # ... with no regression where classes cannot help (low skew: the
    # plan collapses toward one class, so parity +/- host noise).
    for regime in ("narrow_lowskew", "wide_lowskew"):
        got = r[regime]["class_vs_single_ell"]
        assert got >= 0.75, (
            f"degree-class layout regressed single-ELL ({got:.2f}x) "
            f"in {regime}"
        )
    # Predicted-vs-measured residuals of the traffic model across the
    # regime table — the calibration record the ROADMAP's item asks
    # for, refreshed each nightly run alongside the raw timings.
    results["calibration"] = delivery_calibration(results["regimes"])
    cal = results["calibration"]["summary"]
    row(
        "delivery/calibration", 0.0,
        f"mean_abs_residual_log2={cal['mean_abs_residual_log2']:.3f};"
        f"decision_accuracy={cal['decision_accuracy']:.2f};"
        f"suggested_model_scale={cal['suggested_model_scale']:.3f}",
    )
    emit_json("delivery", results)


if __name__ == "__main__":
    run()
