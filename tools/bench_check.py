"""Compare fresh ``BENCH_*.json`` artifacts against committed baselines.

The nightly job produces machine-readable benchmark artifacts
(``benchmarks/common.emit_json``); this tool diffs them against the
checked-in snapshots under ``benchmarks/baselines/`` so regressions
surface in CI instead of in a human eyeballing artifact zips.

Two classes of numeric leaf, two severities:

* **ratio-type** metrics (name contains ``speedup``, ``ratio``,
  ``vs_``, ``_over_``, ``gain``, ``accuracy``, ``coverage``) are
  dimensionless and machine-independent — a real change in one is a
  real change in the system.  A fresh value below HALF its baseline
  **fails** the check (exit 1): that is a >2x regression of a quantity
  host-load drift cannot plausibly produce.
* everything else (wall times, q/s, byte counts) is host-dependent;
  deviations beyond the tolerance band (default ±50%) only **warn**.
  The nightly job stays green through runner roulette but the warning
  lines land in the log.

Usage:
  python tools/bench_check.py --fresh-dir bench-out
  python tools/bench_check.py --fresh-dir bench-out --update   # refresh
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

RATIO_MARKERS = (
    "speedup", "ratio", "vs_", "_over_", "gain", "accuracy", "coverage",
)
# leaves that are config echoes, not measurements — never compared.
SKIP_MARKERS = ("scale", "seed", "nnz", "n_vertices", "n_hyperedges")


def is_ratio_metric(path: str) -> bool:
    leaf = path.rsplit(".", 1)[-1].lower()
    return any(m in leaf for m in RATIO_MARKERS)


def _skip(path: str) -> bool:
    leaf = path.rsplit(".", 1)[-1].lower()
    return any(leaf == m or leaf.startswith(m + "_") for m in SKIP_MARKERS)


def numeric_leaves(doc, prefix: str = "") -> dict:
    """Flatten nested dicts/lists to ``dotted.path -> float`` leaves."""
    out: dict[str, float] = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            out.update(numeric_leaves(v, f"{prefix}.{k}" if prefix else k))
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            out.update(numeric_leaves(v, f"{prefix}[{i}]"))
    elif isinstance(doc, bool):
        pass  # True/False are labels, not measurements
    elif isinstance(doc, (int, float)):
        out[prefix] = float(doc)
    return out


def compare(fresh: dict, baseline: dict, tolerance: float):
    """(failures, warnings) comparing one artifact's numeric leaves."""
    failures: list[str] = []
    warnings: list[str] = []
    f_leaves = numeric_leaves(fresh)
    b_leaves = numeric_leaves(baseline)
    for path, base in sorted(b_leaves.items()):
        if _skip(path):
            continue
        if path not in f_leaves:
            warnings.append(f"missing in fresh run: {path}")
            continue
        got = f_leaves[path]
        if base == 0.0:
            continue  # no meaningful ratio against a zero baseline
        rel = got / base
        if is_ratio_metric(path):
            if rel < 0.5:
                failures.append(
                    f"{path}: {got:.4g} vs baseline {base:.4g} "
                    f"({rel:.2f}x) — >2x regression of a ratio metric"
                )
            elif abs(rel - 1.0) > tolerance:
                warnings.append(
                    f"{path}: {got:.4g} vs baseline {base:.4g} "
                    f"({rel:.2f}x)"
                )
        elif abs(rel - 1.0) > tolerance:
            warnings.append(
                f"{path}: {got:.4g} vs baseline {base:.4g} ({rel:.2f}x)"
            )
    for path in sorted(set(f_leaves) - set(b_leaves)):
        if not _skip(path):
            warnings.append(f"new metric (no baseline): {path}")
    return failures, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines",
                    help="directory of committed baseline snapshots")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="warn band for non-ratio leaves (0.5 = ±50%%)")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh artifacts over the baselines "
                         "instead of comparing")
    args = ap.parse_args(argv)

    fresh_paths = sorted(glob.glob(
        os.path.join(args.fresh_dir, "BENCH_*.json")
    ))
    if args.update:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for p in fresh_paths:
            dst = os.path.join(args.baseline_dir, os.path.basename(p))
            shutil.copyfile(p, dst)
            print(f"baseline updated: {dst}")
        return 0

    if not fresh_paths:
        print(f"no BENCH_*.json under {args.fresh_dir}", file=sys.stderr)
        return 2

    any_failures = False
    fresh_names = {os.path.basename(p) for p in fresh_paths}
    for bpath in sorted(glob.glob(
        os.path.join(args.baseline_dir, "BENCH_*.json")
    )):
        bname = os.path.basename(bpath)
        if bname not in fresh_names:
            print(f"warn: baseline {bname} has no fresh counterpart — "
                  f"the bench that produced it no longer runs?")
    for p in fresh_paths:
        name = os.path.basename(p)
        bpath = os.path.join(args.baseline_dir, name)
        if not os.path.exists(bpath):
            print(f"{name}: no baseline committed — skipped "
                  f"(run with --update to add one)")
            continue
        try:
            with open(p) as f:
                fresh = json.load(f)
            with open(bpath) as f:
                baseline = json.load(f)
        except (OSError, json.JSONDecodeError) as exc:
            # a truncated artifact from a killed runner should surface
            # as a warning line, not crash the whole comparison
            print(f"{name}: warn: unreadable artifact ({exc}) — skipped")
            continue
        failures, warnings = compare(fresh, baseline, args.tolerance)
        status = "FAIL" if failures else "ok"
        print(f"{name}: {status} "
              f"({len(failures)} failures, {len(warnings)} warnings)")
        for w in warnings:
            print(f"  warn: {w}")
        for fmsg in failures:
            print(f"  FAIL: {fmsg}")
        any_failures = any_failures or bool(failures)
    return 1 if any_failures else 0


if __name__ == "__main__":
    sys.exit(main())
