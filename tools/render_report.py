"""Render reports/dryrun_full.json into the EXPERIMENTS.md tables."""
from __future__ import annotations

import json
import sys


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1000:
            return f"{b:.1f}{unit}"
        b /= 1000
    return f"{b:.1f}PB"


def dryrun_table(results) -> str:
    lines = [
        "| cell | mesh | status | compile s | args/dev | temp/dev | "
        "collectives (static) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['cell']} | {r['mesh']} | skipped | — | — | — | "
                f"{r['reason'][:48]} |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['cell']} | {r['mesh']} | FAILED | — | — | — | "
                f"{r.get('error', '')[:60]} |"
            )
            continue
        m = r["memory"]
        cc = r["collective_counts"]
        coll = " ".join(
            f"{k.split('-')[1] if '-' in k else k}:{v}"
            for k, v in cc.items() if v
        )
        lines.append(
            f"| {r['cell']} | {r['mesh']} | ok | {r['compile_s']:.1f} | "
            f"{m['argument_gb']:.2f}GB | {m['temp_gb']:.2f}GB | {coll} |"
        )
    return "\n".join(lines)


def roofline_table(results) -> str:
    lines = [
        "| cell | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | roofline frac | peak mem/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['cell']} | {rf['compute_s']:.2e} | "
            f"{rf['memory_s']:.2e} | {rf['collective_s']:.2e} | "
            f"**{rf['dominant']}** | {rf['useful_ratio']:.3f} | "
            f"{rf['roofline_fraction']:.3f} | "
            f"{rf['peak_mem_gb']:.1f}GB |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun_full.json"
    with open(path) as f:
        results = json.load(f)
    print("## Dry-run table\n")
    print(dryrun_table(results))
    print("\n## Roofline table (single-pod)\n")
    print(roofline_table(results))
