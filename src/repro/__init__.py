"""repro: MESH (distributed hypergraph processing) rebuilt as a JAX/TPU
multi-pod framework. See DESIGN.md for the system inventory."""

__version__ = "0.1.0"
