"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU-native formulation (no ragged tensors): top-k routing -> flatten
(token, k) slots -> argsort by expert -> each expert owns a padded
``[capacity, d]`` block -> batched expert einsum on the MXU -> weighted
combine back by slot.  Dispatch/combine are gathers + one int scatter, the
same gather/segment primitive family the MESH engine runs on (tokens =
vertices, experts = hyperedges, routing = incidence; DESIGN.md §7).

Slots beyond capacity are dropped (GShard/Switch semantics) — the router's
load balance determines drop rate, mirroring how partition balance governs
MESH's padded shards.

``n_groups > 1`` (the §Perf "grouped dispatch" optimization, MaxText-style):
tokens are pre-split into groups aligned with the data-parallel sharding,
and the entire dispatch (argsort/cumsum/gather) is vmapped over groups.
Every dispatch op then carries a leading group dim the SPMD partitioner
shards cleanly — the baseline's global argsort+gather over [T, d] (which
XLA replicates per device) disappears.  Capacity is per-group, so routing
quality is unchanged in expectation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    n_shared_experts: int = 0      # always-on experts (llama4-style)
    router_z_loss: float = 1e-3
    n_groups: int = 1              # dispatch groups (see module docstring)


def moe_init(key, cfg: MoEConfig, d_model: int, dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_ff
    s_in = d_model**-0.5
    s_out = f**-0.5
    params = {
        "router": (jax.random.normal(k1, (d_model, e)) * s_in).astype(dtype),
        "w_gate": (
            jax.random.normal(k2, (e, d_model, f)) * s_in
        ).astype(dtype),
        "w_up": (
            jax.random.normal(k3, (e, d_model, f)) * s_in
        ).astype(dtype),
        "w_down": (
            jax.random.normal(k4, (e, f, d_model)) * s_out
        ).astype(dtype),
    }
    if cfg.n_shared_experts:
        from repro.models.layers import swiglu_init

        params["shared"] = swiglu_init(
            k5, d_model, f * cfg.n_shared_experts, dtype
        )
    return params


def capacity(cfg: MoEConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # pad to lane multiple


def _dispatch_group(xt, logits, cfg: MoEConfig, cap: int):
    """Route one token group: returns (x_e [E, cap, d], combine closure
    inputs).  All shapes static; no cross-group interaction."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)           # [t, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                        # [t*k]
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    pos_in_e = jnp.cumsum(jnp.ones_like(sorted_e)) - 1
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_in_e = pos_in_e - seg_start[sorted_e]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)

    token_of_slot = order // k
    gather_idx = jnp.full((e * cap + 1,), t, jnp.int32).at[dest].set(
        token_of_slot.astype(jnp.int32)
    )[: e * cap]
    x_pad = jnp.concatenate(
        [xt, jnp.zeros((1, d), xt.dtype)], axis=0
    )
    x_e = x_pad[gather_idx].reshape(e, cap, d)
    slot_w = jnp.where(keep, flat_p[order], 0.0)
    return x_e, (dest, token_of_slot, slot_w, keep, flat_e, probs)


def _combine_group(y_e, aux_in, t: int, cap: int, e: int):
    dest, token_of_slot, slot_w, keep, _, _ = aux_in
    d = y_e.shape[-1]
    y_flat = y_e.reshape(e * cap, d)
    y_pad = jnp.concatenate(
        [y_flat, jnp.zeros((1, d), y_e.dtype)], axis=0
    )
    slot_dest = jnp.where(keep, dest, e * cap)
    y_slot = y_pad[slot_dest] * slot_w[:, None].astype(y_e.dtype)
    return jax.ops.segment_sum(y_slot, token_of_slot, num_segments=t)


def moe_ffn(params, x, cfg: MoEConfig, compute_dtype=jnp.bfloat16):
    """x: [..., d]; flattened internally. Returns (y, aux) where aux
    carries the load-balance and router-z losses."""
    from repro.models.sharding import constrain

    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d).astype(compute_dtype)
    t = xt.shape[0]
    e, k = cfg.n_experts, cfg.top_k

    # group count: requested, shrunk to the largest divisor of t.
    # Grouping only pays when each group still has enough tokens to fill
    # expert capacity tiles — tiny-T (decode) stays global (measured: the
    # grouped path regressed decode collectives 2.4x from padding).
    g = max(1, min(cfg.n_groups, t))
    if t < 64 * cfg.n_experts:
        g = 1
    while t % g != 0:
        g -= 1
    tg = t // g
    cap = capacity(cfg, tg)

    logits = jnp.einsum(
        "td,de->te", xt, params["router"].astype(compute_dtype)
    ).astype(jnp.float32)

    if g == 1:
        x_e, aux_in = _dispatch_group(xt, logits, cfg, cap)
        x_e = constrain(x_e, "tp", None, None)
        gf = jnp.einsum(
            "ecd,edf->ecf", x_e, params["w_gate"].astype(compute_dtype)
        )
        uf = jnp.einsum(
            "ecd,edf->ecf", x_e, params["w_up"].astype(compute_dtype)
        )
        h = constrain(jax.nn.silu(gf) * uf, "tp", None, None)
        y_e = constrain(
            jnp.einsum(
                "ecf,efd->ecd", h, params["w_down"].astype(compute_dtype)
            ),
            "tp", None, None,
        )
        y = _combine_group(y_e, aux_in, t, cap, e)
        flat_e = aux_in[4]
        probs = aux_in[5]
    else:
        xg = constrain(xt.reshape(g, tg, d), "dp", None, None)
        lg = logits.reshape(g, tg, e)
        x_e, aux_in = jax.vmap(
            lambda xx, ll: _dispatch_group(xx, ll, cfg, cap)
        )(xg, lg)
        x_e = constrain(x_e, "dp", "tp", None, None)  # [G, E, cap, d]
        gf = jnp.einsum(
            "gecd,edf->gecf", x_e, params["w_gate"].astype(compute_dtype)
        )
        uf = jnp.einsum(
            "gecd,edf->gecf", x_e, params["w_up"].astype(compute_dtype)
        )
        h = constrain(jax.nn.silu(gf) * uf, "dp", "tp", None, None)
        y_e = constrain(
            jnp.einsum(
                "gecf,efd->gecd", h, params["w_down"].astype(compute_dtype)
            ),
            "dp", "tp", None, None,
        )
        y = jax.vmap(
            lambda yy, ai: _combine_group(yy, ai, tg, cap, e)
        )(y_e, aux_in).reshape(t, d)
        flat_e = aux_in[4].reshape(-1)
        probs = aux_in[5].reshape(t, e)

    if cfg.n_shared_experts:
        from repro.models.layers import swiglu

        y = y + swiglu(params["shared"], xt, compute_dtype)

    # Switch load-balance loss: E * sum_e (fraction_tokens_e * mean_prob_e)
    me = probs.mean(axis=0)
    ce = jax.ops.segment_sum(
        jnp.ones_like(flat_e, jnp.float32), flat_e, num_segments=e
    ) / jnp.float32(t * k)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = cfg.router_z_loss * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits.reshape(-1, e), axis=-1))
    )
    aux = {"lb_loss": lb_loss, "z_loss": z_loss}
    return y.reshape(orig_shape).astype(x.dtype), aux
