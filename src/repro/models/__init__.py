"""Model zoo: the 10 assigned architectures' implementations."""
from repro.models.transformer import LMConfig
from repro.models.moe import MoEConfig
from repro.models.gnn import (
    EquivariantConfig,
    GATConfig,
    GraphBatch,
    PNAConfig,
    random_graph,
)
from repro.models.recsys import BERT4RecConfig

__all__ = [
    "LMConfig",
    "MoEConfig",
    "EquivariantConfig",
    "GATConfig",
    "GraphBatch",
    "PNAConfig",
    "random_graph",
    "BERT4RecConfig",
]
