"""Shared neural layers (pure-jnp, pjit-friendly, no framework deps).

Parameters are plain pytrees (nested dicts of arrays); every init function
takes an explicit PRNG key; compute dtype is bf16 by default with fp32
params — the production training setup.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any

DEFAULT_COMPUTE_DTYPE = jnp.bfloat16


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = d_in**-0.5
    return {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}


def dense(params, x, compute_dtype=DEFAULT_COMPUTE_DTYPE):
    w = params["w"].astype(compute_dtype)
    return jnp.einsum("...d,df->...f", x.astype(compute_dtype), w)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32
    )
    return y.astype(dt)


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model**-0.5
    s_out = d_ff**-0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def swiglu(params, x, compute_dtype=DEFAULT_COMPUTE_DTYPE):
    from repro.models.sharding import constrain

    x = x.astype(compute_dtype)
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(compute_dtype))
    tp_spec = ("dp",) + (None,) * (x.ndim - 2) + ("tp",)
    h = constrain(jax.nn.silu(g) * u, *tp_spec)
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(compute_dtype))


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0):
    """Rotary position embedding.

    x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq].
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rot = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return rot.astype(x.dtype)


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {
        "table": (
            jax.random.normal(key, (vocab, d_model)) * (d_model**-0.5)
        ).astype(dtype)
    }


def embed(params, ids, compute_dtype=DEFAULT_COMPUTE_DTYPE):
    return jnp.take(params["table"], ids, axis=0).astype(compute_dtype)


def unembed(params, x, compute_dtype=DEFAULT_COMPUTE_DTYPE):
    """Tied output projection: logits over the vocab."""
    return jnp.einsum(
        "...d,vd->...v",
        x.astype(compute_dtype),
        params["table"].astype(compute_dtype),
    )


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token-level cross entropy in fp32 (stable logsumexp)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    nll = lse - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def fused_unembed_cross_entropy(
    table: jnp.ndarray,
    x: jnp.ndarray,
    labels: jnp.ndarray,
    mask: jnp.ndarray | None = None,
    chunk: int = 512,
    compute_dtype=DEFAULT_COMPUTE_DTYPE,
) -> jnp.ndarray:
    """Fused unembed + softmax-xent, chunked over sequence.

    Never materializes the [B, S, V] logits buffer — each sequence chunk's
    logits live only inside one rematted scan iteration (the classic
    vocab-parallel fused xent; with V>=128k this removes the largest
    activation in training by far).  ``table`` is [V, D] (tied) — pass
    ``lm_head.T``-shaped table for untied heads.
    """
    b, s, d = x.shape
    if s % chunk != 0:
        chunk = s  # degenerate fallback (smoke shapes)
    n_chunks = s // chunk
    tbl = table.astype(compute_dtype)
    transposed = table.shape[0] == d  # [d, V] (untied lm_head) vs [V, d]
    eq = "bsd,dv->bsv" if transposed else "bsd,vd->bsv"

    # python loop (not lax.scan): XLA cost analysis counts while bodies
    # once, and this loop's unembed matmul is a dominant FLOPs term the
    # roofline must see exactly.  Each chunk is rematted.
    @jax.checkpoint
    def chunk_nll(xck, lck, mck):
        logits = jnp.einsum(
            eq, xck.astype(compute_dtype), tbl,
            preferred_element_type=jnp.float32,
        )
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, lck[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        m = mck.astype(jnp.float32)
        return ((lse - ll) * m).sum(), m.sum()

    nll_sum = jnp.float32(0.0)
    msum = jnp.float32(0.0)
    for i in range(n_chunks):
        sl = slice(i * chunk, (i + 1) * chunk)
        mck = (
            mask[:, sl] if mask is not None
            else jnp.ones((b, chunk), jnp.float32)
        )
        nll_c, m_c = chunk_nll(x[:, sl], labels[:, sl], mck)
        nll_sum = nll_sum + nll_c
        msum = msum + m_c
    return nll_sum / jnp.maximum(msum, 1.0)
