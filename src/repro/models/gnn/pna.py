"""PNA (Corso et al., arXiv:2004.05718): multi-aggregator message passing —
4 aggregators (mean/max/min/std) x 3 degree scalers (identity /
amplification / attenuation) -> 12-fold concat -> linear tower."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.graph import GraphBatch
from repro.sparse.segment import (mp_segment_max, mp_segment_min,
    mp_segment_sum, segment_mean, segment_std)


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    n_classes: int = 8
    d_in: int = 16
    delta: float = 2.0  # avg log-degree normalizer (dataset statistic)


def init_params(key, cfg: PNAConfig):
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append(
            {
                "w_pre": jax.random.normal(k1, (2 * d_in, cfg.d_hidden))
                * ((2 * d_in) ** -0.5),
                "w_post": jax.random.normal(
                    k2, (12 * cfg.d_hidden + d_in, cfg.d_hidden)
                )
                * ((12 * cfg.d_hidden) ** -0.5),
            }
        )
        d_in = cfg.d_hidden
    k_out, key = jax.random.split(key)
    return {
        "layers": layers,
        "readout": jax.random.normal(k_out, (cfg.d_hidden, cfg.n_classes))
        * (cfg.d_hidden**-0.5),
    }


def forward(params, cfg: PNAConfig, g: GraphBatch) -> jnp.ndarray:
    x = g.node_feat
    n = g.n_nodes
    deg = mp_segment_sum(g.edge_mask, g.edge_dst, n)
    logd = jnp.log1p(deg)
    amp = (logd / cfg.delta)[:, None]
    att = (cfg.delta / jnp.maximum(logd, 1e-3))[:, None]

    for lp in params["layers"]:
        msg_in = jnp.concatenate(
            [x[g.edge_src], x[g.edge_dst]], axis=-1
        )
        msg = jax.nn.relu(msg_in @ lp["w_pre"]) * g.edge_mask[:, None]
        aggs = []
        mean = segment_mean(msg, g.edge_dst, n)
        mx = mp_segment_max(msg, g.edge_dst, n)
        mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
        mn = mp_segment_min(msg, g.edge_dst, n)
        mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
        std = segment_std(msg, g.edge_dst, n)
        for a in (mean, mx, mn, std):
            aggs.extend([a, a * amp, a * att])
        h = jnp.concatenate(aggs + [x], axis=-1)
        x = jax.nn.relu(h @ lp["w_post"])
    return x @ params["readout"]


def loss_fn(params, cfg: PNAConfig, g: GraphBatch) -> jnp.ndarray:
    logits = forward(params, cfg, g)
    if g.graph_ids is not None and g.n_graphs > 1:
        # graph-level readout: mean-pool nodes per molecule
        pooled = jax.ops.segment_sum(logits, g.graph_ids, g.n_graphs)
        count = jax.ops.segment_sum(
            jnp.ones((g.n_nodes,)), g.graph_ids, g.n_graphs
        )
        logits = pooled / jnp.maximum(count, 1.0)[:, None]
        labels = jax.ops.segment_max(
            g.labels, g.graph_ids, g.n_graphs
        )
    else:
        labels = g.labels
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return nll.mean()
