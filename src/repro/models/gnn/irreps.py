"""Minimal SO(3)-irrep algebra for equivariant GNNs (NequIP / MACE).

Design choice (DESIGN.md §7): instead of porting e3nn, we build the three
primitives the tensor-product kernel regime needs —

* hardcoded real spherical harmonics up to l_max = 3,
* numerically-derived Wigner D matrices (solve Y(R r) = D Y(r) on generic
  points), and
* Clebsch-Gordan intertwiners computed as the null space of the
  equivariance constraint (D1 (x) D2) C = C D3 over random rotations.

The null-space construction is *self-consistent with our SH convention by
definition* (no Condon-Shortley bookkeeping) and captures odd (parity-
antisymmetric) couplings like 1 (x) 1 -> 1 (the cross product) that
sphere-quadrature Gaunt coefficients miss.  Everything is float64 NumPy at
import/cache time; the jit graph only sees constant CG tensors.
"""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np


def sph_harm_np(l: int, v: np.ndarray) -> np.ndarray:
    """Real spherical harmonics of unit vectors ``v [..., 3]`` ->
    ``[..., 2l+1]``, m ordered -l..l, e3nn-style component scaling."""
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return np.ones(v.shape[:-1] + (1,))
    if l == 1:
        return np.sqrt(3.0) * np.stack([y, z, x], axis=-1)
    if l == 2:
        return np.stack(
            [
                np.sqrt(15.0) * x * y,
                np.sqrt(15.0) * y * z,
                np.sqrt(5.0) / 2.0 * (3 * z**2 - 1),
                np.sqrt(15.0) * x * z,
                np.sqrt(15.0) / 2.0 * (x**2 - y**2),
            ],
            axis=-1,
        )
    if l == 3:
        return np.stack(
            [
                np.sqrt(35.0 / 8.0) * y * (3 * x**2 - y**2),
                np.sqrt(105.0) * x * y * z,
                np.sqrt(21.0 / 8.0) * y * (5 * z**2 - 1),
                np.sqrt(7.0) / 2.0 * z * (5 * z**2 - 3),
                np.sqrt(21.0 / 8.0) * x * (5 * z**2 - 1),
                np.sqrt(105.0) / 2.0 * z * (x**2 - y**2),
                np.sqrt(35.0 / 8.0) * x * (x**2 - 3 * y**2),
            ],
            axis=-1,
        )
    raise NotImplementedError(f"l={l}")


def sph_harm(l: int, v: jnp.ndarray) -> jnp.ndarray:
    """jnp version (traceable) of :func:`sph_harm_np`."""
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return jnp.ones(v.shape[:-1] + (1,), v.dtype)
    if l == 1:
        return jnp.sqrt(3.0) * jnp.stack([y, z, x], axis=-1)
    if l == 2:
        return jnp.stack(
            [
                jnp.sqrt(15.0) * x * y,
                jnp.sqrt(15.0) * y * z,
                jnp.sqrt(5.0) / 2.0 * (3 * z**2 - 1),
                jnp.sqrt(15.0) * x * z,
                jnp.sqrt(15.0) / 2.0 * (x**2 - y**2),
            ],
            axis=-1,
        )
    if l == 3:
        return jnp.stack(
            [
                jnp.sqrt(35.0 / 8.0) * y * (3 * x**2 - y**2),
                jnp.sqrt(105.0) * x * y * z,
                jnp.sqrt(21.0 / 8.0) * y * (5 * z**2 - 1),
                jnp.sqrt(7.0) / 2.0 * z * (5 * z**2 - 3),
                jnp.sqrt(21.0 / 8.0) * x * (5 * z**2 - 1),
                jnp.sqrt(105.0) / 2.0 * z * (x**2 - y**2),
                jnp.sqrt(35.0 / 8.0) * x * (x**2 - 3 * y**2),
            ],
            axis=-1,
        )
    raise NotImplementedError(f"l={l}")


def _random_rotation(rng: np.random.Generator) -> np.ndarray:
    """Haar-ish random rotation via QR of a Gaussian matrix."""
    m = rng.standard_normal((3, 3))
    q, r = np.linalg.qr(m)
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


def wigner_d_np(l: int, rot: np.ndarray) -> np.ndarray:
    """Real Wigner D for our SH convention: the (2l+1)x(2l+1) matrix with
    Y_l(R r) = D_l(R) Y_l(r), solved on generic sample points."""
    if l == 0:
        return np.ones((1, 1))
    rng = np.random.default_rng(12345 + l)
    n = 4 * (2 * l + 1)
    pts = rng.standard_normal((n, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    a = sph_harm_np(l, pts)                 # [n, 2l+1]
    b = sph_harm_np(l, pts @ rot.T)          # [n, 2l+1]
    # solve D a^T = b^T in least squares: D = (a \ b)^T
    d, *_ = np.linalg.lstsq(a, b, rcond=None)
    return d.T


@lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """Clebsch-Gordan intertwiner C with (D1 (x) D2) vec(C) = vec(C D3)
    for all rotations, i.e. equivariant bilinear map V_l1 x V_l2 -> V_l3.
    Returns ``[2l1+1, 2l2+1, 2l3+1]`` normalized to unit Frobenius norm,
    or None when the coupling is forbidden."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rng = np.random.default_rng(777)
    rows = []
    for _ in range(6):
        rot = _random_rotation(rng)
        w1 = wigner_d_np(l1, rot)
        w2 = wigner_d_np(l2, rot)
        w3 = wigner_d_np(l3, rot)
        # constraint (for out[k] = sum_ij C[i,j,k] a_i b_j with a -> D1 a):
        #   sum_ij D1[i,i'] D2[j,j'] C[i,j,k] = sum_k' D3[k,k'] C[i',j',k']
        # flat over rows (i',j',k):
        #   (D1^T (x) D2^T (x) I - I (x) I (x) D3) vec(C) = 0
        m = np.kron(np.kron(w1.T, w2.T), np.eye(d3)) - np.kron(
            np.kron(np.eye(d1), np.eye(d2)), w3
        )
        rows.append(m)
    m = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(m)
    null = vt[s < 1e-8 * s[0]] if len(s) else vt[-1:]
    if null.shape[0] == 0:
        # numerical fallback: smallest singular vector if it's tiny
        if s[-1] < 1e-6:
            null = vt[-1:]
        else:
            return None
    c = null[0].reshape(d1, d2, d3)
    c = c / np.linalg.norm(c)
    # canonical sign: first nonzero entry positive
    flat = c.reshape(-1)
    nz = flat[np.abs(flat) > 1e-9]
    if len(nz) and nz[0] < 0:
        c = -c
    return c


def allowed_paths(l_max: int) -> list[tuple[int, int, int]]:
    """All (l1, l2, l3) couplings with every l <= l_max."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if abs(l1 - l2) <= l3 <= l1 + l2:
                    if real_cg(l1, l2, l3) is not None:
                        out.append((l1, l2, l3))
    return out


def bessel_basis(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """NequIP's Bessel radial basis with smooth polynomial cutoff envelope.
    r: [...]; returns [..., n_rbf]."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        n * jnp.pi * r[..., None] / cutoff
    ) / r[..., None]
    # polynomial envelope (p=6) from DimeNet
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    p = 6.0
    env = (
        1.0
        - (p + 1.0) * (p + 2.0) / 2.0 * x**p
        + p * (p + 2.0) * x ** (p + 1.0)
        - p * (p + 1.0) / 2.0 * x ** (p + 2.0)
    )
    return basis * env[..., None]
