"""NequIP (arXiv:2101.03164) and MACE (arXiv:2206.07697) — E(3)-equivariant
interatomic potentials on the irrep tensor-product kernel regime.

Features are dicts ``{l: [N, C, 2l+1]}``; message passing is the standard
gather -> (CG tensor product with edge spherical harmonics, radial-MLP
weighted) -> segment-sum.  MACE adds the many-body expansion: its A-basis
(one message pass) is self-coupled ``correlation_order - 1`` times through
CG products — cardinality-k interactions, the closest native hypergraph
structure in the assigned pool (DESIGN.md §7).

Equivariance is tested, not assumed: rotating+translating inputs leaves
energies invariant (tests/test_equivariant.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.graph import GraphBatch
from repro.models.gnn.irreps import allowed_paths, bessel_basis, real_cg, sph_harm
from repro.sparse.segment import mp_segment_sum


@dataclasses.dataclass(frozen=True)
class EquivariantConfig:
    name: str = "nequip"
    kind: str = "nequip"           # nequip | mace
    n_layers: int = 5
    d_hidden: int = 32             # channels per irrep order
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    correlation_order: int = 1     # mace: 3
    n_species: int = 8
    radial_hidden: int = 64


def _paths(cfg: EquivariantConfig):
    return allowed_paths(cfg.l_max)


def _cg_const(l1, l2, l3):
    return jnp.asarray(np.asarray(real_cg(l1, l2, l3), np.float32))


def init_params(key, cfg: EquivariantConfig):
    paths = _paths(cfg)
    c = cfg.d_hidden
    layers = []
    for _ in range(cfg.n_layers):
        k1, k2, k3, k4, k5, key = jax.random.split(key, 6)
        lp = {
            # radial MLP: n_rbf -> hidden -> (n_paths * C) weights
            "radial_w1": jax.random.normal(
                k1, (cfg.n_rbf, cfg.radial_hidden)
            ) * (cfg.n_rbf**-0.5),
            "radial_w2": jax.random.normal(
                k2, (cfg.radial_hidden, len(paths) * c)
            ) * (cfg.radial_hidden**-0.5),
            # per-l linear channel mixers for aggregated messages & self
            "mix_msg": {
                str(l): jax.random.normal(k3, (c, c)) * (c**-0.5)
                for l in range(cfg.l_max + 1)
            },
            "mix_self": {
                str(l): jax.random.normal(k4, (c, c)) * (c**-0.5)
                for l in range(cfg.l_max + 1)
            },
            # gate scalars for l>0 nonlinearity
            "gate": jax.random.normal(k5, (c, cfg.l_max * c)) * (c**-0.5),
        }
        if cfg.kind == "mace" and cfg.correlation_order > 1:
            kk = jax.random.split(key, cfg.correlation_order)
            key = kk[-1]
            # per-order per-path contraction weights
            lp["corr_w"] = [
                {  # weights for products at order o
                    f"{l1}_{l2}_{l3}": jax.random.normal(
                        kk[o - 2], (c,)
                    ) * 0.1
                    for (l1, l2, l3) in paths
                }
                for o in range(2, cfg.correlation_order + 1)
            ]
        layers.append(lp)
    k_emb, k_out, key = jax.random.split(key, 3)
    return {
        "species_embed": jax.random.normal(
            k_emb, (cfg.n_species, cfg.d_hidden)
        ),
        "layers": layers,
        "readout": jax.random.normal(k_out, (cfg.d_hidden, 1))
        * (cfg.d_hidden**-0.5),
    }


def _tensor_product_msg(cfg, lp, feats, g, sh, radial):
    """One message pass: for each CG path, couple source features (l1) with
    edge SH (l2) into destination irrep l3, weighted by the radial MLP."""
    paths = _paths(cfg)
    c = cfg.d_hidden
    n = g.n_nodes
    w = jax.nn.silu(radial @ lp["radial_w1"]) @ lp["radial_w2"]
    w = w.reshape(-1, len(paths), c) * g.edge_mask[:, None, None]
    out = {
        str(l): jnp.zeros((n, c, 2 * l + 1), jnp.float32)
        for l in range(cfg.l_max + 1)
    }
    for pi, (l1, l2, l3) in enumerate(paths):
        cg = _cg_const(l1, l2, l3)
        src_feat = feats[str(l1)][g.edge_src]          # [E, C, 2l1+1]
        msg = jnp.einsum(
            "eci,ej,ijk->eck", src_feat, sh[str(l2)], cg
        ) * w[:, pi, :, None]
        out[str(l3)] = out[str(l3)] + mp_segment_sum(
            msg, g.edge_dst, n
        )
    return out


def _self_product(cfg, lp, a_basis):
    """MACE many-body contraction: couple the A-basis with itself
    ``correlation_order - 1`` times through CG paths."""
    paths = _paths(cfg)
    current = a_basis
    total = {k: v for k, v in a_basis.items()}
    for order_idx in range(cfg.correlation_order - 1):
        weights = lp["corr_w"][order_idx]
        nxt = {
            str(l): jnp.zeros_like(a_basis[str(l)])
            for l in range(cfg.l_max + 1)
        }
        for (l1, l2, l3) in paths:
            cg = _cg_const(l1, l2, l3)
            prod = jnp.einsum(
                "nci,ncj,ijk->nck",
                current[str(l1)],
                a_basis[str(l2)],
                cg,
            ) * weights[f"{l1}_{l2}_{l3}"][None, :, None]
            nxt[str(l3)] = nxt[str(l3)] + prod
        current = nxt
        for l in nxt:
            total[l] = total[l] + nxt[l]
    return total


def _update(cfg, lp, feats, msgs):
    """Self-interaction + message mix + gated nonlinearity (equivariant:
    linear acts on channels only; l>0 gated by sigmoid of scalar gates)."""
    c = cfg.d_hidden
    new = {}
    scalars = jnp.einsum(
        "nci,cd->ndi", msgs["0"], lp["mix_msg"]["0"]
    ) + jnp.einsum("nci,cd->ndi", feats["0"], lp["mix_self"]["0"])
    new["0"] = jax.nn.silu(scalars)
    if cfg.l_max > 0:
        gates = jax.nn.sigmoid(
            (new["0"][..., 0] @ lp["gate"]).reshape(
                -1, cfg.l_max, c
            )
        )
    for l in range(1, cfg.l_max + 1):
        mixed = jnp.einsum(
            "nci,cd->ndi", msgs[str(l)], lp["mix_msg"][str(l)]
        ) + jnp.einsum(
            "nci,cd->ndi", feats[str(l)], lp["mix_self"][str(l)]
        )
        new[str(l)] = mixed * gates[:, l - 1, :, None]
    return new


def forward(params, cfg: EquivariantConfig, g: GraphBatch) -> jnp.ndarray:
    """Returns per-graph energies ``[n_graphs]``."""
    n = g.n_nodes
    c = cfg.d_hidden
    rel = g.positions[g.edge_src] - g.positions[g.edge_dst]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(rel**2, -1), 1e-12))
    unit = rel / dist[:, None]
    sh = {
        str(l): sph_harm(l, unit).astype(jnp.float32)
        for l in range(cfg.l_max + 1)
    }
    radial = bessel_basis(dist, cfg.n_rbf, cfg.cutoff)

    feats = {
        "0": jnp.take(params["species_embed"], g.species, axis=0)[..., None],
    }
    for l in range(1, cfg.l_max + 1):
        feats[str(l)] = jnp.zeros((n, c, 2 * l + 1), jnp.float32)

    site_energy = jnp.zeros((n,), jnp.float32)
    for lp in params["layers"]:
        msgs = _tensor_product_msg(cfg, lp, feats, g, sh, radial)
        if cfg.kind == "mace" and cfg.correlation_order > 1:
            msgs = _self_product(cfg, lp, msgs)
        feats = _update(cfg, lp, feats, msgs)
        # per-layer readout (MACE-style; harmless for NequIP)
        site_energy = site_energy + (
            feats["0"][..., 0] @ params["readout"]
        )[:, 0]

    mask = g.node_mask if g.node_mask is not None else jnp.ones((n,))
    site_energy = site_energy * mask
    if g.graph_ids is not None and g.n_graphs > 1:
        return jax.ops.segment_sum(site_energy, g.graph_ids, g.n_graphs)
    return site_energy.sum()[None]


def loss_fn(params, cfg: EquivariantConfig, g: GraphBatch) -> jnp.ndarray:
    """Energy MSE (labels = per-graph scalar target)."""
    energy = forward(params, cfg, g)
    target = g.labels.astype(jnp.float32)
    if target.ndim == 1 and target.shape[0] != energy.shape[0]:
        target = jnp.zeros_like(energy)
    return jnp.mean(jnp.square(energy - target))


def forces(params, cfg: EquivariantConfig, g: GraphBatch) -> jnp.ndarray:
    """F = -dE/dpositions; equivariant by construction since E is
    invariant (verified in tests)."""

    def e_of_pos(pos):
        g2 = GraphBatch(
            edge_src=g.edge_src, edge_dst=g.edge_dst, edge_mask=g.edge_mask,
            n_nodes=g.n_nodes, node_feat=g.node_feat, positions=pos,
            species=g.species, node_mask=g.node_mask,
            graph_ids=g.graph_ids, n_graphs=g.n_graphs, labels=g.labels,
        )
        return forward(params, cfg, g2).sum()

    return -jax.grad(e_of_pos)(g.positions)
