"""GNN model zoo: GAT, PNA, NequIP, MACE over the GraphBatch container."""
from repro.models.gnn.graph import GraphBatch, random_graph
from repro.models.gnn.gat import GATConfig
from repro.models.gnn.pna import PNAConfig
from repro.models.gnn.equivariant import EquivariantConfig

__all__ = [
    "GraphBatch",
    "random_graph",
    "GATConfig",
    "PNAConfig",
    "EquivariantConfig",
]
