"""GraphBatch: the uniform device-side graph container.

Every GNN arch (GAT / PNA / NequIP / MACE) and every shape regime
(full-graph, sampled block, batched molecules) lowers to this one static-
shape structure; message passing is ``jnp.take`` + ``segment_*`` over
``edge_src/edge_dst`` — the identical primitive the MESH engine runs on.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphBatch:
    edge_src: jnp.ndarray            # [E] int32
    edge_dst: jnp.ndarray            # [E] int32
    edge_mask: jnp.ndarray           # [E] f32 {0,1}
    n_nodes: int
    node_feat: jnp.ndarray | None = None    # [N, F]
    positions: jnp.ndarray | None = None    # [N, 3]
    species: jnp.ndarray | None = None      # [N] int32
    node_mask: jnp.ndarray | None = None    # [N] f32
    graph_ids: jnp.ndarray | None = None    # [N] int32 (batched molecules)
    n_graphs: int = 1
    labels: Any = None

    def tree_flatten(self):
        children = (
            self.edge_src, self.edge_dst, self.edge_mask, self.node_feat,
            self.positions, self.species, self.node_mask, self.graph_ids,
            self.labels,
        )
        return children, (self.n_nodes, self.n_graphs)

    @classmethod
    def tree_unflatten(cls, aux, c):
        return cls(
            edge_src=c[0], edge_dst=c[1], edge_mask=c[2], n_nodes=aux[0],
            node_feat=c[3], positions=c[4], species=c[5], node_mask=c[6],
            graph_ids=c[7], n_graphs=aux[1], labels=c[8],
        )


def random_graph(
    n_nodes: int,
    n_edges: int,
    d_feat: int | None = None,
    with_positions: bool = False,
    n_species: int = 8,
    n_classes: int = 8,
    n_graphs: int = 1,
    seed: int = 0,
) -> GraphBatch:
    """Synthetic graph batch (tests / smoke / dry-run value path).

    Undirected-ish: random pairs, self-loops allowed; for batched molecules
    (``n_graphs > 1``) nodes are split contiguously and edges stay within a
    graph.
    """
    rng = np.random.default_rng(seed)
    if n_graphs > 1:
        per = n_nodes // n_graphs
        gid = np.repeat(np.arange(n_graphs), per).astype(np.int32)
        gid = np.pad(gid, (0, n_nodes - len(gid)), constant_values=n_graphs - 1)
        base = (rng.integers(0, per, size=(2, n_edges))).astype(np.int32)
        graph_of_edge = rng.integers(0, n_graphs, size=n_edges)
        src = (graph_of_edge * per + base[0]).astype(np.int32)
        dst = (graph_of_edge * per + base[1]).astype(np.int32)
    else:
        gid = np.zeros(n_nodes, np.int32)
        src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
        dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    batch = GraphBatch(
        edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        edge_mask=jnp.ones((n_edges,), jnp.float32),
        n_nodes=n_nodes,
        node_mask=jnp.ones((n_nodes,), jnp.float32),
        graph_ids=jnp.asarray(gid),
        n_graphs=n_graphs,
    )
    if d_feat:
        batch.node_feat = jnp.asarray(
            rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
        )
    if with_positions:
        batch.positions = jnp.asarray(
            (rng.standard_normal((n_nodes, 3)) * 2.0).astype(np.float32)
        )
        batch.species = jnp.asarray(
            rng.integers(0, n_species, size=n_nodes).astype(np.int32)
        )
    batch.labels = jnp.asarray(
        rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    )
    return batch
