"""GAT (Veličković et al., arXiv:1710.10903): SDDMM edge scores ->
segment-softmax -> SpMM, the attention instance of the gather/segment
substrate."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.graph import GraphBatch
from repro.sparse.segment import mp_segment_sum, segment_softmax


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    d_in: int = 1433
    negative_slope: float = 0.2


def init_params(key, cfg: GATConfig):
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        d_out = (
            cfg.n_classes if i == cfg.n_layers - 1 else cfg.d_hidden
        )
        heads = 1 if i == cfg.n_layers - 1 else cfg.n_heads
        layers.append(
            {
                "w": jax.random.normal(k1, (d_in, heads, d_out))
                * (d_in**-0.5),
                "a_src": jax.random.normal(k2, (heads, d_out)) * 0.1,
                "a_dst": jax.random.normal(k3, (heads, d_out)) * 0.1,
            }
        )
        d_in = d_out * heads
    return {"layers": layers}


def forward(params, cfg: GATConfig, g: GraphBatch) -> jnp.ndarray:
    x = g.node_feat
    n = g.n_nodes
    for i, lp in enumerate(params["layers"]):
        h = jnp.einsum("nf,fhd->nhd", x, lp["w"])      # [N, H, D]
        e_src = (h * lp["a_src"]).sum(-1)               # [N, H]
        e_dst = (h * lp["a_dst"]).sum(-1)
        logits = jax.nn.leaky_relu(
            e_src[g.edge_src] + e_dst[g.edge_dst], cfg.negative_slope
        )                                               # [E, H]
        logits = jnp.where(g.edge_mask[:, None] > 0, logits, -1e30)
        alpha = segment_softmax(logits, g.edge_dst, n)  # [E, H]
        alpha = alpha * g.edge_mask[:, None]
        msg = h[g.edge_src] * alpha[..., None]          # [E, H, D]
        agg = mp_segment_sum(msg, g.edge_dst, n)        # [N, H, D]
        if i == cfg.n_layers - 1:
            x = agg.mean(axis=1)                        # average heads
        else:
            x = jax.nn.elu(agg.reshape(n, -1))          # concat heads
    return x


def loss_fn(params, cfg: GATConfig, g: GraphBatch) -> jnp.ndarray:
    logits = forward(params, cfg, g)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, g.labels[:, None], axis=-1)[:, 0]
    m = g.node_mask if g.node_mask is not None else jnp.ones_like(nll)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
