"""Logical activation-sharding constraints.

Model code annotates activations with *logical* axes ('dp', 'tp', 'flat',
None); this module resolves them against whatever mesh is ambient at trace
time — the same model works on (data, model), (pod, data, model), a test
mesh, or no mesh at all (constraints become no-ops on a single device).

This mirrors the MaxText/T5X "logical axis rules" pattern in ~40 lines.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_DP_AXES = ("pod", "data")
_TP_AXIS = "model"


def ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # pragma: no cover - private-API guard
        pass
    return None


def _resolve(mesh, logical):
    names = mesh.axis_names
    if logical is None:
        return None
    if logical == "dp":
        axes = tuple(a for a in _DP_AXES if a in names)
        return axes if axes else None
    if logical == "tp":
        return _TP_AXIS if _TP_AXIS in names else None
    if logical == "flat":
        return tuple(names)
    if logical in names:
        return logical
    return None


def _divides(dim: int, axes, mesh) -> bool:
    if axes is None:
        return True
    import math

    group = axes if isinstance(axes, tuple) else (axes,)
    k = math.prod(mesh.shape[a] for a in group)
    return k > 0 and dim % k == 0


def constrain(x, *logical_axes):
    """with_sharding_constraint with logical names; silent no-op without a
    mesh, and per-dim fallback to None when sizes don't divide."""
    mesh = ambient_mesh()
    if mesh is None or x is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"constrain: {len(logical_axes)} axes for rank-{x.ndim} array"
        )
    spec = []
    for dim, logical in zip(x.shape, logical_axes):
        axes = _resolve(mesh, logical)
        spec.append(axes if _divides(dim, axes, mesh) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
