"""Transformer LM family: dense / GQA / local:global interleave / MoE /
MoE:dense interleave.

One implementation covers all five assigned LM architectures; differences
are pure config.  Layers are scanned over *periods* (period = lcm of the
attention pattern and the MoE interleave): each position j in the period
owns its own stacked parameter pytree ``[n_periods, ...]``, the scan body
unrolls the period statically — exact FLOPs in cost analysis, no dead
branches, heterogeneous (dense|MoE) layers stack cleanly, and the HLO
stays small enough that the 512-device dry-run compiles on one CPU core.

Layouts: activations [B, S, D]; caches {k,v}: [L, B, S, KvH, hd].
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (
    cross_entropy,
    fused_unembed_cross_entropy,
    dense,
    dense_init,
    embed,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    rope,
    swiglu,
    swiglu_init,
    unembed,
)
from repro.models.moe import MoEConfig, moe_ffn, moe_init
from repro.models.sharding import constrain

Params = Any


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 10_000.0
    moe: MoEConfig | None = None
    moe_interleave: int = 1           # layer i is MoE iff i % k == k-1
    # (n_local, n_global) attention pattern per period; None = all full.
    local_global: tuple[int, int] | None = None
    window: int = 1024
    parallel_block: bool = False      # command-r style parallel attn+ffn
    tie_embeddings: bool = True
    remat: bool = True
    attn_block_size: int = 1024
    # sequences >= this threshold shard the *sequence* dim of q over the
    # 'model' axis in global-attention layers (context parallelism) —
    # GQA kv-head counts (4-8) cannot fill a 16-wide model axis, so head
    # sharding leaves 0.5GB f32 score blocks replicated; seq sharding
    # splits them 16x.
    context_parallel_threshold: int = 16384
    compute_dtype: Any = jnp.bfloat16
    # False => python-loop over periods (exact XLA cost analysis; the
    # roofline harness compiles 1- and 2-period unrolled variants and
    # extrapolates — while-loop bodies are counted once by XLA).
    scan_layers: bool = True

    @property
    def period(self) -> int:
        attn_p = 1 if self.local_global is None else sum(self.local_global)
        moe_p = self.moe_interleave if self.moe is not None else 1
        return math.lcm(attn_p, moe_p)

    @property
    def layer_kinds(self) -> tuple[tuple[bool, bool], ...]:
        """(is_local, is_moe) per position within one period."""
        kinds = []
        for j in range(self.period):
            if self.local_global is None:
                is_local = False
            else:
                n_local, _ = self.local_global
                is_local = (j % sum(self.local_global)) < n_local
            if self.moe is None:
                is_moe = False
            else:
                is_moe = (j % self.moe_interleave) == self.moe_interleave - 1
            kinds.append((is_local, is_moe))
        return tuple(kinds)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period={self.period}"
        )
        return self.n_layers // self.period

    def flops_per_token(self) -> float:
        """Forward matmul FLOPs per token (the 2N term of 6ND)."""
        d, hd = self.d_model, self.head_dim
        attn_proj = 2 * d * (self.n_heads + 2 * self.n_kv_heads) * hd
        attn_proj += 2 * self.n_heads * hd * d
        total = 0.0
        for (_is_local, is_moe) in self.layer_kinds:
            if is_moe:
                ffn = 2 * 3 * d * self.moe.d_ff * self.moe.top_k
                ffn += 2 * 3 * d * self.moe.d_ff * self.moe.n_shared_experts
                ffn += 2 * d * self.moe.n_experts
            else:
                ffn = 2 * 3 * d * self.d_ff
            total += attn_proj + ffn
        total *= self.n_periods
        total += 2 * d * self.vocab
        return total


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _layer_init(key, cfg: LMConfig, is_moe: bool):
    ks = jax.random.split(key, 8)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "ln_attn": rmsnorm_init(d),
        "wq": dense_init(ks[0], d, h * hd),
        "wk": dense_init(ks[1], d, kvh * hd),
        "wv": dense_init(ks[2], d, kvh * hd),
        "wo": dense_init(ks[3], h * hd, d),
        "ln_ffn": rmsnorm_init(d),
    }
    if is_moe:
        p["moe"] = moe_init(ks[4], cfg.moe, d)
    else:
        p["ffn"] = swiglu_init(ks[4], d, cfg.d_ff)
    return p


def init_params(key, cfg: LMConfig) -> Params:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    period, n_periods = cfg.period, cfg.n_periods
    kinds = cfg.layer_kinds
    layer_keys = jax.random.split(k_layers, cfg.n_layers).reshape(
        n_periods, period, 2
    )
    stacks = []
    for j, (_is_local, is_moe) in enumerate(kinds):
        stacks.append(
            jax.vmap(lambda k, m=is_moe: _layer_init(k, cfg, m))(
                layer_keys[:, j]
            )
        )
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model),
        "layers": tuple(stacks),
        "ln_out": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab)
    return params


def param_count(cfg: LMConfig) -> int:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn_p = d * (h + 2 * kvh) * hd + h * hd * d + 2 * d
    total = 0
    for (_l, is_moe) in cfg.layer_kinds:
        if is_moe:
            ffn = d * cfg.moe.n_experts
            ffn += cfg.moe.n_experts * 3 * d * cfg.moe.d_ff
            ffn += cfg.moe.n_shared_experts * 3 * d * cfg.moe.d_ff
        else:
            ffn = 3 * d * cfg.d_ff
        total += attn_p + ffn
    total *= cfg.n_periods
    total += cfg.vocab * d + d
    if not cfg.tie_embeddings:
        total += d * cfg.vocab
    return total


def active_param_count(cfg: LMConfig) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn_p = d * (h + 2 * kvh) * hd + h * hd * d + 2 * d
    total = 0
    for (_l, is_moe) in cfg.layer_kinds:
        if is_moe:
            ffn = d * cfg.moe.n_experts
            ffn += (
                cfg.moe.top_k + cfg.moe.n_shared_experts
            ) * 3 * d * cfg.moe.d_ff
        else:
            ffn = 3 * d * cfg.d_ff
        total += attn_p + ffn
    total *= cfg.n_periods
    total += cfg.vocab * d + d
    if not cfg.tie_embeddings:
        total += d * cfg.vocab
    return total


# --------------------------------------------------------------------------
# layer bodies
# --------------------------------------------------------------------------

def _attention_block(lp, x, cfg: LMConfig, is_local: bool, positions):
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xn = rmsnorm(lp["ln_attn"], x)
    q = dense(lp["wq"], xn, cfg.compute_dtype).reshape(b, s, h, hd)
    k = dense(lp["wk"], xn, cfg.compute_dtype).reshape(b, s, kvh, hd)
    v = dense(lp["wv"], xn, cfg.compute_dtype).reshape(b, s, kvh, hd)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if is_local and s > cfg.window:
        o = attn.chunked_local_attention(q, k, v, window=cfg.window)
    elif s <= 2 * cfg.attn_block_size:
        o = attn.naive_attention(
            q, k, v, causal=True,
            window=cfg.window if is_local else None,
        )
    else:
        o = attn.blocked_attention(
            q, k, v, causal=True,
            window=cfg.window if is_local else None,
            block_size=cfg.attn_block_size,
            use_scan=cfg.scan_layers,
        )
    o = constrain(o.reshape(b, s, h, hd), "dp", None, "tp", None)
    o = o.reshape(b, s, h * hd)
    out = constrain(dense(lp["wo"], o, cfg.compute_dtype), "dp", None, None)
    return out, (k, v)


def _ffn_block(lp, x, cfg: LMConfig, is_moe: bool):
    xn = rmsnorm(lp["ln_ffn"], x)
    if is_moe:
        y, aux = moe_ffn(lp["moe"], xn, cfg.moe, cfg.compute_dtype)
        return constrain(y, "dp", None, None), aux["lb_loss"] + aux["z_loss"]
    y = swiglu(lp["ffn"], xn, cfg.compute_dtype)
    return constrain(y, "dp", None, None), jnp.float32(0.0)


def _layer(lp, x, cfg: LMConfig, is_local: bool, is_moe: bool, positions):
    a, _kv = _attention_block(lp, x, cfg, is_local, positions)
    if cfg.parallel_block:
        f, aux = _ffn_block(lp, x, cfg, is_moe)
        return x + a + f, aux
    x = x + a
    f, aux = _ffn_block(lp, x, cfg, is_moe)
    return x + f, aux


def _logits(params, cfg: LMConfig, x):
    x = rmsnorm(params["ln_out"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, cfg.compute_dtype)
    else:
        logits = dense(params["lm_head"], x, cfg.compute_dtype)
    spec = ("dp",) + (None,) * (logits.ndim - 2) + ("tp",)
    return constrain(logits, *spec)


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------

def encode(params, cfg: LMConfig, tokens: jnp.ndarray):
    """tokens [B, S] -> (final hidden states [B, S, D], aux loss)."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens, cfg.compute_dtype)
    x = constrain(x, "dp", None, None)
    positions = jnp.arange(s)[None, :].astype(jnp.int32)
    kinds = cfg.layer_kinds

    layer_fn = _layer
    if cfg.remat:
        # per-layer remat: backward recomputes one layer at a time, so the
        # live set is (period inputs) + (one layer's internals).
        layer_fn = jax.checkpoint(_layer, static_argnums=(2, 3, 4))

    def period_body(carry, period_params):
        x, aux = carry
        for j, (is_local, is_moe) in enumerate(kinds):
            x, a = layer_fn(
                period_params[j], x, cfg, is_local, is_moe, positions
            )
            aux = aux + a
        return (x, aux), None

    body = period_body
    if cfg.remat:
        body = jax.checkpoint(period_body)
    carry = (x, jnp.float32(0.0))
    if cfg.scan_layers:
        carry, _ = jax.lax.scan(body, carry, params["layers"])
    else:
        for i in range(cfg.n_periods):
            pp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
            carry, _ = body(carry, pp)
    x, aux = carry
    return x, aux


def forward(params, cfg: LMConfig, tokens: jnp.ndarray):
    """tokens [B, S] -> (logits [B, S, V], scalar aux loss)."""
    x, aux = encode(params, cfg, tokens)
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: LMConfig, batch) -> jnp.ndarray:
    x, aux = encode(params, cfg, batch["tokens"])
    x = rmsnorm(params["ln_out"], x)
    table = (
        params["embed"]["table"] if cfg.tie_embeddings
        else params["lm_head"]["w"]
    )
    ce = fused_unembed_cross_entropy(
        table, x, batch["labels"], batch.get("mask"),
        compute_dtype=cfg.compute_dtype,
    )
    return ce + 1e-2 * aux


# --------------------------------------------------------------------------
# decode (KV cache)
# --------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray):
    """Full-sequence forward that also returns the KV cache — the serving
    warm-up path.  Returns (last-token logits [B, V], cache): production
    prefill only needs the logits that seed decoding; materializing
    [B, S, V] would be ~2 orders of magnitude more output HBM."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens, cfg.compute_dtype)
    positions = jnp.arange(s)[None, :].astype(jnp.int32)
    kinds = cfg.layer_kinds

    def period_body(x, period_params):
        ks, vs = [], []
        for j, (is_local, is_moe) in enumerate(kinds):
            lp = period_params[j]
            a, (k, v) = _attention_block(lp, x, cfg, is_local, positions)
            if cfg.parallel_block:
                f, _ = _ffn_block(lp, x, cfg, is_moe)
                x = x + a + f
            else:
                x = x + a
                f, _ = _ffn_block(lp, x, cfg, is_moe)
                x = x + f
            ks.append(k.astype(jnp.bfloat16))
            vs.append(v.astype(jnp.bfloat16))
        return x, (jnp.stack(ks), jnp.stack(vs))

    if cfg.scan_layers:
        x, (k_all, v_all) = jax.lax.scan(period_body, x, params["layers"])
    else:
        ks_list, vs_list = [], []
        for i in range(cfg.n_periods):
            pp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
            x, (kp, vp) = period_body(x, pp)
            ks_list.append(kp)
            vs_list.append(vp)
        k_all = jnp.stack(ks_list)
        v_all = jnp.stack(vs_list)
    cache = {
        "k": k_all.reshape((cfg.n_layers,) + k_all.shape[2:]),
        "v": v_all.reshape((cfg.n_layers,) + v_all.shape[2:]),
    }
    return _logits(params, cfg, x[:, -1:])[:, 0], cache


def serve_step(params, cfg: LMConfig, cache, token: jnp.ndarray,
               pos: jnp.ndarray):
    """One decode step: token [B] ids at position ``pos`` (scalar int32)
    against a cache of static max length -> (logits [B, V], new cache)."""
    b = token.shape[0]
    x = embed(params["embed"], token[:, None], cfg.compute_dtype)
    positions = jnp.full((1, 1), pos, jnp.int32)
    kinds = cfg.layer_kinds
    period = cfg.period
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    k_cache = cache["k"].reshape(
        (cfg.n_periods, period) + cache["k"].shape[1:]
    )
    v_cache = cache["v"].reshape(
        (cfg.n_periods, period) + cache["v"].shape[1:]
    )

    def period_body(x, scan_in):
        period_params, k_per, v_per = scan_in
        k_new, v_new = [], []
        for j, (is_local, is_moe) in enumerate(kinds):
            lp = period_params[j]
            xn = rmsnorm(lp["ln_attn"], x)
            q = dense(lp["wq"], xn, cfg.compute_dtype).reshape(b, 1, h, hd)
            k = dense(lp["wk"], xn, cfg.compute_dtype).reshape(b, 1, kvh, hd)
            v = dense(lp["wv"], xn, cfg.compute_dtype).reshape(b, 1, kvh, hd)
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice_in_dim(
                k_per[j], k.astype(k_per[j].dtype), pos, axis=1
            )
            vc = jax.lax.dynamic_update_slice_in_dim(
                v_per[j], v.astype(v_per[j].dtype), pos, axis=1
            )
            o = attn.decode_attention(
                q, kc, vc, pos + 1,
                window=cfg.window if is_local else None,
            )
            a = dense(lp["wo"], o.reshape(b, 1, h * hd), cfg.compute_dtype)
            if cfg.parallel_block:
                f, _ = _ffn_block(lp, x, cfg, is_moe)
                x = x + a + f
            else:
                x = x + a
                f, _ = _ffn_block(lp, x, cfg, is_moe)
                x = x + f
            k_new.append(kc)
            v_new.append(vc)
        return x, (jnp.stack(k_new), jnp.stack(v_new))

    if cfg.scan_layers:
        x, (k_out, v_out) = jax.lax.scan(
            period_body, x, (params["layers"], k_cache, v_cache)
        )
    else:
        ks_list, vs_list = [], []
        for i in range(cfg.n_periods):
            sl = jax.tree.map(
                lambda a, i=i: a[i], (params["layers"], k_cache, v_cache)
            )
            x, (kp, vp) = period_body(x, sl)
            ks_list.append(kp)
            vs_list.append(vp)
        k_out = jnp.stack(ks_list)
        v_out = jnp.stack(vs_list)
    new_cache = {
        "k": k_out.reshape(cache["k"].shape),
        "v": v_out.reshape(cache["v"].shape),
    }
    return _logits(params, cfg, x)[:, 0], new_cache
