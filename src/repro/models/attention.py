"""Attention: GQA, blocked (flash-style) softmax streaming, sliding-window
chunked locality, and KV-cache decode — all pure jnp so pjit/SPMD can
shard it; the Pallas flash kernel in ``kernels/flash`` is the opt-in fast
path validated against this module.

Layouts:
  q:      [B, Sq, H,  hd]
  k, v:   [B, Sk, KvH, hd]     (GQA: H = KvH * rep)
  out:    [B, Sq, H,  hd]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_gqa(q, n_kv: int):
    b, s, h, d = q.shape
    rep = h // n_kv
    return q.reshape(b, s, n_kv, rep, d)


def _merge_gqa(o):
    b, s, kvh, rep, d = o.shape
    return o.reshape(b, s, kvh * rep, d)


def naive_attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """Reference attention; materializes the full score matrix.  Used by
    smoke tests and as the oracle for the blocked path + Pallas kernel."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    qg = _split_gqa(q, kvh)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg, k) / jnp.sqrt(
        jnp.float32(d)
    ).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bgrst,btgd->bsgrd", p.astype(v.dtype), v)
    return _merge_gqa(o)


def blocked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      block_size: int = 1024, use_scan: bool = True):
    """Streaming-softmax attention over KV blocks (FlashAttention recurrence
    in pure jnp).  Peak memory O(Sq * block) instead of O(Sq * Sk).

    ``use_scan=True`` (production): the block loop is a ``lax.scan`` whose
    carry discipline forces XLA to reuse one block's buffers — the peak
    live set is a single (s, p) pair.  ``use_scan=False`` (roofline
    variants): a static python loop, because XLA cost analysis counts
    while-loop bodies once and §Roofline needs exact per-op accounting."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    rep = h // kvh
    if sk % block_size != 0:
        # pad KV to a block multiple with masked slots
        pad = block_size - sk % block_size
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sk_pad = sk + pad
    else:
        sk_pad = sk
    n_blocks = sk_pad // block_size
    qg = _split_gqa(q, kvh)  # stay bf16: MXU takes bf16 in / f32 accum
    kb = k.reshape(b, n_blocks, block_size, kvh, d)
    vb = v.reshape(b, n_blocks, block_size, kvh, d)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qpos = q_offset + jnp.arange(sq)

    def block_update(carry, k_blk, v_blk, lo_pos):
        acc, m, l = carry
        s = jnp.einsum(
            "bsgrd,btgd->bgrst", qg, k_blk,
            preferred_element_type=jnp.float32,
        ) * scale
        kpos = lo_pos + jnp.arange(block_size)
        mask = kpos[None, :] < sk  # padded slots dead
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        # bf16 probabilities into the AV matmul (flash-style): halves the
        # largest live buffer; the accumulator stays f32.
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrst,btgd->bgrsd", p.astype(v.dtype), v_blk
        ).astype(jnp.float32)
        return acc_new, m_new, l_new

    acc = jnp.zeros((b, kvh, rep, sq, d), jnp.float32)
    m = jnp.full((b, kvh, rep, sq), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    if use_scan:
        # remat the block body: without it, autodiff saves every block's
        # [b, kvh, rep, sq, blk] f32 score tensor stacked across the scan
        # (measured 5.4 GB x ~16 live on llama4 train) — recomputing the
        # block in the backward pass costs ~1 extra QK matmul per block.
        @jax.checkpoint
        def body(carry, blk):
            k_blk, v_blk, blk_idx = blk
            return block_update(carry, k_blk, v_blk,
                                blk_idx * block_size), None

        (acc, m, l), _ = jax.lax.scan(
            body, (acc, m, l),
            (
                jnp.moveaxis(kb, 1, 0),
                jnp.moveaxis(vb, 1, 0),
                jnp.arange(n_blocks),
            ),
        )
    else:
        static_offset = isinstance(q_offset, int)
        for blk_idx in range(n_blocks):
            lo = blk_idx * block_size
            # static skip: block entirely after all queries (causal) or
            # entirely before every query's window
            if static_offset and causal and lo > q_offset + sq - 1:
                continue
            if (
                static_offset and window is not None
                and (lo + block_size) <= q_offset - window + 1
            ):
                continue
            acc, m, l = block_update(
                (acc, m, l), kb[:, blk_idx], vb[:, blk_idx], lo
            )
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.moveaxis(o, 3, 1)  # [b, sq, kvh, rep, d]
    return _merge_gqa(o).astype(q.dtype)


def chunked_local_attention(q, k, v, *, window: int):
    """Training-time sliding-window attention with chunked locality:
    queries in chunk i attend to chunks {i-1, i} masked to the window —
    O(S * 2W) FLOPs instead of O(S^2) (the Mistral/gemma-local scheme).

    Requires seq % window == 0; window == chunk size.
    """
    b, s, h, d = q.shape
    _, _, kvh, _ = k.shape
    assert s % window == 0, (s, window)
    n_chunks = s // window
    rep = h // kvh
    qc = q.reshape(b, n_chunks, window, kvh, rep, d)
    kc = k.reshape(b, n_chunks, window, kvh, d)
    vc = v.reshape(b, n_chunks, window, kvh, d)
    # previous chunk (zero for chunk 0, masked below)
    kprev = jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    kcat = jnp.concatenate([kprev, kc], axis=2)  # [b, n, 2W, kvh, d]
    vcat = jnp.concatenate([vprev, vc], axis=2)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s_ = jnp.einsum(
        "bnsgrd,bntgd->bngrst", qc, kcat,
        preferred_element_type=jnp.float32,
    ) * scale
    qpos = jnp.arange(window)[:, None] + window  # position within 2W frame
    kpos = jnp.arange(2 * window)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    # chunk 0 has no previous chunk
    first = jnp.arange(n_chunks)[:, None, None] > 0
    mask = mask[None] & (first | (kpos[None] >= window))
    s_ = jnp.where(mask[None, :, None, None], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1).astype(q.dtype)
    o = jnp.einsum("bngrst,bntgd->bnsgrd", p, vcat.astype(q.dtype))
    return o.reshape(b, s, h, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None):
    """Single-token decode: q [B, 1, H, hd] against a [B, S, KvH, hd]
    cache filled up to ``cache_len`` (scalar).  Window (if set) restricts
    to the last ``window`` positions.  Pure jnp; sequence-sharded caches
    reduce over the sharded axis via SPMD partial softmax."""
    b, sq, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    qg = _split_gqa(q, kvh)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = jnp.einsum(
        "bsgrd,btgd->bgrst", qg, k_cache.astype(qg.dtype),
        preferred_element_type=jnp.float32,
    ) * scale
    kpos = jnp.arange(s)
    mask = kpos < cache_len
    if window is not None:
        mask = mask & (kpos >= cache_len - window)
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum(
        "bgrst,btgd->bsgrd", p, v_cache.astype(q.dtype),
        preferred_element_type=jnp.float32,
    )
    return _merge_gqa(o).astype(q.dtype)
