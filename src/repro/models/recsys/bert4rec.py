"""BERT4Rec (arXiv:1904.06690): bidirectional transformer over item
sequences with cloze (masked-item) training.

The hot path at production scale is the item *embedding table* (10^6 rows
here) — lookup on the way in (gather == the MESH substrate primitive) and
the full-vocab scoring matmul on the way out.  ``retrieval_score`` is the
1M-candidate retrieval shape: one user state against a candidate id list,
a blocked gather+dot, never a loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (
    cross_entropy,
    layernorm,
    layernorm_init,
)


@dataclasses.dataclass(frozen=True)
class BERT4RecConfig:
    name: str = "bert4rec"
    n_items: int = 1_000_000        # production-size vocab (PAD=0 included)
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    max_seq: int = 200
    d_ff_mult: int = 4
    compute_dtype: object = jnp.float32

    @property
    def vocab(self) -> int:
        # n_items + PAD(0 overlay) + [MASK], rounded up to a 512 multiple
        # so the table shards evenly over any production mesh axis.
        raw = self.n_items + 2
        return -(-raw // 512) * 512

    @property
    def mask_id(self) -> int:
        return self.n_items + 1


def init_params(key, cfg: BERT4RecConfig):
    ks = jax.random.split(key, 4 + 6 * cfg.n_blocks)
    d = cfg.embed_dim
    params = {
        "item_embed": jax.random.normal(ks[0], (cfg.vocab, d)) * (d**-0.5),
        "pos_embed": jax.random.normal(ks[1], (cfg.max_seq, d)) * 0.02,
        "ln_in": layernorm_init(d),
        "ln_out": layernorm_init(d),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        o = 4 + 6 * i
        params["blocks"].append(
            {
                "ln1": layernorm_init(d),
                "wqkv": jax.random.normal(ks[o], (d, 3 * d)) * (d**-0.5),
                "wo": jax.random.normal(ks[o + 1], (d, d)) * (d**-0.5),
                "ln2": layernorm_init(d),
                "w1": jax.random.normal(ks[o + 2], (d, cfg.d_ff_mult * d))
                * (d**-0.5),
                "b1": jnp.zeros((cfg.d_ff_mult * d,)),
                "w2": jax.random.normal(
                    ks[o + 3], (cfg.d_ff_mult * d, d)
                ) * ((cfg.d_ff_mult * d) ** -0.5),
                "b2": jnp.zeros((d,)),
            }
        )
    return params


def encode(params, cfg: BERT4RecConfig, items: jnp.ndarray) -> jnp.ndarray:
    """items [B, S] -> hidden [B, S, D] (bidirectional)."""
    b, s = items.shape
    d = cfg.embed_dim
    h = cfg.n_heads
    x = jnp.take(params["item_embed"], items, axis=0)
    x = x + params["pos_embed"][None, :s]
    x = layernorm(params["ln_in"], x)
    pad_mask = (items != 0).astype(jnp.float32)        # PAD=0
    for blk in params["blocks"]:
        xn = layernorm(blk["ln1"], x)
        qkv = xn @ blk["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, h, d // h)
        k = k.reshape(b, s, h, d // h)
        v = v.reshape(b, s, h, d // h)
        # mask PAD keys by zeroing their value contribution via scores
        o = attn.naive_attention(q, k, v, causal=False)
        x = x + (o.reshape(b, s, d) @ blk["wo"])
        xn = layernorm(blk["ln2"], x)
        f = jax.nn.gelu(xn @ blk["w1"] + blk["b1"])
        x = x + (f @ blk["w2"] + blk["b2"])
        x = x * pad_mask[..., None]
    return layernorm(params["ln_out"], x)


def logits_all_items(params, h: jnp.ndarray) -> jnp.ndarray:
    """Full-vocab scoring (training / offline bulk): [..., D] -> [..., V]."""
    return jnp.einsum("...d,vd->...v", h, params["item_embed"])


def loss_fn(params, cfg: BERT4RecConfig, batch) -> jnp.ndarray:
    """Cloze objective: predict original item at masked positions.

    batch: items [B,S] (with MASK substitutions), labels [B,S],
    loss_mask [B,S] in {0,1}.
    """
    h = encode(params, cfg, batch["items"])
    logits = logits_all_items(params, h)
    return cross_entropy(logits, batch["labels"], batch["loss_mask"])


def loss_sampled(params, cfg: BERT4RecConfig, batch) -> jnp.ndarray:
    """Production cloze loss for 10^6-item catalogs: sampled softmax over
    shared in-batch negatives (full-vocab softmax at train batch 65k x 200
    positions x 1M items is ~petabytes of logits — see DESIGN.md).

    batch: items [B,S], masked_pos [B,M] int32, labels [B,M] int32,
    negatives [Nneg] int32 (shared across the batch).
    """
    h = encode(params, cfg, batch["items"])            # [B, S, D]
    hm = jnp.take_along_axis(
        h, batch["masked_pos"][..., None], axis=1
    )                                                  # [B, M, D]
    pos_emb = jnp.take(params["item_embed"], batch["labels"], axis=0)
    neg_emb = jnp.take(params["item_embed"], batch["negatives"], axis=0)
    pos_logit = jnp.einsum("bmd,bmd->bm", hm, pos_emb)
    neg_logit = jnp.einsum("bmd,nd->bmn", hm, neg_emb)
    # positive in slot 0; negatives after
    logits = jnp.concatenate(
        [pos_logit[..., None], neg_logit], axis=-1
    ).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -logp[..., 0].mean()


def serve_score(params, cfg: BERT4RecConfig, items: jnp.ndarray):
    """Online inference: hidden state at the final (MASK) position scored
    against the full catalog. Returns logits [B, V]."""
    h = encode(params, cfg, items)
    return logits_all_items(params, h[:, -1])


def retrieval_score(
    params, cfg: BERT4RecConfig, items: jnp.ndarray,
    candidate_ids: jnp.ndarray,
) -> jnp.ndarray:
    """Retrieval shape: 1 user sequence vs ``n_candidates`` item ids.
    items [1, S]; candidate_ids [C] -> scores [C]."""
    h = encode(params, cfg, items)[:, -1]              # [1, D]
    cand = jnp.take(params["item_embed"], candidate_ids, axis=0)  # [C, D]
    return jnp.einsum("bd,cd->bc", h, cand)[0]
