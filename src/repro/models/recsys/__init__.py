"""RecSys models: BERT4Rec over a production-size item embedding table."""
from repro.models.recsys.bert4rec import BERT4RecConfig

__all__ = ["BERT4RecConfig"]
