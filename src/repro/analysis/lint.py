"""AST lints over ``src/repro``: the four static rules.

* ``traced-cond`` — Python ``if``/``while`` whose test references a
  traced value, inside a **traced region** (a function passed to
  ``jax.jit`` / ``lax.scan`` / ``lax.cond`` / ``vmap`` /
  ``pallas_call`` / ``shard_map`` ..., decorated with one, or nested in
  one).  "Traced value" is a static approximation: the function's
  parameters (minus its ``static_argnames`` and any names bound
  statically through ``functools.partial`` at the tracing call site),
  names tuple-unpacked from them, and names assigned from
  ``jnp.``/``jax.lax.`` calls.  Identity tests (``is None``),
  ``isinstance``/``len``/``callable`` and shape/dtype attribute reads
  are Python-static and never flagged.

* ``host-sync`` — ``.item()`` / ``.tobytes()`` / ``float()`` / ``int()``
  / ``bool()`` / ``np.asarray`` / ``np.array`` / ``jax.device_get`` /
  ``block_until_ready`` call sites, classified against the serve /
  superstep **hot-path inventory** (``HOT_PATHS``):

  - ``finding`` — on a hot path, outside any tracer guard;
  - ``guarded`` — on a hot path but inside ``if tracer is not None:``
    (or after an early ``if tracer is None: return`` fast path) — the
    observability contract: sync only when someone is watching;
  - ``cold-path`` — everywhere else (compile/boot/layout-build time);
    reported as counts, never as findings.

  Casts of static values (``int(x.shape[0])``, ``int(<static arg>)``)
  are Python-level and skipped.

* ``static-arg-array`` — array values meeting ``jax.jit`` static
  arguments: an array-valued default on a static-named parameter, an
  array literal/constructor passed to a static-named kwarg at a call
  site, or a ``functools.partial`` binding an array to a static name.

* ``tracer-gate`` — a function that accepts a ``tracer`` and calls
  ``tracer.span(...)`` / ``tracer.block(...)`` with no ``tracer is
  None`` branch anywhere in its body (``maybe_span`` is the sanctioned
  alternative and never flagged).

Suppression: a trailing ``# analysis: ignore[rule]`` on the finding's
line (or the line above) reclassifies it as ``suppressed`` — the
inline acknowledgment for intentional sites.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from repro.analysis.findings import Finding

# Entry points whose function-valued arguments become traced regions.
_TRACING_ENTRY = {
    "jit", "vmap", "pmap", "scan", "cond", "while_loop", "fori_loop",
    "switch", "pallas_call", "shard_map", "grad", "value_and_grad",
    "checkpoint", "remat", "eval_shape",
}

# Calls whose results are traced arrays inside a traced region.
_ARRAY_ROOTS = ("jnp", "lax", "pl", "pltpu")
_ARRAY_JAX_SUBMODULES = ("lax", "numpy", "nn", "random")

# Python-static predicates: never a traced branch.
_SAFE_CALLS = {
    "isinstance", "hasattr", "callable", "len", "issubclass", "getattr",
    "type", "id", "repr", "str",
}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}

# Host-sync method / function names.
_SYNC_METHODS = {"item", "tobytes", "block_until_ready"}
_SYNC_DOTTED = {
    ("np", "asarray"), ("np", "array"), ("numpy", "asarray"),
    ("numpy", "array"), ("jax", "block_until_ready"),
    ("jax", "device_get"),
}
_SYNC_BARE = {"float", "int", "bool"}

# The serve / superstep hot-path inventory: module-relative path suffix
# -> qualname prefixes.  A call site is "hot" when its file matches and
# its enclosing qualname extends one of these (closures included:
# ``_execute.<locals>._call`` is hot because ``_execute`` is).
HOT_PATHS: dict[str, tuple[str, ...]] = {
    "core/serving.py": (
        "CompiledAlgorithm.run", "CompiledAlgorithm.run_batch",
        "CompiledAlgorithm._execute", "signature", "_initial_msg_sig",
        "_query_sig", "_canon_query", "_build_local_executable",
        "_build_distributed_executable",
    ),
    "core/engine.py": (
        "deliver", "superstep_pair", "compute", "compute_batch",
        "batch_halting_scan",
    ),
    "serve/frontend.py": (
        "Frontend.submit", "Frontend.pump", "Frontend._worker",
        "Frontend._serve_loop", "Frontend._run_flush",
        "Frontend._execute_requests", "Frontend._attempt",
        "Frontend._requeue_after_crash", "Frontend._fail",
        "_stack", "_unstack", "_block",
    ),
    "serve/queue.py": (
        "CoalescingBatcher.submit", "CoalescingBatcher.poll",
        "CoalescingBatcher._take", "AdaptiveDelay.observe",
    ),
    "serve/replica.py": (
        "_serve_replica", "ProcessReplica.poll_messages",
        "ProcessReplica.send",
    ),
    "serve/router.py": (
        "Router.submit", "Router.pump", "Router._admit", "Router._route",
        "Router._dispatch", "Router._on_message", "Router._mark_dead",
        "Router._apply", "Router._fail_pending_if_hopeless",
    ),
    "kernels/deliver/fused.py": (
        "deliver_fused_pallas", "deliver_fused_classes",
        "_combine_kernel",
    ),
    "kernels/deliver/xla.py": ("deliver_ell_leaf",),
}

_SUPPRESS_RE = re.compile(r"#\s*analysis:\s*ignore(?:\[([a-z\-,\s]+)\])?")

# Broad exception classes a handler may catch without naming the real
# failure; and the faults-taxonomy / error-forwarding names whose
# presence in a handler body means the error was routed, not swallowed.
_BROAD_EXC = {"Exception", "BaseException"}
_ERROR_ROUTES = {
    "FaultError", "InjectedFault", "TransientExecuteError",
    "DeadlineExceeded", "FrontendClosed", "PoisonQuery", "CircuitOpen",
    "CorruptCacheEntry", "CheckpointError", "ReplicaLost", "Overloaded",
    "is_transient", "set_exception",
}


def _broad_handler(h: ast.ExceptHandler) -> bool:
    if h.type is None:
        return True
    elts = h.type.elts if isinstance(h.type, ast.Tuple) else [h.type]
    return any(
        (_dotted(e) or "").rsplit(".", 1)[-1] in _BROAD_EXC for e in elts
    )


def _handler_routes(h: ast.ExceptHandler) -> bool:
    """Does the handler re-raise, forward the bound exception, or reach
    into the faults taxonomy?  Any of these counts as routing."""
    for node in ast.walk(h):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Name):
            if node.id in _ERROR_ROUTES:
                return True
            if (
                h.name is not None
                and node.id == h.name
                and isinstance(node.ctx, ast.Load)
            ):
                return True
        if isinstance(node, ast.Attribute) and node.attr in _ERROR_ROUTES:
            return True
    return False


def _dotted(node: ast.expr) -> str | None:
    """``a.b.c`` -> "a.b.c" for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_partial(call: ast.Call) -> bool:
    return _dotted(call.func) in ("partial", "functools.partial")


def _static_argnames(keywords) -> set[str]:
    static: set[str] = set()
    for kw in keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            static |= set(_const_str_tuple(kw.value))
    return static


def _const_str_tuple(node: ast.expr | None) -> tuple[str, ...]:
    """Constant strings out of ``static_argnames=("a", "b")`` forms."""
    if node is None:
        return ()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(
            elt.value for elt in node.elts
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str)
        )
    return ()


def _is_array_expr(node: ast.expr) -> bool:
    """Array literal or constructor call: a value jit can't hash."""
    if isinstance(node, (ast.List, ast.Set)):
        return True
    if isinstance(node, ast.Call):
        name = _dotted(node.func) or ""
        leaf = name.rsplit(".", 1)[-1]
        root = name.split(".", 1)[0]
        return root in ("np", "numpy", "jnp", "jax") and leaf in (
            "asarray", "array", "zeros", "ones", "full", "arange",
            "empty", "linspace",
        )
    return False


def _is_static_expr(node: ast.expr) -> bool:
    """Shape/len reads: host ints by construction, cast-safe."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            return True
        if isinstance(sub, ast.Call):
            name = _dotted(sub.func) or ""
            if name == "len" or name.endswith(".shape"):
                return True
    return False


class _Suppressions:
    """Per-file ``# analysis: ignore[rule]`` index.  A marker covers
    its own line (trailing comment) or, when it sits in a comment-only
    block, every line of that block plus the next source line."""

    def __init__(self, source: str):
        self.by_line: dict[int, set[str] | None] = {}
        lines = source.splitlines()
        for i, text in enumerate(lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = m.group(1)
            parsed = (
                {r.strip() for r in rules.split(",")} if rules else None
            )
            covered = [i]
            if text.lstrip().startswith("#"):
                # comment-only marker: extend through the rest of the
                # comment block to the first source line below
                j = i
                while j < len(lines) and lines[j].lstrip().startswith("#"):
                    j += 1
                    covered.append(j)
                covered.append(j + 1)
            for ln in covered:
                prev = self.by_line.get(ln, set())
                if parsed is None or prev is None:
                    self.by_line[ln] = None   # None = all rules
                else:
                    self.by_line[ln] = prev | parsed

    def covers(self, line: int, rule: str) -> bool:
        rules = self.by_line.get(line, ())
        return rules is None or (rules != () and rule in rules)


# --------------------------------------------------------------------------
# module index: which names are traced / statically jitted
# --------------------------------------------------------------------------

class _ModuleIndex(ast.NodeVisitor):
    """Which local function names are traced regions, which of their
    parameters are static, and which names are jitted with static args
    (the static-arg-array call-site map)."""

    def __init__(self):
        self.traced_names: set[str] = set()
        self.static_names: dict[str, set[str]] = {}
        self.static_jitted: dict[str, set[str]] = {}

    def _note(self, name: str, static: set[str]) -> None:
        self.traced_names.add(name)
        self.static_names.setdefault(name, set()).update(static)

    def _fn_arg(self, node: ast.expr, static: set[str]) -> None:
        """One function-valued argument of a tracing entry point."""
        if isinstance(node, ast.Name):
            self._note(node.id, static)
        elif isinstance(node, ast.IfExp):
            self._fn_arg(node.body, static)
            self._fn_arg(node.orelse, static)
        elif isinstance(node, ast.Call) and _is_partial(node):
            bound = {kw.arg for kw in node.keywords if kw.arg}
            if node.args and isinstance(node.args[0], ast.Name):
                self._note(node.args[0].id, static | bound)

    def _note_jit_call(self, args, static: set[str]) -> None:
        for arg in args:
            self._fn_arg(arg, static)
            if static and isinstance(arg, ast.Name):
                self.static_jitted.setdefault(arg.id, set()).update(static)

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        leaf = (name or "").rsplit(".", 1)[-1]
        if leaf in _TRACING_ENTRY:
            static = _static_argnames(node.keywords)
            if leaf == "jit":
                self._note_jit_call(node.args, static)
            else:
                for arg in node.args:
                    self._fn_arg(arg, static)
        elif isinstance(node.func, ast.Call) and _is_partial(node.func):
            # partial(jax.jit, static_argnames=...)(fn)
            inner = (
                _dotted(node.func.args[0]) if node.func.args else None
            ) or ""
            if inner.rsplit(".", 1)[-1] in _TRACING_ENTRY:
                static = _static_argnames(node.func.keywords)
                self._note_jit_call(node.args, static)
        self.generic_visit(node)


def _decorator_trace_info(fn: ast.AST) -> tuple[bool, set[str]]:
    """(is the def decorated into a traced region, its static names)."""
    static: set[str] = set()
    traced = False
    for dec in getattr(fn, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target) or ""
        leaf = name.rsplit(".", 1)[-1]
        if leaf in _TRACING_ENTRY:
            traced = True
            if isinstance(dec, ast.Call):
                static |= _static_argnames(dec.keywords)
        elif leaf == "partial" and isinstance(dec, ast.Call):
            # @functools.partial(jax.jit, static_argnames=...)
            inner = (_dotted(dec.args[0]) if dec.args else None) or ""
            if inner.rsplit(".", 1)[-1] in _TRACING_ENTRY:
                traced = True
                static |= _static_argnames(dec.keywords)
    return traced, static


def _param_names(fn) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return [n for n in names if n not in ("self", "cls")]


def _collect_traced_locals(fn, params: set[str]) -> set[str]:
    """Names plausibly holding traced values in ``fn``'s body: the
    params, names unpacked/derived from them, jnp/lax call results."""
    traced = set(params)
    for _ in range(2):  # second pass catches unpack -> derive chains
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            v = node.value
            src_traced = False
            if isinstance(v, ast.Name) and v.id in traced:
                src_traced = True
            elif isinstance(v, ast.Subscript):
                if isinstance(v.value, ast.Name) and v.value.id in traced:
                    src_traced = True
            elif isinstance(v, ast.Call):
                name = _dotted(v.func) or ""
                root = name.split(".", 1)[0]
                sub = name.split(".")
                if root in _ARRAY_ROOTS:
                    src_traced = True
                elif root == "jax" and len(sub) > 1 and (
                    sub[1] in _ARRAY_JAX_SUBMODULES
                ):
                    src_traced = True
            if not src_traced:
                continue
            for tgt in node.targets:
                for elt in ast.walk(tgt):
                    if isinstance(elt, ast.Name):
                        traced.add(elt.id)
    return traced


def _test_uses_traced(node: ast.expr, traced: set[str]) -> bool:
    """Does a branch test reference a traced value in a way Python
    must concretize?  Static predicates are excluded."""
    if isinstance(node, ast.BoolOp):
        return any(_test_uses_traced(v, traced) for v in node.values)
    if isinstance(node, ast.UnaryOp):
        return _test_uses_traced(node.operand, traced)
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False
        return _test_uses_traced(node.left, traced) or any(
            _test_uses_traced(c, traced) for c in node.comparators
        )
    if isinstance(node, ast.Call):
        name = _dotted(node.func) or ""
        if name.rsplit(".", 1)[-1] in _SAFE_CALLS:
            return False
        return any(_test_uses_traced(a, traced) for a in node.args)
    if isinstance(node, ast.Attribute):
        # attribute reads are config/shape access until proven traced —
        # direct Name references are the signal this lint keys on.
        return False
    if isinstance(node, ast.Subscript):
        return _test_uses_traced(node.value, traced)
    if isinstance(node, ast.BinOp):
        return (_test_uses_traced(node.left, traced)
                or _test_uses_traced(node.right, traced))
    if isinstance(node, ast.Name):
        return node.id in traced
    return False


def _tracer_exprs(node: ast.expr) -> bool:
    """Does an expression read a tracer (``tracer`` name or ``*.tracer``
    attribute)?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id == "tracer":
            return True
        if isinstance(sub, ast.Attribute) and sub.attr == "tracer":
            return True
    return False


def _is_tracer_none_test(test: ast.expr) -> tuple[bool, bool]:
    """(is a ``tracer is None``-family test, truthy-branch-means-absent).

    Compound ``and`` tests (``tracer is not None and timing``) count as
    guards: their truthy branch can only run with a tracer present.
    """
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        for v in test.values:
            ok, absent = _is_tracer_none_test(v)
            if ok:
                return ok, absent
        return False, False
    if not isinstance(test, ast.Compare) or len(test.ops) != 1:
        return False, False
    if not isinstance(test.ops[0], (ast.Is, ast.IsNot)):
        return False, False
    comp = test.comparators[0]
    if not (isinstance(comp, ast.Constant) and comp.value is None):
        return False, False
    if not _tracer_exprs(test.left):
        return False, False
    return True, isinstance(test.ops[0], ast.Is)


def _sync_call_kind(node: ast.Call, safe_names: set[str]) -> str | None:
    """The host-sync pattern this call matches, or None."""
    if isinstance(node.func, ast.Attribute):
        if node.func.attr in _SYNC_METHODS:
            return f".{node.func.attr}()"
        name = _dotted(node.func)
        if name and tuple(name.split(".")) in _SYNC_DOTTED:
            return name
    elif isinstance(node.func, ast.Name) and node.func.id in _SYNC_BARE:
        if not node.args:
            return None
        arg = node.args[0]
        if isinstance(arg, ast.Constant) or _is_static_expr(arg):
            return None
        if isinstance(arg, ast.Name) and arg.id in safe_names:
            return None
        return f"{node.func.id}()"
    return None


def _returns(body: list[ast.stmt]) -> bool:
    return any(isinstance(s, (ast.Return, ast.Raise)) for s in body)


def _early_tracer_return_line(fn) -> int | None:
    """Line of a top-level ``if tracer is None: return`` fast path."""
    for stmt in fn.body:
        if isinstance(stmt, ast.If):
            ok, absent = _is_tracer_none_test(stmt.test)
            if ok and absent and _returns(stmt.body):
                return stmt.lineno
    return None


def _hot_prefixes(rel_path: str) -> tuple[str, ...]:
    for suffix, prefixes in HOT_PATHS.items():
        if rel_path.endswith(suffix):
            return prefixes
    return ()


def _is_hot(qualname: str, prefixes: tuple[str, ...]) -> bool:
    return any(
        qualname == p or qualname.startswith(p + ".") for p in prefixes
    )


# --------------------------------------------------------------------------
# per-file linter
# --------------------------------------------------------------------------

class _Ctx:
    """Walk context: enclosing qualname, traced-region state, active
    tracer guards, and names safe to cast (static args)."""

    __slots__ = ("qual", "traced", "traced_locals", "guards", "safe_names")

    def __init__(self, qual="", traced=False, traced_locals=frozenset(),
                 guards=(), safe_names=frozenset()):
        self.qual = qual
        self.traced = traced
        self.traced_locals = traced_locals
        self.guards = guards
        self.safe_names = safe_names

    def with_(self, **kw) -> "_Ctx":
        new = _Ctx(self.qual, self.traced, self.traced_locals,
                   self.guards, self.safe_names)
        for k, v in kw.items():
            setattr(new, k, v)
        return new


class _FileLinter:
    def __init__(self, rel_path: str, source: str, tree: ast.Module):
        self.rel = rel_path
        self.tree = tree
        self.suppress = _Suppressions(source)
        self.index = _ModuleIndex()
        self.index.visit(tree)
        self.hot = _hot_prefixes(rel_path)
        self.findings: list[Finding] = []

    def run(self) -> list[Finding]:
        ctx = _Ctx()
        for stmt in self.tree.body:
            self._walk_stmt(stmt, ctx)
        return self.findings

    # -- emit --------------------------------------------------------------

    def _emit(self, rule, node, scope, message, classification="finding"):
        line = getattr(node, "lineno", 0)
        if classification == "finding" and self.suppress.covers(line, rule):
            classification = "suppressed"
        self.findings.append(Finding(
            rule=rule, path=self.rel, line=line, scope=scope,
            message=message, classification=classification,
        ))

    # -- traversal ---------------------------------------------------------

    def _walk_stmt(self, stmt: ast.stmt, ctx: _Ctx) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._enter_function(stmt, ctx)
            return
        if isinstance(stmt, ast.ClassDef):
            inner = ctx.with_(qual=self._join(ctx.qual, stmt.name),
                              traced_locals=frozenset())
            for s in stmt.body:
                self._walk_stmt(s, inner)
            return
        if ctx.traced and isinstance(stmt, (ast.If, ast.While)):
            if _test_uses_traced(stmt.test, ctx.traced_locals):
                kind = "while" if isinstance(stmt, ast.While) else "if"
                names = sorted({
                    n.id for n in ast.walk(stmt.test)
                    if isinstance(n, ast.Name)
                    and n.id in ctx.traced_locals
                })
                self._emit(
                    "traced-cond", stmt, ctx.qual or "<module>",
                    f"`{kind}` on traced value(s) {', '.join(names)} "
                    "inside a traced region",
                )
        if isinstance(stmt, ast.Try):
            self._check_swallowed(stmt, ctx)
        if isinstance(stmt, ast.If):
            is_tracer, absent = _is_tracer_none_test(stmt.test)
            if is_tracer and not absent:
                # truthy branch runs only with a tracer present
                self._walk_expr(stmt.test, ctx)
                on = ctx.with_(guards=ctx.guards + ("tracer",))
                for s in stmt.body:
                    self._walk_stmt(s, on)
                for s in stmt.orelse:
                    self._walk_stmt(s, ctx)
                return
        self._walk_children(stmt, ctx)

    def _walk_children(self, node: ast.AST, ctx: _Ctx) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._enter_function(child, ctx)
            elif isinstance(child, ast.ClassDef):
                self._walk_stmt(child, ctx)
            elif isinstance(child, ast.stmt):
                self._walk_stmt(child, ctx)
            elif isinstance(child, ast.expr):
                self._walk_expr(child, ctx)
            else:  # withitem, ExceptHandler, keyword, arguments, ...
                self._walk_children(child, ctx)

    def _walk_expr(self, node: ast.expr, ctx: _Ctx) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._check_sync_call(sub, ctx)
                self._check_static_arg_call(sub, ctx)

    # -- host-sync ---------------------------------------------------------

    def _check_sync_call(self, node: ast.Call, ctx: _Ctx) -> None:
        kind = _sync_call_kind(node, ctx.safe_names)
        if kind is None:
            return
        scope = ctx.qual or "<module>"
        if not self.hot or not _is_hot(scope, self.hot):
            self._emit("host-sync", node, scope, f"{kind} (cold path)",
                       classification="cold-path")
        elif "tracer" in ctx.guards:
            self._emit("host-sync", node, scope,
                       f"{kind} inside a tracer guard",
                       classification="guarded")
        else:
            self._emit(
                "host-sync", node, scope,
                f"{kind} on hot path `{scope}` outside any tracer guard",
            )

    # -- swallowed-error ---------------------------------------------------

    def _check_swallowed(self, stmt: ast.Try, ctx: _Ctx) -> None:
        """Bare/broad ``except`` that discards the error.  On the serve /
        superstep hot paths this is a finding (a fault silently eaten
        there breaks the every-request-resolves invariant); elsewhere
        it is reported as a cold-path count."""
        scope = ctx.qual or "<module>"
        hot = bool(self.hot) and _is_hot(scope, self.hot)
        for h in stmt.handlers:
            if not _broad_handler(h) or _handler_routes(h):
                continue
            what = (
                "bare `except:`" if h.type is None
                else "broad `except`"
            )
            if hot:
                self._emit(
                    "swallowed-error", h, scope,
                    f"{what} on hot path `{scope}` discards the error "
                    "without routing it through the faults taxonomy",
                )
            else:
                self._emit(
                    "swallowed-error", h, scope, f"{what} (cold path)",
                    classification="cold-path",
                )

    # -- static-arg-array --------------------------------------------------

    def _check_static_arg_call(self, node: ast.Call, ctx: _Ctx) -> None:
        scope = ctx.qual or "<module>"
        fname = node.func.id if isinstance(node.func, ast.Name) else None
        static = self.index.static_jitted.get(fname or "", set())
        for kw in node.keywords:
            if kw.arg in static and _is_array_expr(kw.value):
                self._emit(
                    "static-arg-array", node, scope,
                    f"array value for static argument `{kw.arg}` of "
                    f"jitted `{fname}`",
                )
        if _is_partial(node) and node.args:
            target = node.args[0]
            tname = target.id if isinstance(target, ast.Name) else None
            tstatic = self.index.static_jitted.get(tname or "", set())
            for kw in node.keywords:
                if kw.arg in tstatic and _is_array_expr(kw.value):
                    self._emit(
                        "static-arg-array", node, scope,
                        f"partial binds array to static argument "
                        f"`{kw.arg}` of jitted `{tname}`",
                    )

    # -- function entry ----------------------------------------------------

    def _enter_function(self, fn, ctx: _Ctx) -> None:
        fq = self._join(ctx.qual, fn.name)
        dec_traced, static = _decorator_trace_info(fn)
        static = set(static) | self.index.static_names.get(fn.name, set())
        traced = (
            ctx.traced or dec_traced or fn.name in self.index.traced_names
        )
        params = set(_param_names(fn)) - static
        traced_locals = (
            frozenset(_collect_traced_locals(fn, params))
            if traced else frozenset()
        )

        # array defaults feeding static args
        defaults = fn.args.defaults
        if static and defaults:
            with_defaults = (fn.args.posonlyargs + fn.args.args)
            with_defaults = with_defaults[-len(defaults):]
            for p, d in zip(with_defaults, defaults):
                if p.arg in static and _is_array_expr(d):
                    self._emit(
                        "static-arg-array", d, fq,
                        f"array default on static argument `{p.arg}`",
                    )
        for p, d in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
            if d is not None and p.arg in static and _is_array_expr(d):
                self._emit(
                    "static-arg-array", d, fq,
                    f"array default on static argument `{p.arg}`",
                )

        self._check_tracer_gate(fn, fq)

        inner = ctx.with_(
            qual=fq, traced=traced, traced_locals=traced_locals,
            guards=(), safe_names=frozenset(static | ctx.safe_names),
        )
        guard_line = _early_tracer_return_line(fn)
        if guard_line is None:
            for s in fn.body:
                self._walk_stmt(s, inner)
            return
        # `if tracer is None: return ...` — everything after runs
        # tracer-present.
        guarded = inner.with_(guards=("tracer",))
        for s in fn.body:
            self._walk_stmt(s, inner if s.lineno <= guard_line else guarded)

    def _check_tracer_gate(self, fn, fq: str) -> None:
        if "tracer" not in {
            p.arg for p in (
                fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
            )
        }:
            return
        span_calls = []
        has_guard = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = _dotted(node.func) or ""
                if name in ("tracer.span", "tracer.block"):
                    span_calls.append(node)
                if name.rsplit(".", 1)[-1] == "maybe_span":
                    has_guard = True
            if isinstance(node, ast.If):
                ok, _ = _is_tracer_none_test(node.test)
                has_guard = has_guard or ok
        if span_calls and not has_guard:
            self._emit(
                "tracer-gate", span_calls[0], fq,
                "calls tracer.span/block with no `tracer is None` "
                "fast path",
            )

    @staticmethod
    def _join(qual: str, name: str) -> str:
        return f"{qual}.{name}" if qual else name


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def lint_file(path: str | Path, root: str | Path | None = None
              ) -> list[Finding]:
    path = Path(path).resolve()
    rel = str(path.relative_to(root)) if root else str(path)
    source = path.read_text()
    try:
        tree = ast.parse(source)
    except SyntaxError as err:
        return [Finding(
            rule="traced-cond", path=rel, line=err.lineno or 0,
            scope="<module>", message=f"unparseable: {err.msg}",
        )]
    return _FileLinter(rel, source, tree).run()


def lint_tree(root: str | Path) -> list[Finding]:
    """Lint every ``.py`` under ``root`` (paths reported relative to the
    repo root when ``root`` sits inside one)."""
    root = Path(root)
    repo = _repo_root(root)
    findings: list[Finding] = []
    for path in sorted(root.rglob("*.py")):
        if "_vendor" in path.parts:
            continue
        findings.extend(lint_file(path, root=repo))
    return findings


def _repo_root(start: Path) -> Path | None:
    p = start.resolve()
    for cand in (p, *p.parents):
        if (cand / ".git").exists() or (cand / "pyproject.toml").exists():
            return cand
    return None
