"""Static analysis for the compile-once seam: ``python -m repro.analysis``.

Four passes over the invariants MESH's flexibility bargain rests on:

* ``lint`` — AST rules (traced-cond, host-sync vs the hot-path
  inventory, static-arg-array, tracer-gate) over ``src/repro``;
* ``retrace`` — the compile-once contract, checked live on the warm
  paths (also exported as the ``assert_no_retrace`` guard and the
  ``no_retrace`` pytest fixture);
* ``digest`` — ``stable_digest`` identity / collision / cross-process
  determinism over a spec x config x bucket grid;
* ``shapes`` — ``jax.eval_shape`` agreement between the two delivery
  lowerings plus static VMEM tile budgets.

Findings diff against ``tools/analysis_baseline.json`` so pre-existing
accepted findings never block CI; new ones do.
"""
from repro.analysis.findings import (
    RULES,
    Finding,
    baseline_counts,
    diff_baseline,
    load_baseline,
    save_baseline,
    summarize,
)
from repro.analysis.lint import HOT_PATHS, lint_file, lint_tree
from repro.analysis.retrace import RetraceError, assert_no_retrace

__all__ = [
    "RULES", "Finding", "baseline_counts", "diff_baseline",
    "load_baseline", "save_baseline", "summarize",
    "HOT_PATHS", "lint_file", "lint_tree",
    "RetraceError", "assert_no_retrace",
]
