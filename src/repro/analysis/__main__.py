"""CLI: ``python -m repro.analysis [--passes lint,digest,shapes,retrace]``.

Exit 0 when every finding is covered by the committed baseline
(``tools/analysis_baseline.json``); exit 1 on any new finding.  Each
finding prints as ``file:line: [rule] message`` with the rule's
one-line rationale underneath (``--no-explain`` drops it).

``--update-baseline`` rewrites the baseline from the current findings
— the sanctioned way to accept a new intentional finding (prefer an
inline ``# analysis: ignore[rule]`` where the intent is site-local).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.findings import (
    Finding,
    diff_baseline,
    load_baseline,
    save_baseline,
    summarize,
)

PASSES = ("lint", "digest", "shapes", "retrace")


def _run_pass(name: str, root: Path) -> list[Finding]:
    if name == "lint":
        from repro.analysis.lint import lint_tree

        return lint_tree(root / "src" / "repro")
    if name == "digest":
        from repro.analysis.digest import audit

        return audit()
    if name == "shapes":
        from repro.analysis.shapes import shape_vmem_audit

        return shape_vmem_audit()
    if name == "retrace":
        from repro.analysis.retrace import retrace_smoke

        return retrace_smoke()
    raise SystemExit(f"unknown pass: {name} (choose from {PASSES})")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument(
        "--passes", default="lint,digest,shapes",
        help=f"comma-separated subset of {PASSES} (retrace is live "
             "compilation: opt in)",
    )
    ap.add_argument("--root", default=None,
                    help="repo root (default: inferred from this package)")
    ap.add_argument("--baseline", default="tools/analysis_baseline.json")
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument("--no-explain", action="store_true",
                    help="drop the per-rule rationale lines")
    ap.add_argument("--show", default="finding",
                    help="classifications to print, comma-separated "
                         "(finding,guarded,cold-path,suppressed,all)")
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root else _infer_root()
    passes = [p.strip() for p in args.passes.split(",") if p.strip()]

    findings: list[Finding] = []
    for name in passes:
        found = _run_pass(name, root)
        findings.extend(found)
        print(f"[{name}] {len(found)} result(s)")

    baseline_path = root / args.baseline
    if args.update_baseline:
        save_baseline(baseline_path, findings)
        print(f"baseline updated: {baseline_path} "
              f"({sum(1 for f in findings if f.classification == 'finding')}"
              " finding(s))")
        return 0

    show = {s.strip() for s in args.show.split(",")}
    explain = not args.no_explain
    for f in findings:
        if "all" in show or f.classification in show:
            print(f.format(explain=explain and f.classification
                           == "finding"))

    counts = summarize(findings)
    print("summary:", " ".join(
        f"{k}={v}" for k, v in sorted(counts["by_class"].items())
    ) or "clean")

    fresh, stale = diff_baseline(findings, load_baseline(baseline_path))
    if stale:
        print(f"note: {len(stale)} stale baseline key(s) — rerun with "
              "--update-baseline to tighten:")
        for k in stale:
            print(f"  {k}")
    if fresh:
        print(f"\n{len(fresh)} NEW finding(s) vs baseline "
              f"({baseline_path}):")
        for f in fresh:
            print(f.format(explain=explain))
        return 1
    print("OK: no new findings vs baseline")
    return 0


def _infer_root() -> Path:
    here = Path(__file__).resolve()
    for cand in here.parents:
        if (cand / ".git").exists() or (cand / "pyproject.toml").exists():
            return cand
    return Path.cwd()


if __name__ == "__main__":
    sys.exit(main())
