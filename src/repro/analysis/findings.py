"""The finding model shared by every ``repro.analysis`` pass.

A **finding** is one violated invariant, anchored to a source location
when the pass is static (the AST lints) or to a synthetic location when
it is semantic (digest audit, shape/VMEM validation, retrace smoke).
Every finding carries:

* a **rule id** (one of ``RULES``) — the invariant class;
* a one-line **message** — why THIS site violates it;
* a **classification** — ``finding`` (actionable), ``guarded`` (inside
  a ``tracer`` guard, by design), ``cold-path`` (outside the serve /
  superstep hot paths), or ``suppressed`` (an inline
  ``# analysis: ignore[rule]`` acknowledged it).

Only ``finding``-classified results count against the committed
baseline (``tools/analysis_baseline.json``); the rest are reported as
summary counts so the hot-path host-sync inventory stays visible.

Baseline keys are line-independent (``rule:path:scope``) so unrelated
edits shifting line numbers never invalidate the baseline; a scope
gaining MORE findings of a rule than the baseline records still fails.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from pathlib import Path

# rule id -> the one-line rationale the CLI prints next to every finding.
RULES = {
    "traced-cond": (
        "Python `if`/`while` on a traced value inside a jitted/scanned "
        "region fails at trace time (ConcretizationTypeError) or forces "
        "a host sync; use `lax.cond` / `jnp.where`"
    ),
    "host-sync": (
        "host transfer (`.item()`, `float()`, `np.asarray`, "
        "`block_until_ready`, `.tobytes()`) on a serve/superstep hot "
        "path outside a tracer guard stalls dispatch on every request"
    ),
    "static-arg-array": (
        "array value feeding a `jax.jit` static argument: unhashable "
        "(TypeError at call time) or a fresh trace per call"
    ),
    "tracer-gate": (
        "function takes a tracer but spans unconditionally: the "
        "zero-overhead-when-absent contract needs a `tracer is None` "
        "fast path (or `maybe_span`)"
    ),
    "swallowed-error": (
        "a bare/broad `except` on a serve or superstep hot path "
        "discards the error: route it through the faults taxonomy "
        "(re-raise, forward the bound exception, or resolve a future "
        "with it) or annotate the intentional swallow"
    ),
    "retrace": (
        "a warm-path serve recompiled: the compile-once contract "
        "(same bucket + same design point = one executable) is broken"
    ),
    "digest-unstable": (
        "stable_digest of this signature differs across processes: the "
        "disk executable cache would never hit on replica boot"
    ),
    "digest-collision": (
        "two semantically distinct signatures share one stable_digest: "
        "the disk cache would serve the wrong executable (cache "
        "poisoning)"
    ),
    "digest-identity": (
        "rebuilding the same spec changed its stable_digest: object "
        "identity leaked into the digest, so a new process never hits"
    ),
    "shape-mismatch": (
        "the two delivery lowerings (xla.py, fused.py) disagree on "
        "output shape/dtype for this layout/monoid: the delivery axis "
        "is not a pure design choice anymore"
    ),
    "vmem-budget": (
        "the Pallas select-reduce tile ([block_n, block_e, D]) exceeds "
        "the per-core VMEM budget: this class config cannot run on TPU"
    ),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str                    # repo-relative, or "<pass>" for semantic
    line: int                    # 1-based; 0 for semantic findings
    scope: str                   # enclosing qualname ("<module>" at top)
    message: str                 # one-line site-specific rationale
    classification: str = "finding"

    @property
    def key(self) -> str:
        """Line-independent baseline key."""
        return f"{self.rule}:{self.path}:{self.scope}"

    def format(self, explain: bool = True) -> str:
        """``file:line: [rule] message`` — clickable in a terminal."""
        loc = f"{self.path}:{self.line}" if self.line else self.path
        head = f"{loc}: [{self.rule}] {self.message}"
        if explain and self.rule in RULES:
            head += f"\n    why: {RULES[self.rule]}"
        return head


def summarize(findings: list[Finding]) -> dict:
    """Per-rule / per-classification counts for the CLI summary."""
    by_rule: Counter = Counter()
    by_class: Counter = Counter()
    for f in findings:
        by_rule[f"{f.rule}:{f.classification}"] += 1
        by_class[f.classification] += 1
    return {"by_rule": dict(by_rule), "by_class": dict(by_class)}


# --------------------------------------------------------------------------
# baseline: pre-existing findings that don't block CI
# --------------------------------------------------------------------------

def baseline_counts(findings: list[Finding]) -> dict[str, int]:
    counts: Counter = Counter()
    for f in findings:
        if f.classification == "finding":
            counts[f.key] += 1
    return dict(sorted(counts.items()))


def load_baseline(path: str | Path) -> dict[str, int]:
    p = Path(path)
    if not p.exists():
        return {}
    doc = json.loads(p.read_text())
    return {str(k): int(v) for k, v in doc.get("findings", {}).items()}


def save_baseline(path: str | Path, findings: list[Finding]) -> None:
    Path(path).write_text(json.dumps(
        {"version": 1, "findings": baseline_counts(findings)}, indent=2,
    ) + "\n")


def diff_baseline(
    findings: list[Finding], baseline: dict[str, int]
) -> tuple[list[Finding], list[str]]:
    """(new findings not covered by the baseline, stale baseline keys).

    A key's findings are covered up to the baselined COUNT — a scope
    gaining more violations of a rule than the baseline records
    resurfaces the excess (newest-last within the scope).
    """
    budget = dict(baseline)
    fresh: list[Finding] = []
    for f in findings:
        if f.classification != "finding":
            continue
        if budget.get(f.key, 0) > 0:
            budget[f.key] -= 1
        else:
            fresh.append(f)
    seen = {f.key for f in findings if f.classification == "finding"}
    stale = sorted(k for k, n in baseline.items()
                   if k not in seen or budget.get(k, 0) > 0)
    return fresh, stale
