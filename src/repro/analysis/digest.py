"""Digest-stability audit: ``stable_digest`` against cache poisoning.

The disk executable cache (`serve/cache.py`) re-keys the in-memory
executable signature (`serving.signature`, object-identity based) into
a cross-process ``stable_digest``.  Three invariants make that safe,
and this pass checks each over a real grid of spec / axis / bucket
combinations:

* **identity** — rebuilding the same spec from scratch (fresh function
  objects, fresh arrays) digests identically: object identity must not
  leak in, or a new process never hits the store;
* **collision-freedom** — semantically distinct signatures (different
  algorithm, pads, dtype, query axis, batch pad, design point) all
  digest differently: a collision silently serves the WRONG executable;
* **cross-process determinism** — a child interpreter (fresh
  ``PYTHONHASHSEED``, fresh object addresses) computes the same digest
  per grid point: hash randomization and ``repr`` addresses must not
  reach the hash.

``grid_digests`` is the child-process entry point (imported by the
subprocess the audit spawns).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.analysis.findings import Finding


def build_grid() -> list[tuple[str, object]]:
    """(name, signature-tuple) per grid point — every point is built
    through the REAL key path (``serving.signature`` over real specs /
    configs), and names describe what makes each point distinct."""
    import jax.numpy as jnp

    from repro.algorithms import (
        label_propagation_spec,
        pagerank_spec,
        shortest_paths_spec,
    )
    from repro.core import serving
    from repro.core.executor import ExecutionConfig
    from repro.data import powerlaw_hypergraph

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    specs = {
        "pagerank": pagerank_spec(hg, iters=6),
        "sssp": shortest_paths_spec(hg, 0, 12),
        "labelprop": label_propagation_spec(hg, iters=6),
    }
    base = dict(
        shard_len_pad=0, n_parts=1,
        v_attr_sig=None, he_attr_sig=None,
        e_attr_sig=("float32", (64,)),
        query_sig=None, batch_pad=None, delivery_sig=None,
    )
    grid: list[tuple[str, object]] = []
    for sname, spec in specs.items():
        for backend in ("local", "sharded"):
            for nv_pad, ne_pad, nnz_pad in ((64, 64, 128), (128, 64, 256)):
                for batch_pad in (None, 8):
                    cfg = ExecutionConfig(backend=backend, jit=True)
                    name = (f"{sname}/{backend}/pads={nv_pad}-{ne_pad}-"
                            f"{nnz_pad}/b={batch_pad}")
                    grid.append((name, serving.signature(
                        spec, cfg, nv_pad=nv_pad, ne_pad=ne_pad,
                        nnz_pad=nnz_pad,
                        **{**base, "batch_pad": batch_pad},
                    )))
    # design-point and query-axis variants on one base point
    spec = specs["sssp"]
    cfg = ExecutionConfig(backend="local", jit=True)
    pads = dict(nv_pad=64, ne_pad=64, nnz_pad=128)
    grid.append(("sssp/stats", serving.signature(
        spec, ExecutionConfig(backend="local", jit=True,
                              collect_stats=True),
        **pads, **base,
    )))
    grid.append(("sssp/delivery=xla", serving.signature(
        spec, ExecutionConfig(backend="local", jit=True, delivery="xla"),
        **pads, **base,
    )))
    grid.append(("sssp/query=int32", serving.signature(
        spec, cfg, **pads, **{**base, "query_sig": ("int32", ())},
    )))
    grid.append(("sssp/eattr=f64", serving.signature(
        spec, cfg, **pads,
        **{**base, "e_attr_sig": ("float64", (64,))},
    )))
    grid.append(("sssp/initmsg0", serving.signature(
        spec._replace(initial_msg=jnp.float32(0.0)), cfg, **pads, **base,
    )))
    return grid


def grid_digests(digest_fn=None) -> dict[str, str]:
    """name -> stable_digest over the grid (the child-process entry)."""
    from repro.serve.cache import stable_digest

    fn = digest_fn or stable_digest
    return {name: fn(key) for name, key in build_grid()}


_CHILD = (
    "import json, sys; from repro.analysis.digest import grid_digests; "
    "json.dump(grid_digests(), sys.stdout)"
)


def audit(digest_fn=None, *, cross_process: bool = True) -> list[Finding]:
    """Run all three digest invariants; a non-default ``digest_fn`` is
    the mutation hook the negative tests use (it skips the subprocess,
    which could not import the injected function)."""
    findings: list[Finding] = []
    first = grid_digests(digest_fn)
    second = grid_digests(digest_fn)  # fresh specs, fresh closures

    for name, d in first.items():
        if second[name] != d:
            findings.append(Finding(
                rule="digest-identity", path="<digest-audit>", line=0,
                scope=name,
                message=("rebuilding the spec changed its digest "
                         f"({d[:12]} -> {second[name][:12]})"),
            ))

    by_digest: dict[str, str] = {}
    for name, d in first.items():
        if d in by_digest:
            findings.append(Finding(
                rule="digest-collision", path="<digest-audit>", line=0,
                scope=name,
                message=(f"collides with `{by_digest[d]}` "
                         f"(digest {d[:12]})"),
            ))
        else:
            by_digest[d] = name

    if cross_process and digest_fn is None:
        env = {**os.environ, "PYTHONHASHSEED": "random"}
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (_src_dir(), env.get("PYTHONPATH")) if p
        )
        out = subprocess.run(
            [sys.executable, "-c", _CHILD], env=env,
            capture_output=True, text=True, timeout=300,
        )
        if out.returncode != 0:
            findings.append(Finding(
                rule="digest-unstable", path="<digest-audit>", line=0,
                scope="<subprocess>",
                message=f"child audit failed: {out.stderr[-300:]}",
            ))
            return findings
        child = json.loads(out.stdout)
        for name, d in first.items():
            if child.get(name) != d:
                findings.append(Finding(
                    rule="digest-unstable", path="<digest-audit>", line=0,
                    scope=name,
                    message=("digest differs across processes "
                             f"({d[:12]} vs "
                             f"{str(child.get(name))[:12]})"),
                ))
    return findings


def _src_dir() -> str:
    import repro

    return os.path.dirname(os.path.dirname(os.path.abspath(
        repro.__file__
    )))
