"""Abstract shape agreement + static VMEM budgets for delivery.

Two checks, both hardware-free:

* **Shape agreement** — the delivery axis is a pure design choice only
  if both lowerings (`kernels/deliver/xla.py` reference,
  `kernels/deliver/fused.py` Pallas) agree on output shape AND dtype
  for every degree-class layout / monoid / message width.
  ``jax.eval_shape`` proves it abstractly: the Pallas path traces in
  interpret mode without a TPU, so this runs in fast CI.

* **VMEM footprint** — a static byte model of the fused kernel's
  per-grid-step working set, per degree class:

  - the select-reduce tile ``picked [block_n, block_e_c, D]`` (the
    ``_SELECT_MONOIDS`` path materializes it in VMEM),
  - the MXU one-hot ``[block_n, block_e_c] f32`` (the ``sum`` path),
  - the hit/live masks ``[block_n, block_e_c] i32``,
  - the full messages table ``[n_src+1, D]`` (one BlockSpec block),
  - the output tile ``[block_n, D]`` and three ``[block_e_c] i32``
    index blocks.

  ``check_vmem`` errors when any class exceeds the ~16 MiB/core budget
  — the ROADMAP "VMEM-check [block_n, block_e, D] select-reduce tiles
  at D > 8" caveat as a machine-checked constraint.  ``check_width_gate``
  proves the discharge: at the layout builder's worst-case tile
  geometry, every width the auto path can select
  (``FUSED_MAX_WIDTH_BYTES``) fits the budget.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding

VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # ~VMEM per TPU core
# the layout builder's per-class tile caps (layout.py: block_n default,
# class_block_e capped at 1024)
_WORST_BLOCK_N = 128
_WORST_BLOCK_E = 1024


def vmem_footprint(
    *, block_n: int, block_e: int, d: int, itemsize: int,
    n_src: int, monoid_name: str = "min",
) -> dict[str, int]:
    """Static per-grid-step VMEM bytes of ``_combine_kernel`` for one
    degree class.  ``monoid_name`` picks the combine path; unknown
    names get the worst case (select)."""
    msgs = (n_src + 1) * d * itemsize
    out = block_n * d * itemsize
    idx = 3 * block_e * 4
    masks = block_n * block_e * 4
    select = block_n * block_e * d * itemsize
    onehot = block_n * block_e * 4
    if monoid_name == "sum":
        path = onehot
    else:
        path = select
    total = msgs + out + idx + masks + path
    return {
        "msgs_table": msgs, "out_tile": out, "idx_blocks": idx,
        "masks": masks, "combine_path": path, "total": total,
    }


def check_vmem(
    layout, d: int, itemsize: int, *, monoid_name: str = "min",
    budget: int = VMEM_BUDGET_BYTES, where: str = "<vmem>",
) -> list[Finding]:
    """Every class's tile parameters against the budget, for one
    message width.  ``layout`` is a ``DeliveryLayout``."""
    findings = []
    for c, block_e in enumerate(layout.class_block_e):
        fp = vmem_footprint(
            block_n=layout.block_n, block_e=int(block_e), d=d,
            itemsize=itemsize, n_src=int(layout.n_src),
            monoid_name=monoid_name,
        )
        if fp["total"] > budget:
            findings.append(Finding(
                rule="vmem-budget", path=where, line=0,
                scope=f"class{c}[bn={layout.block_n},be={block_e},"
                      f"D={d}x{itemsize}B,{monoid_name}]",
                message=(
                    f"{fp['total'] / 2**20:.1f} MiB working set "
                    f"(select tile {fp['combine_path'] / 2**20:.1f} MiB) "
                    f"> {budget / 2**20:.0f} MiB VMEM budget"
                ),
            ))
    return findings


def check_width_gate(
    *, width_budget_bytes: float | None = None,
    budget: int = VMEM_BUDGET_BYTES,
) -> list[Finding]:
    """Prove the auto path can't select a VMEM-infeasible width: at the
    layout builder's WORST tile geometry, every row width within
    ``FUSED_MAX_WIDTH_BYTES`` must fit the budget (select path, the
    widest working set)."""
    if width_budget_bytes is None:
        from repro.core.executor import FUSED_MAX_WIDTH_BYTES

        width_budget_bytes = FUSED_MAX_WIDTH_BYTES
    findings = []
    for itemsize in (1, 4, 8):
        max_d = max(1, int(width_budget_bytes // itemsize))
        fp = vmem_footprint(
            block_n=_WORST_BLOCK_N, block_e=_WORST_BLOCK_E, d=max_d,
            itemsize=itemsize, n_src=4096, monoid_name="min",
        )
        if fp["total"] > budget:
            findings.append(Finding(
                rule="vmem-budget", path="<width-gate>", line=0,
                scope=f"worst[bn={_WORST_BLOCK_N},be={_WORST_BLOCK_E},"
                      f"D={max_d}x{itemsize}B]",
                message=(
                    f"auto-selectable width {max_d}x{itemsize}B needs "
                    f"{fp['total'] / 2**20:.1f} MiB "
                    f"> {budget / 2**20:.0f} MiB"
                ),
            ))
    return findings


# --------------------------------------------------------------------------
# abstract shape agreement between the two lowerings
# --------------------------------------------------------------------------

def _build_layouts():
    """Two small real layouts covering the skew regimes (uniform and a
    hub-heavy draw that forces multiple degree classes)."""
    from repro.kernels.deliver.layout import build_delivery_layout

    rng = np.random.default_rng(0)
    out = []
    # uniform: one narrow class
    nnz, n_src, n_dst = 600, 128, 96
    src = rng.integers(0, n_src, nnz)
    dst = rng.integers(0, n_dst, nnz)
    out.append(("uniform", build_delivery_layout(
        src, dst, None, n_src, n_dst,
    )))
    # skewed: a few hubs absorb most edges -> multiple classes
    dst_skew = np.where(
        rng.random(nnz) < 0.6, rng.integers(0, 4, nnz), dst
    )
    out.append(("skewed", build_delivery_layout(
        src, dst_skew, None, n_src, n_dst,
    )))
    return out


def check_shapes(
    *, fused_leaf=None, widths=(1, 8), monoids=("sum", "min", "max", "or"),
) -> list[Finding]:
    """``jax.eval_shape`` agreement between ``deliver_ell_leaf`` and the
    fused-Pallas leaf for every layout x monoid x width x dtype.
    ``fused_leaf`` is the mutation hook for the negative tests."""
    import jax

    from repro.kernels.deliver import _pallas_leaf
    from repro.kernels.deliver.xla import deliver_ell_leaf
    from repro.sparse.segment import MONOIDS

    fused = fused_leaf or (
        lambda m, layout, monoid, active: _pallas_leaf(
            m, layout, monoid, active, interpret=True
        )
    )
    findings = []
    for lname, layout in _build_layouts():
        n_src = int(layout.n_src)
        for mname in monoids:
            monoid = MONOIDS[mname]
            dtypes = ("bool",) if mname == "or" else ("float32", "int32")
            for d in widths:
                for dt in dtypes:
                    msgs = jax.ShapeDtypeStruct((n_src, d), np.dtype(dt))
                    ref = jax.eval_shape(
                        lambda m: deliver_ell_leaf(m, layout, monoid),
                        msgs,
                    )
                    got = jax.eval_shape(
                        lambda m: fused(m, layout, monoid, None), msgs,
                    )
                    if (ref.shape, ref.dtype) != (got.shape, got.dtype):
                        findings.append(Finding(
                            rule="shape-mismatch", path="<shape-audit>",
                            line=0,
                            scope=f"{lname}/{mname}/D={d}/{dt}",
                            message=(
                                f"xla {ref.shape}:{ref.dtype} vs fused "
                                f"{got.shape}:{got.dtype}"
                            ),
                        ))
    return findings


def shape_vmem_audit() -> list[Finding]:
    """The CLI pass: shape agreement over the full grid, VMEM budgets
    for every built layout at each auto-selectable width, and the
    width-gate discharge proof."""
    findings = check_shapes()
    from repro.core.executor import FUSED_MAX_WIDTH_BYTES

    for lname, layout in _build_layouts():
        for itemsize in (4,):
            max_d = int(FUSED_MAX_WIDTH_BYTES // itemsize)
            for d in (1, 8, max_d):
                findings.extend(check_vmem(
                    layout, d, itemsize,
                    where=f"<vmem:{lname}>",
                ))
    findings.extend(check_width_gate())
    return findings
