"""Retrace sentinel: the compile-once contract as a reusable guard.

``Engine`` counts real retraces (``trace_hook`` fires from inside every
jitted body, so cache hits don't count).  The sentinel turns that
counter into an assertion usable three ways:

* ``with assert_no_retrace(engine):`` around any warm-path block —
  raises ``RetraceError`` listing the trace delta if anything
  recompiled;
* the ``no_retrace`` pytest fixture (``tests/conftest.py``) — the
  replacement for the hand-rolled before/after counter assertions in
  ``test_compile.py`` / ``test_serve.py``;
* ``serve.warm(..., require_no_retrace=True)`` — a runtime boot guard:
  a replica that was supposed to come up entirely from the disk store
  fails fast instead of silently eating compile latency.

``retrace_smoke`` is the live CLI pass: it compiles one small spec and
drives the three warm paths that must not retrace (same-bucket second
hypergraph, query changes, batch-size changes within a bucket pad).
"""
from __future__ import annotations

import contextlib

from repro.analysis.findings import Finding


class RetraceError(AssertionError):
    """A region that promised zero retraces compiled something."""

    def __init__(self, traces: int, allow: int, label: str):
        self.traces = traces
        self.allow = allow
        self.label = label
        super().__init__(
            f"{label}: {traces} retrace(s) inside a no-retrace region "
            f"(allowed {allow}) — the compile-once contract is broken"
        )


@contextlib.contextmanager
def assert_no_retrace(engine, *, allow: int = 0, label: str = "no_retrace"):
    """Assert the engine's trace counter moves by at most ``allow``
    inside the block.  Yields a callable returning the delta so far."""
    before = engine.cache_stats()["traces"]

    def delta() -> int:
        return engine.cache_stats()["traces"] - before

    yield delta
    traces = delta()
    if traces > allow:
        raise RetraceError(traces, allow, label)


def _same_bucket_pair():
    from repro.core import bucket_dim
    from repro.data import powerlaw_hypergraph

    hg = powerlaw_hypergraph(47, 33, mean_cardinality=4, seed=0)
    want = (bucket_dim(47), bucket_dim(33), bucket_dim(hg.nnz))
    for seed in range(1, 60):
        hg2 = powerlaw_hypergraph(52, 36, mean_cardinality=4, seed=seed)
        got = (bucket_dim(52), bucket_dim(36), bucket_dim(hg2.nnz))
        if got == want:
            return hg, hg2
    raise AssertionError("no same-bucket draw found")


def retrace_smoke() -> list[Finding]:
    """Live check of the warm paths that must never retrace: the
    same-bucket second hypergraph, query changes, and batch-size
    changes inside one bucket pad."""
    import numpy as np

    from repro.algorithms import shortest_paths_spec
    from repro.core import Engine

    findings: list[Finding] = []
    hg, hg2 = _same_bucket_pair()
    eng = Engine()
    compiled = eng.compile(shortest_paths_spec(hg, 0, 8))
    compiled.run()                                   # first trace: expected
    compiled.run_batch(np.arange(8, dtype=np.int32))  # batch trace: expected

    def check(label: str, fn) -> None:
        try:
            with assert_no_retrace(eng, label=label):
                fn()
        except RetraceError as err:
            findings.append(Finding(
                rule="retrace", path="<retrace-smoke>", line=0,
                scope=label, message=str(err),
            ))

    check("same-bucket-second-hypergraph", lambda: compiled.run(hg2))
    check("query-change", lambda: [
        compiled.run(query=s) for s in (0, 3, 11, 46)
    ])
    check("batch-size-within-pad", lambda: compiled.run_batch(
        np.arange(5, dtype=np.int32)
    ))
    return findings
