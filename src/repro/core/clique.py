"""Clique-expansion representation (``toGraph``).

Expands every hyperedge into a clique over its members — MESH's
constant-folding optimization, valid only for algorithms that never touch
hyperedge state and send symmetric message types (paper §IV-A1).  Built
host-side with NumPy (like GraphX's representation build), since expansion
is a one-time preprocessing step whose *cost itself* is one of the paper's
measured quantities (Fig. 7: partitioning time includes ``toGraph``).

``clique_expansion_size`` computes the edge count without materializing —
how we reproduce Table I's "10.3 billion (approximate)" entries for
hypergraphs whose expansion cannot be materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.hypergraph import HyperGraph


@dataclasses.dataclass
class Graph:
    """A plain dyadic graph (the underlying-engine view)."""

    src: jnp.ndarray
    dst: jnp.ndarray
    n_vertices: int
    e_attr: jnp.ndarray | None = None
    v_attr: object = None


def clique_expansion_size(hg: HyperGraph) -> int:
    """Number of (undirected, pair-deduplicated) clique edges =
    |{(u,v): u<v, exists e with u,v in e}| — without materializing cliques
    beyond hash dedup of pairs."""
    card = np.asarray(hg.cardinalities())
    # Exact for small, estimate sum k*(k-1)/2 upper bound if huge.
    pair_budget = int((card.astype(np.int64) * (card - 1) // 2).sum())
    if pair_budget > 200_000_000:
        return pair_budget  # approximate (upper bound), like Table I.
    return len(_unique_pairs(hg))


def _unique_pairs(hg: HyperGraph) -> np.ndarray:
    src = np.asarray(hg.src)
    dst = np.asarray(hg.dst)
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    bounds = np.searchsorted(dst, np.arange(hg.n_hyperedges + 1))
    pairs = []
    for e in range(hg.n_hyperedges):
        members = src[bounds[e]:bounds[e + 1]]
        k = len(members)
        if k < 2:
            continue
        iu, ju = np.triu_indices(k, k=1)
        a, b = members[iu], members[ju]
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        pairs.append(np.stack([lo, hi], axis=1))
    if not pairs:
        return np.zeros((0, 2), np.int64)
    allp = np.concatenate(pairs).astype(np.int64)
    keys = allp[:, 0] * (2**32) + allp[:, 1]
    _, idx = np.unique(keys, return_index=True)
    return allp[idx]


def to_graph(
    hg: HyperGraph,
    edge_attr_fn: Callable[[np.ndarray], np.ndarray] | None = None,
) -> Graph:
    """Materialize the clique expansion.

    ``edge_attr_fn`` maps the array of shared-hyperedge *counts* per pair to
    the edge attribute (the paper's "user-defined functions applied to the
    set of all hyperedges common to v1 and v2" — we expose the count, the
    common case; richer reductions can precompute per-hyperedge scalars into
    e_attr first).
    """
    src = np.asarray(hg.src)
    dst = np.asarray(hg.dst)
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    bounds = np.searchsorted(dst, np.arange(hg.n_hyperedges + 1))
    pairs = []
    for e in range(hg.n_hyperedges):
        members = src[bounds[e]:bounds[e + 1]]
        k = len(members)
        if k < 2:
            continue
        iu, ju = np.triu_indices(k, k=1)
        a, b = members[iu], members[ju]
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        pairs.append(np.stack([lo, hi], axis=1))
    if pairs:
        allp = np.concatenate(pairs).astype(np.int64)
        keys = allp[:, 0] * (2**32) + allp[:, 1]
        uniq_keys, counts = np.unique(keys, return_counts=True)
        u = (uniq_keys // (2**32)).astype(np.int32)
        v = (uniq_keys % (2**32)).astype(np.int32)
    else:
        u = v = np.zeros(0, np.int32)
        counts = np.zeros(0, np.int64)
    attr = None
    if edge_attr_fn is not None:
        attr = jnp.asarray(edge_attr_fn(counts))
    else:
        attr = jnp.asarray(counts.astype(np.float32))
    # Symmetrize (message flow in both directions).
    return Graph(
        src=jnp.asarray(np.concatenate([u, v])),
        dst=jnp.asarray(np.concatenate([v, u])),
        n_vertices=hg.n_vertices,
        e_attr=jnp.concatenate([attr, attr]) if attr is not None else None,
        v_attr=hg.v_attr,
    )
