"""Compile-once serve-many: shape-bucketed executables for ``Engine.compile``.

``Engine.run`` re-resolves the design point and re-traces on every call —
fine for one-shot analytics, fatal for serving millions of per-source
queries (SSSP sources, personalized-restart seeds) against one partitioned
hypergraph.  This module is the serving half of the facade:

* ``bucket_dim`` quantizes ``n_vertices`` / ``n_hyperedges`` / ``nnz``
  (and batch sizes) to power-of-two buckets, so a stream of
  slightly-varying hypergraphs maps onto a bounded set of padded shapes;
* ``signature`` canonicalizes (programs, design point, bucket dims,
  attribute dtypes, query structure, batch bucket) into the hashable key
  of the Engine's LRU executable cache;
* ``CompiledAlgorithm`` is the serve-many handle ``Engine.compile``
  returns: ``run(hg, query=...)`` executes with zero retracing for any
  same-bucket hypergraph, and ``run_batch(queries)`` vmaps the whole
  executable over the spec's query axis so one compile serves B requests.

Real (unpadded) sizes flow through the executables as *traced* int32
scalars — activity stats and the halting decision mask padding slots
dynamically (``repro.core.engine.compute(n_real=...)``,
``repro.core.distributed.build_distributed_runner``), so results are
bitwise identical to an unpadded run while shapes stay bucket-stable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import constant_initial_msg
from repro.core.engine import compute, compute_batch
from repro.core.hypergraph import HyperGraph
from repro.faults.errors import is_transient
from repro.kernels.deliver import layout_pair

Pytree = Any

# Smallest entity/incidence bucket: graphs below this all share one shape.
BUCKET_FLOOR = 64
# Batch-size buckets start lower — single-digit batches are common.
BATCH_FLOOR = 8


def bucket_dim(n: int, floor: int = BUCKET_FLOOR) -> int:
    """Smallest power-of-two ≥ ``n`` (and ≥ ``floor``).

    Bounded buckets are the compile-amortization contract: padded work
    grows at most 2x, while the number of distinct executables a
    workload can touch is O(log max_size).
    """
    b = int(floor)
    n = int(n)
    while b < n:
        b *= 2
    return b


def _round_up(n: int, mult: int) -> int:
    return -(-int(n) // int(mult)) * int(mult)


def _attr_sig(tree: Pytree):
    """Hashable (treedef, per-leaf dtype + trailing shape): the leading
    entity dim is the bucket's business, dtype/feature-shape changes must
    miss the cache."""
    leaves, treedef = jax.tree.flatten(tree)
    return (
        treedef,
        tuple(
            (jnp.asarray(leaf).dtype.name, tuple(jnp.shape(leaf)[1:]))
            for leaf in leaves
        ),
    )


def _query_sig(query: Pytree):
    """Hashable full dtype/shape structure of one (unbatched) query."""
    if query is None:
        return None
    leaves, treedef = jax.tree.flatten(query)
    return (
        treedef,
        tuple(
            (jnp.asarray(leaf).dtype.name, tuple(jnp.shape(leaf)))
            for leaf in leaves
        ),
    )


def _canon_query(query: Pytree) -> Pytree:
    """Strong-typed device arrays: python ints must produce the same
    signature (and no weak-type retrace) as explicit numpy scalars."""
    # analysis: ignore[host-sync] — queries arrive as host values;
    # strong-typing them IS the ingest contract (scalar-sized)
    return jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), query)


def _initial_msg_sig(initial_msg: Pytree):
    """Hashable VALUE signature of a spec's initial message.

    Unlike the programs (keyed by identity), ``initial_msg`` can be
    swapped via ``spec._replace`` without changing any function object —
    and it is baked into the executable as a traced constant, so its
    concrete bytes must participate in the cache key."""
    leaves, treedef = jax.tree.flatten(initial_msg)
    return (
        treedef,
        tuple(
            # analysis: ignore[host-sync] — memoized once per
            # CompiledAlgorithm (see _execute), not per request
            (arr.dtype.name, arr.shape, arr.tobytes())
            # analysis: ignore[host-sync] — same memo
            for arr in (np.asarray(leaf) for leaf in leaves)
        ),
    )


def signature(
    spec,
    cfg,
    *,
    nv_pad: int,
    ne_pad: int,
    nnz_pad: int,
    shard_len_pad: int,
    n_parts: int,
    v_attr_sig,
    he_attr_sig,
    e_attr_sig,
    query_sig,
    batch_pad: int | None,
    delivery_sig=None,
    initial_msg_sig=None,
):
    """The executable cache key.

    Program objects participate by identity (their closures bake in
    algorithm constants), so distinct specs never collide; everything
    else is the padded-shape/dtype/design-point signature the tentpole
    names: same bucket + same design point = same executable.

    ``delivery_sig``: the fused-delivery layout shapes (ELL width,
    remainder pad, tile geometry — data-dependent within a shape
    bucket); ``None`` on the reference path.  Same-bucket hypergraphs
    usually share them, but a degree-regime shift legitimately
    recompiles.

    ``initial_msg_sig``: the precomputed ``_initial_msg_sig`` value.
    Callers on the per-request path (``CompiledAlgorithm._execute``)
    pass their memo so the key never re-serializes the initial message
    per request (host-sync lint finding, fixed by memoization); ``None``
    recomputes for one-shot callers.
    """
    return (
        spec.v_program,
        spec.he_program,
        spec.bind_query if query_sig is not None else None,
        (initial_msg_sig if initial_msg_sig is not None
         else _initial_msg_sig(spec.initial_msg)),
        cfg.backend,
        cfg.axis,
        cfg.max_iters,
        cfg.collect_stats,
        cfg.delivery,
        n_parts,
        nv_pad,
        ne_pad,
        nnz_pad,
        shard_len_pad,
        v_attr_sig,
        he_attr_sig,
        e_attr_sig,
        query_sig,
        batch_pad,
        delivery_sig,
    )


# --------------------------------------------------------------------------
# executable builders
# --------------------------------------------------------------------------

def _build_local_executable(spec, cfg, has_query, batch_pad, trace_hook):
    """One jitted callable ``(hgp, delivery, nv_real, ne_real, query) ->
    (v_attr, he_attr, stats, executed)`` over a bucket-padded hypergraph.

    Unbatched requests run ``compute`` (per-run halting ``cond``);
    batches run ``compute_batch`` — the scan sits OUTSIDE the query
    vmap, so halting stays a real branch on ``all(halted)`` and a
    skewed-convergence batch stops at its slowest query instead of
    paying ``max_iters`` (the batch-aware halting design point).
    ``executed`` reports the superstep pairs the batch actually ran
    (``None`` unbatched).
    """
    # Close over only what the trace needs — NOT the whole spec, whose
    # hg0 (full structure + attrs) would otherwise stay pinned in the
    # Engine's executable LRU for the cache entry's lifetime.
    v_program, he_program = spec.v_program, spec.he_program
    initial_msg, bind_query = spec.initial_msg, spec.bind_query
    max_iters, collect_stats = cfg.max_iters, cfg.collect_stats

    def raw(hgp: HyperGraph, delivery, nv_real, ne_real, query):
        trace_hook()
        if has_query:
            hgp = bind_query(hgp, query)
        out = compute(
            hgp,
            max_iters=max_iters,
            initial_msg=initial_msg,
            v_program=v_program,
            he_program=he_program,
            return_stats=collect_stats,
            n_real=(nv_real, ne_real),
            delivery=delivery,
        )
        stats = None
        if collect_stats:
            out, stats = out
        return out.v_attr, out.he_attr, stats, None

    def raw_batch(hgp: HyperGraph, delivery, nv_real, ne_real, queries):
        trace_hook()
        # Bind every query onto the padded structure, keep only the
        # per-query attribute states (the structure itself is shared).
        # NOTE: bind_query may only touch v_attr / he_attr — e_attr and
        # e_mask stay unbatched by the batch-aware halting contract.
        bound = jax.vmap(lambda q: bind_query(hgp, q))(queries)
        v_attr_b, he_attr_b = bound.v_attr, bound.he_attr
        v_b, he_b, stats, executed = compute_batch(
            hgp,
            v_attr_b,
            he_attr_b,
            batch_pad,
            max_iters,
            initial_msg,
            v_program,
            he_program,
            n_real=(nv_real, ne_real),
            delivery=delivery,
        )
        return v_b, he_b, (stats if collect_stats else None), executed

    return jax.jit(raw if batch_pad is None else raw_batch)


def _build_distributed_executable(
    spec, cfg, mesh, n_parts, nv_pad, ne_pad, has_query, batch_pad,
    trace_hook,
):
    """Same contract as the local builder, plus the plan's padded edge
    shards: ``(hgp, shard_src, shard_dst, shard_mask, delivery, nv_real,
    ne_real, query) -> (v_attr, he_attr, stats, executed)``.  Query
    binding happens on the full padded state *before* ``shard_map``
    shards it, so one runner serves both backends' layouts.  Batches run
    the BATCH-AWARE runner (``build_distributed_runner(batch=...)``):
    the scan sits outside the query vmap — inside ``shard_map`` — so
    halting stays a real ``cond`` on ``all(halted)`` and
    ``supersteps_executed`` agrees with the local backend."""
    from repro.core.distributed import DistContext, build_distributed_runner

    ctx = DistContext(
        axis=cfg.axis, n_parts=n_parts, nv_pad=nv_pad, ne_pad=ne_pad
    )
    mapped = build_distributed_runner(
        mesh, ctx, spec.v_program, spec.he_program, cfg.max_iters,
        backend=cfg.backend, batch=batch_pad,
    )
    # As in the local builder: keep the spec's hg0 out of the closure.
    initial_msg, bind_query = spec.initial_msg, spec.bind_query
    collect_stats = cfg.collect_stats

    def raw(hgp: HyperGraph, s_src, s_dst, s_mask, delivery, nv_real,
            ne_real, query):
        trace_hook()
        if has_query:
            hgp = bind_query(hgp, query)
        msg0 = constant_initial_msg(initial_msg, nv_pad)
        v_out, he_out, v_trace, he_trace = mapped(
            hgp.v_attr, hgp.he_attr, msg0,
            hgp.degrees(), hgp.cardinalities(),
            s_src, s_dst, s_mask, nv_real, ne_real, delivery,
        )
        stats = (v_trace, he_trace) if collect_stats else None
        return v_out, he_out, stats, None

    def raw_batch(hgp: HyperGraph, s_src, s_dst, s_mask, delivery,
                  nv_real, ne_real, queries):
        trace_hook()
        # Bind every query onto the padded structure, keep only the
        # per-query attribute states (the structure itself is shared) —
        # same contract as the local batch builder: bind_query may only
        # touch v_attr / he_attr.
        bound = jax.vmap(lambda q: bind_query(hgp, q))(queries)
        msg0 = constant_initial_msg(initial_msg, nv_pad)
        msg0_b = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (batch_pad,) + x.shape), msg0
        )
        v_b, he_b, v_tr, he_tr, executed = mapped(
            bound.v_attr, bound.he_attr, msg0_b,
            hgp.degrees(), hgp.cardinalities(),
            s_src, s_dst, s_mask, nv_real, ne_real, delivery,
        )
        # [max_iters, batch] -> [batch, max_iters]: the layout callers
        # (and the local backend) already consume.
        stats = (v_tr.T, he_tr.T) if collect_stats else None
        return v_b, he_b, stats, executed

    return jax.jit(raw if batch_pad is None else raw_batch)


def _pad_shards(plan, shard_len_pad: int):
    """Zero-pad a plan's ``[n_parts, shard_len]`` edge shards out to the
    bucketed shard length (padding lanes carry mask 0)."""
    pad = shard_len_pad - plan.shard_len
    if pad == 0:
        return (
            jnp.asarray(plan.shard_src),
            jnp.asarray(plan.shard_dst),
            jnp.asarray(plan.shard_mask),
        )

    def padded(x):
        return jnp.asarray(
            np.pad(x, ((0, 0), (0, pad)))
        )

    return (
        padded(plan.shard_src), padded(plan.shard_dst),
        padded(plan.shard_mask),
    )


def _warm_executable(exe, args: tuple) -> str:
    """Materialize one executable without serving a request.

    Disk-backed executables (``repro.serve.cache``) resolve their
    deserialize-vs-AOT-compile choice here and report which path won;
    plain jitted executables warm by executing once (the compile is the
    point — the discarded result costs one padded batch)."""
    warm_fn = getattr(exe, "warm", None)
    if warm_fn is not None:
        return warm_fn(args)
    jax.block_until_ready(exe(*args))
    return "jit"


# --------------------------------------------------------------------------
# the serve-many handle
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledAlgorithm:
    """What ``Engine.compile`` returns: a design point resolved once,
    served many times.

    >>> compiled = engine.compile(shortest_paths_spec(hg, 0))
    >>> compiled.run()                         # hg0, baked-in source
    >>> compiled.run(query=7)                  # same executable, source 7
    >>> compiled.run_batch(np.arange(64))      # one vmapped executable
    >>> compiled.run(other_hg)                 # zero retrace if same bucket

    Executables live in the owning Engine's LRU cache keyed by
    ``serving.signature`` — a second same-bucket hypergraph (or a second
    ``compile`` of the same spec) is a cache hit with zero retracing;
    dtype, bucket, or design-point changes miss and compile fresh.
    ``Engine.cache_stats()`` exposes hits/misses/entries/traces so
    benchmarks can assert amortization.
    """

    engine: Any
    spec: Any
    config: Any                       # fully-resolved ExecutionConfig
    decision: dict
    _plan0: Any = None                # compile-time plan (hg0's structure)
    # Warm-path memo: (source_hg identity, rebind) -> padded state, so a
    # serve loop over one hypergraph pays init + padding once, not per
    # request.  Keyed by object identity like the Engine's plan cache
    # (hypergraphs are treated as immutable); bounded to the last few.
    _pad_cache: list = dataclasses.field(default_factory=list)
    # Memoized _initial_msg_sig: serializing the initial message is
    # host-side work that must not run per request (host-sync lint).
    _init_msg_sig: Any = None
    # Memoized graceful-degradation twin: same spec served with
    # delivery="xla" after a pallas_fused layout/execute failure.
    _xla_twin: Any = None

    # -- public API --------------------------------------------------------

    def run(self, hg: HyperGraph | None = None, query: Any = None):
        """Execute on ``hg`` (default: the spec's own hypergraph).

        ``query`` rebinds the spec's per-request state (requires
        ``spec.bind_query``); ``hg`` may be any hypergraph the spec's
        ``init`` can re-initialize — same shape bucket = zero retraces.
        When no query is given but the spec declares one (``query0``),
        the default query is bound through the same traced path, so
        querying and non-querying calls share one executable.
        """
        spec = self.spec
        if (query is None and spec.bind_query is not None
                and spec.init is not None and spec.query0 is not None):
            query = spec.query0
        if self.config.checkpoint_every is not None:
            return self._run_checkpointed(hg, query)
        try:
            prep = self._prepared(hg, rebind=query is not None)
            q = _canon_query(query) if query is not None else None
            return self._execute(prep, q, batch=None)
        except ValueError:
            raise
        except Exception as err:
            twin = (
                self._degraded_sibling(err)
                if not is_transient(err) else None
            )
            if twin is None:
                raise
            return twin.run(hg, query=query)

    def run_batch(self, queries: Any, hg: HyperGraph | None = None):
        """Serve a batch: vmap the executable over the spec's query axis.

        ``queries`` is a query pytree with a leading batch dim B (for
        scalar queries: an array of B values).  Returns one ``Result``
        whose value/stats carry a leading B axis, bitwise equal to B
        sequential ``run(query=...)`` calls.  The batch dim is bucketed
        (queries repeat-padded, results sliced back), so varying B hits
        a bounded set of executables.
        """
        if self.spec.bind_query is None:
            raise ValueError(
                f"spec {self.spec.name!r} has no bind_query: declare the "
                "per-request axis to serve batched queries"
            )
        try:
            prep = self._prepared(hg, rebind=True)
            queries_c = _canon_query(queries)
            sizes = {
                int(jnp.shape(leaf)[0])
                for leaf in jax.tree.leaves(queries_c)
            }
            if len(sizes) != 1:
                raise ValueError(
                    f"query leaves disagree on batch size: {sorted(sizes)}"
                )
            b = sizes.pop()
            b_pad = bucket_dim(b, floor=BATCH_FLOOR)
            # Repeat-pad with the last query: always a *valid* request,
            # and the padded rows are sliced off the results.
            queries_p = jax.tree.map(
                lambda leaf: jnp.concatenate(
                    [leaf] + [leaf[-1:]] * (b_pad - b)
                ) if b_pad > b else leaf,
                queries_c,
            )
            return self._execute(prep, queries_p, batch=(b, b_pad))
        except ValueError:
            raise
        except Exception as err:
            twin = (
                self._degraded_sibling(err)
                if not is_transient(err) else None
            )
            if twin is None:
                raise
            return twin.run_batch(queries, hg=hg)

    def warmup(
        self,
        *,
        query: Any = None,
        batch_sizes: tuple[int, ...] = (),
        hg: HyperGraph | None = None,
    ) -> dict:
        """Materialize executables WITHOUT serving traffic — the
        replica-boot half of ``repro.serve.cache.warm``.

        Resolves the unbatched path plus one batched path per bucket in
        ``batch_sizes`` (sizes quantize through the normal batch
        buckets).  With a disk cache attached to the Engine, each path
        either deserializes from the store (zero retraces) or
        AOT-compiles and populates it; without one, this is a plain
        eager compile.  ``query``: example request for specs whose
        ``query0`` is unset; required to warm query-bearing paths.

        Returns ``{path: {"source": "disk"|"aot"|"jit"}}``.
        """
        spec = self.spec
        if query is None:
            query = spec.query0
        has_query = (
            spec.bind_query is not None
            and spec.init is not None
            and query is not None
        )
        prep = self._prepared(hg, rebind=has_query)
        q = _canon_query(query) if has_query else None
        report = {"single": self._execute(prep, q, batch=None,
                                          warm_only=True)}
        for b in batch_sizes:
            if spec.bind_query is None:
                raise ValueError(
                    f"spec {spec.name!r} has no bind_query: no batched "
                    "path to warm"
                )
            if q is None:
                raise ValueError(
                    "warming a batched path needs an example query "
                    "(spec.query0 is unset — pass query=...)"
                )
            b_pad = bucket_dim(int(b), floor=BATCH_FLOOR)
            queries = jax.tree.map(
                lambda leaf: jnp.broadcast_to(
                    leaf, (b_pad,) + jnp.shape(leaf)
                ),
                q,
            )
            report[f"batch{b_pad}"] = self._execute(
                prep, queries, batch=(b_pad, b_pad), warm_only=True
            )
        return report

    # -- fault tolerance ---------------------------------------------------

    def _degraded_sibling(self, err: Exception):
        """Graceful-degradation chain, delivery link: a ``pallas_fused``
        layout-build or execute failure must not fail the request when
        the ``xla`` lowering can still serve it.

        Returns the memoized ``delivery="xla"`` twin of this handle (one
        compile, shared across subsequent degradations), or ``None``
        when degradation does not apply — already on xla, nothing left
        to fall back to.  Non-sticky by design: the next request tries
        the fused path again, so one fused failure does not permanently
        forfeit the faster lowering.

        Callers gate this on ``not is_transient(err)``: transient
        failures propagate so the serve tier retries them on the SAME
        delivery — the two lowerings agree on shapes, not on float
        rounding, so switching deliveries is reserved for faults that
        would otherwise fail the request outright.
        """
        if self.config.delivery != "pallas_fused":
            return None
        engine = self.engine
        if self._xla_twin is None:
            self._xla_twin = CompiledAlgorithm(
                engine=engine,
                spec=self.spec,
                config=dataclasses.replace(self.config, delivery="xla"),
                decision={**self.decision, "degraded_from": "pallas_fused"},
                _plan0=self._plan0,
            )
        metrics = getattr(engine, "metrics", None)
        if metrics is not None:
            metrics.counter("faults.delivery_degraded").inc()
        tracer = getattr(engine, "tracer", None)
        if tracer is not None:
            from repro.obs.trace import maybe_span

            with maybe_span(
                tracer, "faults.degrade_delivery", cat="faults",
                algorithm=self.spec.name, error=type(err).__name__,
            ):
                pass
        return self._xla_twin

    def _run_checkpointed(self, hg, query):
        """Route through the chunked checkpoint/resume drivers
        (``repro.faults.checkpoint``) instead of the cached executable.

        The chunked drivers run the SAME per-iteration scan body as the
        compiled path (shared ``_halting_body`` / distributed ``_body``)
        on the same padded buffers, snapshotting the carry every
        ``checkpoint_every`` superstep pairs — results are bitwise-equal
        to the uninterrupted executable and a killed run resumes from
        ``checkpoint_dir``'s latest snapshot."""
        from repro.core.executor import Result
        from repro.faults.checkpoint import (
            checkpointed_compute,
            checkpointed_distributed_compute,
        )

        cfg = self.config
        spec = self.spec
        engine = self.engine
        prep = self._prepared(hg, rebind=query is not None)
        q = _canon_query(query) if query is not None else None
        nv, ne = prep["nv"], prep["ne"]
        plan = prep["plan"]
        injector = getattr(engine, "fault_injector", None)
        stats = None
        if cfg.backend == "local":
            hgq = prep["hgp"]
            if q is not None:
                hgq = spec.bind_query(hgq, q)
            out = checkpointed_compute(
                hgq, cfg.max_iters, spec.initial_msg,
                spec.v_program, spec.he_program,
                every=cfg.checkpoint_every, ckpt_dir=cfg.checkpoint_dir,
                return_stats=cfg.collect_stats,
                n_real=(jnp.asarray(nv, jnp.int32),
                        jnp.asarray(ne, jnp.int32)),
                delivery=prep["delivery"], jit=cfg.jit,
                tracer=engine.tracer, metrics=engine.metrics,
                fault_injector=injector,
            )
            if cfg.collect_stats:
                out, stats = out
            # The chunked driver ran on the padded buffers; slice back.
            out = out.with_attrs(
                v_attr=jax.tree.map(lambda x: x[:nv], out.v_attr),
                he_attr=jax.tree.map(lambda x: x[:ne], out.he_attr),
            )
        else:
            base = prep["base"]
            hgq = spec.bind_query(base, q) if q is not None else base
            out = checkpointed_distributed_compute(
                hgq, plan, engine.mesh, cfg.max_iters, spec.initial_msg,
                spec.v_program, spec.he_program,
                every=cfg.checkpoint_every, ckpt_dir=cfg.checkpoint_dir,
                axis=cfg.axis, backend=cfg.backend,
                delivery=cfg.delivery,
                return_stats=cfg.collect_stats,
                tracer=engine.tracer, metrics=engine.metrics,
                fault_injector=injector,
            )
            if cfg.collect_stats:
                out, stats = out
        return Result(
            value=spec.extract(out),
            config=cfg,
            representation=cfg.representation,
            backend=cfg.backend,
            partition=plan.name if plan is not None else None,
            partition_stats=plan.stats if plan is not None else None,
            superstep_stats=stats,
            supersteps_executed=None,
            decision={
                **self.decision,
                "checkpointed": {
                    "every": cfg.checkpoint_every,
                    "dir": cfg.checkpoint_dir,
                },
            },
        )

    # -- internals ---------------------------------------------------------

    def _base_state(self, hg, *, rebind: bool):
        """(initialized state, structure-identity object for plan cache).

        ``rebind=True`` re-initializes even the spec's own hypergraph so
        ``bind_query`` starts from unbound state (hg0 already carries
        ``query0``)."""
        spec = self.spec
        if hg is None and not rebind:
            return spec.hg0, spec.hg0
        if spec.init is None:
            raise ValueError(
                f"spec {self.spec.name!r} has no init: cannot "
                + ("rebind queries" if hg is None else
                   "re-initialize a new hypergraph")
            )
        source = spec.hg0 if hg is None else hg
        return spec.init(source), source

    def _prepared(self, hg, *, rebind: bool):
        """Initialized + bucket-padded inputs for one source hypergraph,
        memoized by (hypergraph identity, rebind): the warm serve loop
        pays init/padding/plan lookup once, not per request."""
        source_probe = self.spec.hg0 if hg is None else hg
        for s, r, prep in self._pad_cache:
            if s is source_probe and r == rebind:
                return prep

        base, source_hg = self._base_state(hg, rebind=rebind)
        cfg = self.config
        nv, ne, nnz = base.n_vertices, base.n_hyperedges, base.nnz
        nv_pad, ne_pad = bucket_dim(nv), bucket_dim(ne)
        nnz_pad = bucket_dim(nnz)
        plan = None
        shards = None
        shard_len_pad = 0
        n_parts = 0
        if cfg.backend != "local":
            plan = self._plan_for(source_hg)
            n_parts = plan.n_parts
            nv_pad = _round_up(nv_pad, n_parts)
            ne_pad = _round_up(ne_pad, n_parts)
            shard_len_pad = bucket_dim(plan.shard_len)
            shards = _pad_shards(plan, shard_len_pad)
        hgp = base.padded(nv_pad, ne_pad, nnz_pad)
        # Fused delivery: the dst-sort + ELL/CSR precompute happens HERE,
        # once per (hypergraph, bucket) — the serve loop never re-sorts.
        # Built from the PADDED structure (padding lanes carry e_mask=0
        # and fold to identity), so the layouts match the executable's
        # shapes; their data-dependent dims enter the cache signature.
        delivery = None
        delivery_sig = None
        if cfg.delivery == "pallas_fused":
            from repro.obs.trace import maybe_span

            with maybe_span(
                self.engine.tracer, "serve.layout_build", cat="compile",
                algorithm=self.spec.name, nnz_pad=int(nnz_pad),
                nv_pad=int(nv_pad), ne_pad=int(ne_pad),
            ):
                inj = getattr(self.engine, "fault_injector", None)
                if inj is not None:
                    inj.maybe_raise(
                        "layout.build", algorithm=self.spec.name
                    )
                if cfg.backend == "local":
                    delivery = layout_pair(
                        hgp.src, hgp.dst, hgp.e_mask, nv_pad, ne_pad
                    )
                else:
                    from repro.core.distributed import build_shard_delivery

                    delivery = build_shard_delivery(
                        *(np.asarray(s) for s in shards), nv_pad, ne_pad
                    )
            delivery_sig = tuple(l.shape_signature() for l in delivery)
        prep = dict(
            base=base,
            nv=nv, ne=ne,
            nv_pad=nv_pad, ne_pad=ne_pad, nnz_pad=nnz_pad,
            plan=plan, n_parts=n_parts, shard_len_pad=shard_len_pad,
            shards=shards, hgp=hgp,
            delivery=delivery, delivery_sig=delivery_sig,
            attr_sigs=(
                _attr_sig(hgp.v_attr), _attr_sig(hgp.he_attr),
                _attr_sig(hgp.e_attr),
            ),
        )
        self._pad_cache.append((source_probe, rebind, prep))
        del self._pad_cache[:-4]  # bound the strong refs we hold
        return prep

    def _plan_for(self, source_hg):
        if self.config.backend == "local":
            return None
        if source_hg is self.spec.hg0 and self._plan0 is not None:
            return self._plan0
        plan, _ = self.engine._cached_plan(
            source_hg, self.config.n_parts, self.config.partition_strategy
        )
        return plan

    def _execute(self, prep: dict, query, batch, warm_only: bool = False):
        from repro.core.executor import Result

        cfg = self.config
        spec = self.spec
        engine = self.engine
        distributed = cfg.backend != "local"
        has_query = query is not None
        b, b_pad = batch if batch is not None else (None, None)

        base, hgp, plan = prep["base"], prep["hgp"], prep["plan"]
        nv, ne = prep["nv"], prep["ne"]
        v_sig, he_sig, e_sig = prep["attr_sigs"]
        one_query = (
            jax.tree.map(lambda leaf: leaf[0], query)
            if batch is not None and has_query
            else query
        )
        if self._init_msg_sig is None:
            self._init_msg_sig = _initial_msg_sig(spec.initial_msg)
        key = signature(
            spec, cfg,
            nv_pad=prep["nv_pad"], ne_pad=prep["ne_pad"],
            nnz_pad=prep["nnz_pad"],
            shard_len_pad=prep["shard_len_pad"], n_parts=prep["n_parts"],
            v_attr_sig=v_sig, he_attr_sig=he_sig, e_attr_sig=e_sig,
            query_sig=_query_sig(one_query),
            batch_pad=b_pad,
            delivery_sig=prep["delivery_sig"],
            initial_msg_sig=self._init_msg_sig,
        )
        meta = {
            "algorithm": spec.name,
            "backend": cfg.backend,
            "delivery": cfg.delivery,
            "nv_pad": prep["nv_pad"],
            "ne_pad": prep["ne_pad"],
            "nnz_pad": prep["nnz_pad"],
            "batch_pad": b_pad,
            "n_parts": prep["n_parts"],
        }

        # Fault injection on the execute seam: one attribute load and a
        # None-check when no injector is attached (the same zero-overhead
        # contract as the tracer below).  Warmup never "executes".
        inj = getattr(engine, "fault_injector", None)
        if inj is not None and not warm_only:
            inj.maybe_raise(
                "execute", algorithm=spec.name, backend=cfg.backend,
                delivery=cfg.delivery,
                # analysis: ignore[host-sync] — b is the host-side batch
                # count (Python int or None), never a device value
                batch=int(b) if b is not None else 0,
            )

        # Tracing on the serve hot path is strictly opt-in: without a
        # tracer this closure is exactly ``exe(*args)`` — no timing, no
        # allocation (the zero-overhead contract bench_obs asserts).
        tracer = engine.tracer
        timing: dict = {}

        def _call(exe, args):
            if tracer is None:
                return exe(*args)
            t0 = time.perf_counter()
            traces0 = engine._trace_count
            with tracer.span(
                "engine.execute", cat="execute", algorithm=spec.name,
                backend=cfg.backend, delivery=cfg.delivery,
                batch=int(b) if b is not None else 0,
            ) as sp:
                out = exe(*args)
                tracer.block(sp, out)
                sp.args["retraces"] = engine._trace_count - traces0
            timing["wall_s"] = time.perf_counter() - t0
            timing["device_wait_s"] = sp.args.get("device_wait_s", 0.0)
            return out

        if distributed:
            exe = engine._executable_for(
                key,
                lambda: _build_distributed_executable(
                    spec, cfg, engine.mesh, prep["n_parts"],
                    prep["nv_pad"], prep["ne_pad"],
                    has_query, b_pad, engine._note_trace,
                ),
                meta=meta,
            )
            s_src, s_dst, s_mask = prep["shards"]
            args = (
                hgp, s_src, s_dst, s_mask, prep["delivery"],
                jnp.asarray(nv, jnp.int32),
                jnp.asarray(ne, jnp.int32),
                query,
            )
            with engine.mesh:
                if warm_only:
                    return {"source": _warm_executable(exe, args)}
                v_attr, he_attr, stats, executed = _call(exe, args)
        else:
            exe = engine._executable_for(
                key,
                lambda: _build_local_executable(
                    spec, cfg, has_query, b_pad, engine._note_trace,
                ),
                meta=meta,
            )
            args = (
                hgp, prep["delivery"],
                jnp.asarray(nv, jnp.int32),
                jnp.asarray(ne, jnp.int32),
                query,
            )
            if warm_only:
                return {"source": _warm_executable(exe, args)}
            v_attr, he_attr, stats, executed = _call(exe, args)

        # Slice padding (and batch padding) back off; extract on a
        # real-size hypergraph whose attrs may carry a leading batch dim
        # (extracts are field accessors, shape-polymorphic over it).
        if batch is not None:
            unslice_v = lambda x: x[:b, :nv]
            unslice_he = lambda x: x[:b, :ne]
            stats = (
                jax.tree.map(lambda x: x[:b], stats)
                if stats is not None else None
            )
        else:
            unslice_v = lambda x: x[:nv]
            unslice_he = lambda x: x[:ne]
        out = base.with_attrs(
            v_attr=jax.tree.map(unslice_v, v_attr),
            he_attr=jax.tree.map(unslice_he, he_attr),
        )
        decision = self.decision
        if tracer is not None and timing:
            # Measured enrichment is tracer-gated here (unlike
            # Engine.run's one-shot path) so warm serving stays
            # allocation-free by default.
            from repro.core.executor import message_width_bytes
            from repro.obs.calibrate import delivery_traffic_pair

            measured: dict = dict(timing)
            if executed is not None:
                try:
                    measured["supersteps"] = int(np.asarray(executed))
                # analysis: ignore[swallowed-error] — best-effort metric
                # enrichment: losing "supersteps" must not fail a serve
                # that already produced its result
                except Exception:
                    pass
            if prep["delivery"] is not None and not distributed:
                measured["delivery"] = delivery_traffic_pair(
                    prep["delivery"], message_width_bytes(spec.initial_msg)
                )
            decision = {**self.decision, "measured": measured}
        return Result(
            value=spec.extract(out),
            config=cfg,
            representation=cfg.representation,
            backend=cfg.backend,
            partition=plan.name if plan is not None else None,
            partition_stats=plan.stats if plan is not None else None,
            superstep_stats=stats,
            supersteps_executed=executed,
            decision=decision,
        )
