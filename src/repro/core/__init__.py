"""MESH core: the paper's contribution as a composable JAX module.

Module map — one API, many design points:

* ``hypergraph``  — the ``HyperGraph`` structure (bipartite incidence
  COO, pytree-registered so whole hypergraphs flow through jit /
  shard_map / scan).
* ``api``         — the programming model: ``Program`` / ``ProcedureOut``
  ("think like a vertex *or hyperedge*", Listing 1), message combiners.
* ``engine``      — the single-device superstep executor (``compute``):
  alternating vertex/hyperedge supersteps inside one ``lax.scan``.
* ``distributed`` — the same supersteps under ``jax.shard_map``:
  ``replicated`` (full-state psum) and ``sharded`` (all_gather +
  psum_scatter over id-range blocks) backends, fed by a
  ``PartitionPlan``.
* ``clique``      — the clique-expansion representation (``to_graph``,
  the paper's constant-folding optimization) and its feasibility
  estimator ``clique_expansion_size``.
* ``executor``    — the ``Engine`` facade: the ONE entry point. Takes an
  ``AlgorithmSpec`` plus an ``ExecutionConfig`` naming every design
  choice (representation / partition strategy / backend / jit /
  max-iters), resolves ``"auto"`` fields with small cost models
  (``select_representation``, ``select_backend``, ``select_partition``)
  and reports the chosen design point on the returned ``Result``.
  ``Engine.analyze`` is the batch twin: an ``AnalyticsSpec`` (h-motif
  census / pair intersections, ``repro.motifs``) resolved over the
  same axes — representation (materialize pair intersections via the
  dual clique expansion vs derive from the incidence), intersection
  kernel (bitset vs sorted-merge), backend (local vs pair blocks tiled
  across the mesh).

Callers should construct an ``Engine`` (or use the algorithm wrappers'
``engine=`` parameter); ``compute`` / ``distributed_compute`` remain
importable as the low-level executors the facade drives.
"""
from repro.core.hypergraph import HyperGraph
from repro.core.api import Program, ProcedureOut, constant_initial_msg
from repro.core.engine import compute, deliver, superstep_pair
from repro.core.clique import Graph, to_graph, clique_expansion_size
from repro.core.executor import (
    AnalyticsResult,
    AnalyticsSpec,
    Engine,
    ExecutionConfig,
    Result,
    select_backend,
    select_partition,
    select_representation,
)

__all__ = [
    "AnalyticsResult",
    "AnalyticsSpec",
    "HyperGraph",
    "Program",
    "ProcedureOut",
    "constant_initial_msg",
    "compute",
    "deliver",
    "superstep_pair",
    "Graph",
    "to_graph",
    "clique_expansion_size",
    "Engine",
    "ExecutionConfig",
    "Result",
    "select_backend",
    "select_partition",
    "select_representation",
]
