"""MESH core: the paper's contribution as a composable JAX module."""
from repro.core.hypergraph import HyperGraph
from repro.core.api import Program, ProcedureOut, constant_initial_msg
from repro.core.engine import compute, deliver, superstep_pair
from repro.core.clique import Graph, to_graph, clique_expansion_size

__all__ = [
    "HyperGraph",
    "Program",
    "ProcedureOut",
    "constant_initial_msg",
    "compute",
    "deliver",
    "superstep_pair",
    "Graph",
    "to_graph",
    "clique_expansion_size",
]
