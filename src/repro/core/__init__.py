"""MESH core: the paper's contribution as a composable JAX module.

Module map — one API, many design points:

* ``hypergraph``  — the ``HyperGraph`` structure (bipartite incidence
  COO, pytree-registered so whole hypergraphs flow through jit /
  shard_map / scan).
* ``api``         — the programming model: ``Program`` / ``ProcedureOut``
  ("think like a vertex *or hyperedge*", Listing 1), message combiners.
* ``engine``      — the single-device superstep executor (``compute``):
  alternating vertex/hyperedge supersteps inside one ``lax.scan``.
* ``distributed`` — the same supersteps under ``jax.shard_map``:
  ``replicated`` (full-state psum) and ``sharded`` (all_gather +
  psum_scatter over id-range blocks) backends, fed by a
  ``PartitionPlan``.
* ``clique``      — the clique-expansion representation (``to_graph``,
  the paper's constant-folding optimization) and its feasibility
  estimator ``clique_expansion_size``.
* ``executor``    — the ``Engine`` facade: the ONE entry point.
  ``Engine.submit`` dispatches on spec type: an ``AlgorithmSpec`` plus
  an ``ExecutionConfig`` naming every design choice (representation /
  partition strategy / backend / jit / max-iters) runs the iterative
  supersteps; an ``AnalyticsSpec`` (h-motif census / pair
  intersections, ``repro.motifs``) runs batch analytics over the same
  axes — representation (materialize pair intersections via the dual
  clique expansion vs derive from the incidence), intersection kernel
  (bitset vs sorted-merge), backend (local vs pair blocks tiled across
  the mesh).  ``"auto"`` fields resolve via small cost models
  (``select_representation``, ``select_backend``, ``select_partition``)
  and the chosen design point is reported on the returned ``Result``.
* ``serving``     — compile-once serve-many: ``Engine.compile(spec)``
  resolves the design point once and returns a ``CompiledAlgorithm``
  whose ``run``/``run_batch`` execute with zero retracing for any
  hypergraph in the same shape bucket (sizes quantized by
  ``bucket_dim``; executables held in the Engine's LRU, inspectable via
  ``Engine.cache_stats()``), vmapping over the spec's query axis to
  serve whole request batches from one compile.

Callers should construct an ``Engine`` (or use the algorithm wrappers'
``engine=`` parameter); ``compute`` / ``distributed_compute`` remain
importable as the low-level executors the facade drives.
"""
from repro.core.hypergraph import HyperGraph
from repro.core.api import Program, ProcedureOut, constant_initial_msg
from repro.core.engine import compute, deliver, superstep_pair
from repro.core.clique import Graph, to_graph, clique_expansion_size
from repro.core.executor import (
    AnalyticsResult,
    AnalyticsSpec,
    Engine,
    ExecutionConfig,
    Result,
    select_backend,
    select_partition,
    select_representation,
)
from repro.core.serving import CompiledAlgorithm, bucket_dim

__all__ = [
    "AnalyticsResult",
    "AnalyticsSpec",
    "CompiledAlgorithm",
    "bucket_dim",
    "HyperGraph",
    "Program",
    "ProcedureOut",
    "constant_initial_msg",
    "compute",
    "deliver",
    "superstep_pair",
    "Graph",
    "to_graph",
    "clique_expansion_size",
    "Engine",
    "ExecutionConfig",
    "Result",
    "select_backend",
    "select_partition",
    "select_representation",
]
