"""The HyperGraph structure: bipartite incidence representation.

A hypergraph H=(V,E) is stored exactly as MESH stores it inside GraphX: a
bipartite incidence list with low-level edges directed vertex -> hyperedge.
``src[i]`` is a vertex id, ``dst[i]`` a hyperedge id; attribute pytrees hang
off each side with leading dims ``n_vertices`` / ``n_hyperedges``.

Registered as a pytree so whole hypergraphs flow through jit / shard_map /
scan unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.segment import segment_count

Pytree = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HyperGraph:
    """Bipartite incidence representation of a hypergraph.

    Attributes:
      src: ``[nnz]`` int32 vertex id per incidence.
      dst: ``[nnz]`` int32 hyperedge id per incidence.
      n_vertices / n_hyperedges: static sizes.
      v_attr / he_attr: attribute pytrees (leading dim = entity count).
      e_attr: optional per-incidence attribute pytree (leading dim nnz),
        e.g. membership weights.
      e_mask: optional ``[nnz]`` float mask (1=live). Padding incidences
        (from partitioning or subHyperGraph) carry 0 and contribute the
        combiner identity.
    """

    src: jnp.ndarray
    dst: jnp.ndarray
    n_vertices: int
    n_hyperedges: int
    v_attr: Pytree = None
    he_attr: Pytree = None
    e_attr: Pytree = None
    e_mask: jnp.ndarray | None = None

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        children = (
            self.src, self.dst, self.v_attr, self.he_attr, self.e_attr,
            self.e_mask,
        )
        aux = (self.n_vertices, self.n_hyperedges)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, v_attr, he_attr, e_attr, e_mask = children
        return cls(
            src=src, dst=dst, n_vertices=aux[0], n_hyperedges=aux[1],
            v_attr=v_attr, he_attr=he_attr, e_attr=e_attr, e_mask=e_mask,
        )

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_hyperedge_lists(
        cls,
        hyperedges: list[list[int]],
        n_vertices: int | None = None,
        v_attr: Pytree = None,
        he_attr: Pytree = None,
    ) -> "HyperGraph":
        """Build from a python list of member lists (tests / tiny inputs)."""
        src = np.concatenate(
            [np.asarray(m, dtype=np.int32) for m in hyperedges]
        ) if hyperedges else np.zeros(0, np.int32)
        dst = np.concatenate(
            [np.full(len(m), i, dtype=np.int32) for i, m in enumerate(hyperedges)]
        ) if hyperedges else np.zeros(0, np.int32)
        nv = n_vertices if n_vertices is not None else (
            int(src.max()) + 1 if len(src) else 0
        )
        return cls(
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            n_vertices=nv,
            n_hyperedges=len(hyperedges),
            v_attr=v_attr,
            he_attr=he_attr,
        )

    @classmethod
    def from_coo(
        cls,
        src: np.ndarray,
        dst: np.ndarray,
        n_vertices: int,
        n_hyperedges: int,
        **kw,
    ) -> "HyperGraph":
        return cls(
            src=jnp.asarray(src, jnp.int32),
            dst=jnp.asarray(dst, jnp.int32),
            n_vertices=int(n_vertices),
            n_hyperedges=int(n_hyperedges),
            **kw,
        )

    # -- basic queries --------------------------------------------------------
    @property
    def nnz(self) -> int:
        return int(self.src.shape[0])

    def degrees(self) -> jnp.ndarray:
        """Vertex degree: number of hyperedges each vertex belongs to."""
        w = (
            self.e_mask.astype(jnp.int32)
            if self.e_mask is not None
            else jnp.ones_like(self.src)
        )
        return jax.ops.segment_sum(w, self.src, self.n_vertices)

    def cardinalities(self) -> jnp.ndarray:
        """Hyperedge cardinality: number of member vertices."""
        w = (
            self.e_mask.astype(jnp.int32)
            if self.e_mask is not None
            else jnp.ones_like(self.dst)
        )
        return jax.ops.segment_sum(w, self.dst, self.n_hyperedges)

    # -- transformations (GraphX-style structural ops) ------------------------
    def map_vertices(self, fn: Callable[[jnp.ndarray, Pytree], Pytree]):
        ids = jnp.arange(self.n_vertices, dtype=jnp.int32)
        return dataclasses.replace(self, v_attr=fn(ids, self.v_attr))

    def map_hyperedges(self, fn: Callable[[jnp.ndarray, Pytree], Pytree]):
        ids = jnp.arange(self.n_hyperedges, dtype=jnp.int32)
        return dataclasses.replace(self, he_attr=fn(ids, self.he_attr))

    def with_attrs(self, v_attr: Pytree = None, he_attr: Pytree = None):
        return dataclasses.replace(
            self,
            v_attr=v_attr if v_attr is not None else self.v_attr,
            he_attr=he_attr if he_attr is not None else self.he_attr,
        )

    def sub_hypergraph(
        self,
        v_pred: np.ndarray | None = None,
        he_pred: np.ndarray | None = None,
    ) -> "HyperGraph":
        """Host-side structural subsetting (preprocessing, not jitted).

        Keeps ids stable; drops incidences touching excluded entities.
        Mirrors GraphX ``subgraph`` semantics where excluded entities keep
        their slot but lose connectivity.
        """
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        keep = np.ones(len(src), dtype=bool)
        if self.e_mask is not None:
            # Padding incidences (mask 0) are dead: they must not be
            # resurrected as live rows of the sub-hypergraph.
            keep &= np.asarray(self.e_mask) != 0
        if v_pred is not None:
            keep &= np.asarray(v_pred)[src]
        if he_pred is not None:
            keep &= np.asarray(he_pred)[dst]
        sub = dataclasses.replace(
            self,
            src=jnp.asarray(src[keep]),
            dst=jnp.asarray(dst[keep]),
            e_attr=jax.tree.map(lambda a: a[jnp.asarray(keep)], self.e_attr)
            if self.e_attr is not None
            else None,
            e_mask=None,
        )
        return sub

    def padded(
        self, nv_pad: int, ne_pad: int, nnz_pad: int
    ) -> "HyperGraph":
        """Pad structure and attributes to the given bucket dims.

        Padding incidences carry ``e_mask=0`` (they reduce to the
        combiner identity in ``deliver``) and reference entity 0; padded
        entity slots are zero-filled and unreachable (no live incidence
        touches them), so real results are unchanged and callers slice
        outputs back to the real counts.  The mask is ALWAYS materialized
        — even when ``nnz_pad == nnz`` — so every hypergraph in a shape
        bucket presents the identical pytree structure to jit.
        """
        if (nv_pad < self.n_vertices or ne_pad < self.n_hyperedges
                or nnz_pad < self.nnz):
            raise ValueError(
                f"padded dims ({nv_pad}, {ne_pad}, {nnz_pad}) must cover "
                f"({self.n_vertices}, {self.n_hyperedges}, {self.nnz})"
            )
        def pad_rows(x, n):
            x = jnp.asarray(x)
            if n == x.shape[0]:
                return x
            return jnp.concatenate(
                [x, jnp.zeros((n - x.shape[0],) + x.shape[1:], x.dtype)]
            )

        mask = (
            jnp.asarray(self.e_mask, jnp.float32)
            if self.e_mask is not None
            else jnp.ones((self.nnz,), jnp.float32)
        )
        return HyperGraph(
            src=pad_rows(self.src, nnz_pad),
            dst=pad_rows(self.dst, nnz_pad),
            n_vertices=nv_pad,
            n_hyperedges=ne_pad,
            v_attr=jax.tree.map(
                lambda a: pad_rows(a, nv_pad), self.v_attr
            ),
            he_attr=jax.tree.map(
                lambda a: pad_rows(a, ne_pad), self.he_attr
            ),
            e_attr=jax.tree.map(
                lambda a: pad_rows(a, nnz_pad), self.e_attr
            ),
            e_mask=pad_rows(mask, nnz_pad),
        )

    def sorted_by_dst(self) -> "HyperGraph":
        """Return an equivalent hypergraph with incidences sorted by
        hyperedge id (CSR-friendly; required by the segsum kernel path)."""
        order = jnp.argsort(self.dst, stable=True)
        take = lambda a: jnp.take(a, order, axis=0)
        return dataclasses.replace(
            self,
            src=take(self.src),
            dst=take(self.dst),
            e_attr=jax.tree.map(take, self.e_attr)
            if self.e_attr is not None
            else None,
            e_mask=take(self.e_mask) if self.e_mask is not None else None,
        )

    def validate(self) -> None:
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        if len(src) != len(dst):
            raise ValueError("src/dst length mismatch")
        if len(src) and (src.min() < 0 or src.max() >= self.n_vertices):
            raise ValueError("vertex id out of range")
        if len(dst) and (dst.min() < 0 or dst.max() >= self.n_hyperedges):
            raise ValueError("hyperedge id out of range")
