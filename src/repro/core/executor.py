"""One API, many design points: the ``Engine`` facade.

MESH's central claim (§IV) is that representation and partitioning are
*pluggable design choices behind one simple API*, selected per data and
application characteristics.  This module is that API: every algorithm,
benchmark, example and launch script routes through ``Engine.run``; the
representation (bipartite incidence vs clique expansion), partitioning
strategy and execution backend (local / replicated / sharded) are named by
an ``ExecutionConfig`` and — when left ``"auto"`` — chosen by small cost
models over the machinery the repo already has:

* clique vs bipartite: ``clique_expansion_size`` against the incidence
  count, gated on the paper's constant-folding precondition (the algorithm
  must never touch hyperedge state — ``AlgorithmSpec.touches_hyperedge_state``);
* replicated vs sharded: ``PartitionStats.sync_bytes_per_dim`` against the
  full-replication sync bound the replicated backend pays by construction;
* partition strategy: min projected sync volume across the strategy
  registry (the selection loop of ``examples/hypergraph_analytics``).

The chosen design point is reported on the returned ``Result`` so callers
(and tests) can see *why* an execution ran the way it did.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import numpy as np

from repro.core.clique import clique_expansion_size, to_graph
from repro.core.engine import compute, compute_jit
from repro.core.hypergraph import HyperGraph

REPRESENTATIONS = ("auto", "bipartite", "clique")
BACKENDS = ("auto", "local", "replicated", "sharded")

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """Every design choice from the paper, in one place.

    ``"auto"`` fields are resolved per spec/plan/mesh by ``Engine.run``;
    the resolved copy (no ``"auto"`` left) is returned on ``Result.config``.

    Attributes:
      representation: ``bipartite`` | ``clique`` | ``auto``.  Clique
        expansion is only legal for specs with
        ``touches_hyperedge_state=False`` (paper §IV-A1) and a
        ``clique_program``.
      backend: ``local`` | ``replicated`` | ``sharded`` | ``auto``.
        Distributed backends need a mesh; ``auto`` with no mesh = local.
      partition_strategy: a name from ``repro.partition.STRATEGIES`` or
        ``auto`` (min projected sync volume).  Ignored when an explicit
        plan is passed to ``Engine``.  Resolved configs may carry
        ``"none"``: the execution partitioned nothing (local / clique).
      n_parts: partition count; defaults to ``mesh.shape[axis]``.
      axis: mesh axis carrying edge partitions.
      jit: wrap the local engine in ``jax.jit`` (distributed path is
        always jitted by construction).
      max_iters: overrides ``spec.max_iters`` when set.
      collect_stats: return per-superstep activity counters (local
        backend only — the distributed scan does not surface them yet).
      clique_edge_budget: clique expansion is auto-picked only when its
        (symmetrized) edge count is within this factor of the bipartite
        incidence count — the build cost and memory are the paper's
        Table I infeasibility argument.
      replicated_bias: sharded wins when the plan's projected sync bytes
        are below ``bias`` x the full-replication sync bound; the bias
        captures replicated's lower constant factor (one fused psum vs
        all_gather + psum_scatter).
    """

    representation: str = "auto"
    backend: str = "auto"
    partition_strategy: str = "auto"
    n_parts: int | None = None
    axis: str = "data"
    jit: bool = False
    max_iters: int | None = None
    collect_stats: bool = False
    clique_edge_budget: float = 4.0
    replicated_bias: float = 0.5

    def __post_init__(self):
        if self.representation not in REPRESENTATIONS:
            raise ValueError(
                f"representation must be one of {REPRESENTATIONS}, "
                f"got {self.representation!r}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}"
            )


@dataclasses.dataclass(frozen=True)
class Result:
    """What an execution produced, plus the design point that produced it.

    Attributes:
      value: the spec's extracted output (same value the legacy
        ``run_local`` / ``run_distributed`` returned).
      config: the fully-resolved ``ExecutionConfig`` (no ``"auto"``).
      representation / backend: the chosen design point (convenience
        mirrors of ``config``).
      partition: name of the partition strategy used, or ``None`` (local /
        clique executions don't partition).
      partition_stats: the plan's ``PartitionStats``, or ``None``.
      superstep_stats: ``(v_active, he_active)`` int32 arrays of length
        ``max_iters`` when ``collect_stats`` was set (local backend),
        else ``None``.
      decision: cost-model numbers behind each ``auto`` choice —
        a dict of dicts, one entry per resolved axis.
    """

    value: Any
    config: ExecutionConfig
    representation: str
    backend: str
    partition: str | None = None
    partition_stats: Any = None
    superstep_stats: Any = None
    decision: Mapping[str, Any] = dataclasses.field(default_factory=dict)


def select_representation(
    spec, hg: HyperGraph, *, edge_budget: float = 4.0
) -> tuple[str, dict]:
    """Clique vs bipartite for one spec — the paper's constant-folding
    rule plus a size cost model.

    Clique expansion is chosen only when (a) the algorithm never touches
    hyperedge state and ships a ``clique_program`` (correctness
    precondition, §IV-A1) and (b) the symmetrized expansion stays within
    ``edge_budget`` x the bipartite incidence count (Table I: heavy-tailed
    cardinalities blow the expansion up quadratically).
    """
    touches = getattr(spec, "touches_hyperedge_state", True)
    has_program = getattr(spec, "clique_program", None) is not None
    why: dict[str, Any] = {
        "touches_hyperedge_state": touches,
        "has_clique_program": has_program,
    }
    if touches or not has_program:
        why["reason"] = (
            "algorithm touches hyperedge state"
            if touches
            else "no clique program supplied"
        )
        return "bipartite", why

    n_clique_edges = 2 * clique_expansion_size(hg)  # symmetrized
    budget = edge_budget * max(hg.nnz, 1)
    why.update(
        clique_edges=int(n_clique_edges),
        bipartite_edges=int(hg.nnz),
        edge_budget=float(budget),
    )
    if n_clique_edges <= budget:
        why["reason"] = "expansion within edge budget"
        return "clique", why
    why["reason"] = "expansion exceeds edge budget"
    return "bipartite", why


def select_backend(
    plan,
    n_vertices: int,
    n_hyperedges: int,
    *,
    replicated_bias: float = 0.5,
) -> tuple[str, dict]:
    """Replicated vs sharded for one partition plan.

    The replicated backend syncs a *full-size* state buffer across every
    partition each half-superstep — equivalent to refreshing ``P - 1``
    replicas of every entity: ``full_sync = 2 * 4 * (P - 1) * (|V|+|E|)``
    bytes per float32 state dim.  The sharded backend's traffic tracks the
    replicas the edge cut actually created, which is exactly
    ``PartitionStats.sync_bytes_per_dim``.  Sharded wins when its
    projected sync is below ``replicated_bias`` x the full bound; the
    bias (< 1) favors replicated for well-connected small states where
    its single fused collective is cheaper in practice (the paper's
    apache/dblp regime).
    """
    stats = plan.stats
    p = plan.n_parts
    full_sync = 2.0 * 4.0 * max(p - 1, 0) * (n_vertices + n_hyperedges)
    sharded_sync = float(stats.sync_bytes_per_dim)
    why = {
        "n_parts": p,
        "sync_bytes_per_dim": sharded_sync,
        "full_replication_sync_bytes": full_sync,
        "replicated_bias": replicated_bias,
    }
    if p <= 1:
        why["reason"] = "single partition: replication is free"
        return "replicated", why
    if sharded_sync < replicated_bias * full_sync:
        why["reason"] = "plan sync volume beats full replication"
        return "sharded", why
    why["reason"] = "cut replicates most entities anyway"
    return "replicated", why


def select_partition(
    hg: HyperGraph, n_parts: int, strategy: str = "auto"
) -> tuple[Any, dict]:
    """Build a plan; ``auto`` = min projected sync volume over the
    strategy registry (greedy strategies run in chunked/approximate mode
    so selection stays preprocessing-cheap)."""
    from repro.partition import STRATEGIES, partition

    if strategy != "auto":
        if strategy not in STRATEGIES:
            raise ValueError(
                f"unknown partition strategy {strategy!r}; pick one of "
                f"{sorted(STRATEGIES)} or 'auto'"
            )
        kw = {"chunk": 256} if "greedy" in strategy else {}
        return partition(strategy, hg, n_parts, **kw), {
            "strategy": strategy, "reason": "explicitly configured",
        }

    best_name, best_plan = None, None
    costs = {}
    for name in sorted(STRATEGIES):
        kw = {"chunk": 256} if "greedy" in name else {}
        try:
            plan = partition(name, hg, n_parts, **kw)
        except ValueError:
            continue  # e.g. greedy bitmask width on wide meshes
        costs[name] = plan.stats.sync_bytes_per_dim
        if best_plan is None or (
            plan.stats.sync_bytes_per_dim
            < best_plan.stats.sync_bytes_per_dim
        ):
            best_name, best_plan = name, plan
    if best_plan is None:
        raise RuntimeError("no partition strategy produced a plan")
    return best_plan, {
        "strategy": best_name,
        "reason": "min projected sync volume",
        "sync_bytes_by_strategy": costs,
    }


class Engine:
    """The single entry point for hypergraph execution.

    >>> eng = Engine()                     # local, auto representation
    >>> res = eng.run(pagerank_spec(hg))
    >>> res.value, res.backend, res.decision

    >>> eng = Engine(mesh=mesh, backend="auto")   # distributed, plan auto
    >>> res = eng.run(label_propagation_spec(hg))

    An ``Engine`` is cheap to construct and stateless apart from its
    config / plan / mesh; algorithms' thin wrappers accept ``engine=`` so
    callers opt any call site into any design point without new APIs.
    """

    def __init__(
        self,
        plan=None,
        mesh=None,
        config: ExecutionConfig | None = None,
        **overrides: Any,
    ):
        cfg = config if config is not None else ExecutionConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.plan = plan
        self.mesh = mesh
        self.config = cfg
        # Auto-built plans, keyed by hypergraph identity: repeated
        # run()/resolve() on the same hypergraph must not re-run the
        # full strategy sweep.  [(hg, n_parts, strategy, plan, why)]
        self._plan_cache: list = []

    # -- resolution ---------------------------------------------------------

    def _resolve_representation(self, spec, cfg) -> tuple[str, dict]:
        if cfg.representation == "bipartite":
            return "bipartite", {"reason": "explicitly configured"}
        touches = getattr(spec, "touches_hyperedge_state", True)
        has_program = getattr(spec, "clique_program", None) is not None
        if cfg.representation == "clique":
            if touches:
                raise ValueError(
                    "representation='clique' is invalid for "
                    f"{getattr(spec, 'name', 'this spec')!r}: clique "
                    "expansion is only legal for algorithms that never "
                    "touch hyperedge state (MESH §IV-A1)"
                )
            if not has_program:
                raise ValueError(
                    "representation='clique' needs a clique_program on "
                    "the AlgorithmSpec"
                )
            if cfg.backend in ("replicated", "sharded"):
                raise ValueError(
                    "representation='clique' executes locally and cannot "
                    f"honor backend={cfg.backend!r}"
                )
            if cfg.max_iters is not None:
                raise ValueError(
                    "max_iters cannot override a clique_program (its "
                    "iteration count is baked into the spec); rebuild "
                    "the spec with the desired iters instead"
                )
            if self.mesh is not None:
                raise ValueError(
                    "representation='clique' executes locally and "
                    "cannot use the supplied mesh; drop the mesh or "
                    "use representation='bipartite'"
                )
            return "clique", {"reason": "explicitly configured"}
        # auto: explicit requests the clique path cannot honor pin
        # bipartite rather than being silently dropped.
        if cfg.backend in ("replicated", "sharded"):
            return "bipartite", {
                "reason": "distributed backend requested; clique "
                "executes locally"
            }
        if self.mesh is not None:
            return "bipartite", {
                "reason": "mesh supplied (distributed intent); clique "
                "executes locally"
            }
        if cfg.max_iters is not None and has_program and not touches:
            return "bipartite", {
                "reason": "max_iters override cannot apply to a "
                "clique_program"
            }
        return select_representation(
            spec, spec.hg0, edge_budget=cfg.clique_edge_budget
        )

    def _resolve_backend(self, spec, cfg) -> tuple[str, Any, dict, dict]:
        """Returns (backend, plan_or_None, backend_why, partition_why)."""
        if cfg.backend == "local":
            return "local", None, {"reason": "explicitly configured"}, {}

        if self.mesh is None:
            if cfg.backend in ("replicated", "sharded"):
                raise ValueError(
                    f"backend={cfg.backend!r} needs a mesh; construct "
                    "Engine(mesh=...) or use backend='local'"
                )
            return "local", None, {"reason": "no mesh available"}, {}

        n_parts = cfg.n_parts or int(self.mesh.shape[cfg.axis])
        plan = self.plan
        part_why: dict[str, Any] = {}
        if plan is None:
            plan, part_why = self._cached_plan(
                spec.hg0, n_parts, cfg.partition_strategy
            )
        else:
            part_why = {"strategy": plan.name,
                        "reason": "plan supplied by caller"}
        if plan.n_parts != n_parts:
            raise ValueError(
                f"plan has {plan.n_parts} partitions but mesh"
                f"[{cfg.axis!r}] = {n_parts}"
            )
        if cfg.backend in ("replicated", "sharded"):
            return (
                cfg.backend, plan,
                {"reason": "explicitly configured"}, part_why,
            )
        backend, why = select_backend(
            plan,
            spec.hg0.n_vertices,
            spec.hg0.n_hyperedges,
            replicated_bias=cfg.replicated_bias,
        )
        return backend, plan, why, part_why

    def _cached_plan(self, hg, n_parts: int, strategy: str):
        for c_hg, c_parts, c_strat, c_plan, c_why in self._plan_cache:
            if c_hg is hg and c_parts == n_parts and c_strat == strategy:
                return c_plan, c_why
        plan, why = select_partition(hg, n_parts, strategy)
        self._plan_cache.append((hg, n_parts, strategy, plan, why))
        del self._plan_cache[:-4]  # bound the strong refs we hold
        return plan, why

    # -- execution ----------------------------------------------------------

    def resolve(
        self, spec, **overrides: Any
    ) -> tuple[ExecutionConfig, Any, dict]:
        """Resolve every ``"auto"`` field for ``spec`` WITHOUT executing.

        Returns ``(resolved_config, plan_or_None, decision)`` — the exact
        design point ``run`` would execute, for dry-run inspection and
        cheap decision tests (no compilation happens here; partition
        construction does run when a plan must be built).
        """
        cfg = (
            dataclasses.replace(self.config, **overrides)
            if overrides
            else self.config
        )
        decision: dict[str, Any] = {}
        representation, rep_why = self._resolve_representation(spec, cfg)
        decision["representation"] = rep_why
        max_iters = (
            cfg.max_iters if cfg.max_iters is not None else spec.max_iters
        )
        if representation == "clique":
            decision["backend"] = {
                "reason": "clique representation executes locally"
            }
            resolved = dataclasses.replace(
                cfg,
                representation="clique",
                backend="local",
                max_iters=max_iters,
                partition_strategy="none",
            )
            return resolved, None, decision

        backend, plan, backend_why, part_why = self._resolve_backend(
            spec, cfg
        )
        decision["backend"] = backend_why
        if part_why:
            decision["partition"] = part_why
        resolved = dataclasses.replace(
            cfg,
            representation="bipartite",
            backend=backend,
            max_iters=max_iters,
            # "none" = this execution partitions nothing (local path);
            # a plan pins its strategy name.
            partition_strategy=(
                plan.name if plan is not None else "none"
            ),
            n_parts=plan.n_parts if plan is not None else cfg.n_parts,
        )
        return resolved, plan, decision

    def run(self, spec, **overrides: Any) -> Result:
        """Execute an ``AlgorithmSpec`` at the configured design point.

        ``overrides`` are per-call ``ExecutionConfig`` replacements
        (e.g. ``engine.run(spec, max_iters=8)``).
        """
        resolved, plan, decision = self.resolve(spec, **overrides)

        if resolved.representation == "clique":
            graph = to_graph(spec.hg0)
            return Result(
                value=spec.clique_program(graph),
                config=resolved,
                representation="clique",
                backend="local",
                decision=decision,
            )

        if resolved.backend == "local":
            fn = compute_jit if resolved.jit else compute
            out = fn(
                spec.hg0,
                max_iters=resolved.max_iters,
                initial_msg=spec.initial_msg,
                v_program=spec.v_program,
                he_program=spec.he_program,
                return_stats=resolved.collect_stats,
            )
            stats = None
            if resolved.collect_stats:
                out, stats = out
            return Result(
                value=spec.extract(out),
                config=resolved,
                representation="bipartite",
                backend="local",
                superstep_stats=stats,
                decision=decision,
            )

        from repro.core.distributed import distributed_compute

        out = distributed_compute(
            spec.hg0,
            plan,
            self.mesh,
            max_iters=resolved.max_iters,
            initial_msg=spec.initial_msg,
            v_program=spec.v_program,
            he_program=spec.he_program,
            axis=resolved.axis,
            backend=resolved.backend,
        )
        return Result(
            value=spec.extract(out),
            config=resolved,
            representation="bipartite",
            backend=resolved.backend,
            partition=plan.name,
            partition_stats=plan.stats,
            decision=decision,
        )
