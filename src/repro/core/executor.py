"""One API, many design points: the ``Engine`` facade.

MESH's central claim (§IV) is that representation and partitioning are
*pluggable design choices behind one simple API*, selected per data and
application characteristics.  This module is that API: every algorithm,
benchmark, example and launch script routes through ``Engine.submit``
(dispatching ``AlgorithmSpec`` -> iterative ``run``, ``AnalyticsSpec``
-> batch ``analyze``) or the compile-once serving path ``Engine.compile
-> CompiledAlgorithm`` (``repro.core.serving``); the representation
(bipartite incidence vs clique expansion), partitioning strategy and
execution backend (local / replicated / sharded) are named by an
``ExecutionConfig`` and — when left ``"auto"`` — chosen by small cost
models over the machinery the repo already has:

* clique vs bipartite: ``clique_expansion_size`` against the incidence
  count, gated on the paper's constant-folding precondition (the algorithm
  must never touch hyperedge state — ``AlgorithmSpec.touches_hyperedge_state``);
* replicated vs sharded: ``PartitionStats.sync_bytes_per_dim`` against the
  full-replication sync bound the replicated backend pays by construction;
* partition strategy: min projected sync volume across the strategy
  registry (the selection loop of ``examples/hypergraph_analytics``).

The chosen design point is reported on the returned ``Result`` so callers
(and tests) can see *why* an execution ran the way it did.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable, Mapping

import jax
import numpy as np

from repro.core.clique import clique_expansion_size, to_graph
from repro.core.engine import compute, compute_jit
from repro.core.hypergraph import HyperGraph
from repro.obs.calibrate import (
    delivery_traffic_pair,
    executed_supersteps,
    reference_traffic,
)
from repro.obs.metrics import default_registry, weak_provider
from repro.obs.trace import maybe_span
from repro.kernels.deliver import (
    DELIVERY_MODES,
    layout_pair,
    plan_degree_classes,
    plan_ell_width,
    select_lowering,
)

from repro.motifs.intersect import INTERSECT_KERNELS

REPRESENTATIONS = ("auto", "bipartite", "clique")
BACKENDS = ("auto", "local", "replicated", "sharded")
ANALYTICS_TASKS = ("hmotif_census", "pair_intersections")
ANALYTICS_MODES = ("auto", "exact", "sample")

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """Every design choice from the paper, in one place.

    ``"auto"`` fields are resolved per spec/plan/mesh by ``Engine.run``;
    the resolved copy (no ``"auto"`` left) is returned on ``Result.config``.

    Attributes:
      representation: ``bipartite`` | ``clique`` | ``auto``.  Clique
        expansion is only legal for specs with
        ``touches_hyperedge_state=False`` (paper §IV-A1) and a
        ``clique_program``.
      backend: ``local`` | ``replicated`` | ``sharded`` | ``auto``.
        Distributed backends need a mesh; ``auto`` with no mesh = local.
      partition_strategy: a name from ``repro.partition.STRATEGIES`` or
        ``auto`` (min projected sync volume).  Ignored when an explicit
        plan is passed to ``Engine``.  Resolved configs may carry
        ``"none"``: the execution partitioned nothing (local / clique).
      n_parts: partition count; defaults to ``mesh.shape[axis]``.
      axis: mesh axis carrying edge partitions.
      jit: wrap the local engine in ``jax.jit`` (distributed path is
        always jitted by construction).
      max_iters: overrides ``spec.max_iters`` when set.
      collect_stats: return per-superstep activity counters.  All
        backends: the distributed scan threads its trace out through
        ``shard_map`` out_specs (replicated — counts are psum'd), and
        counts exclude padding slots, so every backend reports the
        same numbers as the local engine.
      clique_edge_budget: clique expansion is auto-picked only when its
        (symmetrized) edge count is within this factor of the bipartite
        incidence count — the build cost and memory are the paper's
        Table I infeasibility argument.
      replicated_bias: sharded wins when the plan's projected sync bytes
        are below ``bias`` x the full-replication sync bound; the bias
        captures replicated's lower constant factor (one fused psum vs
        all_gather + psum_scatter).
      intersect_kernel: ``bitset`` | ``merge`` | ``auto`` — the
        hyperedge-pair intersection kernel the batch analytics mode
        (``Engine.analyze``) runs; iterative ``run`` ignores it.
        ``auto`` = ``repro.motifs.select_intersect_kernel`` (word lanes
        vs sort-merge work per pair).
      delivery: ``xla`` | ``pallas_fused`` | ``auto`` — the
        deliver/combine data path of every half-superstep.  ``xla`` is
        the reference gather -> mask -> segment-reduce;
        ``pallas_fused`` precomputes a dst-sorted degree-class
        (sliced-ELL) layout once per structure
        (``repro.kernels.deliver``) and fuses gather, mask and combine
        so the ``[nnz, D]`` intermediate never hits HBM.  ``auto``
        resolves via ``select_delivery``'s cost model (message width,
        degree skew via the class plan's padding work, nnz, platform
        lowering), falling back to ``xla`` for custom ``reducer``s and
        per-incidence ``edge_transform``s — the non-monoid paths the
        fused kernel cannot legally take.
    """

    representation: str = "auto"
    backend: str = "auto"
    partition_strategy: str = "auto"
    n_parts: int | None = None
    axis: str = "data"
    jit: bool = False
    max_iters: int | None = None
    collect_stats: bool = False
    clique_edge_budget: float = 4.0
    replicated_bias: float = 0.5
    intersect_kernel: str = "auto"
    delivery: str = "auto"
    # Fault tolerance (repro.faults): snapshot the superstep scan carry
    # every N pairs into ``checkpoint_dir`` (train/checkpoint.py format)
    # so a killed run resumes mid-algorithm bitwise-equal to an
    # uninterrupted one.  ``None`` = no checkpointing (the default; the
    # hot path is untouched).
    checkpoint_every: int | None = None
    checkpoint_dir: str | None = None

    def __post_init__(self):
        if self.checkpoint_every is not None and self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {self.checkpoint_every}"
            )
        if self.checkpoint_every is not None and self.checkpoint_dir is None:
            raise ValueError(
                "checkpoint_every needs checkpoint_dir (where snapshots go)"
            )
        if self.representation not in REPRESENTATIONS:
            raise ValueError(
                f"representation must be one of {REPRESENTATIONS}, "
                f"got {self.representation!r}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}"
            )
        if self.intersect_kernel not in INTERSECT_KERNELS:
            raise ValueError(
                f"intersect_kernel must be one of {INTERSECT_KERNELS}, "
                f"got {self.intersect_kernel!r}"
            )
        if self.delivery not in DELIVERY_MODES:
            raise ValueError(
                f"delivery must be one of {DELIVERY_MODES}, "
                f"got {self.delivery!r}"
            )


@dataclasses.dataclass(frozen=True)
class Result:
    """What an execution produced, plus the design point that produced it.

    Attributes:
      value: the spec's extracted output.
      config: the fully-resolved ``ExecutionConfig`` (no ``"auto"``).
      representation / backend: the chosen design point (convenience
        mirrors of ``config``).
      partition: name of the partition strategy used, or ``None`` (local /
        clique executions don't partition).
      partition_stats: the plan's ``PartitionStats``, or ``None``.
      superstep_stats: ``(v_active, he_active)`` int32 arrays of length
        ``max_iters`` when ``collect_stats`` was set (any backend),
        else ``None``.
      supersteps_executed: batched serving only — the superstep pairs
        the batch-aware halting scan actually ran (== the slowest
        query's convergence, <= max_iters); ``None`` elsewhere.
      decision: cost-model numbers behind each ``auto`` choice —
        a dict of dicts, one entry per resolved axis.
    """

    value: Any
    config: ExecutionConfig
    representation: str
    backend: str
    partition: str | None = None
    partition_stats: Any = None
    superstep_stats: Any = None
    supersteps_executed: Any = None
    decision: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class AnalyticsSpec:
    """A batch analytics workload — the non-iterative counterpart of
    ``AlgorithmSpec``, consumed by ``Engine.analyze``.

    Attributes:
      hg: the input hypergraph.
      task: ``hmotif_census`` (classify connected 3-hyperedge patterns
        into the 26 h-motif classes) or ``pair_intersections``
        (intersection size per hyperedge pair).
      mode: census only — ``exact`` enumerates every connected triple,
        ``sample`` runs the uniform linked-pair estimator, ``auto``
        picks by the overlap-pair budget below.
      n_samples / seed / confidence: sampling-estimator parameters.
      pairs: ``pair_intersections`` only — optional ``(ea, eb)`` id
        arrays; ``None`` = every overlapping pair.
      exact_pair_budget: ``mode="auto"`` runs exact while the overlap
        graph has at most this many linked pairs.
      tile: pair-batch tile size for the intersection kernel.
    """

    hg: HyperGraph
    task: str = "hmotif_census"
    mode: str = "auto"
    n_samples: int = 4000
    seed: int = 0
    confidence: float = 0.95
    pairs: Any = None
    exact_pair_budget: int = 200_000
    tile: int = 2048
    name: str = "hmotifs"

    def __post_init__(self):
        if self.task not in ANALYTICS_TASKS:
            raise ValueError(
                f"task must be one of {ANALYTICS_TASKS}, got {self.task!r}"
            )
        if self.mode not in ANALYTICS_MODES:
            raise ValueError(
                f"mode must be one of {ANALYTICS_MODES}, got {self.mode!r}"
            )


@dataclasses.dataclass(frozen=True)
class AnalyticsResult:
    """What a batch analytics execution produced, plus its design point.

    Attributes:
      value: ``Census`` (exact) / ``CensusEstimate`` (sampled) for the
        census task; ``(pairs, sizes)`` for ``pair_intersections``.
      representation: ``clique`` = pairwise intersections materialized
        from the dual clique expansion; ``bipartite`` = derived on the
        fly from the incidence by the kernel.
      kernel: ``bitset`` | ``merge`` — the intersection kernel path.
      backend: ``local`` | ``sharded`` (pair blocks tiled across the
        mesh).
      mode: ``exact`` | ``sample`` (census task; ``None`` otherwise).
      decision: cost-model numbers behind each ``auto`` choice.
    """

    value: Any
    config: ExecutionConfig
    representation: str
    kernel: str
    backend: str
    mode: str | None = None
    decision: Mapping[str, Any] = dataclasses.field(default_factory=dict)


def select_representation(
    spec, hg: HyperGraph, *, edge_budget: float = 4.0
) -> tuple[str, dict]:
    """Clique vs bipartite for one spec — the paper's constant-folding
    rule plus a size cost model.

    Clique expansion is chosen only when (a) the algorithm never touches
    hyperedge state and ships a ``clique_program`` (correctness
    precondition, §IV-A1) and (b) the symmetrized expansion stays within
    ``edge_budget`` x the bipartite incidence count (Table I: heavy-tailed
    cardinalities blow the expansion up quadratically).
    """
    touches = getattr(spec, "touches_hyperedge_state", True)
    has_program = getattr(spec, "clique_program", None) is not None
    why: dict[str, Any] = {
        "touches_hyperedge_state": touches,
        "has_clique_program": has_program,
    }
    if touches or not has_program:
        why["reason"] = (
            "algorithm touches hyperedge state"
            if touches
            else "no clique program supplied"
        )
        return "bipartite", why

    n_clique_edges = 2 * clique_expansion_size(hg)  # symmetrized
    budget = edge_budget * max(hg.nnz, 1)
    why.update(
        clique_edges=int(n_clique_edges),
        bipartite_edges=int(hg.nnz),
        edge_budget=float(budget),
    )
    if n_clique_edges <= budget:
        why["reason"] = "expansion within edge budget"
        return "clique", why
    why["reason"] = "expansion exceeds edge budget"
    return "bipartite", why


def state_width_bytes(attr: Pytree, n: int, default: float = 4.0) -> float:
    """Bytes of state per entity in an attribute pytree with leading dim
    ``n`` (one float32 dim when there is no state to measure)."""
    leaves = [leaf for leaf in jax.tree.leaves(attr) if hasattr(leaf, "size")]
    if not leaves or n <= 0:
        return default
    total = sum(leaf.size * leaf.dtype.itemsize for leaf in leaves)
    return max(float(total) / n, 1.0)


def select_backend(
    plan,
    n_vertices: int,
    n_hyperedges: int,
    *,
    replicated_bias: float = 0.5,
    v_state_bytes: float = 4.0,
    he_state_bytes: float = 4.0,
) -> tuple[str, dict]:
    """Replicated vs sharded for one partition plan.

    The replicated backend syncs a *full-size* state buffer across every
    partition each half-superstep — equivalent to refreshing ``P - 1``
    replicas of every entity:
    ``full_sync = 2 * (P - 1) * (w_v |V| + w_he |E|)`` bytes, where the
    widths are the spec's actual bytes of state per vertex / hyperedge
    (multi-dim attributes count every dim — bytes do NOT cancel out of
    the comparison, because the two sides can be weighted differently).
    The sharded backend's traffic tracks the replicas the edge cut
    actually created, weighted the same way
    (``PartitionStats.sync_bytes``).  Sharded wins when its projected
    sync is below ``replicated_bias`` x the full bound; the bias (< 1)
    favors replicated for well-connected small states where its single
    fused collective is cheaper in practice (the paper's apache/dblp
    regime).
    """
    stats = plan.stats
    p = plan.n_parts
    full_sync = 2.0 * max(p - 1, 0) * (
        v_state_bytes * n_vertices + he_state_bytes * n_hyperedges
    )
    sharded_sync = stats.sync_bytes(v_state_bytes, he_state_bytes)
    why = {
        "n_parts": p,
        "sync_bytes_per_dim": float(stats.sync_bytes_per_dim),
        "sharded_sync_bytes": sharded_sync,
        "full_replication_sync_bytes": full_sync,
        "v_state_bytes": v_state_bytes,
        "he_state_bytes": he_state_bytes,
        "replicated_bias": replicated_bias,
    }
    if p <= 1:
        why["reason"] = "single partition: replication is free"
        return "replicated", why
    if sharded_sync < replicated_bias * full_sync:
        why["reason"] = "plan sync volume beats full replication"
        return "sharded", why
    why["reason"] = "cut replicates most entities anyway"
    return "replicated", why


def select_partition(
    hg: HyperGraph, n_parts: int, strategy: str = "auto"
) -> tuple[Any, dict]:
    """Build a plan; ``auto`` = min projected sync volume over the
    strategy registry (greedy strategies run in chunked/approximate mode
    so selection stays preprocessing-cheap)."""
    from repro.partition import STRATEGIES, partition

    if strategy != "auto":
        if strategy not in STRATEGIES:
            raise ValueError(
                f"unknown partition strategy {strategy!r}; pick one of "
                f"{sorted(STRATEGIES)} or 'auto'"
            )
        kw = {"chunk": 256} if "greedy" in strategy else {}
        return partition(strategy, hg, n_parts, **kw), {
            "strategy": strategy, "reason": "explicitly configured",
        }

    best_name, best_plan = None, None
    costs = {}
    for name in sorted(STRATEGIES):
        kw = {"chunk": 256} if "greedy" in name else {}
        try:
            plan = partition(name, hg, n_parts, **kw)
        except ValueError:
            continue  # e.g. greedy bitmask width on wide meshes
        costs[name] = plan.stats.sync_bytes_per_dim
        if best_plan is None or (
            plan.stats.sync_bytes_per_dim
            < best_plan.stats.sync_bytes_per_dim
        ):
            best_name, best_plan = name, plan
    if best_plan is None:
        raise RuntimeError("no partition strategy produced a plan")
    return best_plan, {
        "strategy": best_name,
        "reason": "min projected sync volume",
        "sync_bytes_by_strategy": costs,
    }


# Fused-delivery cost model constants (ELL lowering; see
# ``select_delivery``).  Calibrated on ``benchmarks/bench_delivery.py``:
# the degree-class (sliced-ELL) dense reduces beat XLA's serialized
# scatter decisively for messages up to ``FUSED_MAX_WIDTH_BYTES``
# regardless of skew — per-class widths keep hubs dense, so zipf skew
# no longer bleeds into an overflow scatter.  The 64-byte zipf point
# (``wide_highskew``) is the regime the class layout flipped: the PR-4
# single-ELL packing measured a ~2x LOSS to the reference there (its
# capped width spilled over half the incidences into the sorted
# scatter); the class layout wins it ~1.3x (2.7x over single-ELL).
# Past the width cap the reference gather/scatter already vectorizes
# and the dense tables' padded row traffic (and cache footprint)
# multiplies with width — measured losses at every skew on XLA hosts.
FUSED_MAX_WIDTH_BYTES = 64.0    # per-entity message bytes
FUSED_ELL_WORK_BUDGET = 4.0     # padded ELL slots per real incidence
# Below this the layout/dispatch overheads swamp any kernel win AND the
# decision would be noise-sensitive (same-bucket graphs flipping design
# points for sub-ms executions); auto stays on the reference path.
FUSED_MIN_NNZ = 4096


def _non_monoid_reason(spec) -> str | None:
    """Why the fused delivery path is illegal for this spec, or None."""
    for side, prog in (("v_program", spec.v_program),
                       ("he_program", spec.he_program)):
        if getattr(prog, "reducer", None) is not None:
            return f"{side} has a custom (Seq) reducer"
        if getattr(prog, "edge_transform", None) is not None:
            return f"{side} has a per-incidence edge_transform"
    return None


def message_width_bytes(initial_msg: Any) -> float:
    """Bytes per entity of one broadcast message (from the spec's
    ``initial_msg`` template — the only static width signal)."""
    total = 0.0
    for leaf in jax.tree.leaves(initial_msg):
        arr = np.asarray(leaf)
        total += float(arr.size * arr.dtype.itemsize)
    return max(total, 1.0)


def select_delivery(spec, hg: HyperGraph) -> tuple[str, dict]:
    """Fused vs reference delivery for one spec — the tentpole's cost
    model over nnz, message width, dtype and degree skew.

    Hard gates first: the fused kernel folds the combine into the
    layout, so custom ``reducer``s / ``edge_transform``s (which consume
    materialized per-incidence rows) and empty structures take ``xla``.

    Then per lowering (``repro.kernels.deliver.select_lowering``):

    * ``pallas`` (native TPU): fused delivery reads each message row
      once per incident edge instead of gather+mask+re-read (~3x HBM
      traffic) — always projected to win on the monoid path.
    * ``ell`` (XLA hosts): the win comes from replacing the serialized
      scatter with dense reduces, and dies by padding.  The padding
      term is the degree-class plan's summed work
      (``plan_degree_classes`` over both directions' live-degree
      histograms — dense slots at the builder's pow2 row padding
      (``ClassPlan.built_work``) plus residual; exactly what a layout
      built by the LOCAL builder allocates, so model and builder
      cannot disagree there.  The distributed builder plans from
      merged per-shard histograms and harmonizes pads to shard maxima,
      so its realized padded work can exceed this estimate on
      shard-skewed cuts — the budget is a lower bound in that case).
      Pick
      fused while (a) class padding is bounded
      (``FUSED_ELL_WORK_BUDGET`` slots per incidence, both directions)
      and (b) the message row is within ``FUSED_MAX_WIDTH_BYTES`` —
      a boundary the class layout MOVED: at 64-byte rows under zipf
      skew the PR-4 single-ELL packing measured a ~2x loss to the
      reference (overflow scatter), while per-class widths keep hubs
      dense and win the regime.  The reported ``skew_gain`` (single-ELL
      vs class plan, residual-weighted) quantifies how much of the
      decision the degree classes carry.
    """
    reason = _non_monoid_reason(spec)
    why: dict[str, Any] = {}
    if reason is not None:
        why["reason"] = f"non-monoid path: {reason}"
        return "xla", why
    if hg.nnz == 0 or hg.n_vertices == 0 or hg.n_hyperedges == 0:
        why["reason"] = "empty structure"
        return "xla", why

    lowering = select_lowering()
    why["lowering"] = lowering
    if lowering != "ell":
        why["reason"] = (
            "native pallas lowering: fused path streams each message "
            "row once (vs 3x reference HBM traffic)"
        )
        return "pallas_fused", why

    live = (
        np.asarray(hg.e_mask) != 0
        if hg.e_mask is not None
        else np.ones(hg.nnz, bool)
    )
    src = np.asarray(hg.src)[live]
    dst = np.asarray(hg.dst)[live]
    nnz = int(live.sum())
    if nnz == 0:
        why["reason"] = "no live incidences"
        return "xla", why
    width = message_width_bytes(spec.initial_msg)
    why["message_width_bytes"] = width
    if nnz < FUSED_MIN_NNZ:
        why["reason"] = (
            f"tiny incidence ({nnz} < {FUSED_MIN_NNZ}): layout and "
            "dispatch overheads dominate"
        )
        return "xla", why

    from repro.kernels.deliver.layout import RESIDUAL_WEIGHT

    class_work = 0.0
    class_weighted = 0.0
    single_weighted = 0.0
    residual = 0
    plans = {}
    for side, n_dst, ids in (
        ("fwd", hg.n_hyperedges, dst), ("bwd", hg.n_vertices, src)
    ):
        deg = np.bincount(ids, minlength=n_dst)
        plan = plan_degree_classes(deg, nnz)
        k1, rem1 = plan_ell_width(deg, nnz)
        # built_work: dense slots at the builder's pow2 row padding —
        # the work the layout will really do, not the DP's tight count.
        class_work += float(plan.built_work)
        class_weighted += float(
            plan.built_work - plan.residual
            + RESIDUAL_WEIGHT * plan.residual
        )
        single_weighted += float(n_dst * k1 + RESIDUAL_WEIGHT * rem1)
        residual = max(residual, plan.residual)
        plans[side] = {
            "widths": plan.widths, "rows": plan.rows,
            "residual": plan.residual,
        }
    # Residual lanes pay the serialized sorted segment reduce, dense
    # slots a vectorized reduce — compare plans on the weighted scale
    # the DP itself optimizes.
    skew_gain = single_weighted / max(class_weighted, 1.0)
    why.update(
        nnz=nnz,
        class_work_slots=class_work,
        class_weighted_work=class_weighted,
        single_ell_weighted_work=single_weighted,
        skew_gain=skew_gain,
        work_budget=FUSED_ELL_WORK_BUDGET * 2 * nnz,
        residual=residual,
        width_budget=FUSED_MAX_WIDTH_BYTES,
        class_plans=plans,
    )
    if class_work > FUSED_ELL_WORK_BUDGET * 2 * nnz:
        why["reason"] = "degree-class padding exceeds the work budget"
        return "xla", why
    if width > FUSED_MAX_WIDTH_BYTES:
        why["reason"] = (
            "wide message rows: the reference gather/scatter already "
            "vectorizes; class-table row traffic multiplies with width"
        )
        return "xla", why
    why["reason"] = (
        "degree-class dense reduces beat the serialized scatter "
        + ("(skewed degrees: per-class widths keep hubs dense)"
           if skew_gain >= 1.4
           else "(bounded class padding)")
    )
    return "pallas_fused", why


class Engine:
    """The single entry point for hypergraph execution.

    >>> eng = Engine()                     # local, auto representation
    >>> res = eng.run(pagerank_spec(hg))
    >>> res.value, res.backend, res.decision

    >>> eng = Engine(mesh=mesh, backend="auto")   # distributed, plan auto
    >>> res = eng.run(label_propagation_spec(hg))

    An ``Engine`` is cheap to construct and stateless apart from its
    config / plan / mesh; algorithms' thin wrappers accept ``engine=`` so
    callers opt any call site into any design point without new APIs.
    """

    def __init__(
        self,
        plan=None,
        mesh=None,
        config: ExecutionConfig | None = None,
        exec_cache_size: int = 32,
        disk_cache=None,
        tracer=None,
        metrics=None,
        fault_injector=None,
        **overrides: Any,
    ):
        cfg = config if config is not None else ExecutionConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.plan = plan
        self.mesh = mesh
        self.config = cfg
        # Auto-built plans, keyed by hypergraph identity: repeated
        # run()/resolve() on the same hypergraph must not re-run the
        # full strategy sweep.  [(hg, n_parts, strategy, plan, why)]
        self._plan_cache: list = []
        # Fused-delivery layouts, keyed the same way: the dst-sort +
        # ELL/CSR precompute is paid once per structure.  [(hg, layouts)]
        self._delivery_cache: list = []
        # Compile-once serve-many state: the LRU of shape-bucketed
        # executables behind Engine.compile / CompiledAlgorithm (keyed
        # by repro.core.serving.signature), plus the observability
        # counters cache_stats() reports.
        self.exec_cache_size = int(exec_cache_size)
        self._exec_cache: OrderedDict = OrderedDict()
        self._exec_meta: dict = {}
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_evictions = 0
        self._trace_count = 0
        # Optional persistent cross-process store (duck-typed:
        # ``repro.serve.cache.DiskExecutableCache``); when set, freshly
        # built executables are wrapped so their first use resolves
        # disk-deserialize vs AOT-compile-and-store.  Core never imports
        # the serve tier — the dependency points the other way.
        self.disk_cache = disk_cache
        # Observability (repro.obs): an optional span recorder
        # (duck-typed like disk_cache: anything with span/block) and
        # the unified metrics registry this Engine's executable-cache
        # counters surface through.  Both cost NOTHING on hot paths
        # when unused: span sites branch on ``tracer is None`` and the
        # registry provider is a weakref pulled only at snapshot time.
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else default_registry()
        self.metrics.register_provider(
            "engine.exec_cache", weak_provider(self.cache_stats)
        )
        # Fault injection (repro.faults): duck-typed like tracer /
        # disk_cache — instrumented paths branch on ``is None`` first,
        # so an absent injector costs nothing.  The attached disk cache
        # shares the injector (its read/write/deserialize points).
        self.fault_injector = fault_injector
        if fault_injector is not None and disk_cache is not None:
            disk_cache.fault_injector = fault_injector

    # -- resolution ---------------------------------------------------------

    def _resolve_representation(self, spec, cfg) -> tuple[str, dict]:
        if cfg.representation == "bipartite":
            return "bipartite", {"reason": "explicitly configured"}
        touches = getattr(spec, "touches_hyperedge_state", True)
        has_program = getattr(spec, "clique_program", None) is not None
        if cfg.representation == "clique":
            if touches:
                raise ValueError(
                    "representation='clique' is invalid for "
                    f"{getattr(spec, 'name', 'this spec')!r}: clique "
                    "expansion is only legal for algorithms that never "
                    "touch hyperedge state (MESH §IV-A1)"
                )
            if not has_program:
                raise ValueError(
                    "representation='clique' needs a clique_program on "
                    "the AlgorithmSpec"
                )
            if cfg.backend in ("replicated", "sharded"):
                raise ValueError(
                    "representation='clique' executes locally and cannot "
                    f"honor backend={cfg.backend!r}"
                )
            if cfg.max_iters is not None:
                raise ValueError(
                    "max_iters cannot override a clique_program (its "
                    "iteration count is baked into the spec); rebuild "
                    "the spec with the desired iters instead"
                )
            if self.mesh is not None:
                raise ValueError(
                    "representation='clique' executes locally and "
                    "cannot use the supplied mesh; drop the mesh or "
                    "use representation='bipartite'"
                )
            return "clique", {"reason": "explicitly configured"}
        # auto: explicit requests the clique path cannot honor pin
        # bipartite rather than being silently dropped.
        if cfg.backend in ("replicated", "sharded"):
            return "bipartite", {
                "reason": "distributed backend requested; clique "
                "executes locally"
            }
        if self.mesh is not None:
            return "bipartite", {
                "reason": "mesh supplied (distributed intent); clique "
                "executes locally"
            }
        if cfg.max_iters is not None and has_program and not touches:
            return "bipartite", {
                "reason": "max_iters override cannot apply to a "
                "clique_program"
            }
        return select_representation(
            spec, spec.hg0, edge_budget=cfg.clique_edge_budget
        )

    def _resolve_backend(self, spec, cfg) -> tuple[str, Any, dict, dict]:
        """Returns (backend, plan_or_None, backend_why, partition_why)."""
        if cfg.backend == "local":
            return "local", None, {"reason": "explicitly configured"}, {}

        if self.mesh is None:
            if cfg.backend in ("replicated", "sharded"):
                raise ValueError(
                    f"backend={cfg.backend!r} needs a mesh; construct "
                    "Engine(mesh=...) or use backend='local'"
                )
            return "local", None, {"reason": "no mesh available"}, {}

        n_parts = cfg.n_parts or int(self.mesh.shape[cfg.axis])
        plan = self.plan
        part_why: dict[str, Any] = {}
        if plan is None:
            plan, part_why = self._cached_plan(
                spec.hg0, n_parts, cfg.partition_strategy
            )
        else:
            part_why = {"strategy": plan.name,
                        "reason": "plan supplied by caller"}
        if plan.n_parts != n_parts:
            raise ValueError(
                f"plan has {plan.n_parts} partitions but mesh"
                f"[{cfg.axis!r}] = {n_parts}"
            )
        if cfg.backend in ("replicated", "sharded"):
            return (
                cfg.backend, plan,
                {"reason": "explicitly configured"}, part_why,
            )
        backend, why = select_backend(
            plan,
            spec.hg0.n_vertices,
            spec.hg0.n_hyperedges,
            replicated_bias=cfg.replicated_bias,
            v_state_bytes=state_width_bytes(
                spec.hg0.v_attr, spec.hg0.n_vertices
            ),
            he_state_bytes=state_width_bytes(
                spec.hg0.he_attr, spec.hg0.n_hyperedges
            ),
        )
        return backend, plan, why, part_why

    def _cached_plan(self, hg, n_parts: int, strategy: str):
        for c_hg, c_parts, c_strat, c_plan, c_why in self._plan_cache:
            if c_hg is hg and c_parts == n_parts and c_strat == strategy:
                return c_plan, c_why
        plan, why = select_partition(hg, n_parts, strategy)
        self._plan_cache.append((hg, n_parts, strategy, plan, why))
        del self._plan_cache[:-4]  # bound the strong refs we hold
        return plan, why

    def _resolve_delivery(self, spec, cfg) -> tuple[str, dict]:
        if cfg.delivery == "xla":
            return "xla", {"reason": "explicitly configured"}
        if cfg.delivery == "pallas_fused":
            reason = _non_monoid_reason(spec)
            if reason is not None:
                raise ValueError(
                    "delivery='pallas_fused' is invalid for "
                    f"{getattr(spec, 'name', 'this spec')!r}: {reason}; "
                    "the fused kernel serves monoid combiners only"
                )
            if spec.hg0.nnz == 0:
                raise ValueError(
                    "delivery='pallas_fused' needs a non-empty incidence"
                )
            return "pallas_fused", {"reason": "explicitly configured"}
        return select_delivery(spec, spec.hg0)

    def _delivery_layouts(self, hg):
        """Both directions' fused layouts for one structure, cached by
        hypergraph identity (host-side dst-sort + ELL/CSR precompute)."""
        for c_hg, lay in self._delivery_cache:
            if c_hg is hg:
                return lay
        with maybe_span(
            self.tracer, "engine.layout_build", cat="compile",
            nnz=int(hg.nnz), n_vertices=int(hg.n_vertices),
            n_hyperedges=int(hg.n_hyperedges),
        ):
            lay = layout_pair(
                hg.src, hg.dst, hg.e_mask, hg.n_vertices, hg.n_hyperedges
            )
        self._delivery_cache.append((hg, lay))
        del self._delivery_cache[:-4]  # bound the strong refs we hold
        return lay

    # -- execution ----------------------------------------------------------

    def resolve(
        self, spec, **overrides: Any
    ) -> tuple[ExecutionConfig, Any, dict]:
        """Resolve every ``"auto"`` field for ``spec`` WITHOUT executing.

        Returns ``(resolved_config, plan_or_None, decision)`` — the exact
        design point ``run`` would execute, for dry-run inspection and
        cheap decision tests (no compilation happens here; partition
        construction does run when a plan must be built).
        """
        cfg = (
            dataclasses.replace(self.config, **overrides)
            if overrides
            else self.config
        )
        decision: dict[str, Any] = {}
        representation, rep_why = self._resolve_representation(spec, cfg)
        decision["representation"] = rep_why
        max_iters = (
            cfg.max_iters if cfg.max_iters is not None else spec.max_iters
        )
        if representation == "clique":
            decision["backend"] = {
                "reason": "clique representation executes locally"
            }
            decision["delivery"] = {
                "reason": "clique constant-folding runs a host-side "
                "program; no superstep delivery exists"
            }
            resolved = dataclasses.replace(
                cfg,
                representation="clique",
                backend="local",
                max_iters=max_iters,
                partition_strategy="none",
                delivery="xla",
            )
            return resolved, None, decision

        backend, plan, backend_why, part_why = self._resolve_backend(
            spec, cfg
        )
        decision["backend"] = backend_why
        if part_why:
            decision["partition"] = part_why
        delivery, delivery_why = self._resolve_delivery(spec, cfg)
        decision["delivery"] = delivery_why
        resolved = dataclasses.replace(
            cfg,
            representation="bipartite",
            backend=backend,
            max_iters=max_iters,
            # "none" = this execution partitions nothing (local path);
            # a plan pins its strategy name.
            partition_strategy=(
                plan.name if plan is not None else "none"
            ),
            n_parts=plan.n_parts if plan is not None else cfg.n_parts,
            delivery=delivery,
        )
        return resolved, plan, decision

    def explain(self, spec, hg=None, **overrides: Any) -> dict:
        """The full decision tree for every ``auto`` axis — inputs,
        per-candidate predicted costs, winner, reason — WITHOUT
        executing (no compile, no device work).

        Built directly on ``resolve`` (the same call ``run`` and
        ``compile`` make), so the winners here are BY CONSTRUCTION the
        axes an actual execution of the same inputs resolves — asserted
        axis-for-axis in ``tests/test_obs.py``.  On top of the winner,
        every axis reports the costs of the candidates it did NOT pick,
        which ``resolve`` alone never surfaces for pinned or gated
        axes.

        ``hg``: explain against this hypergraph instead of the spec's
        own (applies ``spec.init`` like ``CompiledAlgorithm.run(hg)``).
        ``AnalyticsSpec`` routes to the batch axes (kernel /
        representation / backend / mode).  Returns::

            {"config": resolved ExecutionConfig,
             "decision": the resolve() decision dict,
             "axes": {axis: {"winner", "reason", "inputs",
                             "candidates": {name: {...costs}}}}}
        """
        if isinstance(spec, AnalyticsSpec):
            return self._explain_analytics(spec, **overrides)
        if hg is not None:
            hg = spec.init(hg) if spec.init is not None else hg
            spec = spec._replace(hg0=hg)
        resolved, plan, decision = self.resolve(spec, **overrides)
        cfg = (
            dataclasses.replace(self.config, **overrides)
            if overrides
            else self.config
        )
        hg0 = spec.hg0
        axes: dict[str, Any] = {}

        # -- representation: bipartite vs clique constant-folding ------
        touches = getattr(spec, "touches_hyperedge_state", True)
        has_program = getattr(spec, "clique_program", None) is not None
        eligible = (not touches) and has_program
        clique_edges = (
            int(2 * clique_expansion_size(hg0)) if eligible else None
        )
        axes["representation"] = {
            "winner": resolved.representation,
            "reason": decision["representation"].get("reason"),
            "inputs": {
                "touches_hyperedge_state": touches,
                "has_clique_program": has_program,
                "nnz": int(hg0.nnz),
            },
            "candidates": {
                "bipartite": {
                    "eligible": True,
                    "predicted_cost_edges": int(hg0.nnz),
                },
                "clique": {
                    "eligible": eligible,
                    "predicted_cost_edges": clique_edges,
                    "edge_budget": float(
                        cfg.clique_edge_budget * max(hg0.nnz, 1)
                    ),
                },
            },
        }

        # -- backend: local vs replicated vs sharded -------------------
        if plan is None:
            axes["backend"] = {
                "winner": resolved.backend,
                "reason": decision["backend"].get("reason"),
                "inputs": {"mesh": self.mesh is not None},
                "candidates": {
                    "local": {"eligible": True, "predicted_sync_bytes": 0.0},
                    "replicated": {"eligible": self.mesh is not None},
                    "sharded": {"eligible": self.mesh is not None},
                },
            }
        else:
            v_w = state_width_bytes(hg0.v_attr, hg0.n_vertices)
            he_w = state_width_bytes(hg0.he_attr, hg0.n_hyperedges)
            _, bwhy = select_backend(
                plan, hg0.n_vertices, hg0.n_hyperedges,
                replicated_bias=cfg.replicated_bias,
                v_state_bytes=v_w, he_state_bytes=he_w,
            )
            axes["backend"] = {
                "winner": resolved.backend,
                "reason": decision["backend"].get("reason"),
                "inputs": {
                    "n_parts": bwhy["n_parts"],
                    "v_state_bytes": v_w,
                    "he_state_bytes": he_w,
                    "replicated_bias": cfg.replicated_bias,
                },
                "candidates": {
                    "replicated": {
                        "eligible": True,
                        "predicted_sync_bytes": bwhy[
                            "full_replication_sync_bytes"
                        ],
                        "bias_adjusted_bytes": (
                            cfg.replicated_bias
                            * bwhy["full_replication_sync_bytes"]
                        ),
                    },
                    "sharded": {
                        "eligible": True,
                        "predicted_sync_bytes": bwhy["sharded_sync_bytes"],
                    },
                },
            }

        # -- partition: projected sync volume per strategy -------------
        if plan is None:
            axes["partition"] = {
                "winner": resolved.partition_strategy,
                "reason": "local execution partitions nothing",
                "inputs": {},
                "candidates": {},
            }
        else:
            part_why = decision.get("partition", {})
            costs = part_why.get("sync_bytes_by_strategy")
            if costs is None:
                # pinned strategy / caller-supplied plan: the sweep was
                # skipped — report the one plan actually in play.
                costs = {plan.name: float(plan.stats.sync_bytes_per_dim)}
            axes["partition"] = {
                "winner": resolved.partition_strategy,
                "reason": part_why.get("reason"),
                "inputs": {"n_parts": plan.n_parts},
                "candidates": {
                    nm: {
                        "eligible": True,
                        "predicted_sync_bytes_per_dim": float(c),
                    }
                    for nm, c in costs.items()
                },
            }

        # -- delivery: reference vs fused HBM-traffic model ------------
        # Run the cost model even when the axis was pinned or gated, so
        # the non-winning candidate's predicted cost is always visible.
        gate = _non_monoid_reason(spec)
        _, dwhy = select_delivery(spec, hg0)
        width = dwhy.get(
            "message_width_bytes", message_width_bytes(spec.initial_msg)
        )
        nnz = dwhy.get("nnz", int(hg0.nnz))
        ref_bytes = reference_traffic(
            nnz, hg0.n_hyperedges, width
        ) + reference_traffic(nnz, hg0.n_vertices, width)
        fused_cand: dict[str, Any] = {
            "eligible": gate is None and nnz > 0,
            "gate": gate,
        }
        for k in (
            "class_work_slots", "class_weighted_work",
            "single_ell_weighted_work", "skew_gain", "work_budget",
            "residual", "class_plans",
        ):
            if k in dwhy:
                fused_cand[k] = dwhy[k]
        if "class_work_slots" in dwhy:
            # Predicted fused HBM bytes from the class plan's work
            # slots — the same (width + id) per slot + output model
            # obs.calibrate prices a BUILT layout with.
            fused_cand["predicted_hbm_bytes"] = (
                dwhy["class_work_slots"] * (width + 4.0)
                + (hg0.n_vertices + hg0.n_hyperedges) * width
            )
        axes["delivery"] = {
            "winner": resolved.delivery,
            "reason": decision["delivery"].get("reason"),
            "inputs": {
                "nnz": nnz,
                "message_width_bytes": width,
                "width_budget": FUSED_MAX_WIDTH_BYTES,
                "min_nnz": FUSED_MIN_NNZ,
                "lowering": dwhy.get("lowering"),
            },
            "candidates": {
                "xla": {
                    "eligible": True,
                    "predicted_hbm_bytes": ref_bytes,
                },
                "pallas_fused": fused_cand,
            },
        }

        return {"config": resolved, "decision": decision, "axes": axes}

    def _explain_analytics(self, spec: "AnalyticsSpec", **overrides) -> dict:
        """``explain`` for the batch axes: intersect kernel,
        (dual) representation, backend, census mode."""
        from repro.motifs import (
            overlap_pairs_with_counts,
            select_intersect_kernel,
        )

        cfg = (
            dataclasses.replace(self.config, **overrides)
            if overrides
            else self.config
        )
        pairs, _ = overlap_pairs_with_counts(spec.hg)
        n_pairs = len(pairs)
        resolved, mode, decision = self._resolve_analytics(
            spec, cfg, n_pairs
        )
        _, kwhy = select_intersect_kernel(spec.hg)
        axes: dict[str, Any] = {
            "kernel": {
                "winner": resolved.intersect_kernel,
                "reason": decision["kernel"].get("reason"),
                "inputs": {
                    "n_hyperedges": int(spec.hg.n_hyperedges),
                    "n_vertices": int(spec.hg.n_vertices),
                },
                "candidates": {
                    "bitset": {
                        "eligible": (
                            kwhy["bitset_index_bytes"]
                            <= kwhy["bitset_budget_bytes"]
                        ),
                        "predicted_ops_per_pair": kwhy[
                            "bitset_words_per_pair"
                        ],
                        "index_bytes": kwhy["bitset_index_bytes"],
                    },
                    "merge": {
                        "eligible": True,
                        "predicted_ops_per_pair": kwhy[
                            "merge_ops_per_pair"
                        ],
                    },
                },
            },
            "representation": {
                "winner": resolved.representation,
                "reason": decision["representation"].get("reason"),
                "inputs": {"n_overlap_pairs": n_pairs},
                "candidates": {
                    "bipartite": {
                        "eligible": True,
                        "predicted_cost_edges": int(spec.hg.nnz),
                    },
                    "clique": {
                        "eligible": True,
                        "predicted_cost_edges": 2 * n_pairs,
                        "edge_budget": float(
                            cfg.clique_edge_budget * max(spec.hg.nnz, 1)
                        ),
                    },
                },
            },
            "backend": {
                "winner": resolved.backend,
                "reason": decision["backend"].get("reason"),
                "inputs": {"mesh": self.mesh is not None},
                "candidates": {
                    "local": {"eligible": True},
                    "sharded": {"eligible": self.mesh is not None},
                },
            },
        }
        if mode is not None:
            axes["mode"] = {
                "winner": mode,
                "reason": decision.get("mode", {}).get("reason"),
                "inputs": {
                    "n_overlap_pairs": n_pairs,
                    "exact_pair_budget": spec.exact_pair_budget,
                },
                "candidates": {
                    "exact": {
                        "eligible": spec.hg.n_hyperedges < (1 << 21),
                        "predicted_pairs": n_pairs,
                    },
                    "sample": {
                        "eligible": True,
                        "predicted_pairs": int(spec.n_samples),
                    },
                },
            }
        return {
            "config": resolved,
            "decision": decision,
            "mode": mode,
            "axes": axes,
        }

    def run(self, spec, **overrides: Any) -> Result:
        """Execute an ``AlgorithmSpec`` at the configured design point.

        ``overrides`` are per-call ``ExecutionConfig`` replacements
        (e.g. ``engine.run(spec, max_iters=8)``).
        """
        resolved, plan, decision = self.resolve(spec, **overrides)
        name = getattr(spec, "name", "anonymous")

        if resolved.representation == "clique":
            t0 = time.perf_counter()
            with maybe_span(
                self.tracer, "engine.run", cat="execute",
                algorithm=name, representation="clique",
            ):
                graph = to_graph(spec.hg0)
                value = spec.clique_program(graph)
            decision = {**decision, "measured": {
                "wall_s": time.perf_counter() - t0,
            }}
            return Result(
                value=value,
                config=resolved,
                representation="clique",
                backend="local",
                decision=decision,
            )

        if resolved.backend == "local":
            fn = compute_jit if resolved.jit else compute
            delivery = (
                self._delivery_layouts(spec.hg0)
                if resolved.delivery == "pallas_fused"
                else None
            )
            t0 = time.perf_counter()
            with maybe_span(
                self.tracer, "engine.run", cat="execute",
                algorithm=name, backend="local",
                delivery=resolved.delivery,
            ) as sp:
                if resolved.checkpoint_every is not None:
                    from repro.faults.checkpoint import checkpointed_compute

                    out = checkpointed_compute(
                        spec.hg0,
                        resolved.max_iters,
                        spec.initial_msg,
                        spec.v_program,
                        spec.he_program,
                        every=resolved.checkpoint_every,
                        ckpt_dir=resolved.checkpoint_dir,
                        return_stats=resolved.collect_stats,
                        delivery=delivery,
                        jit=resolved.jit,
                        tracer=self.tracer,
                        metrics=self.metrics,
                        fault_injector=self.fault_injector,
                    )
                else:
                    out = fn(
                        spec.hg0,
                        max_iters=resolved.max_iters,
                        initial_msg=spec.initial_msg,
                        v_program=spec.v_program,
                        he_program=spec.he_program,
                        return_stats=resolved.collect_stats,
                        delivery=delivery,
                    )
                t1 = time.perf_counter()
                jax.block_until_ready(out)
                t2 = time.perf_counter()
                if sp is not None:
                    sp.args["device_wait_s"] = t2 - t1
            stats = None
            if resolved.collect_stats:
                out, stats = out
            decision = {**decision, "measured": self._measured(
                spec, resolved, t0, t1, t2, stats, delivery
            )}
            return Result(
                value=spec.extract(out),
                config=resolved,
                representation="bipartite",
                backend="local",
                superstep_stats=stats,
                decision=decision,
            )

        from repro.core.distributed import distributed_compute

        t0 = time.perf_counter()
        with maybe_span(
            self.tracer, "engine.run", cat="execute",
            algorithm=name, backend=resolved.backend,
            delivery=resolved.delivery, n_parts=plan.n_parts,
        ) as sp:
            if resolved.checkpoint_every is not None:
                from repro.faults.checkpoint import (
                    checkpointed_distributed_compute,
                )

                out = checkpointed_distributed_compute(
                    spec.hg0,
                    plan,
                    self.mesh,
                    resolved.max_iters,
                    spec.initial_msg,
                    spec.v_program,
                    spec.he_program,
                    every=resolved.checkpoint_every,
                    ckpt_dir=resolved.checkpoint_dir,
                    axis=resolved.axis,
                    backend=resolved.backend,
                    delivery=resolved.delivery,
                    return_stats=resolved.collect_stats,
                    tracer=self.tracer,
                    metrics=self.metrics,
                    fault_injector=self.fault_injector,
                )
            else:
                out = distributed_compute(
                    spec.hg0,
                    plan,
                    self.mesh,
                    max_iters=resolved.max_iters,
                    initial_msg=spec.initial_msg,
                    v_program=spec.v_program,
                    he_program=spec.he_program,
                    axis=resolved.axis,
                    backend=resolved.backend,
                    return_stats=resolved.collect_stats,
                    delivery=resolved.delivery,
                )
            t1 = time.perf_counter()
            jax.block_until_ready(out)
            t2 = time.perf_counter()
            if sp is not None:
                sp.args["device_wait_s"] = t2 - t1
        stats = None
        if resolved.collect_stats:
            out, stats = out
        # No measured delivery bytes here: the distributed builders own
        # their per-shard layouts inside shard_map.
        decision = {**decision, "measured": self._measured(
            spec, resolved, t0, t1, t2, stats, None
        )}
        return Result(
            value=spec.extract(out),
            config=resolved,
            representation="bipartite",
            backend=resolved.backend,
            partition=plan.name,
            partition_stats=plan.stats,
            superstep_stats=stats,
            decision=decision,
        )

    @staticmethod
    def _measured(spec, resolved, t0, t1, t2, stats, delivery) -> dict:
        """The measured counterpart of the predicted ``decision``: wall
        and device time, executed supersteps (when stats were
        collected), and actual per-class delivery bytes for a built
        fused layout — what ``obs.calibrate`` compares against the
        cost models' predictions."""
        measured: dict[str, Any] = {
            "wall_s": t2 - t0,
            "dispatch_s": t1 - t0,
            "device_wait_s": t2 - t1,
            "max_iters": resolved.max_iters,
        }
        if stats is not None:
            measured["supersteps"] = executed_supersteps(
                stats, resolved.max_iters
            )
        if delivery is not None:
            measured["delivery"] = delivery_traffic_pair(
                delivery, message_width_bytes(spec.initial_msg)
            )
        return measured

    # -- compile-once serve-many --------------------------------------------

    def compile(self, spec, **overrides: Any):
        """Resolve the design point ONCE and return a ``CompiledAlgorithm``.

        The serve-many half of the facade: the returned handle's
        ``run(hg)`` executes with zero retracing for any hypergraph in
        the same shape bucket (sizes padded to bounded power-of-two
        buckets; executables cached in this Engine's LRU), and
        ``run_batch(queries)`` vmaps over the spec's query axis
        (``AlgorithmSpec.bind_query``) so one compile serves B requests.

        >>> compiled = engine.compile(shortest_paths_spec(hg, 0))
        >>> compiled.run_batch(np.arange(8))      # 8 sources, 1 compile
        >>> engine.cache_stats()                   # hits/misses/traces

        Compiled execution is always jitted and always bipartite (clique
        constant-folding produces a host-side program with nothing to
        cache); ``overrides`` are per-compile ``ExecutionConfig``
        replacements, as for ``run``.
        """
        from repro.core.serving import CompiledAlgorithm

        if isinstance(spec, AnalyticsSpec):
            raise TypeError(
                "Engine.compile serves iterative AlgorithmSpecs; batch "
                "analytics runs one-shot through Engine.analyze/submit"
            )
        probe = (
            dataclasses.replace(self.config, **overrides)
            if overrides
            else self.config
        )
        if probe.representation == "clique":
            raise ValueError(
                "Engine.compile serves the bipartite representation only: "
                "the clique path runs a host-side clique_program with no "
                "executable to cache; use Engine.run for one-shot clique "
                "execution"
            )
        overrides = {**overrides, "representation": "bipartite"}
        resolved, plan, decision = self.resolve(spec, **overrides)
        return CompiledAlgorithm(
            engine=self,
            spec=spec,
            config=resolved,
            decision=decision,
            _plan0=plan,
        )

    def submit(self, spec, **overrides: Any):
        """THE unified entry point: dispatch on spec type.

        ``AlgorithmSpec`` -> iterative superstep execution (``run``),
        ``AnalyticsSpec`` -> batch analytics (``analyze``).  ``run`` and
        ``analyze`` remain as thin, typed sugar over this dispatch.
        """
        if isinstance(spec, AnalyticsSpec):
            return self.analyze(spec, **overrides)
        from repro.algorithms.spec import AlgorithmSpec

        if isinstance(spec, AlgorithmSpec):
            return self.run(spec, **overrides)
        raise TypeError(
            "Engine.submit takes an AlgorithmSpec or AnalyticsSpec, got "
            f"{type(spec).__name__}"
        )

    def cache_stats(self) -> dict:
        """Executable-cache observability: benchmarks assert amortization.

        ``traces`` counts actual executable tracings (a retrace with a
        warm cache is a bug the serving tests assert against);
        ``hits``/``misses`` count ``CompiledAlgorithm`` lookups in this
        Engine's LRU; ``evictions`` counts LRU capacity drops (an
        eviction storm on a serving fleet means the bucket set outgrew
        ``exec_cache_size``).  ``entry_shapes`` describes each live
        entry's bucket (algorithm, padded dims, batch bucket, design
        point) so an operator can see WHAT the cache holds, not just how
        much; ``disk`` mirrors the attached persistent store's counters
        (``None`` without one).
        """
        return {
            "entries": len(self._exec_cache),
            "capacity": self.exec_cache_size,
            "hits": self._cache_hits,
            "misses": self._cache_misses,
            "evictions": self._cache_evictions,
            "traces": self._trace_count,
            "entry_shapes": [
                dict(meta) for meta in self._exec_meta.values()
            ],
            "disk": (
                self.disk_cache.stats()
                if self.disk_cache is not None
                else None
            ),
        }

    def _note_trace(self) -> None:
        """Side-effecting trace probe: runs only while jax traces an
        executable body, so the counter exposes real retraces."""
        self._trace_count += 1

    def _executable_for(self, key, build: Callable[[], Any], meta=None):
        """LRU lookup of a compiled executable by shape signature.

        ``meta``: a small human-readable bucket summary recorded per
        entry for ``cache_stats()["entry_shapes"]``."""
        cache = self._exec_cache
        if key in cache:
            cache.move_to_end(key)
            self._cache_hits += 1
            return cache[key]
        self._cache_misses += 1
        if self.tracer is None:
            exe = build()
        else:
            span_args = {
                k: v
                for k, v in (meta or {}).items()
                if isinstance(v, (str, int, float, bool))
            }
            with self.tracer.span(
                "engine.build_executable", cat="compile", **span_args
            ):
                exe = build()
        if self.disk_cache is not None:
            exe = self.disk_cache.wrap(self, key, exe)
        cache[key] = exe
        if meta is not None:
            self._exec_meta[key] = meta
        while len(cache) > self.exec_cache_size:
            evicted, _ = cache.popitem(last=False)
            self._exec_meta.pop(evicted, None)
            self._cache_evictions += 1
        return exe

    # -- batch analytics -----------------------------------------------------

    def _resolve_analytics(
        self, spec: "AnalyticsSpec", cfg: ExecutionConfig, n_pairs: int
    ) -> tuple[ExecutionConfig, str | None, dict]:
        """Resolve the batch design point given the overlap-pair count.

        Returns ``(resolved_config, mode, decision)``.  Same cost-model
        seam as ``resolve``: representation weighs the (dual) clique
        expansion against the incidence via ``clique_edge_budget``;
        the kernel axis is ``select_intersect_kernel``; the backend
        tiles pair blocks across the mesh when one is available.
        """
        from repro.motifs import select_intersect_kernel

        decision: dict[str, Any] = {}

        if cfg.intersect_kernel == "auto":
            kernel, kernel_why = select_intersect_kernel(spec.hg)
        else:
            kernel = cfg.intersect_kernel
            kernel_why = {"reason": "explicitly configured"}
        decision["kernel"] = kernel_why

        if cfg.representation == "auto":
            # The paper's §IV-A tradeoff, applied to the *dual*: clique
            # expansion of the dual materializes every pairwise
            # intersection; choose it only while the expansion stays
            # within the same edge budget the iterative path uses.
            dual_edges = 2 * n_pairs
            budget = cfg.clique_edge_budget * max(spec.hg.nnz, 1)
            representation = "clique" if dual_edges <= budget else "bipartite"
            decision["representation"] = {
                "dual_clique_edges": dual_edges,
                "bipartite_edges": int(spec.hg.nnz),
                "edge_budget": float(budget),
                "reason": (
                    "dual expansion within edge budget: materialize "
                    "pair intersections"
                    if representation == "clique"
                    else "dual expansion exceeds edge budget: derive "
                    "intersections from the incidence"
                ),
            }
        else:
            representation = cfg.representation
            decision["representation"] = {"reason": "explicitly configured"}

        if cfg.backend == "replicated":
            raise ValueError(
                "backend='replicated' does not apply to batch analytics "
                "(no replicated superstep state); use 'sharded' to tile "
                "pair blocks across the mesh, or 'local'"
            )
        if cfg.backend == "sharded" and self.mesh is None:
            raise ValueError(
                "backend='sharded' needs a mesh; construct "
                "Engine(mesh=...) or use backend='local'"
            )
        if cfg.backend in ("local", "sharded"):
            backend = cfg.backend
            decision["backend"] = {"reason": "explicitly configured"}
        elif self.mesh is not None:
            backend = "sharded"
            decision["backend"] = {
                "reason": "mesh available: tile hyperedge-pair blocks "
                "across it"
            }
        else:
            backend = "local"
            decision["backend"] = {"reason": "no mesh available"}

        mode: str | None = None
        if spec.task == "hmotif_census":
            enumerable = spec.hg.n_hyperedges < (1 << 21)
            if spec.mode != "auto":
                mode = spec.mode
                decision["mode"] = {"reason": "explicitly configured"}
            else:
                mode = (
                    "exact"
                    if enumerable and n_pairs <= spec.exact_pair_budget
                    else "sample"
                )
                decision["mode"] = {
                    "n_overlap_pairs": n_pairs,
                    "exact_pair_budget": spec.exact_pair_budget,
                    "reason": (
                        "overlap graph within exact budget"
                        if mode == "exact"
                        else "overlap graph too large: sample linked pairs"
                    ),
                }
            if mode == "exact" and not enumerable:
                raise ValueError(
                    "mode='exact' needs n_hyperedges < 2^21; use "
                    "mode='sample'"
                )

        resolved = dataclasses.replace(
            cfg,
            representation=representation,
            backend=backend,
            intersect_kernel=kernel,
            partition_strategy="none",
        )
        return resolved, mode, decision

    def resolve_analytics(
        self, spec: "AnalyticsSpec", **overrides: Any
    ) -> tuple[ExecutionConfig, str | None, dict]:
        """Resolve every ``"auto"`` analytics choice WITHOUT executing.

        Runs the host-side overlap-pair discovery (the quantity every
        cost term turns on) but no intersection kernels.
        """
        from repro.motifs import overlap_pairs_with_counts

        cfg = (
            dataclasses.replace(self.config, **overrides)
            if overrides
            else self.config
        )
        pairs, _ = overlap_pairs_with_counts(spec.hg)
        return self._resolve_analytics(spec, cfg, len(pairs))

    def analyze(self, spec: "AnalyticsSpec", **overrides: Any) -> "AnalyticsResult":
        """Execute a batch ``AnalyticsSpec`` at the configured design
        point — the batch-mode twin of ``run``.

        >>> res = Engine().analyze(AnalyticsSpec(hg))
        >>> res.value.counts, res.kernel, res.decision
        """
        from repro import motifs

        cfg = (
            dataclasses.replace(self.config, **overrides)
            if overrides
            else self.config
        )
        # Overlap-pair discovery is the O(sum deg^2) host-side
        # preprocessing step; skip it when nothing consumes it — an
        # explicit pair batch on a pinned bipartite representation
        # needs only the kernel.
        need_pairs = (
            spec.task == "hmotif_census"
            or spec.pairs is None
            or cfg.representation in ("auto", "clique")
        )
        pairs = n_shared = None
        if need_pairs:
            pairs, n_shared = motifs.overlap_pairs_with_counts(spec.hg)
        resolved, mode, decision = self._resolve_analytics(
            spec, cfg, len(pairs) if pairs is not None else 0
        )
        index = motifs.build_index(spec.hg, resolved.intersect_kernel)
        mesh = self.mesh if resolved.backend == "sharded" else None
        pair_sizes = (
            motifs.materialize_pair_sizes(spec.hg, pairs, n_shared)
            if resolved.representation == "clique"
            else None
        )

        if spec.task == "pair_intersections":
            if spec.pairs is not None:
                ea = np.asarray(spec.pairs[0], np.int64)
                eb = np.asarray(spec.pairs[1], np.int64)
            else:
                ea, eb = pairs[:, 0], pairs[:, 1]
            if pair_sizes is not None:
                e = np.int64(spec.hg.n_hyperedges)
                lo, hi = np.minimum(ea, eb), np.maximum(ea, eb)
                sizes = motifs.pair_sizes_lookup(pair_sizes, lo * e + hi)
                # The materialized table holds overlapping a < b pairs
                # only; |e ∩ e| = |e| must not fall through to 0.
                self_pair = ea == eb
                if self_pair.any():
                    sizes = np.where(
                        self_pair, index.cardinalities()[ea], sizes
                    )
            else:
                sizes = motifs.batch_intersections(
                    index, ea, eb, tile=spec.tile, mesh=mesh,
                    axis=resolved.axis,
                ).astype(np.int64)
            value: Any = (np.stack([ea, eb], axis=1), sizes)
        elif mode == "exact":
            value = motifs.exact_census(
                spec.hg, index=index, tile=spec.tile, mesh=mesh,
                axis=resolved.axis, pair_sizes=pair_sizes,
                og=motifs.build_overlap_graph(spec.hg, pairs),
            )
        else:
            value = motifs.sampled_census(
                spec.hg, spec.n_samples, seed=spec.seed,
                confidence=spec.confidence, index=index, tile=spec.tile,
                mesh=mesh, axis=resolved.axis,
                og=motifs.build_overlap_graph(spec.hg, pairs),
                pair_sizes=pair_sizes,
            )
        return AnalyticsResult(
            value=value,
            config=resolved,
            representation=resolved.representation,
            kernel=resolved.intersect_kernel,
            backend=resolved.backend,
            mode=mode,
            decision=decision,
        )
