"""Distributed MESH executor: supersteps under ``jax.shard_map``.

Two backends (DESIGN.md §6), both consuming a ``PartitionPlan``'s padded
edge shards over the mesh's ``data`` axis:

* ``replicated`` — entity state replicated on every partition; each
  partition reduces its local edges into a full-size message buffer and a
  single ``psum``/``pmax``/``pmin`` merges.  One collective of O(N·d) per
  half-superstep; best for small states (apache/dblp regime).

* ``sharded`` — entity state sharded by id range over the ``data`` axis;
  per half-superstep: ``all_gather`` of the sender side's outgoing
  messages, local gather + segment-reduce, then ``psum_scatter`` of the
  destination buffer (sum monoid) or ``pmax/pmin`` + slice.  State memory
  scales 1/P; required for the friendster/orkut regime.

Feature-dim (``model`` axis) sharding composes transparently: every array
here is sharded on its *trailing* feature dim by pjit outside the
shard_map, since gathers/reduces act only on the leading entity dim.

Correctness contract (tested): for any plan and any monoid program pair,
both backends equal the single-device engine bit-for-bit in fp32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core.api import Program, constant_initial_msg
from repro.core.engine import _as_out, batch_halting_scan
from repro.core.hypergraph import HyperGraph
from repro.partition.base import PartitionPlan

Pytree = Any


def _pad_to(n: int, parts: int) -> int:
    return -(-n // parts) * parts


@dataclasses.dataclass(frozen=True)
class DistContext:
    """Static facts the distributed superstep needs.

    Real (unpadded) entity counts are NOT static: they flow through the
    supersteps as traced int32 scalars so one compiled executable serves
    every hypergraph in a shape bucket (activity stats and halting mask
    padding slots dynamically).
    """

    axis: str                  # mesh axis name carrying edge partitions
    n_parts: int
    nv_pad: int
    ne_pad: int


def _local_combine(program: Program, rows, dst_ids, num_dst, live):
    """Per-partition combine of message rows into a full-size buffer."""
    if program.reducer is not None:
        raise NotImplementedError(
            "custom (Seq) reducers are local-engine only; distribute the "
            "sum-decomposed form instead (see pagerank_entropy)."
        )

    def one(leaf):
        monoid = program.monoid_for(leaf)
        if live is not None:
            ident = monoid.identity(leaf.dtype)
            shape = (live.shape[0],) + (1,) * (leaf.ndim - 1)
            leaf = jnp.where(live.reshape(shape), leaf, ident)
        return monoid.segment(leaf, dst_ids, num_segments=num_dst)

    return jax.tree.map(one, rows)


def _cross_combine(program: Program, partials, axis: str):
    """Merge per-partition partial aggregates across the mesh axis with the
    same monoid the local combine used."""

    def one(leaf):
        monoid = program.monoid_for(leaf)
        if monoid.name in ("sum", "or"):
            return jax.lax.psum(leaf, axis)
        if monoid.name == "max":
            return jax.lax.pmax(leaf, axis)
        if monoid.name == "min":
            return jax.lax.pmin(leaf, axis)
        raise NotImplementedError(monoid.name)

    return jax.tree.map(one, partials)


def _cross_combine_scatter(program: Program, partials, axis: str,
                           n_parts: int):
    """Merge partials and keep only this partition's id-range block — a
    true reduce-scatter for every monoid.

    sum -> ``psum_scatter`` (XLA's fused reduce-scatter); max/min have
    no fused collective, so reduce-scatter is built from its definition:
    ``all_to_all`` transposes the per-partition blocks (each device
    receives every device's copy of *its* block — O(n) bytes moved, vs
    the O(n log P) all-reduce a pmax/pmin+slice pays) and a local
    ``max``/``min`` over the received stack finishes the reduction.
    """

    def one(leaf):
        monoid = program.monoid_for(leaf)
        if monoid.name in ("sum", "or"):
            return jax.lax.psum_scatter(
                leaf, axis, scatter_dimension=0, tiled=True
            )
        if monoid.name not in ("max", "min"):
            raise NotImplementedError(monoid.name)
        block = leaf.shape[0] // n_parts
        chunks = leaf.reshape((n_parts, block) + leaf.shape[1:])
        swapped = jax.lax.all_to_all(
            chunks, axis, split_axis=0, concat_axis=0
        )
        reduce = jnp.max if monoid.name == "max" else jnp.min
        return reduce(swapped.reshape((n_parts, block) + leaf.shape[1:]),
                      axis=0)

    return jax.tree.map(one, partials)


def _deliver_local(program, out_msg_full, active_full, src, dst, mask,
                   num_dst, layout=None):
    """gather -> transform -> mask -> local segment combine, over one
    partition's padded edge shard.

    ``layout``: optional per-shard ``DeliveryLayout`` — routes the
    monoid path through the fused delivery kernel (dst-sorted CSR over
    THIS shard's edges; the shard mask is folded into the layout), same
    as the local engine's ``delivery='pallas_fused'`` design point.
    """
    if (layout is not None and program.reducer is None
            and program.edge_transform is None):
        from repro.kernels.deliver import fused_deliver

        return fused_deliver(out_msg_full, active_full, layout, program)
    rows = jax.tree.map(
        lambda leaf: jnp.take(leaf, src, axis=0), out_msg_full
    )
    if program.edge_transform is not None:
        rows = program.edge_transform(rows, None)
    live = mask.astype(bool)
    if active_full is not None:
        live = live & jnp.take(active_full, src, axis=0)
    return _local_combine(program, rows, dst, num_dst, live)


# --------------------------------------------------------------------------
# replicated-state backend
# --------------------------------------------------------------------------

def _superstep_replicated(ctx: DistContext, hg_meta, programs, degs,
                          step, v_attr, he_attr, msg_to_v,
                          src, dst, mask, nv_real, ne_real,
                          delivery=(None, None)):
    v_program, he_program = programs
    v_deg, he_card = degs
    fwd_layout, bwd_layout = delivery
    v_ids = jnp.arange(ctx.nv_pad, dtype=jnp.int32)
    he_ids = jnp.arange(ctx.ne_pad, dtype=jnp.int32)

    v_out = _as_out(
        v_program.procedure(step, v_ids, v_attr, msg_to_v, v_deg),
        v_attr, ctx.nv_pad,
    )
    partial_he = _deliver_local(
        v_program, v_out.msg, v_out.active, src, dst, mask, ctx.ne_pad,
        layout=fwd_layout,
    )
    msg_to_he = _cross_combine(v_program, partial_he, ctx.axis)

    he_out = _as_out(
        he_program.procedure(step + 1, he_ids, he_attr, msg_to_he, he_card),
        he_attr, ctx.ne_pad,
    )
    partial_v = _deliver_local(
        he_program, he_out.msg, he_out.active, dst, src, mask, ctx.nv_pad,
        layout=bwd_layout,
    )
    msg_to_v_next = _cross_combine(he_program, partial_v, ctx.axis)

    def count(active, n_pad, n_real):
        # Activity over *real* entities only: padding slots must not
        # leak into the observable stats (or the halting decision).
        # ``n_real`` may be traced, so mask instead of slicing.
        live = jnp.arange(n_pad, dtype=jnp.int32) < n_real
        if active is not None:
            live = live & active
        return live.sum().astype(jnp.int32)

    stats = (
        count(v_out.active, ctx.nv_pad, nv_real),
        count(he_out.active, ctx.ne_pad, ne_real),
    )
    return v_out.attr, he_out.attr, msg_to_v_next, stats


# --------------------------------------------------------------------------
# sharded-state backend
# --------------------------------------------------------------------------

def _superstep_sharded(ctx: DistContext, hg_meta, programs, degs,
                       step, v_attr_sh, he_attr_sh, msg_to_v_sh,
                       src, dst, mask, nv_real, ne_real,
                       delivery=(None, None)):
    """State arrays carry only this partition's id-range block
    (``[n/P, ...]``); ids are globalized with the axis index."""
    v_program, he_program = programs
    v_deg_sh, he_card_sh = degs
    fwd_layout, bwd_layout = delivery
    p = jax.lax.axis_index(ctx.axis)
    v_block = ctx.nv_pad // ctx.n_parts
    he_block = ctx.ne_pad // ctx.n_parts
    v_ids = p * v_block + jnp.arange(v_block, dtype=jnp.int32)
    he_ids = p * he_block + jnp.arange(he_block, dtype=jnp.int32)

    v_out = _as_out(
        v_program.procedure(step, v_ids, v_attr_sh, msg_to_v_sh, v_deg_sh),
        v_attr_sh, v_block,
    )
    # sender messages (and activity) must be visible to every partition
    # whose edges reference them -> all_gather over the partition axis.
    v_msg_full = jax.tree.map(
        lambda leaf: jax.lax.all_gather(
            leaf, ctx.axis, axis=0, tiled=True
        ),
        v_out.msg,
    )
    v_act_full = (
        jax.lax.all_gather(v_out.active, ctx.axis, axis=0, tiled=True)
        if v_out.active is not None
        else None
    )
    partial_he = _deliver_local(
        v_program, v_msg_full, v_act_full, src, dst, mask, ctx.ne_pad,
        layout=fwd_layout,
    )
    msg_to_he_sh = _cross_combine_scatter(
        v_program, partial_he, ctx.axis, ctx.n_parts
    )

    he_out = _as_out(
        he_program.procedure(
            step + 1, he_ids, he_attr_sh, msg_to_he_sh, he_card_sh
        ),
        he_attr_sh, he_block,
    )
    he_msg_full = jax.tree.map(
        lambda leaf: jax.lax.all_gather(
            leaf, ctx.axis, axis=0, tiled=True
        ),
        he_out.msg,
    )
    he_act_full = (
        jax.lax.all_gather(he_out.active, ctx.axis, axis=0, tiled=True)
        if he_out.active is not None
        else None
    )
    partial_v = _deliver_local(
        he_program, he_msg_full, he_act_full, dst, src, mask, ctx.nv_pad,
        layout=bwd_layout,
    )
    msg_to_v_next_sh = _cross_combine_scatter(
        he_program, partial_v, ctx.axis, ctx.n_parts
    )

    def count(active, ids, n_real):
        # Real-entity activity, globalized with one psum so every
        # partition carries the same (replicated) stat.
        real = ids < n_real
        local = (
            real if active is None else (active & real)
        ).sum().astype(jnp.int32)
        return jax.lax.psum(local, ctx.axis)

    stats = (
        count(v_out.active, v_ids, nv_real),
        count(he_out.active, he_ids, ne_real),
    )
    return v_out.attr, he_out.attr, msg_to_v_next_sh, stats


# --------------------------------------------------------------------------
# fused-delivery shard layouts
# --------------------------------------------------------------------------

def _stack_layouts(layouts):
    """Stack per-partition ``DeliveryLayout``s along a new leading axis
    (the shard_map operand form).  Callers guarantee uniform shapes
    (one shared class plan, harmonized per-class row/edge/remainder
    pads); the static grid extents (``class_max_blocks``) and the
    residual-skip count (``rem_nnz``) take the max so one kernel
    serves every shard."""
    from repro.kernels.deliver import DeliveryLayout

    ref = layouts[0]
    n_classes = ref.n_classes
    stack = lambda get: jnp.stack([get(l) for l in layouts])
    per_class = lambda get: tuple(
        stack(lambda l, c=c: get(l, c)) for c in range(n_classes)
    )
    return DeliveryLayout(
        class_ell=per_class(lambda l, c: l.class_ell[c]),
        class_src=per_class(lambda l, c: l.class_src[c]),
        class_dst=per_class(lambda l, c: l.class_dst[c]),
        class_bounds=per_class(lambda l, c: l.class_bounds[c]),
        inv_perm=stack(lambda l: l.inv_perm),
        rem_src=stack(lambda l: l.rem_src),
        rem_dst=stack(lambda l: l.rem_dst),
        n_src=ref.n_src,
        n_dst=ref.n_dst,
        nnz=ref.nnz,
        rem_nnz=max(l.rem_nnz for l in layouts),
        class_widths=ref.class_widths,
        class_rows=ref.class_rows,
        block_n=ref.block_n,
        class_block_e=ref.class_block_e,
        class_max_blocks=tuple(
            max(l.class_max_blocks[c] for l in layouts)
            for c in range(n_classes)
        ),
    )


def build_shard_delivery(shard_src, shard_dst, shard_mask,
                         nv_pad: int, ne_pad: int):
    """Per-shard fused-delivery layouts for both half-superstep
    directions, over a plan's ``[n_parts, shard_len]`` edge shards.

    Each shard gets its own dst-sorted degree-class layout over the
    *full* padded entity range (both backends combine into full-size
    buffers before their cross-partition collective).  Class boundaries
    and widths are planned ONCE per direction from the merged per-shard
    live-degree histograms — every (shard, destination) pair is a row
    the plan must place, so the DP sees the true row population — and
    the remaining data-dependent shapes (per-class row counts, edge
    lengths, remainder pad) are harmonized to per-class maxima across
    shards.  Cheap bincounts, no throwaway layout build; the resulting
    layouts stack into one shard_map operand.
    """
    from repro.kernels.deliver import (
        build_delivery_layout,
        classify_degrees,
        plan_degree_classes,
    )
    from repro.kernels.deliver.layout import (
        _PAD_FLOOR, _ROW_FLOOR, _pow2_at_least,
    )

    shard_src = np.asarray(shard_src)
    shard_dst = np.asarray(shard_dst)
    shard_mask = np.asarray(shard_mask)
    n_parts = shard_src.shape[0]

    def direction(srcs, dsts, n_src, n_dst):
        live = shard_mask != 0
        degs = [
            np.bincount(dsts[p][live[p]], minlength=max(n_dst, 1))[:n_dst]
            for p in range(n_parts)
        ]
        plan = plan_degree_classes(
            np.concatenate(degs), int(live.sum())
        )
        widths = np.asarray(plan.widths, np.int64)
        n_classes = len(widths)
        rows_max = np.zeros(n_classes, np.int64)
        nnz_max = np.zeros(n_classes, np.int64)
        rem_max = 0
        for deg in degs:
            cls = classify_degrees(deg, widths)
            pos = cls >= 0
            rows = np.bincount(cls[pos], minlength=n_classes)
            nnz_c = np.bincount(
                cls[pos], weights=deg[pos].astype(np.float64),
                minlength=n_classes,
            ).astype(np.int64)
            np.maximum(rows_max, rows, out=rows_max)
            np.maximum(nnz_max, nnz_c, out=nnz_max)
            spill = int(
                np.maximum(deg[pos] - widths[cls[pos]], 0).sum()
            )
            rem_max = max(rem_max, spill)
        class_rows_pad = tuple(
            _pow2_at_least(max(int(r), 1), _ROW_FLOOR) for r in rows_max
        )
        rem_pad = _pow2_at_least(max(rem_max, 1), _PAD_FLOOR)
        final = [
            build_delivery_layout(
                srcs[p], dsts[p], shard_mask[p], n_src, n_dst,
                plan=plan,
                class_rows_pad=class_rows_pad,
                class_nnz_pad=tuple(int(n) for n in nnz_max),
                rem_pad_to=rem_pad,
            )
            for p in range(n_parts)
        ]
        return _stack_layouts(final)

    return (
        direction(shard_src, shard_dst, nv_pad, ne_pad),
        direction(shard_dst, shard_src, ne_pad, nv_pad),
    )


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def _pad_leading(x: jnp.ndarray, n_pad: int) -> jnp.ndarray:
    pad = n_pad - x.shape[0]
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
    )


def build_distributed_runner(
    mesh: Mesh,
    ctx: DistContext,
    v_program: Program,
    he_program: Program,
    max_iters: int,
    backend: str = "replicated",
    batch: int | None = None,
    resumable: bool = False,
):
    """Build the ``shard_map``-wrapped superstep scan for one design point.

    Returns a traceable callable
    ``(v_attr, he_attr, msg0, v_deg, he_card, shard_src, shard_dst,
    shard_mask, nv_real, ne_real, delivery) -> (v_attr, he_attr,
    v_trace, he_trace)`` over bucket-padded full-size arrays
    (``[nv_pad, ...]`` state, ``[n_parts, shard_len]`` edge shards).
    ``nv_real`` / ``ne_real`` are traced int32 scalars, so the same
    runner — and therefore the same compiled executable — serves every
    hypergraph whose padded shapes match (the ``Engine.compile`` serving
    path); ``distributed_compute`` is the eager single-shot wrapper.

    ``delivery``: ``None`` (reference path) or the
    ``build_shard_delivery`` pair of stacked per-shard layouts — the
    fused delivery design point, identical on both backends (each
    partition's local combine runs fused over its own edge block).

    ``batch``: when set, state/msg operands carry a leading query batch
    dim ``[batch, ...]`` and the runner is BATCH-AWARE (mirroring the
    local ``compute_batch``): the per-iteration superstep vmaps over the
    query axis INSIDE the ``shard_map`` scan, so halting stays a real
    ``lax.cond`` on ``all(halted)`` across the batch — a
    skewed-convergence batch stops at its slowest query instead of
    paying ``max_iters``.  Returns ``(v_attr_b, he_attr_b, v_trace
    [max_iters, batch], he_trace, supersteps_executed)``; per-query
    results and stats are bitwise those of the unbatched runner (halted
    queries freeze by selection — exactly what the vmapped
    ``cond``-as-``select`` would have computed — and report zero
    activity).
    """
    if backend == "replicated":
        state_spec = P()
        superstep = _superstep_replicated
    elif backend == "sharded":
        state_spec = P(ctx.axis)
        superstep = _superstep_sharded
    else:
        raise ValueError(backend)
    deg_spec = state_spec
    # Batched state shards the ENTITY dim, which sits after the query dim.
    batch_state_spec = (
        state_spec if backend == "replicated" else P(None, ctx.axis)
    )
    edge_spec = P(ctx.axis)  # leading dim = n_parts, one row per partition
    programs = (v_program, he_program)

    def _body(superstep, degs_local, src, dst, mask, nv_real, ne_real,
              delivery_local):
        # The per-iteration scan body — ONE definition shared by the
        # single-shot and resumable runners, so a chunked (checkpointed)
        # distributed run agrees bitwise with an uninterrupted one.
        def body(carry, _):
            step, v_a, he_a, msg, halted = carry

            def go(args):
                step, v_a, he_a, msg = args
                nv_a, nhe_a, nmsg, stats = superstep(
                    ctx, None, programs, degs_local,
                    step, v_a, he_a, msg, src, dst, mask,
                    nv_real, ne_real, delivery_local,
                )
                v_act, he_act = stats
                return nv_a, nhe_a, nmsg, (v_act + he_act) == 0, stats

            def skip(args):
                _, v_a, he_a, msg = args
                zero = jnp.asarray(0, jnp.int32)
                return v_a, he_a, msg, jnp.asarray(True), (zero, zero)

            nv_a, nhe_a, nmsg, halted2, stats = jax.lax.cond(
                halted, skip, go, (step, v_a, he_a, msg)
            )
            return (step + 2, nv_a, nhe_a, nmsg, halted | halted2), stats

        return body

    def run(v_attr, he_attr, msg0, v_deg, he_card, src, dst, mask,
            nv_real, ne_real, delivery):
        # shard_map gives each device its [1, shard_len] edge row; squeeze.
        src, dst, mask = src[0], dst[0], mask[0]
        delivery_local = (
            jax.tree.map(lambda a: a[0], delivery)
            if delivery is not None
            else (None, None)
        )
        body = _body(superstep, (v_deg, he_card), src, dst, mask,
                     nv_real, ne_real, delivery_local)
        init = (
            jnp.asarray(0, jnp.int32), v_attr, he_attr, msg0,
            jnp.asarray(False),
        )
        (_, v_a, he_a, _, _), (v_trace, he_trace) = jax.lax.scan(
            body, init, None, length=max_iters
        )
        return v_a, he_a, v_trace, he_trace

    def run_resumable(v_attr, he_attr, msg, halted, step0, v_deg, he_card,
                      src, dst, mask, nv_real, ne_real, delivery):
        # The checkpoint/resume seam: scan carry in, scan carry out.
        src, dst, mask = src[0], dst[0], mask[0]
        delivery_local = (
            jax.tree.map(lambda a: a[0], delivery)
            if delivery is not None
            else (None, None)
        )
        body = _body(superstep, (v_deg, he_card), src, dst, mask,
                     nv_real, ne_real, delivery_local)
        init = (step0, v_attr, he_attr, msg, halted)
        (step, v_a, he_a, msg, halted), (v_trace, he_trace) = jax.lax.scan(
            body, init, None, length=max_iters
        )
        return v_a, he_a, msg, halted, step, v_trace, he_trace

    def run_batch(v_attr_b, he_attr_b, msg0_b, v_deg, he_card, src, dst,
                  mask, nv_real, ne_real, delivery):
        src, dst, mask = src[0], dst[0], mask[0]
        delivery_local = (
            jax.tree.map(lambda a: a[0], delivery)
            if delivery is not None
            else (None, None)
        )
        degs_local = (v_deg, he_card)

        def one_step(step, v_a, he_a, msg):
            # The superstep reads only shared structure besides the
            # per-query state; collectives batch elementwise under vmap.
            return superstep(
                ctx, None, programs, degs_local,
                step, v_a, he_a, msg, src, dst, mask,
                nv_real, ne_real, delivery_local,
            )

        batched_step = jax.vmap(one_step, in_axes=(None, 0, 0, 0))

        # The halting scaffold (freeze-by-selection, real cond on
        # all(halted), executed counter) is the LOCAL backend's —
        # shared so the executed counts agree by construction.
        v_a, he_a, (v_tr, he_tr), executed = batch_halting_scan(
            batched_step, v_attr_b, he_attr_b, msg0_b, batch, max_iters
        )
        return v_a, he_a, v_tr, he_tr, executed

    # replication checking off: the halt flag is partition-uniform by
    # construction, which 0.4.x check_rep cannot prove.  The activity
    # traces are likewise partition-uniform (psum'd / computed on the
    # replicated full-size buffers), so their out_spec is P().
    if resumable:
        if batch is not None:
            raise ValueError("resumable runner is unbatched")
        return _shard_map(
            run_resumable,
            mesh=mesh,
            in_specs=(
                state_spec, state_spec, state_spec, P(), P(),
                deg_spec, deg_spec,
                edge_spec, edge_spec, edge_spec, P(), P(),
                edge_spec,
            ),
            out_specs=(
                state_spec, state_spec, state_spec, P(), P(), P(), P(),
            ),
        )
    if batch is None:
        return _shard_map(
            run,
            mesh=mesh,
            in_specs=(
                state_spec, state_spec, state_spec, deg_spec, deg_spec,
                edge_spec, edge_spec, edge_spec, P(), P(),
                edge_spec,  # delivery layouts: tree prefix, [n_parts, ...]
            ),
            out_specs=(state_spec, state_spec, P(), P()),
        )
    return _shard_map(
        run_batch,
        mesh=mesh,
        in_specs=(
            batch_state_spec, batch_state_spec, batch_state_spec,
            deg_spec, deg_spec,
            edge_spec, edge_spec, edge_spec, P(), P(),
            edge_spec,
        ),
        out_specs=(
            batch_state_spec, batch_state_spec, P(), P(), P(),
        ),
    )


def distributed_compute(
    hg: HyperGraph,
    plan: PartitionPlan,
    mesh: Mesh,
    max_iters: int,
    initial_msg: Pytree,
    v_program: Program,
    he_program: Program,
    *,
    axis: str = "data",
    backend: str = "replicated",
    feature_axis: str | None = None,
    return_stats: bool = False,
    delivery: str = "xla",
) -> HyperGraph:
    """Run ``compute`` distributed over ``mesh[axis]`` per ``plan``.

    ``feature_axis``: optional mesh axis to shard trailing feature dims
    over (2-D hypergraph parallelism; DESIGN.md §6).

    ``return_stats``: also return per-superstep ``(v_active, he_active)``
    activity traces (int32, length ``max_iters``) — the scan trace
    threaded out through ``shard_map`` as replicated outputs, matching
    the local engine's ``return_stats`` bit for bit.

    ``delivery``: ``'xla'`` (reference) or ``'pallas_fused'`` — the
    resolved ``ExecutionConfig.delivery`` axis; fused builds per-shard
    dst-sorted layouts from the plan's edge shards.
    """
    n_parts = plan.n_parts
    assert mesh.shape[axis] == n_parts, (
        f"plan has {n_parts} partitions but mesh[{axis!r}] = "
        f"{mesh.shape[axis]}"
    )
    nv_pad = _pad_to(hg.n_vertices, n_parts)
    ne_pad = _pad_to(hg.n_hyperedges, n_parts)
    ctx = DistContext(
        axis=axis, n_parts=n_parts, nv_pad=nv_pad, ne_pad=ne_pad,
    )

    v_deg = _pad_leading(hg.degrees(), nv_pad)
    he_card = _pad_leading(hg.cardinalities(), ne_pad)
    v_attr = jax.tree.map(lambda x: _pad_leading(x, nv_pad), hg.v_attr)
    he_attr = jax.tree.map(lambda x: _pad_leading(x, ne_pad), hg.he_attr)
    msg0 = constant_initial_msg(initial_msg, nv_pad)

    shard_src = jnp.asarray(plan.shard_src)
    shard_dst = jnp.asarray(plan.shard_dst)
    shard_mask = jnp.asarray(plan.shard_mask)
    layouts = None
    if delivery == "pallas_fused":
        layouts = build_shard_delivery(
            plan.shard_src, plan.shard_dst, plan.shard_mask,
            nv_pad, ne_pad,
        )

    mapped = build_distributed_runner(
        mesh, ctx, v_program, he_program, max_iters, backend=backend
    )
    with mesh:
        v_out, he_out, v_trace, he_trace = jax.jit(mapped)(
            v_attr, he_attr, msg0, v_deg, he_card,
            shard_src, shard_dst, shard_mask,
            jnp.asarray(hg.n_vertices, jnp.int32),
            jnp.asarray(hg.n_hyperedges, jnp.int32),
            layouts,
        )
    unpad_v = jax.tree.map(lambda x: x[: hg.n_vertices], v_out)
    unpad_he = jax.tree.map(lambda x: x[: hg.n_hyperedges], he_out)
    out = hg.with_attrs(v_attr=unpad_v, he_attr=unpad_he)
    if return_stats:
        return out, (v_trace, he_trace)
    return out


def distributed_initial_state(hg: HyperGraph, plan: PartitionPlan,
                              initial_msg: Pytree) -> dict:
    """The explicit (partition-padded) scan carry ``distributed_compute``
    starts from, as a checkpoint-serializable pytree — the distributed
    twin of ``engine.initial_superstep_state``."""
    n_parts = plan.n_parts
    nv_pad = _pad_to(hg.n_vertices, n_parts)
    ne_pad = _pad_to(hg.n_hyperedges, n_parts)
    return {
        "step": jnp.asarray(0, jnp.int32),
        "v_attr": jax.tree.map(
            lambda x: _pad_leading(x, nv_pad), hg.v_attr
        ),
        "he_attr": jax.tree.map(
            lambda x: _pad_leading(x, ne_pad), hg.he_attr
        ),
        "msg": constant_initial_msg(initial_msg, nv_pad),
        "halted": jnp.asarray(False),
    }


def distributed_compute_resumable(
    hg: HyperGraph,
    plan: PartitionPlan,
    mesh: Mesh,
    n_iters: int,
    state: dict,
    v_program: Program,
    he_program: Program,
    *,
    axis: str = "data",
    backend: str = "replicated",
    delivery: str = "xla",
):
    """Run ``n_iters`` superstep pairs from an explicit carry ``state``
    (see ``distributed_initial_state``); returns ``(state', trace)``.

    ``distributed_compute`` with the scan carry lifted to an argument —
    the distributed checkpoint/resume seam.  The per-iteration body is
    shared with the single-shot runner, so chunked runs compose bitwise
    into an uninterrupted run (same contract as the local engine's
    ``compute_resumable``)."""
    n_parts = plan.n_parts
    assert mesh.shape[axis] == n_parts
    nv_pad = _pad_to(hg.n_vertices, n_parts)
    ne_pad = _pad_to(hg.n_hyperedges, n_parts)
    ctx = DistContext(
        axis=axis, n_parts=n_parts, nv_pad=nv_pad, ne_pad=ne_pad,
    )
    v_deg = _pad_leading(hg.degrees(), nv_pad)
    he_card = _pad_leading(hg.cardinalities(), ne_pad)
    layouts = None
    if delivery == "pallas_fused":
        layouts = build_shard_delivery(
            plan.shard_src, plan.shard_dst, plan.shard_mask,
            nv_pad, ne_pad,
        )
    mapped = build_distributed_runner(
        mesh, ctx, v_program, he_program, n_iters, backend=backend,
        resumable=True,
    )
    with mesh:
        v_a, he_a, msg, halted, step, v_tr, he_tr = jax.jit(mapped)(
            state["v_attr"], state["he_attr"], state["msg"],
            state["halted"], state["step"],
            v_deg, he_card,
            jnp.asarray(plan.shard_src), jnp.asarray(plan.shard_dst),
            jnp.asarray(plan.shard_mask),
            jnp.asarray(hg.n_vertices, jnp.int32),
            jnp.asarray(hg.n_hyperedges, jnp.int32),
            layouts,
        )
    out = {
        "step": step, "v_attr": v_a, "he_attr": he_a,
        "msg": msg, "halted": halted,
    }
    return out, (v_tr, he_tr)
