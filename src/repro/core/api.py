"""The MESH programming model: "think like a vertex *or hyperedge*".

Faithful JAX port of the paper's Listing-1 API.  Differences forced by SPMD
(and recorded in DESIGN.md §4): procedures are *vectorized* over the whole
entity set instead of per-entity closures; ``ctx.become`` is the returned
attribute; ``ctx.broadcast`` is the returned message; ``ctx.send(f, to)``
per-destination messages are the optional per-incidence ``edge_transform``.

A ``Program`` owns the ``MessageCombiner`` for the messages it *sends*
(same ownership as the paper).  ``combiner=None`` auto-derives it from the
message type — the Algebird feature, via ``sparse.segment.derive_monoid_for``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.sparse.segment import Monoid, derive_monoid_for, resolve_monoid

Pytree = Any


class ProcedureOut(NamedTuple):
    """What one superstep of a vertex/hyperedge program produces.

    attr: updated attribute pytree, leading dim = entity count
      (``ctx.become``).
    msg: outgoing message pytree, leading dim = entity count
      (``ctx.broadcast`` — delivered to every incident entity, combined at
      the destination with the sender program's combiner).
    active: optional ``[n] bool``; inactive entities send nothing this
      superstep (their message rows are replaced by the combiner identity).
      ``None`` = all active (PageRank/LabelProp semantics).
    """

    attr: Pytree
    msg: Pytree
    active: jnp.ndarray | None = None


# (step, ids[n], attr, in_msg, degree[n]) -> ProcedureOut
Procedure = Callable[
    [jnp.ndarray, jnp.ndarray, Pytree, Pytree, jnp.ndarray], ProcedureOut
]

# optional per-incidence message transform:
# (msg_row_pytree, e_attr_row_pytree) -> msg_row_pytree
EdgeTransform = Callable[[Pytree, Pytree], Pytree]


@dataclasses.dataclass(frozen=True)
class Program:
    """One side's behavior (vertex Program or hyperedge Program).

    ``reducer`` generalizes the MessageCombiner beyond monoids: it receives
    the *per-incidence* message rows plus destination ids and produces the
    combined per-destination message — the vectorized equivalent of the
    paper's ``Seq``-typed messages (PageRank-Entropy needs the full member
    multiset, not a fold).  When ``reducer`` is None the monoid fast path
    (``combiner``) is used; monoids are what allow pre-aggregation before
    the network hop, so programs should prefer them.
    """

    procedure: Procedure
    combiner: str | Monoid | None = None  # None => auto-derive per leaf
    edge_transform: EdgeTransform | None = None
    # (rows pytree [nnz,...], dst_ids [nnz], num_dst, live [nnz] bool|None)
    #   -> combined msg pytree [num_dst, ...]
    reducer: Callable | None = None

    def monoid_for(self, msg_leaf: jnp.ndarray) -> Monoid:
        if self.combiner is None:
            return derive_monoid_for(msg_leaf)
        return resolve_monoid(self.combiner)


def constant_initial_msg(template: Pytree, n: int) -> Pytree:
    """Broadcast the user's ``initialMsg`` to every entity (superstep 0)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(
            jnp.asarray(x), (n,) + jnp.shape(jnp.asarray(x))
        ),
        template,
    )


def identity_rows(monoid: Monoid, template_leaf: jnp.ndarray, n: int):
    ident = monoid.identity(template_leaf.dtype)
    return jnp.broadcast_to(ident, (n,) + template_leaf.shape[1:]).astype(
        template_leaf.dtype
    )
