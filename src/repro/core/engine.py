"""The MESH superstep engine (single-device reference executor).

``compute`` is the paper's ``HyperGraph.compute``: alternating vertex /
hyperedge supersteps, message delivery along the bipartite incidence with
combiner-merged messages, dynamic termination when every entity goes
inactive (SSSP) inside a static ``lax.scan`` (BSP barrier == one scan
iteration).

The distributed executor (``core.distributed``) reuses ``deliver`` /
``superstep_pair`` verbatim inside ``shard_map`` — the engine is written so
the only distributed delta is *where* the segment reduction's results get
combined (psum / psum_scatter instead of nothing).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import Program, ProcedureOut, constant_initial_msg
from repro.core.hypergraph import HyperGraph

Pytree = Any


def deliver(
    out_msg: Pytree,
    active: jnp.ndarray | None,
    src_ids: jnp.ndarray,
    dst_ids: jnp.ndarray,
    num_dst: int,
    program: Program,
    e_attr: Pytree = None,
    e_mask: jnp.ndarray | None = None,
    layout=None,
) -> Pytree:
    """Deliver broadcast messages along incidences and combine by
    destination with the *sender* program's MessageCombiner.

    The reference (``delivery='xla'``) data path is gather (``take``) ->
    optional per-incidence transform -> mask dead rows to the monoid
    identity -> segment-reduce by destination.  This is the entire data
    path of one half-superstep; everything else is pointwise.

    ``layout``: optional precomputed ``DeliveryLayout`` (the
    ``delivery='pallas_fused'`` design point) — routes the monoid fast
    path through ``repro.kernels.deliver`` (dst-sorted CSR; gather,
    mask and combine fused, no ``[nnz, D]`` intermediate).  Custom
    ``reducer``s and per-incidence ``edge_transform``s always take the
    reference path: they consume the materialized rows by contract.
    """
    if (layout is not None and program.reducer is None
            and program.edge_transform is None):
        from repro.kernels.deliver import fused_deliver

        return fused_deliver(out_msg, active, layout, program)

    rows = jax.tree.map(lambda leaf: jnp.take(leaf, src_ids, axis=0), out_msg)
    if program.edge_transform is not None:
        rows = program.edge_transform(rows, e_attr)

    live = None
    if active is not None:
        live = jnp.take(active, src_ids, axis=0)
    if e_mask is not None:
        em = e_mask.astype(bool)
        live = em if live is None else (live & em)

    if program.reducer is not None:
        return program.reducer(rows, dst_ids, num_dst, live)

    def combine_leaf(leaf: jnp.ndarray) -> jnp.ndarray:
        monoid = program.monoid_for(leaf)
        if live is not None:
            ident = monoid.identity(leaf.dtype)
            shape = (live.shape[0],) + (1,) * (leaf.ndim - 1)
            leaf = jnp.where(live.reshape(shape), leaf, ident)
        return monoid.segment(leaf, dst_ids, num_segments=num_dst)

    return jax.tree.map(combine_leaf, rows)


def _as_out(res, attr, n) -> ProcedureOut:
    """Normalize procedure output (allow returning (attr, msg) tuples)."""
    if isinstance(res, ProcedureOut):
        return res
    if isinstance(res, tuple) and len(res) == 2:
        return ProcedureOut(res[0], res[1], None)
    raise TypeError(
        "Procedure must return ProcedureOut or (attr, msg); got "
        f"{type(res)}"
    )


class SuperstepStats(NamedTuple):
    """Per-iteration activity counters (observability hook)."""

    v_active: jnp.ndarray  # [] int32
    he_active: jnp.ndarray  # [] int32


def superstep_pair(
    hg: HyperGraph,
    step: jnp.ndarray,
    v_attr: Pytree,
    he_attr: Pytree,
    msg_to_v: Pytree,
    v_program: Program,
    he_program: Program,
    v_deg: jnp.ndarray,
    he_card: jnp.ndarray,
    n_real: tuple | None = None,
    delivery: tuple | None = None,
):
    """One (vertex, hyperedge) pair of supersteps. Pure; jit/scan-safe.

    ``n_real``: optional ``(nv_real, ne_real)`` — ints or traced int32
    scalars.  When the hypergraph is padded to a shape bucket (the
    compile-once serving path), activity counts mask to the first
    ``n_real`` slots so padding entities never leak into the observable
    stats or the halting decision; traced scalars keep one executable
    serving every real size in the bucket.

    ``delivery``: optional ``(fwd_layout, bwd_layout)`` pair of
    ``DeliveryLayout``s (vertex->hyperedge, hyperedge->vertex) routing
    both half-supersteps through the fused delivery kernel.
    """
    fwd_layout, bwd_layout = delivery if delivery is not None else (None, None)
    v_ids = jnp.arange(hg.n_vertices, dtype=jnp.int32)
    he_ids = jnp.arange(hg.n_hyperedges, dtype=jnp.int32)

    v_out = _as_out(
        v_program.procedure(step, v_ids, v_attr, msg_to_v, v_deg),
        v_attr,
        hg.n_vertices,
    )
    msg_to_he = deliver(
        v_out.msg, v_out.active, hg.src, hg.dst, hg.n_hyperedges,
        v_program, hg.e_attr, hg.e_mask, layout=fwd_layout,
    )
    he_out = _as_out(
        he_program.procedure(step + 1, he_ids, he_attr, msg_to_he, he_card),
        he_attr,
        hg.n_hyperedges,
    )
    msg_to_v_next = deliver(
        he_out.msg, he_out.active, hg.dst, hg.src, hg.n_vertices,
        he_program, hg.e_attr, hg.e_mask, layout=bwd_layout,
    )

    def count(active, n, real):
        if real is None:
            if active is None:
                return jnp.asarray(n, jnp.int32)
            return active.sum().astype(jnp.int32)
        live = jnp.arange(n, dtype=jnp.int32) < real
        if active is not None:
            live = live & active
        return live.sum().astype(jnp.int32)

    nv_real, ne_real = n_real if n_real is not None else (None, None)
    stats = SuperstepStats(
        v_active=count(v_out.active, hg.n_vertices, nv_real),
        he_active=count(he_out.active, hg.n_hyperedges, ne_real),
    )
    return v_out.attr, he_out.attr, msg_to_v_next, stats


def _halting_body(hg, v_program, he_program, v_deg, he_card, n_real,
                  delivery):
    """The per-iteration scan body shared by ``compute`` and
    ``compute_resumable`` — ONE definition, so a chunked
    (checkpointed) run and an uninterrupted run execute the same
    per-iteration computation and agree bitwise by construction."""

    def body(carry, _):
        step, v_attr, he_attr, msg_to_v, halted = carry

        def run(args):
            step, v_attr, he_attr, msg_to_v = args
            nv_attr, nhe_attr, nmsg, stats = superstep_pair(
                hg, step, v_attr, he_attr, msg_to_v,
                v_program, he_program, v_deg, he_card, n_real, delivery,
            )
            now_halted = (stats.v_active + stats.he_active) == 0
            return (nv_attr, nhe_attr, nmsg, now_halted, stats)

        def skip(args):
            _, v_attr, he_attr, msg_to_v = args
            stats = SuperstepStats(
                v_active=jnp.asarray(0, jnp.int32),
                he_active=jnp.asarray(0, jnp.int32),
            )
            return (v_attr, he_attr, msg_to_v, jnp.asarray(True), stats)

        nv_attr, nhe_attr, nmsg, halted2, stats = jax.lax.cond(
            halted, skip, run, (step, v_attr, he_attr, msg_to_v)
        )
        return (
            step + 2, nv_attr, nhe_attr, nmsg, halted | halted2,
        ), (stats.v_active, stats.he_active)

    return body


def compute(
    hg: HyperGraph,
    max_iters: int,
    initial_msg: Pytree,
    v_program: Program,
    he_program: Program,
    *,
    return_stats: bool = False,
    n_real: tuple | None = None,
    delivery: tuple | None = None,
):
    """Run the alternating-superstep computation; returns the updated
    HyperGraph (and per-iteration activity stats when requested).

    ``max_iters`` counts (vertex, hyperedge) superstep pairs — the paper's
    "iterations" (30 for its PageRank/LabelProp runs). Dynamic termination:
    once every entity reports inactive the remaining scan iterations are
    no-ops via ``lax.cond`` (compiled once, skipped cheaply at runtime).

    ``n_real``: optional ``(nv_real, ne_real)`` for bucket-padded inputs
    (see ``superstep_pair``); activity/halting then ignore padding slots.

    ``delivery``: optional ``(fwd, bwd)`` ``DeliveryLayout`` pair — the
    fused delivery design point (see ``superstep_pair``).
    """
    v_deg = hg.degrees()
    he_card = hg.cardinalities()
    msg0 = constant_initial_msg(initial_msg, hg.n_vertices)

    body = _halting_body(
        hg, v_program, he_program, v_deg, he_card, n_real, delivery
    )
    init = (
        jnp.asarray(0, jnp.int32),
        hg.v_attr,
        hg.he_attr,
        msg0,
        jnp.asarray(False),
    )
    (_, v_attr, he_attr, _, _), trace = jax.lax.scan(
        body, init, None, length=max_iters
    )
    out = hg.with_attrs(v_attr=v_attr, he_attr=he_attr)
    if return_stats:
        return out, trace
    return out


def initial_superstep_state(hg: HyperGraph, initial_msg: Pytree) -> dict:
    """The explicit scan carry ``compute`` starts from, as a pytree a
    checkpoint can serialize: superstep counter, both attribute trees,
    the in-flight vertex-bound message buffer, and the halt flag."""
    return {
        "step": jnp.asarray(0, jnp.int32),
        "v_attr": hg.v_attr,
        "he_attr": hg.he_attr,
        "msg": constant_initial_msg(initial_msg, hg.n_vertices),
        "halted": jnp.asarray(False),
    }


def compute_resumable(
    hg: HyperGraph,
    n_iters: int,
    state: dict,
    v_program: Program,
    he_program: Program,
    *,
    n_real: tuple | None = None,
    delivery: tuple | None = None,
):
    """Run ``n_iters`` superstep pairs from an explicit carry ``state``
    (see ``initial_superstep_state``); returns ``(state', trace)``.

    This is ``compute`` with the scan carry lifted to an argument — the
    checkpoint/resume seam.  Running k1 pairs, snapshotting, and running
    k2 more from the snapshot executes the identical per-iteration body
    (``_halting_body``) in the identical order as one ``k1 + k2`` run,
    so resumed results are bitwise those of an uninterrupted run.
    """
    body = _halting_body(
        hg, v_program, he_program, hg.degrees(), hg.cardinalities(),
        n_real, delivery,
    )
    init = (
        state["step"], state["v_attr"], state["he_attr"],
        state["msg"], state["halted"],
    )
    (step, v_attr, he_attr, msg, halted), trace = jax.lax.scan(
        body, init, None, length=n_iters
    )
    out = {
        "step": step, "v_attr": v_attr, "he_attr": he_attr,
        "msg": msg, "halted": halted,
    }
    return out, trace


compute_jit = partial(jax.jit, static_argnames=("max_iters", "v_program",
                                                "he_program",
                                                "return_stats"))(compute)

compute_resumable_jit = partial(
    jax.jit, static_argnames=("n_iters", "v_program", "he_program")
)(compute_resumable)


def batch_halting_scan(
    batched_step,
    v_attr_b: Pytree,
    he_attr_b: Pytree,
    msg0_b: Pytree,
    batch: int,
    max_iters: int,
):
    """The batch-aware halting scan shared by the local and distributed
    batched executables.

    ``batched_step(step, v_attr_b, he_attr_b, msg_b) -> (v_attr_b,
    he_attr_b, msg_b, (v_active_b, he_active_b))`` is one vmapped
    superstep pair over the query axis.  The scan wraps it in a REAL
    ``lax.cond`` on ``all(halted)`` — once the last query converges the
    remaining iterations are skipped — while preserving per-query
    semantics bitwise: a halted query's state is frozen by selection
    (exactly what the vmapped ``cond``-as-``select`` would compute) and
    its activity counts report zero.  One definition, two callers
    (``compute_batch`` and ``distributed.build_distributed_runner``),
    so ``Result.supersteps_executed`` agrees across backends by
    construction.

    Returns ``(v_attr_b, he_attr_b, (v_trace, he_trace) [max_iters,
    batch], supersteps_executed)``.
    """

    def select(halted_b, old, new):
        def one(o, n):
            m = halted_b.reshape((batch,) + (1,) * (o.ndim - 1))
            return jnp.where(m, o, n)
        return jax.tree.map(one, old, new)

    def body(carry, _):
        step, v_a, he_a, msg, halted_b, executed = carry
        zero_b = jnp.zeros((batch,), jnp.int32)

        def run(args):
            step, v_a, he_a, msg, halted_b, executed = args
            nv_a, nhe_a, nmsg, stats = batched_step(step, v_a, he_a, msg)
            v_act = jnp.where(halted_b, 0, stats[0])
            he_act = jnp.where(halted_b, 0, stats[1])
            now_halted = halted_b | ((v_act + he_act) == 0)
            return (
                select(halted_b, v_a, nv_a),
                select(halted_b, he_a, nhe_a),
                select(halted_b, msg, nmsg),
                now_halted,
                executed + 1,
                (v_act, he_act),
            )

        def skip(args):
            _, v_a, he_a, msg, halted_b, executed = args
            return v_a, he_a, msg, halted_b, executed, (zero_b, zero_b)

        nv_a, nhe_a, nmsg, halted2, executed, stats = jax.lax.cond(
            halted_b.all(), skip, run,
            (step, v_a, he_a, msg, halted_b, executed),
        )
        return (step + 2, nv_a, nhe_a, nmsg, halted2, executed), stats

    init = (
        jnp.asarray(0, jnp.int32),
        v_attr_b,
        he_attr_b,
        msg0_b,
        jnp.zeros((batch,), bool),
        jnp.asarray(0, jnp.int32),
    )
    (_, v_a, he_a, _, _, executed), traces = jax.lax.scan(
        body, init, None, length=max_iters
    )
    return v_a, he_a, traces, executed


def compute_batch(
    hg: HyperGraph,
    v_attr_b: Pytree,
    he_attr_b: Pytree,
    batch: int,
    max_iters: int,
    initial_msg: Pytree,
    v_program: Program,
    he_program: Program,
    *,
    n_real: tuple | None = None,
    delivery: tuple | None = None,
):
    """Batched superstep computation with BATCH-AWARE halting.

    ``jax.vmap(compute)`` turns the per-query halting ``lax.cond`` into a
    ``select``: both branches execute every iteration, so a batch always
    pays ``max_iters`` supersteps even when every query converged early.
    Here the vmap sits *inside* the scan — one batched superstep per
    iteration — so the halting ``cond`` stays a real branch on
    ``all(halted)`` across the batch: once the LAST query converges the
    remaining iterations are skipped, restoring early exit for
    skewed-convergence batches.

    Per-query semantics are preserved bitwise: a halted query's state is
    frozen by selection (exactly what the vmapped ``cond``-as-``select``
    computed) and its activity counts report zero, so results and stats
    match ``B`` sequential ``compute`` runs.

    ``hg`` carries the (unbatched) structure; ``v_attr_b`` /
    ``he_attr_b`` are the per-query attribute pytrees with a leading
    batch dim ``batch``.  Returns ``(v_attr_b, he_attr_b,
    (v_trace, he_trace) [batch, max_iters], supersteps_executed)`` —
    the executed count is the scan iterations actually run (== the
    slowest query's convergence, <= max_iters).
    """
    v_deg = hg.degrees()
    he_card = hg.cardinalities()
    msg0 = constant_initial_msg(initial_msg, hg.n_vertices)
    msg0_b = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (batch,) + x.shape), msg0
    )

    def one_step(step, v_attr, he_attr, msg_to_v):
        # superstep_pair reads only hg's structure (src/dst/e_attr/
        # e_mask/sizes); the per-query attrs travel as parameters.
        return superstep_pair(
            hg, step, v_attr, he_attr, msg_to_v,
            v_program, he_program, v_deg, he_card, n_real, delivery,
        )

    batched_step = jax.vmap(one_step, in_axes=(None, 0, 0, 0))

    v_a, he_a, (v_tr, he_tr), executed = batch_halting_scan(
        batched_step, v_attr_b, he_attr_b, msg0_b, batch, max_iters
    )
    # [max_iters, batch] -> [batch, max_iters]: match the vmap layout
    # callers already consume.
    return v_a, he_a, (v_tr.T, he_tr.T), executed
