"""Hypergraph PageRank and PageRank-Entropy (paper Listings 2 & 3).

Messages:
  v -> he : rank_v / totalWeight_v                       (sum combiner)
  he -> v : (weight_e, rank_e / cardinality_e)           (sum combiner)

``totalWeight_v`` is the sum of incident hyperedge weights — delivered as
the first component of the he->v message, exactly as in Listing 2.

Aux lookups inside procedures go through ``ids`` (``jnp.take``) so the same
procedure runs on the local engine (ids = arange) and on id-range shards
(global ids) — the one structural concession SPMD demands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import Program, ProcedureOut
from repro.core.hypergraph import HyperGraph
from repro.algorithms.spec import AlgorithmSpec, resolve_engine


def pagerank_spec(
    hg: HyperGraph,
    iters: int = 30,
    alpha: float = 0.15,
    he_weight: jnp.ndarray | None = None,
) -> AlgorithmSpec:
    nv, ne = hg.n_vertices, hg.n_hyperedges
    weight_full = (
        he_weight.astype(jnp.float32)
        if he_weight is not None
        else jnp.ones((ne,), jnp.float32)
    )

    def vertex(step, ids, attr, msg, deg):
        total_weight, rank = msg
        new_rank = alpha + (1.0 - alpha) * rank
        tw = jnp.maximum(total_weight, 1e-12)
        return ProcedureOut(attr=new_rank, msg=new_rank / tw)

    def hyperedge(step, ids, attr, msg, cards):
        w = jnp.take(weight_full, jnp.minimum(ids, ne - 1), axis=0)
        card = jnp.maximum(cards.astype(jnp.float32), 1.0)
        new_rank = msg * w
        return ProcedureOut(attr=new_rank, msg=(w, new_rank / card))

    def init(hg: HyperGraph) -> HyperGraph:
        # NOTE for the compiled serve-many path: custom ``he_weight`` is
        # traced in sized to THIS hypergraph; rebind on a new structure
        # only with default (unit) weights.
        return hg.with_attrs(
            v_attr=jnp.ones((hg.n_vertices,), jnp.float32),
            he_attr=jnp.ones((hg.n_hyperedges,), jnp.float32),
        )

    return AlgorithmSpec(
        hg0=init(hg),
        initial_msg=(jnp.float32(1.0), jnp.float32(1.0)),
        v_program=Program(procedure=vertex, combiner="sum"),
        he_program=Program(procedure=hyperedge, combiner="sum"),
        max_iters=iters,
        extract=lambda out: (out.v_attr, out.he_attr),
        name="pagerank",
        touches_hyperedge_state=True,  # extracts hyperedge ranks
        init=init,
    )


def vertex_pagerank_spec(
    hg: HyperGraph, iters: int = 30, alpha: float = 0.15
) -> AlgorithmSpec:
    """PageRank restricted to vertex ranks — the clique-eligible variant.

    Drops the hyperedge-rank output, which makes the spec satisfy the
    paper's constant-folding precondition (§IV-A1); the clique program is
    the Fig. 7 baseline (``graph_pagerank`` weighted by shared-hyperedge
    count).  Note the two representations are the paper's two *design
    points*, not numerically identical algorithms.
    """
    from repro.algorithms.graph_pagerank import graph_pagerank

    base = pagerank_spec(hg, iters, alpha)
    return base._replace(
        extract=lambda out: out.v_attr,
        name="pagerank[vertex]",
        touches_hyperedge_state=False,
        clique_program=lambda g: graph_pagerank(
            g, iters=iters, alpha=alpha
        ),
    )


def pagerank(hg, iters=30, alpha=0.15, he_weight=None, *, engine=None):
    """Returns (vertex_ranks, hyperedge_ranks)."""
    return resolve_engine(engine).run(
        pagerank_spec(hg, iters, alpha, he_weight)
    ).value


def pagerank_entropy_spec(
    hg: HyperGraph,
    iters: int = 30,
    alpha: float = 0.15,
    he_weight: jnp.ndarray | None = None,
) -> AlgorithmSpec:
    """PageRank + per-hyperedge entropy of member rank shares (Listing 3).

    Sum-decomposed formulation: with S = sum_v r_v and Q = sum_v
    r_v*log2(r_v) over members, entropy H = log2(S) - Q/S — three sum-monoid
    message components, so messages stay pre-aggregatable before the network
    hop (the distributable form; ``pagerank_entropy_seq`` is the literal
    Seq-typed port used as its oracle).
    """
    nv, ne = hg.n_vertices, hg.n_hyperedges
    weight_full = (
        he_weight.astype(jnp.float32)
        if he_weight is not None
        else jnp.ones((ne,), jnp.float32)
    )

    def vertex(step, ids, attr, msg, deg):
        total_weight, rank = msg
        new_rank = alpha + (1.0 - alpha) * rank
        tw = jnp.maximum(total_weight, 1e-12)
        r = jnp.maximum(new_rank, 1e-12)
        return ProcedureOut(
            attr=new_rank,
            msg=(new_rank / tw, r, r * jnp.log2(r)),
        )

    def hyperedge(step, ids, attr, msg, cards):
        share_sum, s, q = msg
        w = jnp.take(weight_full, jnp.minimum(ids, ne - 1), axis=0)
        card = jnp.maximum(cards.astype(jnp.float32), 1.0)
        s = jnp.maximum(s, 1e-12)
        ent = jnp.log2(s) - q / s
        new_rank = share_sum * w
        return ProcedureOut(
            attr=(new_rank, w, ent),
            msg=(w, new_rank / card),
        )

    hg0 = hg.with_attrs(
        v_attr=jnp.ones((nv,), jnp.float32),
        he_attr=(
            jnp.ones((ne,), jnp.float32),
            weight_full,
            jnp.zeros((ne,), jnp.float32),
        ),
    )
    return AlgorithmSpec(
        hg0=hg0,
        initial_msg=(jnp.float32(1.0), jnp.float32(1.0)),
        v_program=Program(procedure=vertex, combiner="sum"),
        he_program=Program(procedure=hyperedge, combiner="sum"),
        max_iters=iters,
        extract=lambda out: (
            out.v_attr, out.he_attr[0], out.he_attr[2]
        ),
        name="pagerank_entropy",
        touches_hyperedge_state=True,
    )


def pagerank_entropy(hg, iters=30, alpha=0.15, he_weight=None, *,
                     engine=None):
    """Returns (vertex_ranks, hyperedge_ranks, hyperedge_entropy)."""
    return resolve_engine(engine).run(
        pagerank_entropy_spec(hg, iters, alpha, he_weight)
    ).value


def pagerank_entropy_seq(
    hg: HyperGraph,
    iters: int = 30,
    alpha: float = 0.15,
    he_weight: jnp.ndarray | None = None,
):
    """Seq-combiner formulation — the literal port of Listing 3 where the
    hyperedge sees the member rank multiset, via a custom ``reducer``
    (vectorized Seq message). Local-engine only; oracle for the decomposed
    form above."""
    nv, ne = hg.n_vertices, hg.n_hyperedges
    card = jnp.maximum(hg.cardinalities().astype(jnp.float32), 1.0)
    weight = (
        he_weight.astype(jnp.float32)
        if he_weight is not None
        else jnp.ones((ne,), jnp.float32)
    )

    def vertex(step, ids, attr, msg, deg):
        total_weight, rank = msg
        new_rank = alpha + (1.0 - alpha) * rank
        tw = jnp.maximum(total_weight, 1e-12)
        # broadcast (rank -> totalWeight) pairs, Listing 3.
        return ProcedureOut(attr=new_rank, msg=(new_rank, tw))

    def entropy_reducer(rows, dst_ids, num_dst, live):
        rank, tw = rows
        if live is not None:
            rank = jnp.where(live, rank, 0.0)
        share_sum = jax.ops.segment_sum(rank / tw, dst_ids, num_dst)
        total = jnp.maximum(
            jax.ops.segment_sum(rank, dst_ids, num_dst), 1e-12
        )
        p = jnp.maximum(rank / total[dst_ids], 1e-12)
        ent = jax.ops.segment_sum(-p * jnp.log(p), dst_ids, num_dst)
        ent = ent / jnp.log(2.0)
        return (share_sum, ent)

    def hyperedge(step, ids, attr, msg, cards):
        share_sum, ent = msg
        new_rank = share_sum * weight
        return ProcedureOut(
            attr=(new_rank, weight, ent),
            msg=(weight, new_rank / card),
        )

    from repro.core.executor import Engine

    hg0 = hg.with_attrs(
        v_attr=jnp.ones((nv,), jnp.float32),
        he_attr=(
            jnp.ones((ne,), jnp.float32),
            weight,
            jnp.zeros((ne,), jnp.float32),
        ),
    )
    spec = AlgorithmSpec(
        hg0=hg0,
        initial_msg=(jnp.float32(1.0), jnp.float32(1.0)),
        v_program=Program(
            procedure=vertex, combiner="sum", reducer=entropy_reducer
        ),
        he_program=Program(procedure=hyperedge, combiner="sum"),
        max_iters=iters,
        extract=lambda out: (
            out.v_attr, out.he_attr[0], out.he_attr[2]
        ),
        name="pagerank_entropy[seq]",
        touches_hyperedge_state=True,
    )
    # Seq reducers have no distributed decomposition: pin the backend.
    return Engine(backend="local").run(spec).value
