"""PageRank over the clique-expanded Graph representation (Fig. 7 baseline).

Only valid for algorithms with no hyperedge state — exactly the restriction
the paper documents.  Weighted by shared-hyperedge count (the ``toGraph``
edge attribute).

This is the ``clique_program`` behind ``vertex_pagerank_spec``: the Engine
facade routes here when ``representation`` resolves to ``clique`` (see
``repro.core.executor.select_representation``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.clique import Graph


def graph_pagerank(
    g: Graph, iters: int = 30, alpha: float = 0.15
) -> jnp.ndarray:
    nv = g.n_vertices
    w = g.e_attr if g.e_attr is not None else jnp.ones_like(
        g.src, jnp.float32
    )
    out_w = jax.ops.segment_sum(w, g.src, nv)
    out_w = jnp.maximum(out_w, 1e-12)

    def step(rank, _):
        contrib = (rank / out_w)[g.src] * w
        agg = jax.ops.segment_sum(contrib, g.dst, nv)
        return alpha + (1.0 - alpha) * agg, None

    rank0 = jnp.ones((nv,), jnp.float32)
    rank, _ = jax.lax.scan(step, rank0, None, length=iters)
    return rank
