"""Label Propagation (paper Listing 4): community detection where both
vertices and hyperedges carry a community label; max-combined messages."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.api import Program, ProcedureOut
from repro.core.hypergraph import HyperGraph
from repro.algorithms.spec import AlgorithmSpec, resolve_engine


def label_propagation_spec(hg: HyperGraph, iters: int = 30) -> AlgorithmSpec:
    def vertex(step, ids, attr, msg, deg):
        new_label = jnp.where(step == 0, ids, jnp.maximum(msg, attr))
        return ProcedureOut(attr=new_label, msg=new_label)

    def hyperedge(step, ids, attr, msg, card):
        new_label = jnp.maximum(msg, attr)
        return ProcedureOut(attr=new_label, msg=new_label)

    def init(hg: HyperGraph) -> HyperGraph:
        return hg.with_attrs(
            v_attr=jnp.zeros((hg.n_vertices,), jnp.int32),
            he_attr=jnp.zeros((hg.n_hyperedges,), jnp.int32),
        )

    return AlgorithmSpec(
        hg0=init(hg),
        initial_msg=jnp.int32(0),
        v_program=Program(procedure=vertex, combiner="max"),
        he_program=Program(procedure=hyperedge, combiner="max"),
        max_iters=iters,
        extract=lambda out: (out.v_attr, out.he_attr),
        name="label_propagation",
        touches_hyperedge_state=True,  # labels persist on hyperedges
        init=init,
    )


def label_propagation(hg, iters=30, *, engine=None):
    """Returns (vertex_labels, hyperedge_labels) as int32."""
    return resolve_engine(engine).run(
        label_propagation_spec(hg, iters)
    ).value
