"""AlgorithmSpec: one definition, every engine.

Each algorithm module builds a spec (initial state + programs + design
metadata); the ``Engine`` facade (``repro.core.executor``) consumes it on
any representation/partition/backend design point.  The property tests
assert every design point agrees — the system's core correctness
invariant.

The compile-once/serve-many lifecycle (``Engine.compile`` ->
``CompiledAlgorithm``) additionally needs algorithms to declare which
parts of their state depend on the *input structure* and which vary *per
request*:

* ``init(hg)`` rebuilds the algorithm's initial attributes on a new
  hypergraph — what the spec constructor did to produce ``hg0`` — so one
  compiled executable can serve a stream of same-bucket hypergraphs.
* ``bind_query(hg0, query)`` binds one request's varying state (an SSSP
  source, a personalized-restart seed) onto an *initialized, unbound*
  hypergraph.  It is traced into the executable, so the query is a
  runtime argument: changing it never recompiles, and
  ``CompiledAlgorithm.run_batch`` vmaps over it to serve B queries from
  one compile.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.core.api import Program
from repro.core.hypergraph import HyperGraph


class AlgorithmSpec(NamedTuple):
    """A runnable algorithm: state + programs + design-choice metadata.

    The trailing metadata fields feed the Engine's auto-selection:

    * ``name`` labels results / reports.
    * ``touches_hyperedge_state``: True when the algorithm reads or
      returns per-hyperedge state — the paper's precondition gate: clique
      expansion (constant folding, §IV-A1) is only legal when False.
    * ``clique_program``: optional equivalent computation over the
      clique-expanded ``Graph`` (``repro.core.clique.to_graph``); required
      for the clique representation to be selectable.

    Serving metadata (``Engine.compile``):

    * ``init``: rebuild initial attributes on a fresh structure,
      ``(hg) -> hg0_unbound``.  Required to run a compiled algorithm on
      hypergraphs other than ``hg0``, and for any query rebinding.
    * ``bind_query``: bind one request's varying state,
      ``(hg0_unbound, query) -> hg0``.  Must be jit-traceable and
      vmap-able over ``query`` (scalar/fixed-shape queries; structure
      sizes come from the hypergraph argument, which may be padded).
    * ``query0``: the query baked into ``hg0`` (for reports/defaults);
      ``None`` when the spec is query-free or hg0 is unbound.
    """

    hg0: HyperGraph
    initial_msg: Any
    v_program: Program
    he_program: Program
    max_iters: int
    extract: Callable[[HyperGraph], Any]
    name: str = "custom"
    touches_hyperedge_state: bool = True
    clique_program: Callable[..., Any] | None = None
    init: Callable[[HyperGraph], HyperGraph] | None = None
    bind_query: Callable[[HyperGraph, Any], HyperGraph] | None = None
    query0: Any = None


def resolve_engine(engine=None):
    """The algorithm wrappers' engine policy: caller-supplied engine, or
    a fresh default (auto representation, local-unless-meshed backend).
    One place to change if the wrappers' default design point moves."""
    if engine is not None:
        return engine
    from repro.core.executor import Engine

    return Engine()
