"""AlgorithmSpec: one definition, every engine.

Each algorithm module builds a spec (initial state + programs + design
metadata); the ``Engine`` facade (``repro.core.executor``) consumes it on
any representation/partition/backend design point.  The property tests
assert every design point agrees — the system's core correctness
invariant.

``run_local`` / ``run_distributed`` are the pre-facade entry points, kept
as deprecated shims: they delegate to ``Engine`` and will be removed once
nothing imports them.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, NamedTuple

from repro.core.api import Program
from repro.core.hypergraph import HyperGraph


class AlgorithmSpec(NamedTuple):
    """A runnable algorithm: state + programs + design-choice metadata.

    The trailing metadata fields feed the Engine's auto-selection:

    * ``name`` labels results / reports.
    * ``touches_hyperedge_state``: True when the algorithm reads or
      returns per-hyperedge state — the paper's precondition gate: clique
      expansion (constant folding, §IV-A1) is only legal when False.
    * ``clique_program``: optional equivalent computation over the
      clique-expanded ``Graph`` (``repro.core.clique.to_graph``); required
      for the clique representation to be selectable.
    """

    hg0: HyperGraph
    initial_msg: Any
    v_program: Program
    he_program: Program
    max_iters: int
    extract: Callable[[HyperGraph], Any]
    name: str = "custom"
    touches_hyperedge_state: bool = True
    clique_program: Callable[..., Any] | None = None


def resolve_engine(engine=None):
    """The algorithm wrappers' engine policy: caller-supplied engine, or
    a fresh default (auto representation, local-unless-meshed backend).
    One place to change if the wrappers' default design point moves."""
    if engine is not None:
        return engine
    from repro.core.executor import Engine

    return Engine()


def run_local(spec: AlgorithmSpec):
    """Deprecated: use ``Engine(backend='local').run(spec).value``."""
    warnings.warn(
        "run_local is deprecated; route through repro.core.Engine",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.core.executor import Engine

    # Pin the legacy design point exactly: bipartite + local compute
    # (representation='auto' could pick clique for eligible specs, which
    # is a *different* numerical result).
    return Engine(representation="bipartite", backend="local").run(
        spec
    ).value


def run_distributed(
    spec: AlgorithmSpec,
    plan,
    mesh,
    *,
    backend: str = "replicated",
    axis: str = "data",
):
    """Deprecated: use ``Engine(plan=..., mesh=..., backend=...)``."""
    warnings.warn(
        "run_distributed is deprecated; route through repro.core.Engine",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.core.executor import Engine

    return Engine(
        plan=plan, mesh=mesh, representation="bipartite",
        backend=backend, axis=axis,
    ).run(spec).value
