"""AlgorithmSpec: one definition, every engine.

Each algorithm module builds a spec (initial state + programs); thin
wrappers run it on the local engine, and ``run_distributed`` runs the same
spec under shard_map per a PartitionPlan — the property tests assert the
two agree, which is the system's core correctness invariant.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.core.api import Program
from repro.core.engine import compute
from repro.core.hypergraph import HyperGraph


class AlgorithmSpec(NamedTuple):
    hg0: HyperGraph
    initial_msg: Any
    v_program: Program
    he_program: Program
    max_iters: int
    extract: Callable[[HyperGraph], Any]


def run_local(spec: AlgorithmSpec):
    out = compute(
        spec.hg0,
        max_iters=spec.max_iters,
        initial_msg=spec.initial_msg,
        v_program=spec.v_program,
        he_program=spec.he_program,
    )
    return spec.extract(out)


def run_distributed(
    spec: AlgorithmSpec,
    plan,
    mesh,
    *,
    backend: str = "replicated",
    axis: str = "data",
):
    from repro.core.distributed import distributed_compute

    out = distributed_compute(
        spec.hg0,
        plan,
        mesh,
        max_iters=spec.max_iters,
        initial_msg=spec.initial_msg,
        v_program=spec.v_program,
        he_program=spec.he_program,
        axis=axis,
        backend=backend,
    )
    return spec.extract(out)
