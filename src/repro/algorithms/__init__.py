"""Hypergraph applications written against the public MESH API.

Each is a faithful port of a paper listing (LOC parity is itself one of the
paper's claims — see ``benchmarks/bench_loc.py``).
"""
from repro.algorithms.pagerank import (
    pagerank,
    pagerank_entropy,
    pagerank_entropy_seq,
    pagerank_spec,
    pagerank_entropy_spec,
    vertex_pagerank_spec,
)
from repro.algorithms.label_propagation import (
    label_propagation,
    label_propagation_spec,
)
from repro.algorithms.sssp import shortest_paths, shortest_paths_spec
from repro.algorithms.random_walk import random_walk, random_walk_spec
from repro.algorithms.components import (
    connected_components,
    connected_components_spec,
)
from repro.algorithms.graph_pagerank import graph_pagerank
from repro.algorithms.spec import AlgorithmSpec

__all__ = [
    "pagerank",
    "pagerank_entropy",
    "pagerank_entropy_seq",
    "pagerank_spec",
    "pagerank_entropy_spec",
    "vertex_pagerank_spec",
    "label_propagation",
    "label_propagation_spec",
    "shortest_paths",
    "shortest_paths_spec",
    "random_walk",
    "random_walk_spec",
    "connected_components",
    "connected_components_spec",
    "graph_pagerank",
    "AlgorithmSpec",
]
