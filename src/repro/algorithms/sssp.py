"""Single-Source Shortest Paths (paper Listing 5).

Distance unit = number of hyperedges traversed (vertex->he hop costs 1).
Only updated entities broadcast (sparse activation); the engine halts the
scan when every entity is inactive — the paper's termination condition.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.api import Program, ProcedureOut
from repro.core.hypergraph import HyperGraph
from repro.algorithms.spec import AlgorithmSpec, resolve_engine

INF = jnp.float32(jnp.inf)


def shortest_paths_spec(
    hg: HyperGraph, source: int, max_iters: int = 64
) -> AlgorithmSpec:
    def vertex(step, ids, attr, msg, deg):
        new_hop = msg
        # Superstep 0: the source activates itself with distance 0
        # (Pregel-style source bootstrap).
        is_src_boot = (step == 0) & (ids == source)
        new_hop = jnp.where(is_src_boot, 0.0, new_hop)
        updated = attr > new_hop
        attr2 = jnp.where(updated, new_hop, attr)
        return ProcedureOut(attr=attr2, msg=attr2 + 1.0, active=updated)

    def hyperedge(step, ids, attr, msg, card):
        new_hop = msg
        updated = attr > new_hop
        attr2 = jnp.where(updated, new_hop, attr)
        return ProcedureOut(attr=attr2, msg=attr2, active=updated)

    nv, ne = hg.n_vertices, hg.n_hyperedges
    hg0 = hg.with_attrs(
        v_attr=jnp.full((nv,), INF),
        he_attr=jnp.full((ne,), INF),
    )
    return AlgorithmSpec(
        hg0=hg0,
        initial_msg=INF,
        v_program=Program(procedure=vertex, combiner="min"),
        he_program=Program(procedure=hyperedge, combiner="min"),
        max_iters=max_iters,
        extract=lambda out: (out.v_attr, out.he_attr),
        name="sssp",
        touches_hyperedge_state=True,  # per-hyperedge distances persist
    )


def shortest_paths(hg, source, max_iters=64, *, engine=None):
    """Returns (vertex_hops, hyperedge_hops); unreachable = +inf."""
    return resolve_engine(engine).run(
        shortest_paths_spec(hg, source, max_iters)
    ).value
