"""Single-Source Shortest Paths (paper Listing 5).

Distance unit = number of hyperedges traversed (vertex->he hop costs 1).
Only updated entities broadcast (sparse activation); the engine halts the
scan when every entity is inactive — the paper's termination condition.

The *source* is the per-request axis: ``bind_query`` seeds distance 0 at
the query vertex on an all-infinite initial state, and the step-0
bootstrap activates every finite-distance vertex (equivalent to the
classic "source activates itself" formulation, but source-independent in
the traced program).  One ``Engine.compile`` therefore serves any source
— and ``run_batch`` serves a whole batch of sources — with zero
recompilation.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.api import Program, ProcedureOut
from repro.core.hypergraph import HyperGraph
from repro.algorithms.spec import AlgorithmSpec, resolve_engine

INF = jnp.float32(jnp.inf)


def shortest_paths_spec(
    hg: HyperGraph, source: int, max_iters: int = 64
) -> AlgorithmSpec:
    def vertex(step, ids, attr, msg, deg):
        new_hop = msg
        updated = attr > new_hop
        attr2 = jnp.where(updated, new_hop, attr)
        # Superstep 0: every vertex with a finite seeded distance (the
        # bound source) activates and broadcasts — Pregel-style source
        # bootstrap, expressed over state so the program itself is
        # source-independent (the source is a bindable query).
        boot = (step == 0) & jnp.isfinite(attr2)
        return ProcedureOut(
            attr=attr2, msg=attr2 + 1.0, active=updated | boot
        )

    def hyperedge(step, ids, attr, msg, card):
        new_hop = msg
        updated = attr > new_hop
        attr2 = jnp.where(updated, new_hop, attr)
        return ProcedureOut(attr=attr2, msg=attr2, active=updated)

    def init(hg: HyperGraph) -> HyperGraph:
        return hg.with_attrs(
            v_attr=jnp.full((hg.n_vertices,), INF),
            he_attr=jnp.full((hg.n_hyperedges,), INF),
        )

    def bind_query(hg0: HyperGraph, source) -> HyperGraph:
        src = jnp.asarray(source, jnp.int32)
        return hg0.with_attrs(v_attr=hg0.v_attr.at[src].set(0.0))

    return AlgorithmSpec(
        hg0=bind_query(init(hg), source),
        initial_msg=INF,
        v_program=Program(procedure=vertex, combiner="min"),
        he_program=Program(procedure=hyperedge, combiner="min"),
        max_iters=max_iters,
        extract=lambda out: (out.v_attr, out.he_attr),
        name="sssp",
        touches_hyperedge_state=True,  # per-hyperedge distances persist
        init=init,
        bind_query=bind_query,
        query0=int(source),
    )


def shortest_paths(hg, source=0, max_iters=64, *, sources=None,
                   engine=None):
    """Returns (vertex_hops, hyperedge_hops); unreachable = +inf.

    ``sources``: optional batch of source vertices — compiles the
    algorithm once and serves every source through
    ``CompiledAlgorithm.run_batch`` (results gain a leading batch axis).
    """
    eng = resolve_engine(engine)
    if sources is not None:
        if source != 0:
            raise ValueError(
                "pass either source (single query) or sources (batched "
                "serve), not both"
            )
        spec = shortest_paths_spec(hg, 0, max_iters)
        return eng.compile(spec).run_batch(
            np.asarray(sources, np.int32)
        ).value
    return eng.run(shortest_paths_spec(hg, source, max_iters)).value
