"""Connected components over the hypergraph (min-label flood fill).

Two vertices are connected iff some hyperedge path joins them.  Min-combined
label propagation with sparse activation; terminates via the engine's halt
flag well before ``max_iters`` on small-diameter hypergraphs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.api import Program, ProcedureOut
from repro.core.hypergraph import HyperGraph
from repro.algorithms.spec import AlgorithmSpec, resolve_engine


def connected_components_spec(
    hg: HyperGraph, max_iters: int = 128
) -> AlgorithmSpec:
    def vertex(step, ids, attr, msg, deg):
        boot = step == 0
        candidate = jnp.where(boot, ids, jnp.minimum(attr, msg))
        updated = boot | (candidate < attr)
        return ProcedureOut(attr=candidate, msg=candidate, active=updated)

    def hyperedge(step, ids, attr, msg, card):
        candidate = jnp.minimum(attr, msg)
        updated = candidate < attr
        return ProcedureOut(attr=candidate, msg=candidate, active=updated)

    imax = jnp.iinfo(jnp.int32).max
    nv, ne = hg.n_vertices, hg.n_hyperedges
    hg0 = hg.with_attrs(
        v_attr=jnp.full((nv,), imax, jnp.int32),
        he_attr=jnp.full((ne,), imax, jnp.int32),
    )
    return AlgorithmSpec(
        hg0=hg0,
        initial_msg=jnp.int32(imax),
        v_program=Program(procedure=vertex, combiner="min"),
        he_program=Program(procedure=hyperedge, combiner="min"),
        max_iters=max_iters,
        extract=lambda out: (out.v_attr, out.he_attr),
        name="connected_components",
        touches_hyperedge_state=True,  # per-hyperedge labels persist
    )


def connected_components(hg, max_iters=128, *, engine=None):
    """Returns (vertex_component, hyperedge_component) int32 labels.
    The component id is the minimum member vertex id."""
    return resolve_engine(engine).run(
        connected_components_spec(hg, max_iters)
    ).value
