"""Hypergraph random walk with restart (the paper's RW application).

One walk step: vertex -> uniformly-random incident hyperedge -> uniformly-
random member vertex (Zhou et al.'s hypergraph walk).  Power iteration on
that Markov chain with restart mass ``alpha`` at the seed distribution.

The restart distribution rides in the vertex state (``v_attr = (p,
restart)``) instead of a traced-in closure constant, which makes it the
per-request axis: ``bind_query`` rebinds a one-hot restart at a seed
vertex, so one ``Engine.compile`` serves personalized walks from any
seed — ``run_batch`` over a seed batch is the personalized-PageRank
serving pattern.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.api import Program, ProcedureOut
from repro.core.hypergraph import HyperGraph
from repro.algorithms.spec import AlgorithmSpec, resolve_engine


def random_walk_spec(
    hg: HyperGraph,
    seeds: jnp.ndarray | None = None,
    iters: int = 30,
    alpha: float = 0.15,
) -> AlgorithmSpec:
    def vertex(step, ids, attr, msg, deg):
        p, restart = attr
        d = jnp.maximum(deg.astype(jnp.float32), 1.0)
        dangling = (deg == 0).astype(jnp.float32)
        # dangling vertices (no incident hyperedge) keep their mass in
        # place instead of leaking it — the walk stays a distribution.
        p_next = jnp.where(
            step == 0,
            restart,
            (1.0 - alpha) * (msg + p * dangling) + alpha * restart,
        )
        return ProcedureOut(
            attr=(p_next, restart), msg=p_next / d * (1.0 - dangling)
        )

    def hyperedge(step, ids, attr, msg, card):
        c = jnp.maximum(card.astype(jnp.float32), 1.0)
        return ProcedureOut(attr=msg, msg=msg / c)

    def init(hg: HyperGraph) -> HyperGraph:
        # ``seeds`` live here (not just in hg0) so a compiled handle
        # serving a NEW same-bucket hypergraph keeps the seeded restart
        # instead of silently reverting to the uniform walk.
        nv = hg.n_vertices
        if seeds is None:
            restart = jnp.full((nv,), 1.0 / max(nv, 1), jnp.float32)
        else:
            restart = jnp.zeros((nv,), jnp.float32).at[seeds].set(
                1.0 / seeds.shape[0]
            )
        return hg.with_attrs(
            v_attr=(restart, restart),
            he_attr=jnp.zeros((hg.n_hyperedges,), jnp.float32),
        )

    def bind_query(hg0: HyperGraph, seed) -> HyperGraph:
        """Personalize: all restart mass on one seed vertex."""
        p, _ = hg0.v_attr
        ids = jnp.arange(p.shape[0], dtype=jnp.int32)
        restart = (ids == jnp.asarray(seed, jnp.int32)).astype(
            jnp.float32
        )
        return hg0.with_attrs(v_attr=(restart, restart))

    if seeds is not None:
        seeds = jnp.asarray(seeds)
    return AlgorithmSpec(
        hg0=init(hg),
        initial_msg=jnp.float32(0.0),
        v_program=Program(procedure=vertex, combiner="sum"),
        he_program=Program(procedure=hyperedge, combiner="sum"),
        max_iters=iters,
        extract=lambda out: out.v_attr[0],
        name="random_walk",
        # hyperedges only relay mass (attr never read across steps), but
        # the cardinality normalization has no clique equivalent:
        touches_hyperedge_state=True,
        init=init,
        bind_query=bind_query,
    )


def random_walk(hg, seeds=None, iters=30, alpha=0.15, *, seed_batch=None,
                engine=None):
    """Returns the stationary visit distribution over vertices.

    ``seed_batch``: optional batch of seed vertices — compiles once and
    serves a personalized walk per seed via ``run_batch`` (the result
    gains a leading batch axis; row b restarts at ``seed_batch[b]``).
    """
    eng = resolve_engine(engine)
    if seed_batch is not None:
        if seeds is not None:
            raise ValueError(
                "pass either seeds (one walk, arbitrary restart set) or "
                "seed_batch (one personalized walk per seed), not both"
            )
        spec = random_walk_spec(hg, None, iters, alpha)
        return eng.compile(spec).run_batch(
            np.asarray(seed_batch, np.int32)
        ).value
    return eng.run(random_walk_spec(hg, seeds, iters, alpha)).value
