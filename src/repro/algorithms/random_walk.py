"""Hypergraph random walk with restart (the paper's RW application).

One walk step: vertex -> uniformly-random incident hyperedge -> uniformly-
random member vertex (Zhou et al.'s hypergraph walk).  Power iteration on
that Markov chain with restart mass ``alpha`` at the seed distribution.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.api import Program, ProcedureOut
from repro.core.hypergraph import HyperGraph
from repro.algorithms.spec import AlgorithmSpec, resolve_engine


def random_walk_spec(
    hg: HyperGraph,
    seeds: jnp.ndarray | None = None,
    iters: int = 30,
    alpha: float = 0.15,
) -> AlgorithmSpec:
    nv, ne = hg.n_vertices, hg.n_hyperedges
    if seeds is None:
        restart_full = jnp.full((nv,), 1.0 / nv, jnp.float32)
    else:
        restart_full = jnp.zeros((nv,), jnp.float32).at[seeds].set(
            1.0 / seeds.shape[0]
        )

    def vertex(step, ids, attr, msg, deg):
        restart = jnp.take(restart_full, jnp.minimum(ids, nv - 1), axis=0)
        d = jnp.maximum(deg.astype(jnp.float32), 1.0)
        dangling = (deg == 0).astype(jnp.float32)
        # dangling vertices (no incident hyperedge) keep their mass in
        # place instead of leaking it — the walk stays a distribution.
        p = jnp.where(
            step == 0,
            restart,
            (1.0 - alpha) * (msg + attr * dangling) + alpha * restart,
        )
        return ProcedureOut(attr=p, msg=p / d * (1.0 - dangling))

    def hyperedge(step, ids, attr, msg, card):
        c = jnp.maximum(card.astype(jnp.float32), 1.0)
        return ProcedureOut(attr=msg, msg=msg / c)

    hg0 = hg.with_attrs(
        v_attr=restart_full, he_attr=jnp.zeros((ne,), jnp.float32)
    )
    return AlgorithmSpec(
        hg0=hg0,
        initial_msg=jnp.float32(0.0),
        v_program=Program(procedure=vertex, combiner="sum"),
        he_program=Program(procedure=hyperedge, combiner="sum"),
        max_iters=iters,
        extract=lambda out: out.v_attr,
        name="random_walk",
        # hyperedges only relay mass (attr never read across steps), but
        # the cardinality normalization has no clique equivalent:
        touches_hyperedge_state=True,
    )


def random_walk(hg, seeds=None, iters=30, alpha=0.15, *, engine=None):
    """Returns the stationary visit distribution over vertices."""
    return resolve_engine(engine).run(
        random_walk_spec(hg, seeds, iters, alpha)
    ).value
