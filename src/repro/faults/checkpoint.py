"""Superstep checkpoint/resume: the engine-side analogue of lineage.

MESH-on-Spark replays a lost executor's superstep from RDD lineage; the
equivalent here is snapshotting the scan carry — ``(step, v_attr,
he_attr, msg_to_v, halted)`` — every ``checkpoint_every`` superstep
pairs, so a killed process resumes mid-algorithm instead of restarting.

Bitwise contract (tested): the drivers below run the SAME per-iteration
scan body as ``compute`` / ``distributed_compute`` (shared via
``_halting_body`` / the distributed ``_body``), just split into
host-side chunks of ``every`` pairs with the carry threaded through.
Running k1 pairs, snapshotting, and running k2 more therefore executes
the identical computation in the identical order as one uninterrupted
``k1 + k2`` run — resumed results and activity traces are bitwise equal.

Snapshots reuse ``train/checkpoint.py`` verbatim: per-leaf ``.npy`` +
hashed JSON manifest, atomic ``.tmp``-then-rename publish, and
``latest_checkpoint`` crash-loop restart semantics.  A checkpoint that
fails to restore (corrupt, foreign, wrong shapes) degrades gracefully:
the run restarts from superstep 0 rather than raising — the same
quarantine-and-recompute posture as the disk executable cache.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import (
    compute_resumable,
    compute_resumable_jit,
    initial_superstep_state,
)
from repro.obs.trace import maybe_span
from repro.train.checkpoint import (
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)


def _restore_or_fresh(ckpt_dir, template, tracer, metrics):
    """Latest durable snapshot, or the fresh carry when none loads."""
    path = latest_checkpoint(ckpt_dir) if ckpt_dir else None
    if path is None:
        return template, 0
    try:
        with maybe_span(tracer, "faults.checkpoint_restore", cat="faults",
                        path=path):
            state, done = restore_checkpoint(path, template)
        if metrics is not None:
            metrics.counter("faults.checkpoint.restored").inc()
        return state, int(done)
    except Exception:
        # Degrade, don't die: a corrupt snapshot must not be worse than
        # having no snapshot at all.
        if metrics is not None:
            metrics.counter("faults.checkpoint.restore_failed").inc()
        return template, 0


def _finish_traces(traces, done, max_iters):
    """Concatenate per-chunk traces, zero-padding iterations skipped
    after global halt — matching ``compute``'s full-length trace."""
    tail = max_iters - done
    if tail:
        zeros = jnp.zeros((tail,), jnp.int32)
        traces.append((zeros, zeros))
    v_tr = jnp.concatenate([t[0] for t in traces])
    he_tr = jnp.concatenate([t[1] for t in traces])
    return v_tr, he_tr


def checkpointed_compute(
    hg,
    max_iters: int,
    initial_msg,
    v_program,
    he_program,
    *,
    every: int,
    ckpt_dir: str | None = None,
    return_stats: bool = False,
    n_real=None,
    delivery=None,
    jit: bool = True,
    tracer=None,
    metrics=None,
    fault_injector=None,
):
    """``engine.compute`` in checkpointed chunks of ``every`` superstep
    pairs; resumes from ``ckpt_dir``'s latest snapshot when one exists.

    Same signature contract as ``compute``: returns the updated
    hypergraph (plus the full-length ``(v_trace, he_trace)`` when
    ``return_stats``)."""
    template = initial_superstep_state(hg, initial_msg)
    state, done = _restore_or_fresh(ckpt_dir, template, tracer, metrics)
    runner = compute_resumable_jit if jit else compute_resumable
    traces = []
    while done < max_iters:
        k = min(every, max_iters - done)
        state, tr = runner(
            hg, k, state, v_program, he_program,
            n_real=n_real, delivery=delivery,
        )
        traces.append(tr)
        done += k
        if ckpt_dir:
            with maybe_span(tracer, "faults.checkpoint_save", cat="faults",
                            step=done):
                save_checkpoint(ckpt_dir, done, state)
            if metrics is not None:
                metrics.counter("faults.checkpoint.saved").inc()
        if fault_injector is not None:
            fault_injector.maybe_raise("checkpoint.chunk", step=done)
        if bool(state["halted"]):  # analysis: ignore[host-sync] — chunk boundary, cold path
            break
    out = hg.with_attrs(
        v_attr=state["v_attr"], he_attr=state["he_attr"]
    )
    if return_stats:
        return out, _finish_traces(traces, done, max_iters)
    return out


def checkpointed_distributed_compute(
    hg,
    plan,
    mesh,
    max_iters: int,
    initial_msg,
    v_program,
    he_program,
    *,
    every: int,
    ckpt_dir: str | None = None,
    axis: str = "data",
    backend: str = "replicated",
    delivery: str = "xla",
    return_stats: bool = False,
    tracer=None,
    metrics=None,
    fault_injector=None,
):
    """``distributed_compute`` in checkpointed chunks — the sharded twin
    of ``checkpointed_compute``; one snapshot covers the full padded
    carry, so an elastic restart restores under the current mesh."""
    from repro.core.distributed import (
        distributed_compute_resumable,
        distributed_initial_state,
    )

    template = distributed_initial_state(hg, plan, initial_msg)
    state, done = _restore_or_fresh(ckpt_dir, template, tracer, metrics)
    traces = []
    while done < max_iters:
        k = min(every, max_iters - done)
        state, tr = distributed_compute_resumable(
            hg, plan, mesh, k, state, v_program, he_program,
            axis=axis, backend=backend, delivery=delivery,
        )
        traces.append(tr)
        done += k
        if ckpt_dir:
            with maybe_span(tracer, "faults.checkpoint_save", cat="faults",
                            step=done):
                save_checkpoint(ckpt_dir, done, state)
            if metrics is not None:
                metrics.counter("faults.checkpoint.saved").inc()
        if fault_injector is not None:
            fault_injector.maybe_raise("checkpoint.chunk", step=done)
        if bool(state["halted"]):  # analysis: ignore[host-sync] — chunk boundary, cold path
            break
    import jax

    unpad_v = jax.tree.map(
        lambda x: x[: hg.n_vertices], state["v_attr"]
    )
    unpad_he = jax.tree.map(
        lambda x: x[: hg.n_hyperedges], state["he_attr"]
    )
    out = hg.with_attrs(v_attr=unpad_v, he_attr=unpad_he)
    if return_stats:
        return out, _finish_traces(traces, done, max_iters)
    return out
