"""Fault tolerance: injection harness, typed errors, checkpoint/resume.

MESH inherits fault tolerance from Spark (RDD lineage replays a lost
executor's superstep); this reproduction has no such substrate, so the
reliability layer is built here instead:

* ``errors``   — the typed taxonomy every degradation path speaks
  (``FaultError`` and friends); callers can catch one base class.
* ``plan``     — ``FaultPlan``: named failure points x deterministic
  trigger schedules (nth-call / every-nth / probabilistic-with-seed /
  always), JSON round-trippable for ``--fault-plan``.
* ``inject``   — ``FaultInjector``: attaches to ``Engine`` /
  ``Frontend`` duck-typed like ``tracer`` / ``disk_cache``; hot paths
  branch on ``is None`` so an absent injector costs nothing.
* ``checkpoint`` — superstep checkpoint/resume on the iterative seam
  (``ExecutionConfig.checkpoint_every``), the engine-side analogue of
  lineage: resume mid-algorithm bitwise-equal to an uninterrupted run.
"""
from repro.faults.errors import (
    CheckpointError,
    CircuitOpen,
    CorruptCacheEntry,
    DeadlineExceeded,
    FaultError,
    FrontendClosed,
    InjectedFault,
    Overloaded,
    PoisonQuery,
    ReplicaLost,
    TransientExecuteError,
    is_transient,
)
from repro.faults.inject import FaultInjector
from repro.faults.plan import FAULT_POINTS, FaultPlan, FaultRule

__all__ = [
    "FAULT_POINTS",
    "CheckpointError",
    "CircuitOpen",
    "CorruptCacheEntry",
    "DeadlineExceeded",
    "FaultError",
    "FaultInjector",
    "FaultPlan",
    "FaultRule",
    "FrontendClosed",
    "InjectedFault",
    "Overloaded",
    "PoisonQuery",
    "ReplicaLost",
    "TransientExecuteError",
    "is_transient",
]
