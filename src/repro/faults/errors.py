"""The typed error taxonomy of the fault-tolerance layer.

Every failure the engine or serving tier can surface to a caller is one
of these classes — a submitted request either resolves with a result or
with a ``FaultError`` subclass; nothing hangs and nothing raises a bare
``Exception`` from the resilience paths.  ``FaultError`` subclasses
``RuntimeError`` so pre-taxonomy callers that caught ``RuntimeError``
keep working.

Transience is a property of the *class* (plus the ``transient`` flag on
``InjectedFault``): ``is_transient`` is the single predicate the serve
tier's retry loop consults, so a new retryable failure mode is one
subclass away.
"""
from __future__ import annotations


class FaultError(RuntimeError):
    """Base of the taxonomy; every typed failure is one of these."""


class InjectedFault(FaultError):
    """Raised by the ``FaultInjector`` at a named failure point.

    ``transient=True`` marks the injection as retryable (the serve
    tier's backoff loop will re-execute); ``transient=False`` models a
    hard failure that must degrade or surface.
    """

    def __init__(self, message: str, *, point: str = "",
                 transient: bool = True):
        super().__init__(message)
        self.point = point
        self.transient = transient


class TransientExecuteError(FaultError):
    """An execute failure expected to succeed on retry (e.g. a device
    OOM under transient pressure, a preempted worker)."""


class DeadlineExceeded(FaultError):
    """The request's hard deadline passed before it could be served.

    The future RESOLVES with this error — an expired request never
    hangs, it fails typed."""


class FrontendClosed(FaultError):
    """The front-end was closed: ``submit`` after ``close()`` raises
    this immediately, and requests still queued at close time have
    their futures failed with it (never silently dropped)."""


class PoisonQuery(FaultError):
    """One query deterministically fails its batch.  Batch bisection
    isolated it: this error carries the original cause (``__cause__``)
    and fails only the poison request, not its flush-mates."""


class CircuitOpen(FaultError):
    """The per-signature circuit breaker is open: recent flushes for
    this compiled path failed repeatedly, so requests fail fast instead
    of burning execute retries until the cooldown elapses."""


class CorruptCacheEntry(FaultError):
    """A disk-cache entry failed its checksum / deserialize — the file
    is quarantined (renamed ``.corrupt``) and the executable recompiled."""


class CheckpointError(FaultError):
    """A superstep checkpoint could not be saved or restored."""


class ReplicaLost(FaultError):
    """A request exhausted its failover budget: every replica it was
    routed to died (missed heartbeats / broken pipe) before answering.
    The future RESOLVES with this error after ``MAX_FAILOVERS``
    re-routes — bounded, typed, never a hang."""


class Overloaded(FaultError):
    """The router shed this request at admission: total queue depth
    (pending + in-flight across the replica pool) hit the backpressure
    limit.  Fail-fast load shedding — the client should back off and
    retry; the pool keeps serving what it already accepted."""


def is_transient(err: BaseException) -> bool:
    """Should the serve tier retry after ``err``?  The one predicate the
    backoff loop consults."""
    if isinstance(err, TransientExecuteError):
        return True
    if isinstance(err, InjectedFault):
        return err.transient
    return False
