"""``FaultInjector``: fires a ``FaultPlan``'s scheduled failures.

Attaches to ``Engine(fault_injector=...)`` / ``Frontend(...)`` exactly
like ``tracer`` and ``disk_cache`` — duck-typed, and every instrumented
hot path branches on ``fault_injector is None`` first, so the absent
case costs one attribute load and a predictable branch (benchmarked in
``bench_serve_tier``'s fault-free-overhead gate).

Determinism contract: firing is a pure function of the plan and the
per-point call sequence.  Counters are per-injector and lock-protected
(the serve worker thread and the caller thread both hit them); the
probabilistic trigger draws from a per-rule ``random.Random(seed)``
stream advanced once per call to its point, so replaying the same
traffic replays the same faults.
"""
from __future__ import annotations

import random
import threading
from collections import Counter

from repro.faults.errors import (
    CorruptCacheEntry,
    InjectedFault,
    TransientExecuteError,
)
from repro.faults.plan import FaultPlan


class FaultInjector:
    """Raise the plan's scheduled fault when an instrumented point is hit.

    ``maybe_raise(point)`` is the whole API surface the instrumented
    code uses; ``calls`` / ``fired`` / ``snapshot()`` are for tests and
    the CLI's chaos report.
    """

    def __init__(self, plan: FaultPlan | None = None):
        self.plan = plan if plan is not None else FaultPlan()
        self._lock = threading.Lock()
        self._calls: Counter[str] = Counter()
        self._fired: Counter[str] = Counter()
        self._rule_fired: Counter[int] = Counter()
        self._rng: dict[int, random.Random] = {
            i: random.Random(rule.seed)
            for i, rule in enumerate(self.plan.rules)
            if rule.trigger == "prob"
        }
        # point -> [(rule_index, rule)]; points with no rules never take
        # the lock's slow path beyond the counter bump.
        self._by_point: dict[str, list] = {}
        for i, rule in enumerate(self.plan.rules):
            self._by_point.setdefault(rule.point, []).append((i, rule))

    @classmethod
    def from_json(cls, obj) -> "FaultInjector":
        return cls(FaultPlan.from_json(obj))

    def maybe_raise(self, point: str, **ctx) -> None:
        """Advance the point's call counter; raise if a rule fires."""
        with self._lock:
            self._calls[point] += 1
            call_idx = self._calls[point]
            rules = self._by_point.get(point)
            if not rules:
                return
            for i, rule in rules:
                if rule.times is not None and self._rule_fired[i] >= rule.times:
                    continue
                if not self._triggers(i, rule, call_idx):
                    continue
                self._rule_fired[i] += 1
                self._fired[point] += 1
                err = self._make_error(rule, point, call_idx, ctx)
                break
            else:
                return
        raise err

    def _triggers(self, i: int, rule, call_idx: int) -> bool:
        if rule.trigger == "always":
            return True
        if rule.trigger == "nth":
            return call_idx == rule.n
        if rule.trigger == "every":
            return call_idx % rule.n == 0
        # prob: one draw per call, deterministic per rule seed.
        return self._rng[i].random() < rule.p

    @staticmethod
    def _make_error(rule, point, call_idx, ctx):
        detail = f" ({ctx})" if ctx else ""
        msg = (
            f"injected {rule.error} fault at {point!r} "
            f"(call #{call_idx}){detail}"
        )
        if rule.error == "corrupt":
            return CorruptCacheEntry(msg)
        if rule.error == "transient":
            return TransientExecuteError(msg)
        return InjectedFault(msg, point=point, transient=False)

    # -- inspection --------------------------------------------------------

    def calls(self, point: str) -> int:
        with self._lock:
            return self._calls[point]

    def fired(self, point: str | None = None) -> int:
        with self._lock:
            if point is None:
                return sum(self._fired.values())
            return self._fired[point]

    def snapshot(self) -> dict:
        """Per-point calls/fired, plus ``never_fired``: points the plan
        targets whose rules never triggered — chaos CI asserts this is
        empty to prove the plan actually exercised every scheduled
        failure (a plan that silently misses its points tests nothing)."""
        with self._lock:
            planned = {r.point for r in self.plan.rules}
            return {
                "calls": dict(self._calls),
                "fired": dict(self._fired),
                "never_fired": sorted(
                    p for p in planned if self._fired[p] == 0
                ),
            }
