"""``FaultPlan``: a deterministic schedule of injected failures.

A plan is a list of ``FaultRule``s.  Each rule names a **failure
point** — a string the instrumented code passes to
``FaultInjector.maybe_raise`` — and a **trigger schedule** deciding on
which calls the fault fires:

* ``always``            — every call (bounded by ``times``);
* ``nth`` (``n=k``)     — exactly the k-th call to that point (1-based);
* ``every`` (``n=k``)   — every k-th call;
* ``prob`` (``p``, ``seed``) — each call independently with probability
  ``p`` from a per-rule ``random.Random(seed)`` stream, so a plan is a
  pure function of (seed, call sequence): same traffic, same faults.

Plans round-trip through JSON (``to_json`` / ``from_json``) so the
``--fault-plan`` CLI flag and the nightly chaos replay can commit them
as artifacts.

The failure points the engine + serve tier instrument today:

==================  ======================================================
``disk.read``       ``DiskExecutableCache.load`` (before the file read)
``disk.write``      ``DiskExecutableCache.store`` (before the write)
``disk.deserialize``executable deserialization after a successful read
``compile.aot``     AOT ``lower().compile()`` in ``_DiskBackedExecutable``
``layout.build``    fused-delivery layout build in ``_prepared``
``execute``         ``CompiledAlgorithm`` run / run_batch dispatch
``serve.flush``     ``Frontend._run_flush`` (before the batch executes)
``serve.worker``    the front-end worker loop (models a thread crash)
``checkpoint.chunk``after each superstep checkpoint chunk is saved
``replica.crash``   the replica request loop — a fire hard-exits the
                    process (``os._exit``), modeling kill -9
``replica.hang``    the replica request loop — a fire stops heartbeats
                    without exiting, modeling a wedged process
``router.route``    ``Router.submit`` routing — a fire resolves that
                    request with the injected typed error
==================  ======================================================

Unknown points are legal in a plan (they simply never fire) so plans
stay forward-compatible; ``FaultPlan.validate`` warns on typos.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

FAULT_POINTS = (
    "disk.read",
    "disk.write",
    "disk.deserialize",
    "compile.aot",
    "layout.build",
    "execute",
    "serve.flush",
    "serve.worker",
    "checkpoint.chunk",
    "replica.crash",
    "replica.hang",
    "router.route",
)

_TRIGGERS = ("always", "nth", "every", "prob")
_ERRORS = ("transient", "fatal", "corrupt")


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One scheduled failure: *where* (point), *when* (trigger), *what*
    (error kind — ``transient``/``fatal`` map onto the taxonomy's
    retryability split; ``corrupt`` raises ``CorruptCacheEntry``)."""

    point: str
    trigger: str = "always"        # always | nth | every | prob
    n: int | None = None           # for nth / every
    p: float | None = None         # for prob
    seed: int = 0                  # for prob
    times: int | None = None       # max total fires (None = unbounded)
    error: str = "transient"       # transient | fatal | corrupt

    def __post_init__(self):
        if self.trigger not in _TRIGGERS:
            raise ValueError(
                f"unknown trigger {self.trigger!r}; one of {_TRIGGERS}"
            )
        if self.trigger in ("nth", "every") and (
            self.n is None or self.n < 1
        ):
            raise ValueError(f"trigger {self.trigger!r} needs n >= 1")
        if self.trigger == "prob" and not (
            self.p is not None and 0.0 <= self.p <= 1.0
        ):
            raise ValueError("trigger 'prob' needs p in [0, 1]")
        if self.error not in _ERRORS:
            raise ValueError(
                f"unknown error kind {self.error!r}; one of {_ERRORS}"
            )

    def to_dict(self) -> dict:
        out = {"point": self.point, "trigger": self.trigger,
               "error": self.error}
        if self.n is not None:
            out["n"] = self.n
        if self.p is not None:
            out["p"] = self.p
        if self.seed:
            out["seed"] = self.seed
        if self.times is not None:
            out["times"] = self.times
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "FaultRule":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown FaultRule fields: {sorted(extra)}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered tuple of rules; the unit the CLI / tests commit."""

    rules: tuple[FaultRule, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))

    def for_point(self, point: str) -> tuple[FaultRule, ...]:
        return tuple(r for r in self.rules if r.point == point)

    def validate(self) -> list[str]:
        """Non-fatal lint: rule points nothing instruments today.  Each
        warning lists the valid inventory so a typo'd plan is fixable
        from the warning alone."""
        inventory = ", ".join(FAULT_POINTS)
        return [
            f"rule targets unknown point {r.point!r}; "
            f"instrumented points: {inventory}"
            for r in self.rules
            if r.point not in FAULT_POINTS
        ]

    # -- JSON round trip ---------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(
            {"rules": [r.to_dict() for r in self.rules]}, indent=1
        )

    @classmethod
    def from_json(cls, obj: Any) -> "FaultPlan":
        """Accept a JSON string, a parsed dict, or a list of rule dicts."""
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        if isinstance(obj, dict):
            obj = obj.get("rules", [])
        if not isinstance(obj, (list, tuple)):
            raise ValueError(
                "fault plan must be {'rules': [...]} or a rule list"
            )
        return cls(rules=tuple(FaultRule.from_dict(dict(r)) for r in obj))
