"""Structured trace spans: where a superstep's wall time actually goes.

``Tracer`` records phase spans — layout build, lowering/compile,
disk-cache load, execute, per-flush serve pump — into a bounded ring
buffer, and exports them as Chrome-trace JSON (``tracer.export(path)``)
loadable in Perfetto / ``chrome://tracing``.

Design constraints, in order:

* **zero overhead when absent** — hot paths branch on ``tracer is
  None`` (or call ``maybe_span``, which returns a no-op context);
  nothing is computed, allocated or locked without a tracer attached
  (benchmarked in ``benchmarks/bench_obs.py``);
* **clock-injected** — ``Tracer(clock=...)`` like the front-end, so
  span timing is deterministic under test;
* **bounded** — a ``deque(maxlen=capacity)`` ring; long serve loops
  keep the newest spans and count the ``dropped`` rest;
* **device-time aware** — jax dispatch returns before the device
  finishes, so a span around ``exe(*args)`` alone measures enqueue
  time.  ``tracer.block(span, value)`` runs ``block_until_ready`` and
  records the wait as ``args["device_wait_s"]``: span duration =
  dispatch + device completion, the wall time a caller actually sees.

Attach with ``Engine(tracer=Tracer())`` — duck-typed like
``disk_cache``: anything with ``span``/``block`` works.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager, nullcontext
from typing import Any


class Span:
    """One completed (or open) phase: name, category, [t0, t0+dur)."""

    __slots__ = ("name", "cat", "t0", "dur_s", "tid", "depth", "args")

    def __init__(self, name, cat, t0, tid, depth, args):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.dur_s = 0.0
        self.tid = tid
        self.depth = depth
        self.args = args

    def to_chrome(self) -> dict:
        """One Chrome-trace complete event ("ph": "X", microseconds)."""
        return {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": self.t0 * 1e6,
            "dur": self.dur_s * 1e6,
            "pid": os.getpid(),
            "tid": self.tid,
            "args": {"depth": self.depth, **self.args},
        }

    def __repr__(self):
        return (
            f"Span({self.name!r}, cat={self.cat!r}, "
            f"dur={self.dur_s * 1e3:.3f}ms, depth={self.depth})"
        )


class Tracer:
    """Ring-buffered span recorder; thread-safe, nesting per thread."""

    def __init__(self, capacity: int = 4096, clock=time.perf_counter):
        self.capacity = int(capacity)
        self.clock = clock
        self._lock = threading.Lock()
        self._spans: deque[Span] = deque(maxlen=self.capacity)
        self._local = threading.local()
        self.total = 0  # spans ever recorded; dropped = total - len(ring)

    @property
    def dropped(self) -> int:
        return max(self.total - len(self._spans), 0)

    @contextmanager
    def span(self, name: str, cat: str = "engine", **args):
        """Record one phase; yields the ``Span`` so callers can attach
        measurements (``sp.args[...] = ...``) before it closes.  Spans
        nest per thread (``depth`` reflects the enclosing stack)."""
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        sp = Span(
            name, cat, self.clock(), threading.get_ident(),
            len(stack), dict(args),
        )
        stack.append(sp)
        try:
            yield sp
        finally:
            sp.dur_s = self.clock() - sp.t0
            stack.pop()
            with self._lock:
                self._spans.append(sp)
                self.total += 1

    def block(self, sp: Span, value: Any) -> Any:
        """``block_until_ready(value)``, recording the device wait on
        the span; returns ``value``.  The dispatch/completion split is
        the one number XLA won't tell you from wall time alone."""
        t0 = self.clock()
        try:
            import jax

            jax.block_until_ready(value)
        except Exception:  # numpy-only values / test doubles
            pass
        sp.args["device_wait_s"] = self.clock() - t0
        return value

    # -- inspection / export -----------------------------------------------

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.total = 0

    def chrome_trace(self) -> dict:
        """The ``{"traceEvents": [...]}`` payload Perfetto loads."""
        return {
            "traceEvents": [sp.to_chrome() for sp in self.spans()],
            "displayTimeUnit": "ms",
            "otherData": {"dropped_spans": self.dropped},
        }

    def export(self, path: str) -> str:
        """Write Chrome-trace JSON; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


def maybe_span(tracer, name: str, cat: str = "engine", **args):
    """``tracer.span(...)`` or a no-op context yielding ``None``."""
    if tracer is None:
        return nullcontext(None)
    return tracer.span(name, cat=cat, **args)
