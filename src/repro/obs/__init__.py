"""Engine-wide observability: trace spans, explain/calibrate, metrics.

Three cross-cutting pieces, wired through core and serve:

* ``trace``     — clock-injected, ring-buffered ``Tracer`` recording
  phase spans (layout build, compile, disk load, execute, serve flush)
  with device time from ``block_until_ready`` deltas; exports
  Chrome-trace JSON loadable in Perfetto.  Attach with
  ``Engine(tracer=Tracer())``; zero overhead when absent.
* ``metrics``   — the unified ``MetricsRegistry`` (counters, gauges,
  log-spaced histograms, snapshot providers) every counting subsystem
  registers into; one ``snapshot()``, surfaced via ``--metrics-json``
  on the launchers and merged into ``Frontend.stats()``.
  ``LatencyHistogram`` lives here (``serve.metrics`` re-exports it).
* ``calibrate`` — predicted-vs-measured residuals per `auto` axis:
  ``Engine.explain(spec)`` reports every candidate's predicted cost
  without executing; ``Engine.run`` enriches ``Result.decision`` with
  measured counterparts; this module compares the two (and
  ``bench_delivery``'s regime table) in log2 space.
"""
from repro.obs.calibrate import (
    decision_residuals,
    delivery_calibration,
    delivery_traffic_pair,
    executed_supersteps,
    fused_traffic,
    reference_traffic,
    residual_log2,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    LatencyHistogram,
    MetricsRegistry,
    default_registry,
    reset_default_registry,
    weak_provider,
)
from repro.obs.trace import Span, Tracer, maybe_span

__all__ = [
    "Counter",
    "Gauge",
    "LatencyHistogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "decision_residuals",
    "default_registry",
    "delivery_calibration",
    "delivery_traffic_pair",
    "executed_supersteps",
    "fused_traffic",
    "maybe_span",
    "reference_traffic",
    "reset_default_registry",
    "residual_log2",
    "weak_provider",
]
