"""The unified metrics registry: one ``snapshot()`` across the engine.

Every subsystem that counts something — the serving front-end
(``ServeMetrics``), the Engine executable LRU, ``DiskExecutableCache``,
the delivery layout builders — registers into one ``MetricsRegistry``
instead of growing its own ad-hoc dict.  Two registration styles:

* **owned metrics** (``counter`` / ``gauge`` / ``histogram``): the
  registry get-or-creates the instrument by name and owns its storage.
  Used by code without a natural stats object (the layout builders).
* **providers** (``register_provider(name, fn)``): a zero-arg callable
  returning a dict, merged into every ``snapshot()``.  Used by
  subsystems that already keep their own state (``ServeMetrics``,
  ``Engine.cache_stats``, ``DiskExecutableCache.stats``).  Providers
  are typically registered through ``weak_provider`` so a registry held
  in a module-global never keeps an Engine alive: a dead provider
  returns ``None`` and is pruned at the next snapshot.

``LatencyHistogram`` lives here (moved from ``serve/metrics.py``, which
re-exports it): ONE log-spaced histogram implementation shared by the
serving tier and the registry.
"""
from __future__ import annotations

import bisect
import math
import threading
import weakref
from typing import Any, Callable

# Histogram bin upper bounds: 1us .. ~4600s, quarter-decade spacing —
# ~2x resolution per bin, 40 bins, fixed memory.
_BOUNDS = [1e-6 * (10 ** (i / 4)) for i in range(40)]


class LatencyHistogram:
    """Fixed-bin log histogram over seconds; quantiles report the upper
    bound of the covering bin (<= ~78% relative overestimate at
    quarter-decade spacing — plenty for p50-vs-p999 shape)."""

    def __init__(self):
        self._counts = [0] * (len(_BOUNDS) + 1)
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        seconds = max(float(seconds), 0.0)
        self._counts[bisect.bisect_left(_BOUNDS, seconds)] += 1
        self.count += 1
        self.total += seconds
        self.max = max(self.max, seconds)

    def quantile(self, q: float) -> float:
        """Upper bound of the bin holding the q-quantile (0 when empty)."""
        if self.count == 0:
            return 0.0
        target = math.ceil(q * self.count)
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= target:
                return _BOUNDS[i] if i < len(_BOUNDS) else self.max
        return self.max

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.total / self.count if self.count else 0.0,
            "p50_s": self.quantile(0.50),
            "p99_s": self.quantile(0.99),
            "p999_s": self.quantile(0.999),
            "max_s": self.max,
        }


class Counter:
    """A monotonically increasing count (lock shared with the registry)."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock):
        self.value = 0
        self._lock = lock

    def inc(self, n: int | float = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """A point-in-time value (last write wins)."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock):
        self.value = 0.0
        self._lock = lock

    def set(self, v) -> None:
        with self._lock:
            self.value = v

    def snapshot(self):
        return self.value


class _LockedHistogram(LatencyHistogram):
    """Registry-owned histogram: records under the registry lock
    (multiple writers; ``ServeMetrics`` keeps its own lock instead)."""

    def __init__(self, lock):
        super().__init__()
        self._lock = lock

    def record(self, seconds: float) -> None:
        with self._lock:
            super().record(seconds)

    def snapshot(self) -> dict:
        with self._lock:
            return super().snapshot()


class MetricsRegistry:
    """Counters/gauges/histograms + snapshot providers, one namespace."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: dict[str, Any] = {}
        self._providers: dict[str, Callable[[], dict | None]] = {}

    # -- owned instruments -------------------------------------------------

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self._lock)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> LatencyHistogram:
        return self._get(name, _LockedHistogram)

    # -- providers ---------------------------------------------------------

    def register_provider(
        self, name: str, fn: Callable[[], dict | None]
    ) -> str:
        """Merge ``fn()`` into every snapshot under ``name`` (suffixed
        ``#2``, ``#3``... on collision).  Returns the registered name.
        A provider returning ``None`` (dead weakref) is pruned."""
        with self._lock:
            base, n, unique = name, 2, name
            while unique in self._providers:
                unique = f"{base}#{n}"
                n += 1
            self._providers[unique] = fn
            return unique

    def unregister_provider(self, name: str) -> None:
        with self._lock:
            self._providers.pop(name, None)

    # -- the one snapshot --------------------------------------------------

    def snapshot(self) -> dict:
        """Every owned instrument + every live provider, one dict."""
        with self._lock:
            out: dict[str, Any] = {
                name: m.snapshot() for name, m in self._metrics.items()
            }
            dead = []
            for name, fn in self._providers.items():
                try:
                    v = fn()
                except Exception as err:  # noqa: BLE001 - keep snapshotting
                    v = {"error": repr(err)}
                if v is None:
                    dead.append(name)
                else:
                    out[name] = v
            for name in dead:
                del self._providers[name]
            return out


def weak_provider(method) -> Callable[[], dict | None]:
    """Wrap a bound method as a provider that dies with its owner."""
    ref = weakref.WeakMethod(method)

    def call():
        m = ref()
        return m() if m is not None else None

    return call


# -- the process-wide default (what Engine / serve wire into) --------------

_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _DEFAULT


def reset_default_registry() -> MetricsRegistry:
    """Fresh default registry (test isolation); returns the new one.
    Objects constructed before the reset keep writing to the old one."""
    global _DEFAULT
    _DEFAULT = MetricsRegistry()
    return _DEFAULT
