"""Predicted-vs-measured calibration for the Engine's cost models.

The `auto` axes resolve through small analytic cost models
(``select_delivery``'s HBM-traffic model, ``select_backend``'s sync
bytes, ...) whose predictions were never checked against measured
reality — the feedback loop the ROADMAP's TPU-calibration item stalls
on.  This module closes it with pure host-side arithmetic:

* ``fused_traffic`` / ``reference_traffic`` — modeled HBM bytes of the
  two delivery lowerings for a BUILT layout (per degree class, so the
  measured side of ``Result.decision["measured"]["delivery"]`` reports
  actual bytes moved per class, not just a total);
* ``executed_supersteps`` — superstep pairs that did real work, from
  collected activity stats (the measured counterpart of ``max_iters``);
* ``delivery_calibration`` — per-regime predicted-vs-measured residuals
  (log2 ratio) over ``bench_delivery``'s regime table, plus decision
  accuracy: did ``auto`` pick the measured winner?  Written into
  ``BENCH_delivery.json`` each nightly run;
* ``decision_residuals`` — the same comparison for one enriched
  ``Result.decision``.

Residuals are in log2 space: ``residual_log2 = log2(pred / meas)``, so
0 is perfect, +1 means the model predicted 2x the measured ratio, and
the mean over regimes is a geometric-mean correction factor
(``suggested_model_scale``) the traffic model could fold in.
"""
from __future__ import annotations

import math

import numpy as np

ID_BYTES = 4.0  # int32 incidence ids


def reference_traffic(nnz: int, n_dst: int, width_bytes: float) -> float:
    """Modeled HBM bytes of one reference (gather -> mask ->
    segment-reduce) half-superstep: the ``[nnz, D]`` rows intermediate
    is written and re-read, plus src/dst id reads and the output —
    the same model ``bench_delivery`` plots."""
    return float(nnz) * (3.0 * width_bytes + 2.0 * ID_BYTES) + (
        float(n_dst) * width_bytes
    )


def fused_traffic(layout, width_bytes: float) -> dict:
    """Modeled HBM bytes of one fused half-superstep over a BUILT
    degree-class layout, itemized per class.  Uses the layout's padded
    dims (``class_rows`` are array dims), so this is what the dense
    reduces actually stream — the measured side of the cost model's
    work-slot prediction."""
    width_bytes = float(width_bytes)
    per_class = [
        float(int(r) * int(k)) * (width_bytes + ID_BYTES)
        for r, k in zip(layout.class_rows, layout.class_widths)
    ]
    residual = float(layout.rem_len) * (width_bytes + ID_BYTES)
    output = float(layout.n_dst) * width_bytes
    return {
        "class_widths": [int(k) for k in layout.class_widths],
        "class_rows": [int(r) for r in layout.class_rows],
        "per_class_bytes": per_class,
        "residual_bytes": residual,
        "output_bytes": output,
        "total_bytes": float(sum(per_class)) + residual + output,
        "ell_slots": int(layout.ell_slots),
        "residual_lanes": int(layout.rem_len),
        "nnz": int(layout.nnz),
    }


def delivery_traffic_pair(layouts, width_bytes: float) -> dict:
    """Both delivery directions (v->he forward, he->v backward) of one
    superstep; ``layouts`` is the Engine's ``(fwd, bwd)`` pair."""
    fwd, bwd = layouts
    f, b = fused_traffic(fwd, width_bytes), fused_traffic(bwd, width_bytes)
    return {
        "fwd": f,
        "bwd": b,
        "total_bytes": f["total_bytes"] + b["total_bytes"],
        "reference_total_bytes": (
            reference_traffic(fwd.nnz, fwd.n_dst, width_bytes)
            + reference_traffic(bwd.nnz, bwd.n_dst, width_bytes)
        ),
    }


def executed_supersteps(superstep_stats, max_iters: int | None = None):
    """Superstep pairs that did real work, from collected activity
    stats ``(v_active, he_active)``.  Batched stats (leading query dim)
    report the slowest query — the pair count the batch actually ran."""
    if superstep_stats is None:
        return None
    v_act, he_act = superstep_stats
    v = np.asarray(v_act, np.int64)
    he = np.asarray(he_act, np.int64)
    while v.ndim > 1:
        v = v.max(axis=0)
    while he.ndim > 1:
        he = he.max(axis=0)
    n = int(((v + he) > 0).sum())
    return min(n, int(max_iters)) if max_iters is not None else n


def residual_log2(predicted: float, measured: float) -> float:
    """log2(pred / meas), clamped away from zero on both sides."""
    return math.log2(max(float(predicted), 1e-12)
                     / max(float(measured), 1e-12))


def delivery_calibration(regimes: dict) -> dict:
    """Predicted-vs-measured residuals for ``select_delivery``'s
    HBM-traffic model over ``bench_delivery``'s regime records.

    Per regime: the model's predicted fused-vs-reference traffic ratio
    (``model_traffic_ratio``) against the measured speedup
    (``fused_speedup``), the log2 residual between them, and whether
    ``auto``'s pick matches the measured winner.  The summary's
    ``suggested_model_scale`` is the geometric-mean correction the
    traffic model would need to center its residuals — the number the
    ROADMAP's TPU-calibration item asks for, per platform."""
    per: dict[str, dict] = {}
    resids: list[float] = []
    agree = 0
    for name, r in regimes.items():
        pred = float(r["model_traffic_ratio"])
        meas = float(
            r["fused_speedup"]
            if r.get("fused_speedup") is not None
            else r["xla_s"] / r["fused_s"]
        )
        resid = residual_log2(pred, meas)
        measured_winner = "pallas_fused" if meas >= 1.0 else "xla"
        auto_pick = r.get("auto_picks")
        agrees = auto_pick == measured_winner
        agree += int(agrees)
        resids.append(resid)
        per[name] = {
            "predicted_ratio": pred,
            "measured_ratio": meas,
            "residual_log2": resid,
            "auto_picks": auto_pick,
            "measured_winner": measured_winner,
            "decision_agrees": agrees,
        }
    n = max(len(per), 1)
    summary = {
        "regimes": len(per),
        "mean_abs_residual_log2": (
            float(np.mean(np.abs(resids))) if resids else 0.0
        ),
        "max_abs_residual_log2": (
            float(np.max(np.abs(resids))) if resids else 0.0
        ),
        "decision_accuracy": agree / n,
        "suggested_model_scale": (
            float(2.0 ** (-np.mean(resids))) if resids else 1.0
        ),
    }
    return {"regimes": per, "summary": summary}


def decision_residuals(decision: dict) -> dict:
    """Per-axis predicted-vs-measured residuals for ONE enriched
    ``Result.decision`` (an ``Engine.run`` result; the ``measured``
    entry is added post-run).  Axes without both sides are omitted."""
    out: dict[str, dict] = {}
    measured = (decision or {}).get("measured") or {}

    dwhy = decision.get("delivery") or {}
    md = measured.get("delivery")
    if md is not None and dwhy.get("class_work_slots") is not None:
        predicted = float(dwhy["class_work_slots"])
        built = float(
            md["fwd"]["ell_slots"] + md["fwd"]["residual_lanes"]
            + md["bwd"]["ell_slots"] + md["bwd"]["residual_lanes"]
        )
        out["delivery"] = {
            "predicted_work_slots": predicted,
            "built_work_slots": built,
            "residual_log2": residual_log2(predicted, built),
        }

    supersteps = measured.get("supersteps")
    if supersteps is not None:
        budget = decision.get("max_iters") or measured.get("max_iters")
        out["supersteps"] = {
            "executed": int(supersteps),
            **({"budget": int(budget)} if budget else {}),
        }
    return out
