"""Version-portability shims for jax APIs that moved between releases.

``shard_map`` lives at ``jax.shard_map`` with a ``check_vma`` flag on
current jax, and at ``jax.experimental.shard_map.shard_map`` with the
older ``check_rep`` spelling on 0.4.x.  Everything in this repo goes
through this wrapper so version skew is handled in exactly one place.
"""
from __future__ import annotations

import jax


def shard_map(fn, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions.

    ``check``: whether to enable replication/varying-manual-axes checking
    (``check_vma`` on new jax, ``check_rep`` on 0.4.x).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check,
    )
