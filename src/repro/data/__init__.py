"""Datasets: synthetic hypergraph generators calibrated to the paper's
Table I regimes (no network access in this environment; SNAP data is
emulated by matching V:E ratio, degree/cardinality skew, and scale)."""
from repro.data.generators import (
    DATASET_REGIMES,
    powerlaw_hypergraph,
    make_dataset,
)

__all__ = ["DATASET_REGIMES", "powerlaw_hypergraph", "make_dataset"]
