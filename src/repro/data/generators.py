"""Synthetic hypergraph generators matching the paper's dataset regimes.

Table I of the paper spans four qualitatively different shapes; the
partitioning result ("no strategy dominates — it depends on the
vertex:hyperedge ratio and skew") is reproduced on these:

  apache      V << E        (3.3k vertices, 78k hyperedges), mild skew
  dblp        V ~= E        (899k vs 783k), low skew, small cardinalities
  friendster  V >> E        (7.9M vs 1.6M), heavy-tailed
  orkut       E >> V        (2.3M vs 15.3M), heavy-tailed

Each regime scales down with ``scale`` for CI-sized runs while preserving
ratio and tail exponents.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hypergraph import HyperGraph


@dataclasses.dataclass(frozen=True)
class Regime:
    name: str
    n_vertices: int
    n_hyperedges: int
    mean_cardinality: float
    cardinality_alpha: float  # power-law tail exponent for |e|
    popularity_alpha: float   # vertex popularity tail exponent


DATASET_REGIMES: dict[str, Regime] = {
    "apache": Regime("apache", 3_316, 78_080, 5.2, 2.2, 1.6),
    "dblp": Regime("dblp", 899_393, 782_659, 3.4, 2.8, 2.4),
    "friendster": Regime("friendster", 7_944_949, 1_620_991, 14.5, 1.9, 2.0),
    "orkut": Regime("orkut", 2_322_299, 15_301_901, 7.0, 2.0, 1.8),
}


def _powerlaw_ints(
    rng: np.random.Generator, n: int, alpha: float, xmin: int, xmax: int
) -> np.ndarray:
    """Discrete power-law sample via inverse transform on the continuous
    Pareto, clipped to [xmin, xmax]."""
    u = rng.random(n)
    x = xmin * (1.0 - u) ** (-1.0 / (alpha - 1.0))
    return np.clip(x.astype(np.int64), xmin, xmax)


def powerlaw_hypergraph(
    n_vertices: int,
    n_hyperedges: int,
    mean_cardinality: float = 5.0,
    cardinality_alpha: float = 2.2,
    popularity_alpha: float = 2.0,
    max_cardinality: int | None = None,
    seed: int = 0,
) -> HyperGraph:
    """Sample a hypergraph with power-law cardinalities and power-law
    vertex popularity (rich-get-richer membership)."""
    rng = np.random.default_rng(seed)
    max_card = max_cardinality or max(int(mean_cardinality * 40), 16)
    card = _powerlaw_ints(rng, n_hyperedges, cardinality_alpha, 1, max_card)
    # rescale to hit the target mean (power-law means drift with clipping)
    ratio = mean_cardinality / max(card.mean(), 1e-9)
    if ratio > 1.0:
        card = np.minimum(
            (card * ratio).astype(np.int64) + 1, max_card
        )
    card = np.maximum(card, 1)
    nnz = int(card.sum())

    # vertex popularity ~ Zipf over a permuted id space
    pop = 1.0 / np.arange(1, n_vertices + 1) ** (1.0 / popularity_alpha)
    pop /= pop.sum()
    perm = rng.permutation(n_vertices)
    members = rng.choice(n_vertices, size=nnz, p=pop)
    members = perm[members].astype(np.int32)

    dst = np.repeat(
        np.arange(n_hyperedges, dtype=np.int32), card
    )
    # dedupe members within a hyperedge (resample collisions once, then
    # accept residual duplicates — harmless for the algorithms, matches
    # multiset membership semantics)
    key = dst.astype(np.int64) * np.int64(n_vertices) + members
    _, first_idx = np.unique(key, return_index=True)
    keep = np.zeros(nnz, bool)
    keep[first_idx] = True
    src, dst = members[keep], dst[keep]

    return HyperGraph.from_coo(src, dst, n_vertices, n_hyperedges)


def make_dataset(
    name: str, scale: float = 1.0, seed: int = 0
) -> HyperGraph:
    """Instantiate one of the Table-I regimes, optionally scaled down."""
    r = DATASET_REGIMES[name]
    nv = max(int(r.n_vertices * scale), 8)
    ne = max(int(r.n_hyperedges * scale), 4)
    return powerlaw_hypergraph(
        n_vertices=nv,
        n_hyperedges=ne,
        mean_cardinality=r.mean_cardinality,
        cardinality_alpha=r.cardinality_alpha,
        popularity_alpha=r.popularity_alpha,
        seed=seed,
    )
