"""Training substrate: optimizer, train step factory, checkpointing."""
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
    schedule,
)
from repro.train.step import TrainState, init_train_state, make_train_step
from repro.train.checkpoint import (
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "schedule",
    "TrainState",
    "init_train_state",
    "make_train_step",
    "latest_checkpoint",
    "restore_checkpoint",
    "save_checkpoint",
]
