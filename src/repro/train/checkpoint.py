"""Checkpoint/restore: fault tolerance for multi-pod training.

Design (DESIGN.md §9):
* A checkpoint is the full training pytree (params, optimizer moments,
  step, data cursor, PRNG key) serialized leaf-by-leaf as ``.npy`` inside a
  directory, plus a JSON manifest carrying the treedef, shapes, dtypes and
  a content hash per leaf (corruption detection on restore).
* Writes are atomic: serialize into ``<dir>.tmp`` then ``rename`` — a
  killed process never leaves a half-checkpoint that restore would trust.
* Restore is mesh-agnostic: leaves are loaded as host arrays and re-placed
  under whatever sharding the *current* mesh prescribes, so a job restarted
  on a different pod count (elastic re-shard) restores transparently.
* ``latest_checkpoint`` scans for the highest complete step, enabling
  crash-loop restart semantics (cron/daemon re-launches the trainer, the
  trainer resumes from the last durable step).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Atomically persist ``tree`` for ``step``; returns the final path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    names, leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
        manifest["leaves"].append(
            {
                "name": name,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256_16": digest,
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_checkpoint(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    ]
    if not steps:
        return None
    return os.path.join(ckpt_dir, sorted(steps)[-1])


def restore_checkpoint(path: str, tree_like, *, shardings=None,
                       verify: bool = True):
    """Restore into the structure of ``tree_like``.  ``shardings`` (same
    structure) re-places leaves for the current mesh — elastic restarts
    load checkpoints written under a different topology."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _leaf_paths(tree_like)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(leaves)}"
        )
    out = []
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else None
    )
    for i, meta in enumerate(manifest["leaves"]):
        arr = np.load(os.path.join(path, meta["file"]))
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            if digest != meta["sha256_16"]:
                raise IOError(
                    f"checkpoint leaf {meta['name']} corrupt "
                    f"(hash mismatch)"
                )
        if list(arr.shape) != list(np.shape(leaves[i])):
            raise ValueError(
                f"leaf {meta['name']}: checkpoint shape {arr.shape} != "
                f"expected {np.shape(leaves[i])}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
