"""AdamW, implemented directly on pytrees (no optax dependency).

fp32 moments regardless of param dtype; decoupled weight decay; global-norm
gradient clipping; linear warmup + cosine decay schedule helper.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params: Params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu2 = b1 * mu + (1 - b1) * g
        nu2 = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu2 / bc1
        nhat = nu2 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), mu2, nu2

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = jax.tree.leaves(grads)
    mu_leaves = jax.tree.leaves(opt_state["mu"])
    nu_leaves = jax.tree.leaves(opt_state["nu"])
    out = [upd(*t) for t in zip(p_leaves, g_leaves, mu_leaves, nu_leaves)]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in out])
    new_mu = jax.tree.unflatten(treedef, [t[1] for t in out])
    new_nu = jax.tree.unflatten(treedef, [t[2] for t in out])
    return new_params, {
        "mu": new_mu, "nu": new_nu, "step": step
    }, {"grad_norm": gnorm, "lr": lr}
