"""Train-step factory: loss fn -> jit-ready (state, batch) -> (state, metrics).

Supports gradient (micro-batch) accumulation via an inner scan — the
standard large-scale trick for fitting global batch under HBM limits, and a
§Perf lever (microbatch size trades activation memory for pipeline
efficiency).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt_state: Any


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt_state=adamw_init(params))


def make_train_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    opt_cfg: AdamWConfig = AdamWConfig(),
    accum_steps: int = 1,
):
    """``loss_fn(params, batch) -> scalar``; batch microbatched on dim 0 of
    every leaf when ``accum_steps > 1``."""

    def train_step(state: TrainState, batch):
        params = state.params

        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape(
                        (accum_steps, x.shape[0] // accum_steps)
                        + x.shape[1:]
                    ),
                    b,
                )

            micro_batches = micro(batch)

            def body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                return (
                    loss_acc + loss,
                    jax.tree.map(jnp.add, grad_acc, grads),
                ), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zero_grads), micro_batches
            )
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt_state, params
        )
        metrics = {"loss": loss, **opt_metrics}
        return TrainState(new_params, new_opt), metrics

    return train_step
