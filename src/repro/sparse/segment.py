"""Segment reductions — the single primitive under all MESH supersteps.

A MESH superstep is ``gather -> per-edge transform -> combine-by-key``.
The combine step must be a commutative monoid so that (a) GraphX-style
pre-aggregation before the network hop is legal, and (b) XLA may reassociate
freely.  This module defines the monoid registry (the JAX analogue of the
paper's Algebird auto-derived ``MessageCombiner``) and the segment kernels.

All functions are shard_map-friendly: static ``num_segments``, no
data-dependent shapes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Edge-sharded execution context (the MESH replicated backend, exposed to
# every consumer of segment ops).  Inside ``edge_sharded(axes)`` each
# segment reduction computes a *local* partial over this shard's edges and
# merges across shards with the matching collective (psum/pmax/pmin) —
# models stay oblivious; only the executor wraps them in shard_map.
# ---------------------------------------------------------------------------
_CTX = threading.local()


@contextlib.contextmanager
def edge_sharded(axes):
    prev = getattr(_CTX, "axes", None)
    _CTX.axes = axes
    try:
        yield
    finally:
        _CTX.axes = prev


def _merge_axes():
    return getattr(_CTX, "axes", None)


def _psum(x):
    axes = _merge_axes()
    return jax.lax.psum(x, axes) if axes else x


def _pmax(x):
    """Differentiable cross-shard max: pmax has no JVP rule, so merge via
    a stop-gradient pmax and re-select locally — the cotangent flows to
    the shard(s) holding the max (exact up to fp ties across shards)."""
    axes = _merge_axes()
    if not axes:
        return x
    m = jax.lax.pmax(jax.lax.stop_gradient(x), axes)
    return jnp.where(x >= m, x, jax.lax.stop_gradient(m))


def _pmin(x):
    axes = _merge_axes()
    if not axes:
        return x
    m = jax.lax.pmin(jax.lax.stop_gradient(x), axes)
    return jnp.where(x <= m, x, jax.lax.stop_gradient(m))


@dataclasses.dataclass(frozen=True)
class Monoid:
    """Commutative monoid: identity + combine + a fused segment reduction.

    ``segment`` must satisfy ``segment(x, ids, n)[i] == fold(combine,
    identity, [x[j] for j where ids[j]==i])`` — the law the property tests
    assert.
    """

    name: str
    identity: Callable[[jnp.dtype], jnp.ndarray]
    combine: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    segment: Callable[..., jnp.ndarray]

    def identity_like(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.full((), self.identity(x.dtype), dtype=x.dtype)


def _min_identity(dtype) -> jnp.ndarray:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def _max_identity(dtype) -> jnp.ndarray:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


MONOIDS: dict[str, Monoid] = {
    "sum": Monoid(
        "sum",
        identity=lambda dt: jnp.zeros((), dt),
        combine=jnp.add,
        segment=jax.ops.segment_sum,
    ),
    "max": Monoid(
        "max",
        identity=_max_identity,
        combine=jnp.maximum,
        segment=jax.ops.segment_max,
    ),
    "min": Monoid(
        "min",
        identity=_min_identity,
        combine=jnp.minimum,
        segment=jax.ops.segment_min,
    ),
    "prod": Monoid(
        "prod",
        identity=lambda dt: jnp.ones((), dt),
        combine=jnp.multiply,
        segment=jax.ops.segment_prod,
    ),
    "or": Monoid(
        "or",
        identity=lambda dt: jnp.zeros((), dt),
        combine=jnp.logical_or,
        # ``> 0`` (not ``astype(bool)``): segment_max fills EMPTY segments
        # with iinfo.min, which a bool cast would turn into True — the
        # monoid law requires the identity (False) for empty folds.
        segment=lambda x, ids, num_segments, **kw: jax.ops.segment_max(
            x.astype(jnp.int32), ids, num_segments, **kw
        ) > 0,
    ),
}


def resolve_monoid(combiner: str | Monoid) -> Monoid:
    if isinstance(combiner, Monoid):
        return combiner
    try:
        return MONOIDS[combiner]
    except KeyError as e:  # pragma: no cover - defensive
        raise ValueError(
            f"unknown combiner {combiner!r}; known: {sorted(MONOIDS)}"
        ) from e


def derive_monoid_for(x: jnp.ndarray) -> Monoid:
    """Auto-derive a MessageCombiner from the message type.

    The JAX analogue of MESH's Algebird import: floats/ints default to the
    ``sum`` monoid, bools to ``or``.  Algorithms needing max/min (label
    propagation, SSSP) say so explicitly, exactly as ``msg.max()`` does in
    the paper's listings.
    """
    if jnp.issubdtype(x.dtype, jnp.bool_):
        return MONOIDS["or"]
    return MONOIDS["sum"]


@partial(jax.jit, static_argnames=("num_segments", "monoid_name"))
def _segment_reduce_impl(data, segment_ids, num_segments, monoid_name):
    monoid = MONOIDS[monoid_name]
    return monoid.segment(data, segment_ids, num_segments=num_segments)


def segment_reduce(
    data: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    combiner: str | Monoid = "sum",
    *,
    fill_identity: bool = False,
) -> jnp.ndarray:
    """Reduce ``data`` rows by key. Empty segments get 0 (sum/or) or the
    monoid identity when ``fill_identity`` (max/min return dtype-min/max
    from XLA already, which *is* the identity)."""
    monoid = resolve_monoid(combiner)
    out = monoid.segment(data, segment_ids, num_segments=num_segments)
    if fill_identity and monoid.name in ("max", "min"):
        # segment_max/min already emit -inf/+inf (or int extremes) for empty
        # segments on float inputs; normalize ints too for predictability.
        pass
    return out


def mp_segment_sum(data, segment_ids, num_segments):
    """segment_sum that merges across edge shards when inside
    ``edge_sharded`` (local partial + psum)."""
    return _psum(jax.ops.segment_sum(data, segment_ids, num_segments))


def mp_segment_max(data, segment_ids, num_segments):
    return _pmax(jax.ops.segment_max(data, segment_ids, num_segments))


def mp_segment_min(data, segment_ids, num_segments):
    return _pmin(jax.ops.segment_min(data, segment_ids, num_segments))


def segment_count(segment_ids: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    return mp_segment_sum(
        jnp.ones_like(segment_ids, dtype=jnp.int32), segment_ids,
        num_segments,
    )


def segment_mean(
    data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int
) -> jnp.ndarray:
    total = mp_segment_sum(data, segment_ids, num_segments)
    count = segment_count(segment_ids, num_segments)
    count = jnp.maximum(count, 1).astype(data.dtype)
    return total / count.reshape((-1,) + (1,) * (data.ndim - 1))


def segment_std(
    data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int,
    eps: float = 1e-5,
) -> jnp.ndarray:
    """Per-segment standard deviation (PNA's ``std`` aggregator)."""
    mean = segment_mean(data, segment_ids, num_segments)
    sq_mean = segment_mean(jnp.square(data), segment_ids, num_segments)
    return jnp.sqrt(jnp.maximum(sq_mean - jnp.square(mean), 0.0) + eps)


def segment_softmax(
    logits: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int
) -> jnp.ndarray:
    """Numerically-stable softmax within each segment (GAT edge softmax).
    Edge-shard-aware: max and denominator merge across shards."""
    seg_max = mp_segment_max(logits, segment_ids, num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[segment_ids]
    exp = jnp.exp(shifted)
    denom = mp_segment_sum(exp, segment_ids, num_segments)
    denom = jnp.maximum(denom[segment_ids], 1e-30)
    return exp / denom


def segment_logsumexp(
    logits: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int
) -> jnp.ndarray:
    seg_max = mp_segment_max(logits, segment_ids, num_segments)
    safe_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    exp = jnp.exp(logits - safe_max[segment_ids])
    s = mp_segment_sum(exp, segment_ids, num_segments)
    return safe_max + jnp.log(jnp.maximum(s, 1e-30))


def segment_normalize(
    data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int
) -> jnp.ndarray:
    """Divide each row by its segment's sum (used by PageRank broadcast)."""
    denom = jax.ops.segment_sum(data, segment_ids, num_segments)
    denom = jnp.where(jnp.abs(denom) < 1e-30, 1.0, denom)
    return data / denom[segment_ids]
