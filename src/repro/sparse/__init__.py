"""Sparse/ragged primitives: the substrate under the MESH engine.

JAX has no native EmbeddingBag and only BCOO sparse; every irregular
aggregation in this framework funnels through the segment ops in this
package (``jnp.take`` gathers + ``jax.ops.segment_*`` reductions), which is
exactly the regime the MESH paper's gather/combine/scatter supersteps
occupy.
"""
from repro.sparse.segment import (
    Monoid,
    MONOIDS,
    edge_sharded,
    mp_segment_max,
    mp_segment_min,
    mp_segment_sum,
    segment_reduce,
    segment_softmax,
    segment_mean,
    segment_std,
    segment_logsumexp,
)
from repro.sparse.embedding_bag import embedding_bag, EmbeddingBagSpec
from repro.sparse.sampler import NeighborSampler, SampledBlock, build_csr

__all__ = [
    "Monoid",
    "MONOIDS",
    "edge_sharded",
    "mp_segment_sum",
    "mp_segment_max",
    "mp_segment_min",
    "segment_reduce",
    "segment_softmax",
    "segment_mean",
    "segment_std",
    "segment_logsumexp",
    "embedding_bag",
    "EmbeddingBagSpec",
    "NeighborSampler",
    "SampledBlock",
    "build_csr",
]
