"""EmbeddingBag built from gather + segment-reduce.

JAX has no ``nn.EmbeddingBag``; this is the canonical TPU-native
construction: ``jnp.take`` over the (possibly vocab-sharded) table followed
by a per-bag segment reduction.  The same primitive serves three masters in
this framework:

* recsys multi-hot field pooling (BERT4Rec side features / DLRM-style),
* the MESH engine's message delivery (a bag == the incidence list of one
  hyperedge),
* GNN neighborhood pooling.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.sparse.segment import segment_mean, segment_reduce


@dataclasses.dataclass(frozen=True)
class EmbeddingBagSpec:
    vocab_size: int
    dim: int
    mode: str = "sum"  # sum | mean | max
    dtype: jnp.dtype = jnp.float32

    def init(self, key: jax.Array) -> jnp.ndarray:
        scale = self.dim**-0.5
        return (
            jax.random.normal(key, (self.vocab_size, self.dim)) * scale
        ).astype(self.dtype)


def embedding_bag(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    bag_ids: jnp.ndarray,
    num_bags: int,
    *,
    mode: str = "sum",
    weights: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Pool rows of ``table`` selected by ``indices`` into ``num_bags`` bags.

    Args:
      table: ``[vocab, dim]`` embedding table.
      indices: ``[nnz]`` int row ids (flattened ragged multi-hot).
      bag_ids: ``[nnz]`` int bag id per index, in ``[0, num_bags)``.
      num_bags: static bag count.
      mode: ``sum`` | ``mean`` | ``max``.
      weights: optional ``[nnz]`` per-sample weights (sum/mean only).
    """
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    if mode == "mean":
        return segment_mean(rows, bag_ids, num_bags)
    if mode == "max":
        out = segment_reduce(rows, bag_ids, num_bags, "max")
        return jnp.where(jnp.isfinite(out), out, 0.0)
    return segment_reduce(rows, bag_ids, num_bags, "sum")


def embedding_bag_dense(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    *,
    mode: str = "sum",
    pad_id: int | None = None,
) -> jnp.ndarray:
    """Rectangular variant: ``indices [batch, bag_width]`` (padded multi-hot).

    Preferred on TPU when bag widths are bounded: the segment reduce becomes
    a dense masked reduction — no scatter at all.
    """
    rows = jnp.take(table, indices, axis=0)  # [batch, width, dim]
    if pad_id is not None:
        mask = (indices != pad_id)[..., None].astype(rows.dtype)
        rows = rows * mask
        denom = jnp.maximum(mask.sum(axis=1), 1.0)
    else:
        denom = jnp.full(
            rows.shape[:1] + rows.shape[2:], rows.shape[1], rows.dtype
        )
    if mode == "mean":
        return rows.sum(axis=1) / denom
    if mode == "max":
        if pad_id is not None:
            rows = jnp.where(
                (indices == pad_id)[..., None], -jnp.inf, rows
            )
        out = rows.max(axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    return rows.sum(axis=1)
