"""Fanout neighbor sampler (GraphSAGE-style) for minibatch GNN training.

The full graph lives host-side in CSR (NumPy); each step samples a k-hop
block with fixed fanouts, producing *static-shape* device arrays (padded
with a sink node) so the jitted train step never recompiles.  This is the
real sampler the ``minibatch_lg`` shape requires — 233k nodes / 115M edges
stay on host, only the sampled block ships to device.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def build_csr(
    src: np.ndarray, dst: np.ndarray, n_nodes: int
) -> tuple[np.ndarray, np.ndarray]:
    """Sort a COO edge list into CSR (indptr, indices) keyed by dst.

    ``indices[indptr[v]:indptr[v+1]]`` = in-neighbors of ``v``.
    """
    order = np.argsort(dst, kind="stable")
    sorted_src = src[order]
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, sorted_src.astype(np.int32)


@dataclasses.dataclass
class SampledBlock:
    """One k-hop sampled computation block, padded to static shape.

    ``nodes`` lists unique node ids layer-by-layer (seeds first);
    ``edge_src``/``edge_dst`` index into ``nodes`` (local ids).  Padding
    edges point at local sink ``len(nodes)-1`` with ``edge_mask`` 0.
    """

    nodes: np.ndarray       # [n_block] global node ids (int32)
    edge_src: np.ndarray    # [n_edges] local ids
    edge_dst: np.ndarray    # [n_edges] local ids
    edge_mask: np.ndarray   # [n_edges] float32 {0,1}
    seed_count: int

    @property
    def n_nodes(self) -> int:
        return int(self.nodes.shape[0])


class NeighborSampler:
    """Uniform fanout sampler over a host-side CSR graph."""

    def __init__(
        self,
        indptr: np.ndarray,
        indices: np.ndarray,
        fanouts: tuple[int, ...],
        seed: int = 0,
    ):
        self.indptr = indptr
        self.indices = indices
        self.fanouts = tuple(fanouts)
        self._rng = np.random.default_rng(seed)

    def _sample_neighbors(self, frontier: np.ndarray, fanout: int):
        """For each node in frontier sample ``fanout`` in-neighbors
        (with replacement when degree < fanout, mask 0 when degree == 0)."""
        deg = (self.indptr[frontier + 1] - self.indptr[frontier]).astype(
            np.int64
        )
        offsets = self.indptr[frontier]
        draw = self._rng.integers(
            0, np.maximum(deg, 1)[:, None], size=(len(frontier), fanout)
        )
        flat_idx = (offsets[:, None] + draw).reshape(-1)
        flat_idx = np.minimum(flat_idx, len(self.indices) - 1)
        nbrs = self.indices[flat_idx].reshape(len(frontier), fanout)
        mask = (deg > 0)[:, None] & np.ones((1, fanout), dtype=bool)
        return nbrs, mask

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        seeds = np.asarray(seeds, dtype=np.int32)
        all_src: list[np.ndarray] = []
        all_dst: list[np.ndarray] = []
        all_mask: list[np.ndarray] = []
        frontier = seeds
        layers = [seeds]
        for fanout in self.fanouts:
            nbrs, mask = self._sample_neighbors(frontier, fanout)
            dst = np.repeat(frontier, fanout)
            src = nbrs.reshape(-1)
            all_src.append(src)
            all_dst.append(dst)
            all_mask.append(mask.reshape(-1))
            frontier = src
            layers.append(src)
        # Build local id space: unique nodes, seeds first.
        cat = np.concatenate(layers)
        uniq, inv = np.unique(cat, return_inverse=True)
        # remap seeds to the front
        seed_pos = inv[: len(seeds)]
        perm = np.full(len(uniq), -1, dtype=np.int64)
        order = list(dict.fromkeys(seed_pos.tolist()))
        rest = [i for i in range(len(uniq)) if i not in set(order)]
        new_order = np.array(order + rest, dtype=np.int64)
        perm[new_order] = np.arange(len(uniq))
        nodes = uniq[new_order].astype(np.int32)
        global_to_local = {int(g): i for i, g in enumerate(nodes)}
        src = np.concatenate(all_src)
        dst = np.concatenate(all_dst)
        mask = np.concatenate(all_mask).astype(np.float32)
        loc = np.vectorize(global_to_local.__getitem__, otypes=[np.int64])
        edge_src = loc(src).astype(np.int32)
        edge_dst = loc(dst).astype(np.int32)
        return SampledBlock(
            nodes=nodes,
            edge_src=edge_src,
            edge_dst=edge_dst,
            edge_mask=mask,
            seed_count=len(seeds),
        )

    def padded_block_shape(self, batch_nodes: int) -> tuple[int, int]:
        """Static (n_nodes, n_edges) upper bound for jit."""
        n_edges = 0
        frontier = batch_nodes
        n_nodes = batch_nodes
        for fanout in self.fanouts:
            n_edges += frontier * fanout
            frontier = frontier * fanout
            n_nodes += frontier
        return n_nodes, n_edges

    def sample_padded(self, seeds: np.ndarray) -> SampledBlock:
        """Sample then pad nodes/edges to the static upper bound."""
        block = self.sample(seeds)
        n_nodes_max, n_edges_max = self.padded_block_shape(len(seeds))
        n_nodes_max += 1  # sink node
        nodes = np.full(n_nodes_max, 0, dtype=np.int32)
        nodes[: block.n_nodes] = block.nodes
        sink = n_nodes_max - 1
        pad_e = n_edges_max - len(block.edge_src)
        edge_src = np.concatenate(
            [block.edge_src, np.full(pad_e, sink, np.int32)]
        )
        edge_dst = np.concatenate(
            [block.edge_dst, np.full(pad_e, sink, np.int32)]
        )
        mask = np.concatenate(
            [block.edge_mask, np.zeros(pad_e, np.float32)]
        )
        return SampledBlock(
            nodes=nodes,
            edge_src=edge_src,
            edge_dst=edge_dst,
            edge_mask=mask,
            seed_count=block.seed_count,
        )
