"""Three-term roofline from the compiled SPMD module.

Methodology (EXPERIMENTS.md §Roofline):

* ``compiled.cost_analysis()`` supplies HLO FLOPs and bytes — but XLA
  counts while-loop bodies ONCE (verified empirically in this repo), so a
  production scan-over-layers program under-reports by ~n_layers.  We
  therefore compile *unrolled* 1-period and 2-period model variants and
  extrapolate: ``total = f1 + (n_periods - 1) * (f2 - f1)``.  The
  difference f2-f1 isolates exactly one period; f1 - (f2-f1) is the fixed
  overhead (embedding, unembed, optimizer).  Verified against analytic
  6ND within a few percent.

* Collective bytes are NOT in cost_analysis: we parse the partitioned
  ``compiled.as_text()`` and sum result-buffer sizes of all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute ops, with
  the same 1-vs-2-period differencing.  Shapes in the partitioned module
  are already per-device.  Convention: all-reduce counts 2x (ring RS+AG);
  others count their result bytes.

* Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
  (3D torus, ~6 links usable; we charge the per-device collective bytes
  against one link's 50 GB/s lane to stay conservative).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12         # bf16 per chip
    hbm_bw: float = 819e9              # bytes/s per chip
    ici_bw: float = 50e9               # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# shape like f32[1,2048,512]{2,1,0} or bf16[16]
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    if not dims:
        return nbytes
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    bytes_by_kind: dict[str, float]

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def merged(self, other: "CollectiveStats", scale: float = 1.0):
        counts = dict(self.counts)
        by = dict(self.bytes_by_kind)
        for k, v in other.counts.items():
            counts[k] = counts.get(k, 0) + int(v * scale)
        for k, v in other.bytes_by_kind.items():
            by[k] = by.get(k, 0.0) + v * scale
        return CollectiveStats(counts, by)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device result bytes of every collective op in (partitioned)
    HLO text.  all-reduce counted 2x (ring = reduce-scatter + all-gather
    over the same payload)."""
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    bytes_by: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.*?)\s+(\S+)\(", stripped)
        if not m:
            continue
        op = m.group(2).split(".")[0]
        # fusion(...) etc will not match a collective name
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue
        shapes = _SHAPE_RE.findall(m.group(1))
        size = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        mult = 2.0 if kind == "all-reduce" else 1.0
        counts[kind] += 1
        bytes_by[kind] += size * mult
    return CollectiveStats(counts, bytes_by)


@dataclasses.dataclass
class RooflineReport:
    name: str
    n_devices: int
    hlo_flops: float                  # global (all devices)
    hlo_bytes: float                  # global HBM traffic
    collective_bytes_per_dev: float   # per-device wire bytes
    collective_counts: dict[str, int]
    collective_bytes_by_kind: dict[str, float]
    model_flops: float
    peak_memory_per_dev: float        # bytes (from memory_analysis)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finish(self, hw: HW = HW()):
        self.compute_s = self.hlo_flops / (self.n_devices * hw.peak_flops)
        self.memory_s = self.hlo_bytes / (self.n_devices * hw.hbm_bw)
        self.collective_s = self.collective_bytes_per_dev / hw.ici_bw
        return self

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time: dominant term (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS / (devices x peak x step_time) — the MFU the
        roofline model predicts if the dominant term is the wall."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        denom = self.n_devices * HW().peak_flops * t
        return self.model_flops / denom

    def row(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "devices": self.n_devices,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes_dev": self.collective_bytes_per_dev,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_gb": self.peak_memory_per_dev / 1e9,
        }


def _cost(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _peak_memory(compiled) -> float:
    ma = compiled.memory_analysis()
    try:
        return float(
            ma.temp_size_in_bytes
            + ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            - ma.alias_size_in_bytes
        )
    except AttributeError:
        return 0.0


def analyze_compiled(name, compiled, n_devices, model_flops=0.0):
    flops, nbytes = _cost(compiled)
    coll = parse_collectives(compiled.as_text())
    return RooflineReport(
        name=name,
        n_devices=n_devices,
        hlo_flops=flops * n_devices,
        hlo_bytes=nbytes * n_devices,
        collective_bytes_per_dev=coll.total_bytes,
        collective_counts=coll.counts,
        collective_bytes_by_kind=coll.bytes_by_kind,
        model_flops=model_flops,
        peak_memory_per_dev=_peak_memory(compiled),
    ).finish()


def analyze_task(task, *, extrapolate: tuple | None = None) -> RooflineReport:
    """Lower+compile ``task`` and derive the three roofline terms.

    ``extrapolate=(report_1p, report_2p, n_periods)`` applies the
    unrolled-differencing correction for scan-over-layer programs:
    ``total = r1 + (n_periods - 1) * (r2 - r1)`` per additive field.
    """
    lowered = task.lower()
    compiled = lowered.compile()
    base = analyze_compiled(
        task.name, compiled, task_n_devices(task), task.model_flops_per_step
    )
    if extrapolate is not None:
        r1, r2, n_periods = extrapolate
        k = n_periods - 1
        base.hlo_flops = r1.hlo_flops + k * (r2.hlo_flops - r1.hlo_flops)
        base.hlo_bytes = r1.hlo_bytes + k * (r2.hlo_bytes - r1.hlo_bytes)
        base.collective_bytes_per_dev = (
            r1.collective_bytes_per_dev
            + k * (r2.collective_bytes_per_dev - r1.collective_bytes_per_dev)
        )
        base.collective_bytes_by_kind = {
            kk: r1.collective_bytes_by_kind.get(kk, 0.0)
            + k * (
                r2.collective_bytes_by_kind.get(kk, 0.0)
                - r1.collective_bytes_by_kind.get(kk, 0.0)
            )
            for kk in set(r1.collective_bytes_by_kind)
            | set(r2.collective_bytes_by_kind)
        }
        base.finish()
    return base


def task_n_devices(task) -> int:
    import math

    return math.prod(task.mesh.devices.shape)
