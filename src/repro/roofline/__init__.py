"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline.analysis import (
    HW,
    CollectiveStats,
    RooflineReport,
    analyze_task,
    parse_collectives,
)

__all__ = [
    "HW",
    "CollectiveStats",
    "RooflineReport",
    "analyze_task",
    "parse_collectives",
]
