"""Launch layer: meshes, task builders, dry-run, trainers, serving.

Hypergraph analytics launches through ``repro.launch.hypergraph`` (the
Engine-facade CLI); LM/GNN training and serving through ``train`` /
``serve`` / ``dryrun``.
"""
from repro.launch.mesh import (
    dp_axes,
    flat_axes,
    make_host_mesh,
    make_production_mesh,
    total_devices,
)

__all__ = [
    "dp_axes",
    "flat_axes",
    "make_host_mesh",
    "make_production_mesh",
    "total_devices",
]
