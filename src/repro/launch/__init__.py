"""Launch layer: meshes, task builders, dry-run, trainers, serving.

Hypergraph analytics launches through ``repro.launch.hypergraph`` (the
Engine-facade CLI) and serves through ``repro.launch.serve_hypergraph``
(the coalescing front-end + persistent executable cache); LM/GNN
training and *LM decode* serving through ``train`` / ``serve`` /
``dryrun`` — note ``serve`` (LM) vs ``serve_hypergraph`` (hypergraph).
"""
from repro.launch.mesh import (
    dp_axes,
    flat_axes,
    make_host_mesh,
    make_production_mesh,
    total_devices,
)

__all__ = [
    "dp_axes",
    "flat_axes",
    "make_host_mesh",
    "make_production_mesh",
    "total_devices",
]
