"""Hypergraph query serving: replay a mixed trace through the serve tier.

Naming note: ``repro.launch.serve`` is the *LM decode* driver (prefill +
token generation for the transformer stack); THIS module is the
*hypergraph analytics* serving entry point, built on ``repro.serve``
(async front-end + coalescing batcher + persistent executable cache)
over the compile-once seam (``Engine.compile``).

Replays a mixed SSSP / PPR (random-walk) request trace against one
generated dataset:

  PYTHONPATH=src python -m repro.launch.serve_hypergraph \
      --regime dblp --scale 0.003 --requests 200 \
      --max-batch 16 --max-delay-ms 5

  # replica boot from the persistent cache (second run is warm):
  REPRO_CACHE_DIR=/tmp/repro-cache \
  PYTHONPATH=src python -m repro.launch.serve_hypergraph --warm

Flags of note: ``--mix`` sets the SSSP fraction of the trace;
``--no-warm`` skips the boot-time ``serve.warm`` pass (first requests
then pay the compile); ``--cache-dir`` / ``$REPRO_CACHE_DIR`` place the
on-disk executable store; ``--verify`` cross-checks a sample of served
results bitwise against sequential ``CompiledAlgorithm.run``;
``--fault-plan`` (inline JSON or a file path) arms a ``FaultPlan`` of
scheduled failures — the chaos replay: every request still resolves
(result or typed error), successes stay bitwise-correct, and the
per-point calls/fired report prints after the run, e.g.::

  --fault-plan '{"rules": [{"point": "execute", "trigger": "every",
                            "n": 7, "error": "transient"}]}'

The device-count env fix must run before any jax import, hence the
module-level pattern shared with ``repro.launch.hypergraph``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def build_paths(regime: str = "dblp", scale: float = 0.003,
                seed: int = 0, iters: int = 12) -> dict:
    """Replica builder (``ReplicaConfig.builder`` target): constructs
    the served paths INSIDE the worker process, so nothing unpicklable
    crosses the spawn boundary — each replica regenerates the (seeded,
    deterministic) dataset and spec set locally, and ``stable_digest``
    re-keys them onto the same shared disk-store entries."""
    from repro import algorithms as alg
    from repro.data import make_dataset

    hg = make_dataset(regime, scale=scale, seed=seed)
    return {
        "specs": {
            "sssp": alg.shortest_paths_spec(hg, source=0, max_iters=iters),
            "ppr": alg.random_walk_spec(hg, iters=iters),
        },
        "warm_queries": [0, 0],  # ppr has no query0; seed vertex 0
    }


def _parse(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--regime", default="dblp",
                    help="dataset regime (apache/dblp/friendster/orkut)")
    ap.add_argument("--scale", type=float, default=0.003)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=12,
                    help="superstep budget per query")
    ap.add_argument("--devices", type=int, default=1,
                    help="forced host device count (1 = local execution)")
    ap.add_argument("--requests", type=int, default=200,
                    help="trace length (mixed across algorithms)")
    ap.add_argument("--mix", type=float, default=0.6,
                    help="fraction of the trace that is SSSP "
                         "(the rest is PPR / random-walk)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="coalescing batch bucket per registered path")
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="max queue wait before a partial flush")
    ap.add_argument("--adaptive-delay", action="store_true",
                    help="let the front-end adapt the flush deadline "
                         "from the observed wait/execute split "
                         "(bounded EWMA controller; --max-delay-ms "
                         "becomes the upper clamp)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record engine + serve trace spans; export "
                         "Chrome-trace JSON here (loadable in Perfetto)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the unified metrics-registry snapshot "
                         "as JSON ('-' for stdout)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent executable cache dir "
                         "(default $REPRO_CACHE_DIR or .repro_cache/)")
    ap.add_argument("--no-warm", dest="warm", action="store_false",
                    help="skip the boot-time warmup pass")
    ap.add_argument("--warm", dest="warm", action="store_true",
                    default=True)
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through a pool of N replica processes "
                         "behind the heartbeat-failover Router (0 = "
                         "single-process front-end); replicas boot from "
                         "the shared --cache-dir store")
    ap.add_argument("--heartbeat-timeout-ms", type=float, default=2000.0,
                    help="router declares a replica dead after this "
                         "long without a heartbeat")
    ap.add_argument("--fault-plan", default=None, metavar="JSON",
                    help="chaos mode: a FaultPlan as inline JSON or a "
                         "file path; scheduled failures are injected at "
                         "the engine/serve failure points and a per-point "
                         "calls/fired report is printed after the replay")
    ap.add_argument("--verify", type=int, default=8,
                    help="cross-check N served results bitwise against "
                         "sequential run (0 = skip)")
    ap.add_argument("--log-every-s", type=float, default=5.0)
    ap.add_argument("--json", action="store_true",
                    help="dump the full stats snapshot as JSON")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(argv)
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import numpy as np

    from repro import algorithms as alg
    from repro.core import Engine
    from repro.data import make_dataset
    from repro.launch.mesh import make_host_mesh
    from repro.serve import DiskExecutableCache, Frontend, warm

    hg = make_dataset(args.regime, scale=args.scale, seed=args.seed)
    print(f"{args.regime}: |V|={hg.n_vertices} |E|={hg.n_hyperedges} "
          f"nnz={hg.nnz}")

    mesh = make_host_mesh(args.devices) if args.devices > 1 else None
    tracer = None
    if args.trace:
        from repro.obs import Tracer

        tracer = Tracer()
    injector, plan_json = None, None
    if args.fault_plan:
        from repro.faults import FaultInjector, FaultPlan

        raw = args.fault_plan
        if os.path.exists(raw):
            with open(raw) as f:
                raw = f.read()
        plan = FaultPlan.from_json(raw)
        for warning in plan.validate():
            print(f"fault-plan: {warning}", file=sys.stderr)
        injector = FaultInjector(plan)
        plan_json = plan.to_json()
        print(f"fault-plan: {len(plan.rules)} rule(s) armed")
    engine = Engine(
        mesh=mesh, disk_cache=DiskExecutableCache(args.cache_dir),
        tracer=tracer,
        # In pool mode the parent engine is the prewarmer + verify
        # oracle, never the system under test: the plan is armed inside
        # each replica (and on the router for ``router.route``) instead.
        fault_injector=None if args.replicas > 0 else injector,
    )
    specs = {
        "sssp": alg.shortest_paths_spec(hg, source=0,
                                        max_iters=args.iters),
        "ppr": alg.random_walk_spec(hg, iters=args.iters),
    }

    if args.warm:
        report = warm(
            engine, list(specs.values()),
            batch_sizes=(args.max_batch,),
            queries=[0, 0],  # ppr has no query0; seed vertex 0
        )
        print(f"warm boot: {report['boot_s']:.3f}s, "
              f"{report['traces']} traces, "
              f"{report['from_disk']} from disk, "
              f"{report['compiled']} compiled")

    if args.replicas > 0:
        return _serve_pool(args, engine, specs, hg, injector, plan_json)

    fe = Frontend(
        engine, max_batch=args.max_batch,
        max_delay_ms=args.max_delay_ms, log_every_s=args.log_every_s,
        adaptive_delay=args.adaptive_delay,
    )
    for key, spec in specs.items():
        fe.register(key, spec)

    rng = np.random.default_rng(args.seed)
    trace = [
        ("sssp" if rng.random() < args.mix else "ppr",
         int(rng.integers(0, hg.n_vertices)))
        for _ in range(args.requests)
    ]

    t0 = time.perf_counter()
    results, failures = [], []
    with fe:
        futs = [(key, q, fe.submit(key, query=q)) for key, q in trace]
        for key, q, f in futs:
            try:
                results.append((key, q, f.result()))
            except RuntimeError as err:
                # Under an injected fault plan, requests may resolve
                # with a typed FaultError instead of a value — counted
                # and reported, never a hang or a crashed replay.
                failures.append((key, q, err))
    wall_s = time.perf_counter() - t0
    if failures and injector is None:
        print(f"{len(failures)} requests failed without a fault plan",
              file=sys.stderr)
        return 1

    st = fe.stats()
    print(f"served {len(results)} requests in {wall_s:.3f}s "
          f"({len(results) / wall_s:.1f} q/s sustained)")
    print(f"  wait    p50={st['queue_wait']['p50_s'] * 1e3:.2f}ms "
          f"p99={st['queue_wait']['p99_s'] * 1e3:.2f}ms")
    print(f"  execute p50={st['execute']['p50_s'] * 1e3:.2f}ms "
          f"p99={st['execute']['p99_s'] * 1e3:.2f}ms")
    print(f"  flushes {st['flush_reasons']}")
    for bucket, occ in st["buckets"].items():
        print(f"  bucket {bucket}: {occ['flushes']} flushes, "
              f"occupancy {occ['mean_occupancy']:.2f}")
    print(f"  engine cache: entries={st['engine_cache']['entries']} "
          f"hits={st['engine_cache']['hits']} "
          f"traces={st['engine_cache']['traces']}")
    if st["disk_cache"] is not None:
        d = st["disk_cache"]
        print(f"  disk cache:   entries={d['entries']} "
              f"hits={d['disk_hits']} stores={d['disk_stores']} "
              f"({d['dir']})")
    if st.get("adaptive_delay") is not None:
        a = st["adaptive_delay"]
        print(f"  adaptive delay: {a['delay_s'] * 1e3:.2f}ms "
              f"(exec ewma {a['exec_ewma_s'] * 1e3:.2f}ms, "
              f"{a['observations']} obs)")
    if injector is not None:
        snap = injector.snapshot()
        print(f"  fault injection: {sum(snap['fired'].values())} fired "
              f"across {sum(snap['calls'].values())} instrumented calls; "
              f"{len(failures)} requests resolved with typed errors")
        for point in sorted(snap["calls"]):
            print(f"    {point}: calls={snap['calls'][point]} "
                  f"fired={snap['fired'].get(point, 0)}")

    if args.verify:
        # The sequential re-runs are the ORACLE, not the system under
        # test: disarm injection so the reference path runs fault-free.
        engine.fault_injector = None
        idx = rng.choice(len(results), size=min(args.verify, len(results)),
                         replace=False)
        for i in idx:
            key, q, served = results[i]
            seq = fe.compiled(key).run(query=q)
            for a, b in zip(jax.tree.leaves(seq.value),
                            jax.tree.leaves(served.value)):
                if not np.array_equal(np.asarray(a), np.asarray(b),
                                      equal_nan=True):
                    print(f"VERIFY FAILED: {key} query={q}",
                          file=sys.stderr)
                    return 1
        print(f"verified {len(idx)} served results bitwise vs "
              f"sequential run")

    if args.json:
        print(json.dumps(st, indent=2, sort_keys=True, default=str))
    if args.trace and tracer is not None:
        tracer.export(args.trace)
        print(f"trace: {len(tracer.spans())} spans "
              f"({tracer.dropped} dropped) -> {args.trace}")
    if args.metrics_json:
        payload = json.dumps(engine.metrics.snapshot(), indent=2,
                             sort_keys=True, default=str)
        if args.metrics_json == "-":
            print(payload)
        else:
            with open(args.metrics_json, "w") as f:
                f.write(payload + "\n")
            print(f"metrics -> {args.metrics_json}")
    return 0


def _serve_pool(args, engine, specs, hg, injector, plan_json) -> int:
    """Replay the trace through a ``Router`` over N replica processes.

    The parent already prewarmed the shared disk store (under
    ``--warm``), so every replica boots ``require_no_retrace=True``;
    the parent engine stays fault-free and serves as the bitwise
    ``--verify`` oracle.  The chaos invariant being demonstrated:
    every request resolves even when ``replica.crash`` kills workers
    mid-replay, and the survivors' successes match the sequential run.
    """
    import dataclasses
    import itertools

    import jax
    import numpy as np

    from repro.serve import ProcessReplica, ReplicaConfig, Router

    cfg = ReplicaConfig(
        builder="repro.launch.serve_hypergraph:build_paths",
        kwargs={"regime": args.regime, "scale": args.scale,
                "seed": args.seed, "iters": args.iters},
        cache_dir=args.cache_dir,
        max_batch=args.max_batch,
        max_delay_ms=args.max_delay_ms,
        fault_plan=plan_json,
        require_no_retrace=args.warm,
        heartbeat_interval_s=min(0.1, args.heartbeat_timeout_ms / 4e3),
    )
    # Every spawned instance (initial or respawn) gets a distinct prob
    # seed offset, so a respawned replica doesn't replay the exact fault
    # draws that killed its predecessor (see ReplicaConfig.seed_offset).
    spawns = itertools.count()

    def factory(index: int) -> ProcessReplica:
        return ProcessReplica(index, dataclasses.replace(
            cfg, seed_offset=1009 * next(spawns)))

    router = Router(
        factory, args.replicas,
        heartbeat_timeout_ms=args.heartbeat_timeout_ms,
        max_in_flight=2 * args.max_batch,
        fault_injector=injector,
    ).start()
    try:
        t0 = time.perf_counter()
        router.wait_ready()
        boot_s = time.perf_counter() - t0
        boots = [s["boot"] for s in router.stats()["per_replica"]]
        print(f"pool: {args.replicas} replicas ready in {boot_s:.3f}s; "
              f"boots: " + ", ".join(
                  f"#{b['index']} {b['boot_s']:.2f}s "
                  f"(disk={b['from_disk']} aot={b['compiled']} "
                  f"traces={b['traces']})"
                  for b in boots if b))

        rng = np.random.default_rng(args.seed)
        trace = [
            ("sssp" if rng.random() < args.mix else "ppr",
             int(rng.integers(0, hg.n_vertices)))
            for _ in range(args.requests)
        ]
        t0 = time.perf_counter()
        futs = [(key, q, router.submit(key, query=q)) for key, q in trace]
        results, failures = [], []
        for key, q, f in futs:
            try:
                results.append((key, q, f.result(timeout=300)))
            except RuntimeError as err:  # typed FaultError taxonomy
                failures.append((key, q, err))
        wall_s = time.perf_counter() - t0
    finally:
        router.close()

    st = router.stats()
    if st["in_flight"] != 0 or st["pending"] != 0:
        print(f"ROUTER LEAK: in_flight={st['in_flight']} "
              f"pending={st['pending']} after drain", file=sys.stderr)
        return 1
    if failures and injector is None:
        print(f"{len(failures)} requests failed without a fault plan",
              file=sys.stderr)
        return 1
    print(f"served {len(results)}/{len(trace)} requests in {wall_s:.3f}s "
          f"({len(results) / wall_s:.1f} q/s aggregate)")
    print(f"  pool: deaths={st['deaths']} respawns={st['respawns']} "
          f"failovers={st['failovers']} lost={st['lost']} "
          f"shed={st['shed']}")
    for p in st["per_replica"]:
        print(f"  replica {p['index']}: {p['state']} served={p['served']} "
              f"errors={p['errors']} deaths={p['deaths']} "
              f"respawns={p['respawns']}")
    if injector is not None:
        snap = injector.snapshot()
        print(f"  router-side fault injection: "
              f"{sum(snap['fired'].values())} fired across "
              f"{sum(snap['calls'].values())} calls; "
              f"never fired: {snap['never_fired'] or 'none'} "
              f"(replica-side points fire inside the workers); "
              f"{len(failures)} requests resolved with typed errors")

    if args.verify and results:
        idx = rng.choice(len(results),
                         size=min(args.verify, len(results)),
                         replace=False)
        for i in idx:
            key, q, served = results[i]
            seq = engine.compile(specs[key]).run(query=q)
            for a, b in zip(jax.tree.leaves(seq.value),
                            jax.tree.leaves(served.value)):
                if not np.array_equal(np.asarray(a), np.asarray(b),
                                      equal_nan=True):
                    print(f"VERIFY FAILED: {key} query={q}",
                          file=sys.stderr)
                    return 1
        print(f"verified {len(idx)} pool-served results bitwise vs "
              f"sequential run")

    if args.metrics_json:
        from repro.obs.metrics import default_registry

        payload = json.dumps(default_registry().snapshot(), indent=2,
                             sort_keys=True, default=str)
        if args.metrics_json == "-":
            print(payload)
        else:
            with open(args.metrics_json, "w") as f:
                f.write(payload + "\n")
            print(f"metrics -> {args.metrics_json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
