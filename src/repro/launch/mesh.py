"""Production meshes.

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import; tests see
the default single device).

Physical model (TPU v5e-256 pods):
  single pod:  16 x 16 chips -> mesh (data=16, model=16)
  two pods:    (pod=2, data=16, model=16); the ``pod`` axis crosses DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devs)} "
            "are visible — the dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import"
        )
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_host_mesh(n_devices: int | None = None, axis: str = "data"):
    """Small mesh over whatever devices exist (tests / local runs)."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = n_devices or len(devs)
    return Mesh(np.array(devs[:n]).reshape(n), (axis,))


def dp_axes(mesh) -> tuple:
    """Axes carrying data parallelism (pod x data when multi-pod)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def flat_axes(mesh) -> tuple:
    """Every mesh axis flattened (GNN node/edge sharding)."""
    return tuple(mesh.axis_names)


def total_devices(mesh) -> int:
    import math

    return math.prod(mesh.devices.shape)
