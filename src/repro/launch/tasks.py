"""Task builders: (arch x shape x mesh) -> lowerable step + shardings.

These build *LM/GNN* tasks (train / decode-serve / dryrun); hypergraph
query serving has its own entry, ``repro.launch.serve_hypergraph``.

``build_task`` is the single entry the dry-run, the roofline harness and
the trainers share.  ``input_specs`` returns ShapeDtypeStruct stand-ins —
weak-type-correct, shardable, zero allocation; abstract parameters come
from ``jax.eval_shape`` over the real initializers, so the dry-run proves
exactly what a real launch would compile.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map as compat_shard_map
from repro.configs.base import ArchSpec, ShapeSpec
from repro.launch.mesh import dp_axes, flat_axes, total_devices
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainState, make_train_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _path_str(path) -> str:
    """Normalize a tree path to 'a/b/0/c' (DictKey renders as ['a'])."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _pad_up(n: int, m: int) -> int:
    return -(-n // m) * m


@dataclasses.dataclass
class Task:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    name: str
    fn: Callable                      # closed over static config
    abstract_args: tuple              # ShapeDtypeStructs (pytrees)
    in_shardings: tuple               # matching pytrees of NamedSharding
    out_shardings: Any                # or None to infer
    mesh: Any
    # analysis metadata
    model_flops_per_step: float = 0.0
    notes: str = ""

    def lower(self):
        with self.mesh:
            jitted = jax.jit(
                self.fn,
                in_shardings=self.in_shardings,
                out_shardings=self.out_shardings,
            )
            return jitted.lower(*self.abstract_args)


# ==========================================================================
# LM family
# ==========================================================================

def _lm_param_spec(path_str: str, leaf) -> P:
    """FSDP (d_model over 'data') x TP (heads/ff/vocab over 'model')
    sharding rules; see DESIGN.md §8."""
    nd = leaf.ndim
    if "embed/table" in path_str or "item_embed" in path_str:
        return P("model", "data")
    if "lm_head" in path_str:
        return P("data", "model")
    if any(k in path_str for k in ("wq/", "wk/", "wv/")):
        return P(None, "data", "model") if nd == 3 else P("data", "model")
    if "wo/" in path_str:
        return P(None, "model", "data") if nd == 3 else P("model", "data")
    if "moe/router" in path_str:
        return P(None, "data", None)
    if "moe/w_gate" in path_str or "moe/w_up" in path_str:
        return P(None, "model", "data", None)
    if "moe/w_down" in path_str:
        return P(None, "model", None, "data")
    if "shared/w_gate" in path_str or "shared/w_up" in path_str:
        return P(None, "data", "model")
    if "shared/w_down" in path_str:
        return P(None, "model", "data")
    if "ffn/w_gate" in path_str or "ffn/w_up" in path_str:
        return P(None, "data", "model")
    if "ffn/w_down" in path_str:
        return P(None, "model", "data")
    return P()  # norms, biases, scalars


def _divisible(shape, spec: P, mesh) -> bool:
    for dim, axis in zip(shape, tuple(spec) + (None,) * len(shape)):
        if axis is None:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        k = math.prod(mesh.shape[a] for a in axes)
        if dim % k != 0:
            return False
    return True


def _named(mesh, spec: P):
    return NamedSharding(mesh, spec)


def lm_param_shardings(params_abs, mesh):
    def per_leaf(path, leaf):
        path_str = _path_str(path)
        spec = _lm_param_spec(path_str, leaf)
        if not _divisible(leaf.shape, spec, mesh):
            spec = P()  # fallback: replicate (guard, not expected)
        return _named(mesh, spec)

    return jax.tree_util.tree_map_with_path(per_leaf, params_abs)


def _abstract_lm_state(cfg) -> tuple:
    from repro.models.transformer import init_params
    from repro.train.optimizer import adamw_init

    params_abs = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg)
    )
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    return params_abs, opt_abs


def build_lm_task(spec: ArchSpec, shape: ShapeSpec, mesh,
                  accum_steps: int = 1) -> Task:
    from repro.models import transformer as tfm

    cfg = spec.model
    dims = shape.dims
    dp = dp_axes(mesh)
    n_dev = total_devices(mesh)
    name = f"{spec.arch_id}:{shape.name}"

    if shape.kind == "train":
        seq, batch = dims["seq_len"], dims["global_batch"]
        accum = dims.get("accum_steps", accum_steps)
        loss = lambda p, b: tfm.loss_fn(p, cfg, b)
        step = make_train_step(loss, AdamWConfig(), accum)
        params_abs, opt_abs = _abstract_lm_state(cfg)
        state_abs = TrainState(params_abs, opt_abs)
        batch_abs = {
            "tokens": _sds((batch, seq), jnp.int32),
            "labels": _sds((batch, seq), jnp.int32),
        }
        p_sh = lm_param_shardings(params_abs, mesh)
        opt_sh = {
            "mu": lm_param_shardings(opt_abs["mu"], mesh),
            "nu": lm_param_shardings(opt_abs["nu"], mesh),
            "step": _named(mesh, P()),
        }
        state_sh = TrainState(p_sh, opt_sh)
        batch_sh = {
            "tokens": _named(mesh, P(dp, None)),
            "labels": _named(mesh, P(dp, None)),
        }
        metrics_sh = _named(mesh, P())
        model_flops = 3 * 2 * tfm.active_param_count(cfg) * batch * seq
        return Task(
            name=name,
            fn=step,
            abstract_args=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, {
                "loss": metrics_sh, "grad_norm": metrics_sh,
                "lr": metrics_sh,
            }),
            mesh=mesh,
            model_flops_per_step=model_flops,
            notes=f"accum_steps={accum}",
        )

    if shape.kind == "prefill":
        seq, batch = dims["seq_len"], dims["global_batch"]
        params_abs, _ = _abstract_lm_state(cfg)
        p_sh = lm_param_shardings(params_abs, mesh)
        tokens_abs = _sds((batch, seq), jnp.int32)
        fn = lambda p, t: tfm.prefill(p, cfg, t)
        logits_sh = _named(mesh, P(dp, "model"))
        # keep the sequence dim sharded over 'model' — the same split-KV
        # layout decode consumes, and no kvh all-gather on the way out.
        cache_sh = {
            "k": _named(mesh, P(None, dp, "model", None, None)),
            "v": _named(mesh, P(None, dp, "model", None, None)),
        }
        model_flops = 2 * tfm.active_param_count(cfg) * batch * seq
        return Task(
            name=name,
            fn=fn,
            abstract_args=(params_abs, tokens_abs),
            in_shardings=(p_sh, _named(mesh, P(dp, None))),
            out_shardings=(logits_sh, cache_sh),
            mesh=mesh,
            model_flops_per_step=model_flops,
        )

    if shape.kind == "decode":
        seq, batch = dims["seq_len"], dims["global_batch"]
        params_abs, _ = _abstract_lm_state(cfg)
        p_sh = lm_param_shardings(params_abs, mesh)
        cache_abs = jax.eval_shape(
            lambda: tfm.init_cache(cfg, batch, seq)
        )
        if batch >= math.prod(mesh.shape[a] for a in dp):
            # batch carries DP; KV sequence split over 'model' (split-KV)
            cache_spec = P(None, dp, "model", None, None)
        else:
            # long-context: batch tiny; sequence-parallel KV over all axes
            cache_spec = P(None, None, tuple(mesh.axis_names), None, None)
        if not _divisible(cache_abs["k"].shape, cache_spec, mesh):
            cache_spec = P(None, dp, None, None, None)
        cache_sh = {
            "k": _named(mesh, cache_spec),
            "v": _named(mesh, cache_spec),
        }
        token_abs = _sds((batch,), jnp.int32)
        token_spec = P(dp) if batch % math.prod(
            mesh.shape[a] for a in dp
        ) == 0 else P()
        pos_abs = _sds((), jnp.int32)
        fn = lambda p, c, t, pos: tfm.serve_step(p, cfg, c, t, pos)
        logits_sh = _named(
            mesh, P(dp, "model") if token_spec != P() else P(None, "model")
        )
        model_flops = 2 * tfm.active_param_count(cfg) * batch
        return Task(
            name=name,
            fn=fn,
            abstract_args=(params_abs, cache_abs, token_abs, pos_abs),
            in_shardings=(
                p_sh, cache_sh, _named(mesh, token_spec), _named(mesh, P())
            ),
            out_shardings=(logits_sh, cache_sh),
            mesh=mesh,
            model_flops_per_step=model_flops,
        )

    raise ValueError(f"unknown LM shape kind {shape.kind}")


# ==========================================================================
# GNN family
# ==========================================================================

def _gnn_model_cfg(spec: ArchSpec, dims: dict):
    """Specialize the model config to the shape's feature/class dims."""
    m = spec.model
    if hasattr(m, "d_in"):
        m = dataclasses.replace(
            m, d_in=dims.get("d_feat", m.d_in),
            n_classes=dims.get("n_classes", m.n_classes),
        )
    return m


def _gnn_sizes(shape: ShapeSpec, n_dev: int) -> tuple[int, int, int]:
    """(n_nodes, n_edges, n_graphs) padded to device multiples."""
    d = shape.dims
    if "batch_nodes" in d:  # sampled minibatch: the device-side block
        seeds = d["batch_nodes"]
        f0, f1 = d["fanout0"], d["fanout1"]
        n_nodes = seeds * (1 + f0 + f0 * f1) + 1
        n_edges = seeds * (f0 + f0 * f1)
        n_graphs = 1
    elif "batch" in d:      # batched molecules
        n_graphs = d["batch"]
        n_nodes = d["n_nodes"] * n_graphs
        n_edges = d["n_edges"] * n_graphs
    else:
        n_nodes, n_edges, n_graphs = d["n_nodes"], d["n_edges"], 1
    return _pad_up(n_nodes, n_dev), _pad_up(n_edges, n_dev), n_graphs


def _gnn_model_flops(spec: ArchSpec, cfg, n_nodes: int,
                     n_edges: int) -> float:
    """Analytic fwd+bwd model FLOPs (~2x matmul-fwd x3 for training).
    Coarse (+-2x) — used only for the useful-ratio / roofline-fraction
    columns, documented as estimates."""
    if hasattr(cfg, "n_heads"):          # GAT family
        per_layer = (
            2 * n_nodes * cfg.d_in * cfg.n_heads * cfg.d_hidden
            + 4 * n_edges * cfg.n_heads * cfg.d_hidden
        )
        fwd = cfg.n_layers * per_layer
    elif hasattr(cfg, "d_in"):           # PNA family
        h = cfg.d_hidden
        per_layer = (
            4 * n_edges * cfg.d_in * h + 2 * n_nodes * (12 * h) * h
        )
        fwd = cfg.n_layers * per_layer
    else:  # equivariant (nequip / mace): has l_max
        from repro.models.gnn.irreps import allowed_paths

        c = cfg.d_hidden
        paths = allowed_paths(cfg.l_max)
        tp = sum(
            2 * c * (2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)
            for (l1, l2, l3) in paths
        )
        radial = 2 * (cfg.n_rbf * cfg.radial_hidden
                      + cfg.radial_hidden * len(paths) * c)
        mix = 2 * 2 * (cfg.l_max + 1) * c * c * 3
        per_layer = n_edges * (tp + radial) + n_nodes * mix
        if getattr(cfg, "kind", "") == "mace":
            per_layer += (
                (cfg.correlation_order - 1) * n_nodes * c * tp // c
            )
        fwd = cfg.n_layers * per_layer
    return 3.0 * fwd  # fwd+bwd


def build_gnn_task(spec: ArchSpec, shape: ShapeSpec, mesh,
                   exec_mode: str = "pjit") -> Task:
    """exec_mode: 'pjit' (baseline: XLA partitions the gathers) or
    'edge_sharded' (explicit shard_map message passing — the MESH
    replicated backend; §Perf hillclimb H2, sum-aggregation models)."""
    from repro.models.gnn import equivariant, gat, pna
    from repro.models.gnn.graph import GraphBatch

    cfg = _gnn_model_cfg(spec, shape.dims)
    n_dev = total_devices(mesh)
    fa = flat_axes(mesh)
    n_nodes, n_edges, n_graphs = _gnn_sizes(shape, n_dev)
    name = f"{spec.arch_id}:{shape.name}"
    # prefix match: smoke configs carry a "-smoke" suffix
    is_equiv = spec.arch_id.startswith(("mace", "nequip"))

    if is_equiv:
        mod = equivariant
        batch_abs = GraphBatch(
            edge_src=_sds((n_edges,), jnp.int32),
            edge_dst=_sds((n_edges,), jnp.int32),
            edge_mask=_sds((n_edges,), jnp.float32),
            n_nodes=n_nodes,
            positions=_sds((n_nodes, 3), jnp.float32),
            species=_sds((n_nodes,), jnp.int32),
            node_mask=_sds((n_nodes,), jnp.float32),
            graph_ids=_sds((n_nodes,), jnp.int32),
            n_graphs=n_graphs,
            labels=_sds((n_graphs,), jnp.float32),
        )
        node_leaf_specs = {
            "positions": P(fa, None), "species": P(fa),
            "node_mask": P(fa), "graph_ids": P(fa),
        }
        label_spec = P()
    else:
        mod = gat if spec.arch_id.startswith("gat") else pna
        d_feat = shape.dims.get("d_feat", 16)
        batch_abs = GraphBatch(
            edge_src=_sds((n_edges,), jnp.int32),
            edge_dst=_sds((n_edges,), jnp.int32),
            edge_mask=_sds((n_edges,), jnp.float32),
            n_nodes=n_nodes,
            node_feat=_sds((n_nodes, d_feat), jnp.float32),
            node_mask=_sds((n_nodes,), jnp.float32),
            graph_ids=_sds((n_nodes,), jnp.int32),
            n_graphs=n_graphs,
            labels=_sds((n_nodes,), jnp.int32),
        )
        node_leaf_specs = {
            "node_feat": P(fa, None), "node_mask": P(fa),
            "graph_ids": P(fa),
        }
        label_spec = P(fa)

    params_abs = jax.eval_shape(
        lambda: mod.init_params(jax.random.PRNGKey(0), cfg)
    )
    if exec_mode == "edge_sharded":
        from repro.launch.gnn_sharded import make_edge_sharded_step

        step = make_edge_sharded_step(mod, cfg, mesh)
    else:
        loss = lambda p, b: mod.loss_fn(p, cfg, b)
        step = make_train_step(loss, AdamWConfig())
    from repro.train.optimizer import adamw_init

    opt_abs = jax.eval_shape(adamw_init, params_abs)
    state_abs = TrainState(params_abs, opt_abs)
    repl = _named(mesh, P())
    state_sh = jax.tree.map(lambda _: repl, state_abs)

    def batch_sharding(batch):
        def per_path(path, leaf):
            field = _path_str(path[:1])
            if field in ("edge_src", "edge_dst", "edge_mask") or (
                field.isdigit() and int(field) in (0, 1, 2)
            ):
                return _named(mesh, P(fa) if leaf.ndim == 1 else P(fa, None))
            if exec_mode == "edge_sharded":
                return repl  # node arrays replicated (MESH repl. backend)
            if field in node_leaf_specs:
                return _named(mesh, node_leaf_specs[field])
            if field == "labels":
                return _named(mesh, label_spec)
            return repl

        return jax.tree_util.tree_map_with_path(per_path, batch)

    batch_sh = batch_sharding(batch_abs)
    metrics_sh = {"loss": repl, "grad_norm": repl, "lr": repl}
    return Task(
        name=name,
        fn=step,
        abstract_args=(state_abs, batch_abs),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        mesh=mesh,
        model_flops_per_step=_gnn_model_flops(spec, cfg, n_nodes, n_edges),
        notes=f"padded nodes={n_nodes} edges={n_edges} exec={exec_mode}",
    )


# ==========================================================================
# RecSys family
# ==========================================================================

def build_recsys_task(spec: ArchSpec, shape: ShapeSpec, mesh,
                      n_masked: int = 20, n_neg: int = 8192) -> Task:
    from repro.models.recsys import bert4rec as b4r

    cfg = spec.model
    dims = shape.dims
    dp = dp_axes(mesh)
    name = f"{spec.arch_id}:{shape.name}"
    params_abs = jax.eval_shape(
        lambda: b4r.init_params(jax.random.PRNGKey(0), cfg)
    )

    def param_sharding(path, leaf):
        path_str = _path_str(path)
        if "item_embed" in path_str:
            return _named(mesh, P("model", None))
        return _named(mesh, P())

    p_sh = jax.tree_util.tree_map_with_path(param_sharding, params_abs)
    repl = _named(mesh, P())

    def _b4r_fwd_flops(batch: int) -> float:
        d = cfg.embed_dim
        s_len = cfg.max_seq
        per_block = (
            8 * s_len * d * d          # qkv+o proj
            + 4 * s_len * s_len * d    # scores + AV
            + 4 * s_len * d * cfg.d_ff_mult * d
        )
        return batch * cfg.n_blocks * per_block

    if shape.kind == "recsys_train":
        batch = dims["batch"]
        batch_abs = {
            "items": _sds((batch, cfg.max_seq), jnp.int32),
            "masked_pos": _sds((batch, n_masked), jnp.int32),
            "labels": _sds((batch, n_masked), jnp.int32),
            "negatives": _sds((n_neg,), jnp.int32),
        }
        loss = lambda p, b: b4r.loss_sampled(p, cfg, b)
        step = make_train_step(loss, AdamWConfig())
        from repro.train.optimizer import adamw_init

        opt_abs = jax.eval_shape(adamw_init, params_abs)
        state_abs = TrainState(params_abs, opt_abs)
        opt_sh = jax.tree.map(lambda _: repl, opt_abs)
        opt_sh["mu"] = jax.tree_util.tree_map_with_path(
            param_sharding, opt_abs["mu"]
        )
        opt_sh["nu"] = jax.tree_util.tree_map_with_path(
            param_sharding, opt_abs["nu"]
        )
        state_sh = TrainState(p_sh, opt_sh)
        batch_sh = {
            "items": _named(mesh, P(dp, None)),
            "masked_pos": _named(mesh, P(dp, None)),
            "labels": _named(mesh, P(dp, None)),
            "negatives": repl,
        }
        metrics_sh = {"loss": repl, "grad_norm": repl, "lr": repl}
        sampled_softmax = 2 * batch * n_masked * (1 + n_neg) * cfg.embed_dim
        return Task(
            name=name, fn=step,
            abstract_args=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh),
            mesh=mesh,
            model_flops_per_step=3 * (_b4r_fwd_flops(batch)
                                      + sampled_softmax),
        )

    if shape.kind == "recsys_serve":
        batch = dims["batch"]
        items_abs = _sds((batch, cfg.max_seq), jnp.int32)
        # serving shards the batch over EVERY axis; the 'model' axis then
        # cannot also shard the vocab without forcing XLA to replicate the
        # [B, V] scores (measured: 1 TB/device). Replicate the 0.26 GB
        # table instead.
        p_sh = jax.tree.map(lambda _: _named(mesh, P()), params_abs)

        fa = flat_axes(mesh)

        def fn(p, items):
            from repro.models.sharding import constrain

            # online scoring is embarrassingly batch-parallel: the batch
            # shards over EVERY mesh axis (the embedding table is gathered
            # once — 0.25 GB — instead of 84 TB of attention scores being
            # only 16-way sharded).
            scores = b4r.serve_score(p, cfg, items)      # [B, V]
            scores = constrain(scores, "flat", None)
            # lax.top_k's sort is not batch-partitionable (XLA all-gathers
            # the [B, V] scores; measured 1 TB/device) — shard_map it so
            # each device sorts only its own batch rows.
            vals, idx = compat_shard_map(
                lambda sc: tuple(jax.lax.top_k(sc, 100)),
                mesh=mesh,
                in_specs=P(fa, None),
                out_specs=(P(fa, None), P(fa, None)),
                check=True,  # preserve jax.shard_map's checking default
            )(scores)
            return vals, idx

        return Task(
            name=name, fn=fn,
            abstract_args=(params_abs, items_abs),
            in_shardings=(p_sh, _named(mesh, P(fa, None))),
            out_shardings=(
                (_named(mesh, P(fa, None)), _named(mesh, P(fa, None)))
            ),
            mesh=mesh,
            model_flops_per_step=_b4r_fwd_flops(batch)
            + 2 * batch * cfg.vocab * cfg.embed_dim,
        )

    if shape.kind == "recsys_retrieval":
        n_cand = dims["n_candidates"]
        fa = flat_axes(mesh)
        items_abs = _sds((1, cfg.max_seq), jnp.int32)
        cand_abs = _sds((_pad_up(n_cand, total_devices(mesh)),), jnp.int32)

        def fn(p, items, cand):
            scores = b4r.retrieval_score(p, cfg, items, cand)
            vals, idx = jax.lax.top_k(scores, 100)
            return vals, idx

        return Task(
            name=name, fn=fn,
            abstract_args=(params_abs, items_abs, cand_abs),
            in_shardings=(p_sh, repl, _named(mesh, P(fa))),
            out_shardings=(repl, repl),
            mesh=mesh,
        )

    raise ValueError(f"unknown recsys shape kind {shape.kind}")


# ==========================================================================
# dispatch
# ==========================================================================

def build_task(spec: ArchSpec, shape: ShapeSpec, mesh, **kw) -> Task:
    if spec.family == "lm":
        return build_lm_task(spec, shape, mesh, **kw)
    if spec.family == "gnn":
        return build_gnn_task(spec, shape, mesh, **kw)
    if spec.family == "recsys":
        return build_recsys_task(spec, shape, mesh)
    raise ValueError(spec.family)


def input_specs(arch_id: str, shape_name: str, mesh=None, smoke=False):
    """ShapeDtypeStruct stand-ins for every model input of one cell
    (the documented dry-run entry point)."""
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh

    mesh = mesh or make_production_mesh()
    spec = get_config(arch_id, smoke=smoke)
    task = build_task(spec, spec.shape(shape_name), mesh)
    return task.abstract_args
