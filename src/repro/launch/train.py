"""End-to-end trainer with checkpoint/restart fault tolerance.

Runs any LM arch (full or smoke config) on synthetic data.  The data
pipeline is a pure function of (seed, step), so a crash + restore resumes
bit-exactly — the property tests/test_checkpoint.py asserts.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-12b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20

On a real pod the same entry point runs under
``jax.distributed.initialize()`` (one process per host); see README
§Multi-pod launch.  Crash-loop semantics: the launcher (cron / k8s /
Borg) simply re-executes this script; ``--resume`` finds the latest
complete checkpoint and continues.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch(vocab: int, batch: int, seq: int, step: int,
                    seed: int = 0):
    """Deterministic batch keyed on (seed, step) — replayable after
    restart; a real pipeline would checkpoint its cursor the same way."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    tokens = jax.random.randint(key, (batch, seq), 0, vocab)
    return {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.transformer import init_params, loss_fn
    from repro.train import (
        AdamWConfig,
        init_train_state,
        latest_checkpoint,
        make_train_step,
        restore_checkpoint,
        save_checkpoint,
    )

    spec = get_config(args.arch, smoke=args.smoke)
    cfg = spec.model
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    state = init_train_state(params)
    start_step = 0

    if args.resume and args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            state, start_step = restore_checkpoint(path, state)
            print(f"resumed from {path} at step {start_step}")

    step_fn = jax.jit(
        make_train_step(
            lambda p, b: loss_fn(p, cfg, b),
            AdamWConfig(lr=args.lr, total_steps=args.steps),
        )
    )

    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = synthetic_batch(cfg.vocab, args.batch, args.seq, step,
                                args.seed)
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} [{dt:.1f}s]",
                flush=True,
            )
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step + 1, state)
            print(f"checkpoint -> {path}")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    print("done")


if __name__ == "__main__":
    main()
