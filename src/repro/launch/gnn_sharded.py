"""Edge-sharded GNN execution: the MESH replicated backend applied to the
GNN family (DESIGN.md §6, §Perf hillclimb #1).

Baseline pjit execution leaves XLA to partition gathers over sharded edge
arrays, and its gather partitioner replicates the [E, hidden] message
tensors per device (measured: TB-scale temps on ogb_products).  This
executor makes the partitioning explicit:

  * edge arrays sharded over every mesh axis (one edge shard per device),
  * node arrays + params replicated,
  * every segment reduction computes a local partial and merges with
    psum/pmax/pmin (via ``repro.sparse.edge_sharded``) — identical
    semantics to the hypergraph engine's replicated-state backend,
  * gradients of replicated params are handled by shard_map's
    replication-checked autodiff (cotangents of replicated inputs are
    psummed exactly once).

Per-device memory: O(E/P * hidden + N * hidden); collectives: one psum of
the [N, hidden] aggregate per layer — the quantity the partitioning
strategies in the paper optimize.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as compat_shard_map
from repro.sparse.segment import edge_sharded
from repro.train.optimizer import AdamWConfig, adamw_update
from repro.train.step import TrainState


def make_edge_sharded_step(mod, cfg, mesh, opt_cfg: AdamWConfig = None):
    """Returns (state, batch) -> (state, metrics).

    Only the *forward loss* runs inside shard_map (edges sharded, nodes +
    params replicated, segment reductions psum-merged); the gradient is
    taken by differentiating THROUGH the shard_map — JAX's shard_map
    transpose inserts the correct psums for replicated-input cotangents,
    so grads are exact without manual bookkeeping."""
    opt_cfg = opt_cfg or AdamWConfig()
    axes = tuple(mesh.axis_names)

    def local_loss(params, batch):
        with edge_sharded(axes):
            return mod.loss_fn(params, cfg, batch)

    # GraphBatch flattens positionally (tree_flatten children tuple):
    # indices 0-2 are the edge arrays; everything else is node-level or
    # scalar and stays replicated.
    _EDGE_CHILD_IDX = {0, 1, 2}

    def batch_spec(batch):
        def per_field(path, leaf):
            # custom pytree nodes yield FlattenedIndexKey(.key: int) or
            # SequenceKey(.idx: int) depending on registration
            idx = getattr(path[0], "idx", getattr(path[0], "key", None))
            if idx in _EDGE_CHILD_IDX:
                return P(axes) if leaf.ndim == 1 else P(axes, None)
            return P(*((None,) * leaf.ndim))

        return jax.tree_util.tree_map_with_path(per_field, batch)

    def step(state, batch):
        params_spec = jax.tree.map(
            lambda x: P(*((None,) * getattr(x, "ndim", 0))), state.params
        )
        sharded_loss = compat_shard_map(
            local_loss,
            mesh=mesh,
            in_specs=(params_spec, batch_spec(batch)),
            out_specs=P(),
            check=True,
        )
        loss, grads = jax.value_and_grad(sharded_loss)(state.params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt_state, state.params
        )
        return TrainState(new_params, new_opt), {
            "loss": loss, **opt_metrics
        }

    return step
