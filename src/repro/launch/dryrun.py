"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
SPMD-partitions, and compiles for the production meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out reports/dryrun.json

The FIRST TWO LINES below must run before any other import (jax locks the
device count at first init): 512 placeholder host devices back both the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402


def _mesh_for(kind: str):
    from repro.launch.mesh import make_production_mesh

    return make_production_mesh(multi_pod=(kind == "multi"))


def _lm_variant_reports(spec, shape, mesh):
    """Compile unrolled 1- and 2-period variants for the cost-analysis
    differencing (see roofline.analysis docstring).

    Gradient-accumulated train steps also hide an inner scan; variants run
    accum=1 on one microbatch and every additive term is scaled back by
    accum_steps."""
    from repro.launch.tasks import build_task
    from repro.roofline.analysis import analyze_task

    cfg = spec.model
    accum = shape.dims.get("accum_steps", 1)
    var_shape = shape
    if accum > 1:
        dims = dict(shape.dims)
        dims["global_batch"] //= accum
        dims["accum_steps"] = 1
        var_shape = dataclasses.replace(shape, dims=dims)
    reports = []
    for n_periods in (1, 2):
        var_cfg = dataclasses.replace(
            cfg, n_layers=cfg.period * n_periods, scan_layers=False
        )
        var_spec = dataclasses.replace(spec, model=var_cfg)
        var_task = build_task(var_spec, var_shape, mesh)
        var_task.name += f"[unroll{n_periods}p]"
        rep = analyze_task(var_task)
        if accum > 1:
            rep.hlo_flops *= accum
            rep.hlo_bytes *= accum
            rep.collective_bytes_per_dev *= accum
            rep.collective_bytes_by_kind = {
                k: v * accum for k, v in rep.collective_bytes_by_kind.items()
            }
        reports.append(rep)
    return reports[0], reports[1], cfg.n_periods


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             smoke: bool = False, with_roofline: bool = True) -> dict:
    from repro.configs import get_config
    from repro.launch.tasks import build_task
    from repro.roofline.analysis import (
        analyze_compiled, parse_collectives, task_n_devices,
    )

    spec = get_config(arch_id, smoke=smoke)
    shape = spec.shape(shape_name)
    if shape.skip:
        return {
            "cell": f"{arch_id}:{shape_name}", "mesh": mesh_kind,
            "status": "skipped", "reason": shape.skip,
        }
    mesh = _mesh_for(mesh_kind)
    t0 = time.perf_counter()
    task = build_task(spec, shape, mesh)
    lowered = task.lower()
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_row = {
        "argument_gb": mem.argument_size_in_bytes / 1e9,
        "output_gb": mem.output_size_in_bytes / 1e9,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "alias_gb": mem.alias_size_in_bytes / 1e9,
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = parse_collectives(compiled.as_text())

    row = {
        "cell": f"{arch_id}:{shape_name}",
        "mesh": mesh_kind,
        "status": "ok",
        "devices": task_n_devices(task),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_row,
        "cost_flops_per_dev": float(cost.get("flops", 0.0)),
        "cost_bytes_per_dev": float(cost.get("bytes accessed", 0.0)),
        "collective_counts": coll.counts,
        "collective_bytes_per_dev_static": coll.total_bytes,
        "notes": task.notes,
    }

    if with_roofline and mesh_kind == "single":
        rep = analyze_compiled(
            task.name, compiled, task_n_devices(task),
            task.model_flops_per_step,
        )
        if spec.family == "lm" and spec.model.scan_layers:
            r1, r2, n_periods = _lm_variant_reports(spec, shape, mesh)
            k = n_periods - 1
            rep.hlo_flops = r1.hlo_flops + k * (r2.hlo_flops - r1.hlo_flops)
            rep.hlo_bytes = r1.hlo_bytes + k * (r2.hlo_bytes - r1.hlo_bytes)
            rep.collective_bytes_per_dev = (
                r1.collective_bytes_per_dev
                + k * (r2.collective_bytes_per_dev
                       - r1.collective_bytes_per_dev)
            )
            rep.collective_bytes_by_kind = {
                kk: r1.collective_bytes_by_kind.get(kk, 0.0)
                + k * (r2.collective_bytes_by_kind.get(kk, 0.0)
                       - r1.collective_bytes_by_kind.get(kk, 0.0))
                for kk in set(r1.collective_bytes_by_kind)
                | set(r2.collective_bytes_by_kind)
            }
            rep.finish()
        row["roofline"] = rep.row()
    return row


def iter_cells(archs=None, shapes=None, smoke=False):
    from repro.configs import ARCH_IDS, get_config

    for arch_id in archs or ARCH_IDS:
        spec = get_config(arch_id, smoke=smoke)
        for shape_name in spec.shapes:
            if shapes and shape_name not in shapes:
                continue
            yield arch_id, shape_name


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (CI)")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if not args.all and not args.arch:
        ap.error("pass --arch <id> (repeatable) or --all")

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = []
    failures = 0
    for arch_id, shape_name in iter_cells(args.arch, args.shape,
                                          args.smoke):
        for mesh_kind in meshes:
            label = f"{arch_id}:{shape_name}@{mesh_kind}"
            try:
                row = run_cell(
                    arch_id, shape_name, mesh_kind, smoke=args.smoke,
                    with_roofline=not args.no_roofline,
                )
            except Exception as e:  # noqa: BLE001 - report and continue
                traceback.print_exc()
                row = {
                    "cell": f"{arch_id}:{shape_name}", "mesh": mesh_kind,
                    "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                }
                failures += 1
            results.append(row)
            status = row["status"]
            extra = ""
            if status == "ok":
                m = row["memory"]
                extra = (
                    f"compile={row['compile_s']:.1f}s "
                    f"args={m['argument_gb']:.2f}GB "
                    f"temp={m['temp_gb']:.2f}GB"
                )
                if "roofline" in row:
                    r = row["roofline"]
                    extra += (
                        f" dom={r['dominant']}"
                        f" frac={r['roofline_fraction']:.3f}"
                    )
            elif status == "skipped":
                extra = row["reason"][:60]
            print(f"[{status:7s}] {label:55s} {extra}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
