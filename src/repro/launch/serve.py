"""Batched LM serving driver: prefill a prompt batch, then decode N tokens.

Naming note: this is the *LM decode* entry point (transformer stack).
Hypergraph query serving — the coalescing front-end over
``Engine.compile`` — lives in ``repro.launch.serve_hypergraph``.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
      --batch 4 --prompt-len 32 --gen 16

Production notes: on a pod this runs under the decode sharding of
launch/tasks.py (batch over data axes, KV sequence over 'model' — the
split-KV layout the dry-run compiles); here it demonstrates the full
request path on CPU with the reduced config.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.transformer import (
        init_cache,
        init_params,
        prefill,
        serve_step,
    )

    spec = get_config(args.arch, smoke=args.smoke)
    cfg = spec.model
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    max_seq = args.prompt_len + args.gen

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )

    prefill_jit = jax.jit(lambda p, t: prefill(p, cfg, t))
    step_jit = jax.jit(
        lambda p, c, tok, pos: serve_step(p, cfg, c, tok, pos)
    )

    t0 = time.perf_counter()
    logits, warm_cache = prefill_jit(params, prompts)
    # move prefill KV into a full-length cache
    cache = init_cache(cfg, args.batch, max_seq, dtype=warm_cache["k"].dtype)
    cache = {
        k: jax.lax.dynamic_update_slice_in_dim(
            cache[k], warm_cache[k], 0, axis=2
        )
        for k in cache
    }
    tok = jnp.argmax(logits, axis=-1)
    t_prefill = time.perf_counter() - t0

    generated = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = step_jit(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    out = jnp.stack(generated, axis=1)
    print(f"prefill: {t_prefill * 1e3:.1f} ms for "
          f"{args.batch}x{args.prompt_len} tokens")
    print(f"decode:  {t_decode * 1e3:.1f} ms for {args.gen - 1} steps "
          f"({t_decode / max(args.gen - 1, 1) * 1e3:.2f} ms/step)")
    print(f"generated ids [batch 0]: {out[0].tolist()}")


if __name__ == "__main__":
    main()
