"""Launch hypergraph analytics through the ``Engine`` facade.

The hypergraph counterpart of ``repro.launch.dryrun``: run any built-in
algorithm on a generated dataset regime at any design point — or let the
facade's cost models pick representation / partition strategy / backend.

Usage:
  PYTHONPATH=src python -m repro.launch.hypergraph \
      --algorithm pagerank --regime dblp --scale 0.003 \
      --devices 8 --backend auto --partition auto

  # batch analytics (Engine.analyze): the h-motif census
  PYTHONPATH=src python -m repro.launch.hypergraph \
      --algorithm motifs --regime dblp --scale 0.003 \
      --mode auto --kernel auto --devices 4

  # compile-once serve-many (Engine.compile -> run_batch): 64 SSSP
  # sources against one compiled executable
  PYTHONPATH=src python -m repro.launch.hypergraph \
      --algorithm sssp --regime dblp --scale 0.003 --batch 64
  PYTHONPATH=src python -m repro.launch.hypergraph \
      --algorithm random_walk --sources 3,17,99

The device-count env fix must run before any jax import, hence the
module-level XLA_FLAGS block (same pattern as ``dryrun``).
"""
import argparse
import os
import sys
import time


def _parse(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--algorithm", default="pagerank",
                    choices=["pagerank", "vertex_pagerank",
                             "pagerank_entropy", "label_propagation",
                             "sssp", "random_walk",
                             "connected_components", "motifs"])
    ap.add_argument("--regime", default="dblp",
                    help="dataset regime (apache/dblp/friendster/orkut)")
    ap.add_argument("--scale", type=float, default=0.003)
    ap.add_argument("--iters", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=1,
                    help="forced host device count (1 = local execution)")
    ap.add_argument("--representation", default="auto",
                    choices=["auto", "bipartite", "clique"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "local", "replicated", "sharded"])
    ap.add_argument("--partition", default="auto",
                    help="partition strategy name or 'auto'")
    ap.add_argument("--stats", action="store_true",
                    help="print per-superstep activity")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "exact", "sample"],
                    help="motifs only: census mode")
    ap.add_argument("--samples", type=int, default=4000,
                    help="motifs only: sample count for --mode sample")
    ap.add_argument("--kernel", default="auto",
                    choices=["auto", "bitset", "merge"],
                    help="motifs only: intersection kernel path")
    ap.add_argument("--sources", default=None,
                    help="comma-separated query vertices (sssp sources / "
                         "random_walk seeds): compile once, serve the "
                         "batch via CompiledAlgorithm.run_batch")
    ap.add_argument("--batch", type=int, default=None,
                    help="serve N random query vertices through one "
                         "compiled executable (see --sources)")
    ap.add_argument("--explain", action="store_true",
                    help="print the full auto-axis decision tree "
                         "(per-candidate predicted costs) before running")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record engine trace spans; export Chrome-trace "
                         "JSON here (loadable in Perfetto)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the unified metrics-registry snapshot "
                         "as JSON ('-' for stdout)")
    ap.add_argument("--cache-stats", action="store_true",
                    help="print the executable-cache statistics "
                         "(entries, hits/misses, evictions, per-entry "
                         "bucket shapes) after the run")
    return ap.parse_args(argv)


def build_spec(name: str, hg, iters: int):
    from repro import algorithms as alg

    if name == "pagerank":
        return alg.pagerank_spec(hg, iters=iters)
    if name == "vertex_pagerank":
        # vertex ranks only — the clique-eligible variant, so
        # --representation clique/auto can actually constant-fold.
        return alg.vertex_pagerank_spec(hg, iters=iters)
    if name == "pagerank_entropy":
        return alg.pagerank_entropy_spec(hg, iters=iters)
    if name == "label_propagation":
        return alg.label_propagation_spec(hg, iters=iters)
    if name == "sssp":
        return alg.shortest_paths_spec(hg, source=0, max_iters=iters)
    if name == "random_walk":
        return alg.random_walk_spec(hg, iters=iters)
    if name == "connected_components":
        return alg.connected_components_spec(hg, max_iters=iters)
    raise ValueError(name)


def _print_cache_stats(engine) -> None:
    s = engine.cache_stats()
    print(f"cache: entries={s['entries']}/{s['capacity']} "
          f"hits={s['hits']} misses={s['misses']} "
          f"evictions={s['evictions']} traces={s['traces']}")
    for meta in s["entry_shapes"]:
        print(f"  entry: {meta}")
    if s.get("disk") is not None:
        print(f"  disk: {s['disk']}")


def _print_explain(ex: dict) -> None:
    print("explain:")
    for axis, info in ex["axes"].items():
        print(f"  {axis}: winner={info.get('winner')} "
              f"({info.get('reason')})")
        for cand, costs in info.get("candidates", {}).items():
            mark = "*" if cand == info.get("winner") else " "
            kv = " ".join(
                f"{k}={v}" for k, v in costs.items()
                if k not in ("class_plans",) and not isinstance(v, dict)
            )
            print(f"   {mark} {cand}: {kv}")


def _emit_obs(engine, args) -> None:
    if args.trace and engine.tracer is not None:
        engine.tracer.export(args.trace)
        print(f"trace: {len(engine.tracer.spans())} spans "
              f"({engine.tracer.dropped} dropped) -> {args.trace}")
    if args.metrics_json:
        import json

        payload = json.dumps(engine.metrics.snapshot(), indent=2,
                             sort_keys=True, default=str)
        if args.metrics_json == "-":
            print(payload)
        else:
            with open(args.metrics_json, "w") as f:
                f.write(payload + "\n")
            print(f"metrics -> {args.metrics_json}")


def main(argv=None) -> int:
    args = _parse(argv)
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import numpy as np

    from repro.core import AnalyticsSpec, Engine
    from repro.data import make_dataset
    from repro.launch.mesh import make_host_mesh

    hg = make_dataset(args.regime, scale=args.scale, seed=args.seed)
    print(f"{args.regime}: |V|={hg.n_vertices} |E|={hg.n_hyperedges} "
          f"nnz={hg.nnz}")

    mesh = make_host_mesh(args.devices) if args.devices > 1 else None
    tracer = None
    if args.trace:
        from repro.obs import Tracer

        tracer = Tracer()
    engine = Engine(
        mesh=mesh,
        tracer=tracer,
        representation=args.representation,
        backend=args.backend,
        partition_strategy=args.partition,
        collect_stats=args.stats,
        intersect_kernel=args.kernel,
    )

    if args.algorithm == "motifs":
        aspec = AnalyticsSpec(
            hg, mode=args.mode, n_samples=args.samples, seed=args.seed,
        )
        if args.explain:
            _print_explain(engine.explain(aspec))
        res = engine.analyze(aspec)
        print(f"design point: representation={res.representation} "
              f"kernel={res.kernel} backend={res.backend} "
              f"mode={res.mode}")
        for ax, why in res.decision.items():
            reason = why.get("reason") if isinstance(why, dict) else why
            print(f"  {ax}: {reason}")
        c = res.value
        if res.mode == "exact":
            print(f"census: {c.total} connected triples over "
                  f"{c.n_pairs} overlapping pairs "
                  f"({c.n_duplicate_triples} duplicate-hyperedge "
                  f"triples dropped)")
            counts = c.counts
        else:
            print(f"census (estimated from {c.n_samples} sampled "
                  f"linked pairs of {c.n_pairs}): total ~{c.total:.0f}")
            counts = c.counts
        top = np.argsort(counts)[::-1][:6]
        for m in top:
            if counts[m] > 0:
                line = f"  h-motif {m:2d}: {counts[m]:.0f}"
                if res.mode == "sample":
                    line += (f"  [{c.ci_low[m]:.0f}, {c.ci_high[m]:.0f}] "
                             f"@{c.confidence:.0%}")
                print(line)
        _emit_obs(engine, args)
        return 0

    spec = build_spec(args.algorithm, hg, args.iters)
    if args.explain:
        _print_explain(engine.explain(spec))

    if args.sources is not None or args.batch is not None:
        # compile-once serve-many: one executable, B queries.
        if spec.bind_query is None:
            print(f"--sources/--batch need a query-capable algorithm "
                  f"(sssp, random_walk); {args.algorithm} has no query "
                  f"axis", file=sys.stderr)
            return 2
        if args.sources is not None:
            queries = np.asarray(
                [int(s) for s in args.sources.split(",")], np.int32
            )
        else:
            rng = np.random.default_rng(args.seed)
            queries = rng.integers(
                0, hg.n_vertices, size=args.batch
            ).astype(np.int32)
        compiled = engine.compile(spec)
        t0 = time.perf_counter()
        res = compiled.run_batch(queries)
        jax.block_until_ready(res.value)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = compiled.run_batch(queries)
        jax.block_until_ready(res.value)
        warm_s = time.perf_counter() - t0
        print(f"design point: representation={res.representation} "
              f"backend={res.backend} partition={res.partition}")
        print(f"served {len(queries)} queries: cold {cold_s:.3f}s "
              f"({len(queries) / cold_s:.1f} q/s incl. compile), warm "
              f"{warm_s:.3f}s ({len(queries) / warm_s:.1f} q/s)")
        _print_cache_stats(engine)
        leaves = jax.tree.leaves(res.value)
        first = np.asarray(leaves[0])
        for i, q in enumerate(queries[:4]):
            print(f"  query {int(q):4d}: {first[i].ravel()[:5]}")
        _emit_obs(engine, args)
        return 0

    res = engine.run(spec)

    print(f"design point: representation={res.representation} "
          f"backend={res.backend} partition={res.partition}")
    for axis, why in res.decision.items():
        if axis == "measured":
            continue
        reason = why.get("reason") if isinstance(why, dict) else why
        print(f"  {axis}: {reason}")
    m = res.decision.get("measured")
    if m:
        line = (f"  measured: wall={m['wall_s'] * 1e3:.1f}ms "
                f"device_wait={m['device_wait_s'] * 1e3:.2f}ms")
        if m.get("supersteps") is not None:
            line += f" supersteps={m['supersteps']}/{m['max_iters']}"
        print(line)
    if res.partition_stats is not None:
        s = res.partition_stats
        print(f"  plan: vrep={s.vertex_replication:.2f} "
              f"herep={s.hyperedge_replication:.2f} "
              f"sync={s.sync_bytes_per_dim / 1e6:.3f} MB/dim")
    if res.superstep_stats is not None:
        v_act, he_act = res.superstep_stats
        print(f"  activity: v={np.asarray(v_act).tolist()}")
        print(f"            he={np.asarray(he_act).tolist()}")
    leaves = jax.tree.leaves(res.value)
    print(f"result: {len(leaves)} output array(s); "
          f"first = {np.asarray(leaves[0]).ravel()[:6]}")
    if args.cache_stats:
        _print_cache_stats(engine)
    _emit_obs(engine, args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
