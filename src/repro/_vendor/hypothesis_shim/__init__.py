"""A tiny, dependency-free stand-in for ``hypothesis``.

The repo's property tests (``tests/test_partition.py``,
``tests/test_segment_ops.py``, ``tests/test_executor.py``) are written
against the real hypothesis API; when the package is installed it is used
unchanged.  This shim exists so the tier-1 suite *runs* those properties —
rather than skipping them — on minimal images where ``pip install`` is not
available.  It covers exactly the API surface the tests use:

* ``@given(*strategies)`` — deterministic seeded example loop
  (seed = example index, so failures reproduce run-to-run),
* ``settings`` / ``settings.register_profile`` / ``settings.load_profile``
  with ``max_examples`` (``deadline`` accepted and ignored),
* ``hypothesis.strategies``: ``integers``, ``floats``, ``lists``,
  ``sampled_from``, ``booleans``, ``tuples``, ``composite``.

No shrinking, no example database — a failing example is reported verbatim
instead.  ``tests/conftest.py`` installs this under ``sys.modules
["hypothesis"]`` only when the real package is missing.
"""
from __future__ import annotations

import functools
import inspect
import random as _random
from typing import Any

from repro._vendor.hypothesis_shim import strategies
from repro._vendor.hypothesis_shim.strategies import SearchStrategy

__all__ = ["given", "settings", "strategies", "SearchStrategy", "example"]

IS_SHIM = True  # lets tests / tooling detect the fallback


class settings:
    """Profile-based example-count control (subset of hypothesis')."""

    _profiles: dict[str, dict[str, Any]] = {"default": {"max_examples": 20}}
    _current: dict[str, Any] = dict(_profiles["default"])

    def __init__(self, parent: "settings | None" = None, **kwargs: Any):
        self._kwargs = dict(kwargs)

    def __call__(self, fn):
        fn._shim_settings = self._kwargs
        return fn

    @classmethod
    def register_profile(cls, name: str, **kwargs: Any) -> None:
        cls._profiles[name] = dict(kwargs)

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._current = dict(cls._profiles["default"])
        cls._current.update(cls._profiles.get(name, {}))


def example(*args: Any, **kwargs: Any):
    """Accepted for API compatibility; explicit examples are prepended."""

    def deco(fn):
        fn._shim_examples = getattr(fn, "_shim_examples", []) + [args]
        return fn

    return deco


def given(*given_strategies: SearchStrategy):
    if not given_strategies:
        raise TypeError("given() requires at least one strategy")

    def deco(fn):
        n_params = len(given_strategies)

        @functools.wraps(fn)
        def wrapper(*fixture_args: Any, **fixture_kwargs: Any):
            cfg = dict(settings._current)
            cfg.update(getattr(fn, "_shim_settings", {}))
            max_examples = int(cfg.get("max_examples", 20))
            for explicit in getattr(fn, "_shim_examples", []):
                fn(*fixture_args, *explicit, **fixture_kwargs)
            for i in range(max_examples):
                rng = _random.Random(0xC0FFEE ^ (i * 7919))
                drawn = [s.do_draw(rng) for s in given_strategies]
                try:
                    fn(*fixture_args, *drawn, **fixture_kwargs)
                except Exception:
                    print(
                        f"Falsifying example (shim, #{i}): "
                        f"{fn.__name__}{tuple(drawn)!r}"
                    )
                    raise

        # Hide the strategy-filled parameters from pytest's fixture
        # resolution: the wrapper's visible signature keeps only the
        # leading params NOT supplied by @given.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        kept = params[: max(0, len(params) - n_params)]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        return wrapper

    return deco
