"""Strategy combinators for the vendored hypothesis shim.

Implements only what this repo's property tests use: ``integers``,
``floats``, ``lists``, ``sampled_from``, ``booleans``, ``tuples`` and
``composite``.  Every strategy is a thin wrapper around a draw function
``random.Random -> value``; shrinking and the database are intentionally
out of scope (the real hypothesis, when installed, takes precedence — see
``tests/conftest.py``).
"""
from __future__ import annotations

import random as _random
from typing import Any, Callable, Sequence


class SearchStrategy:
    """A lazily-drawn value source (mirror of hypothesis' class name)."""

    def __init__(self, draw_fn: Callable[[_random.Random], Any],
                 label: str = "strategy"):
        self._draw_fn = draw_fn
        self._label = label

    def do_draw(self, rng: _random.Random) -> Any:
        return self._draw_fn(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw_fn(rng)),
                              f"{self._label}.map")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<shim {self._label}>"


def integers(min_value: int, max_value: int) -> SearchStrategy:
    lo, hi = int(min_value), int(max_value)

    def draw(rng: _random.Random) -> int:
        # Bias toward the boundaries occasionally — cheap edge coverage.
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rng.randint(lo, hi)

    return SearchStrategy(draw, f"integers({lo},{hi})")


def floats(
    min_value: float,
    max_value: float,
    allow_nan: bool = False,
    allow_infinity: bool = False,
    width: int = 64,
) -> SearchStrategy:
    lo, hi = float(min_value), float(max_value)

    def draw(rng: _random.Random) -> float:
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        if r < 0.15:
            return 0.0 if lo <= 0.0 <= hi else lo
        return rng.uniform(lo, hi)

    return SearchStrategy(draw, f"floats({lo},{hi})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rng: pool[rng.randrange(len(pool))],
                          f"sampled_from(<{len(pool)}>)")


def lists(
    elements: SearchStrategy,
    min_size: int = 0,
    max_size: int | None = None,
) -> SearchStrategy:
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng: _random.Random) -> list:
        n = rng.randint(min_size, hi)
        return [elements.do_draw(rng) for _ in range(n)]

    return SearchStrategy(draw, f"lists({min_size},{hi})")


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.do_draw(rng) for s in strategies), "tuples"
    )


def composite(fn: Callable) -> Callable[..., SearchStrategy]:
    """``@st.composite``: ``fn(draw, *args) -> value`` becomes a strategy
    factory; ``draw`` resolves nested strategies against the same RNG."""

    def builder(*args: Any, **kwargs: Any) -> SearchStrategy:
        def draw_value(rng: _random.Random) -> Any:
            def draw(strategy: SearchStrategy) -> Any:
                return strategy.do_draw(rng)

            return fn(draw, *args, **kwargs)

        return SearchStrategy(draw_value, f"composite:{fn.__name__}")

    return builder
