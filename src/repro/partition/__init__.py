"""Hypergraph partitioning: the paper's central design axis."""
from repro.partition.base import PartitionPlan, PartitionStats, build_plan
from repro.partition.strategies import STRATEGIES, partition

__all__ = [
    "PartitionPlan",
    "PartitionStats",
    "build_plan",
    "STRATEGIES",
    "partition",
]
