"""Partition plans: the ``getAllPartitions`` abstraction, TPU-shaped.

A partitioner maps every incidence edge to a partition id (the paper's
extended GraphX interface returns exactly this RDD).  From that assignment
we derive:

* padded, statically-shaped per-partition edge shards (XLA needs equal
  shapes across the ``data`` mesh axis — padding edges carry ``mask=0`` and
  reduce to the combiner identity), and
* the stats the paper's evaluation turns on: replication factors, load
  balance, and projected per-superstep collective bytes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PartitionStats:
    n_parts: int
    edge_balance: float          # max shard / mean shard (1.0 = perfect)
    vertex_replication: float    # avg #partitions holding a vertex replica
    hyperedge_replication: float
    pad_fraction: float          # wasted lanes from static-shape padding
    # projected bytes moved per superstep per float32 of entity state:
    #   sync cost of every replica beyond the master copy, both directions.
    sync_bytes_per_dim: float
    # the per-side replica surplus behind sync_bytes_per_dim (number of
    # extra entity copies the cut created); kept separate so consumers
    # can weight each side by its actual state width in bytes
    # (select_backend folds attribute widths in — wide hyperedge state
    # must not be priced like a scalar vertex rank).
    v_extra_replicas: float = 0.0
    he_extra_replicas: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def sync_bytes(
        self, v_state_bytes: float = 4.0, he_state_bytes: float = 4.0
    ) -> float:
        """Projected per-superstep sync volume with each side weighted
        by its state width (bytes per entity); the historical
        ``sync_bytes_per_dim`` is the 4-byte-uniform special case."""
        return 2.0 * (
            v_state_bytes * self.v_extra_replicas
            + he_state_bytes * self.he_extra_replicas
        )


@dataclasses.dataclass
class PartitionPlan:
    """Edge->partition assignment plus padded shards."""

    name: str
    n_parts: int
    edge_part: np.ndarray        # [nnz] int32
    # padded shards, shape [n_parts, shard_len]:
    shard_src: np.ndarray
    shard_dst: np.ndarray
    shard_mask: np.ndarray       # float32 {0,1}
    stats: PartitionStats
    partition_time_s: float = 0.0

    @property
    def shard_len(self) -> int:
        return int(self.shard_src.shape[1])


def _replication(entity_ids: np.ndarray, parts: np.ndarray, n: int) -> float:
    """Average number of distinct partitions touching each entity."""
    if len(entity_ids) == 0 or n == 0:
        return 0.0
    key = entity_ids.astype(np.int64) * np.int64(2**20) + parts.astype(np.int64)
    distinct = len(np.unique(key))
    present = len(np.unique(entity_ids))
    return distinct / max(present, 1)


def build_plan(
    name: str,
    src: np.ndarray,
    dst: np.ndarray,
    n_vertices: int,
    n_hyperedges: int,
    edge_part: np.ndarray,
    n_parts: int,
    pad_multiple: int = 8,
    partition_time_s: float = 0.0,
) -> PartitionPlan:
    nnz = len(src)
    counts = np.bincount(edge_part, minlength=n_parts)
    shard_len = int(counts.max()) if nnz else pad_multiple
    shard_len = -(-shard_len // pad_multiple) * pad_multiple

    shard_src = np.zeros((n_parts, shard_len), np.int32)
    shard_dst = np.zeros((n_parts, shard_len), np.int32)
    shard_mask = np.zeros((n_parts, shard_len), np.float32)
    order = np.argsort(edge_part, kind="stable")
    s_sorted, d_sorted = src[order], dst[order]
    offsets = np.zeros(n_parts + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    for p in range(n_parts):
        lo, hi = offsets[p], offsets[p + 1]
        k = hi - lo
        shard_src[p, :k] = s_sorted[lo:hi]
        shard_dst[p, :k] = d_sorted[lo:hi]
        shard_mask[p, :k] = 1.0

    v_rep = _replication(src, edge_part, n_vertices)
    he_rep = _replication(dst, edge_part, n_hyperedges)
    mean_load = max(counts.mean(), 1e-9)
    # Sync model (paper §IV-B): every replica beyond the first must be
    # refreshed (gather) and its partial aggregate merged back (scatter)
    # once per superstep -> 2 transfers x 4 bytes per state dim.
    n_v_present = len(np.unique(src)) if nnz else 0
    n_he_present = len(np.unique(dst)) if nnz else 0
    v_extra = max((v_rep - 1.0) * n_v_present, 0.0)
    he_extra = max((he_rep - 1.0) * n_he_present, 0.0)
    stats = PartitionStats(
        n_parts=n_parts,
        edge_balance=float(counts.max() / mean_load) if nnz else 1.0,
        vertex_replication=float(v_rep),
        hyperedge_replication=float(he_rep),
        pad_fraction=float(1.0 - nnz / (n_parts * shard_len)),
        sync_bytes_per_dim=float(2 * 4 * (v_extra + he_extra)),
        v_extra_replicas=float(v_extra),
        he_extra_replicas=float(he_extra),
    )
    return PartitionPlan(
        name=name,
        n_parts=n_parts,
        edge_part=edge_part.astype(np.int32),
        shard_src=shard_src,
        shard_dst=shard_dst,
        shard_mask=shard_mask,
        stats=stats,
        partition_time_s=partition_time_s,
    )
