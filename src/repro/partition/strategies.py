"""The seven MESH partitioning strategies (paper §IV-B).

All operate host-side on the incidence COO (NumPy), exactly as GraphX
partitioning runs before the iterative phase; partition *time* is part of
the paper's reported results so each returns it.

Naming follows the paper: "X-cut" means entity set X gets *cut*
(replicated) while the other set is partitioned intact.
"""
from __future__ import annotations

import time

import numpy as np

from repro.partition.base import PartitionPlan, build_plan

# A large prime for multiplicative hashing (the paper's ``mPrime``).
M_PRIME = np.int64(1_000_000_007)


def _hash(x: np.ndarray, n_parts: int) -> np.ndarray:
    return ((np.abs(x.astype(np.int64)) * M_PRIME) % n_parts).astype(np.int32)


def _finish(name, src, dst, nv, ne, edge_part, n_parts, t0):
    return build_plan(
        name, src, dst, nv, ne, edge_part, n_parts,
        partition_time_s=time.perf_counter() - t0,
    )


def random_vertex_cut(src, dst, nv, ne, n_parts) -> PartitionPlan:
    """Hash by hyperedge: hyperedges partitioned intact, vertices cut."""
    t0 = time.perf_counter()
    part = _hash(dst, n_parts)
    return _finish("random_vertex_cut", src, dst, nv, ne, part, n_parts, t0)


def random_hyperedge_cut(src, dst, nv, ne, n_parts) -> PartitionPlan:
    """Hash by vertex: vertices partitioned intact, hyperedges cut."""
    t0 = time.perf_counter()
    part = _hash(src, n_parts)
    return _finish("random_hyperedge_cut", src, dst, nv, ne, part, n_parts, t0)


def random_both_cut(src, dst, nv, ne, n_parts) -> PartitionPlan:
    """Hash by (src, dst): both sets cut (GraphX EdgePartition2D spirit)."""
    t0 = time.perf_counter()
    key = src.astype(np.int64) * np.int64(1_000_003) + dst.astype(np.int64)
    part = _hash(key, n_parts)
    return _finish("random_both_cut", src, dst, nv, ne, part, n_parts, t0)


def hybrid_vertex_cut(
    src, dst, nv, ne, n_parts, cutoff: int = 100
) -> PartitionPlan:
    """PowerLyra-style: partition hyperedges by dst-hash, except
    high-cardinality hyperedges (> cutoff) get scattered by src-hash
    (Listing 8)."""
    t0 = time.perf_counter()
    card = np.bincount(dst, minlength=ne)
    high = card[dst] > cutoff
    part = np.where(high, _hash(src, n_parts), _hash(dst, n_parts))
    return _finish("hybrid_vertex_cut", src, dst, nv, ne, part, n_parts, t0)


def hybrid_hyperedge_cut(
    src, dst, nv, ne, n_parts, cutoff: int = 100
) -> PartitionPlan:
    """Dual: partition vertices by src-hash, except high-degree vertices
    scattered by dst-hash."""
    t0 = time.perf_counter()
    deg = np.bincount(src, minlength=nv)
    high = deg[src] > cutoff
    part = np.where(high, _hash(dst, n_parts), _hash(src, n_parts))
    return _finish("hybrid_hyperedge_cut", src, dst, nv, ne, part, n_parts, t0)


def _greedy(
    group_ids: np.ndarray,      # entity grouping the loop walks (dst or src)
    member_ids: np.ndarray,     # the other endpoint (src or dst)
    n_groups: int,
    n_members: int,
    n_parts: int,
    chunk: int,
) -> np.ndarray:
    """Aweto-style greedy: assign one group (hyperedge or vertex) at a time
    to the partition with max ``overlap - sqrt(load)`` (Listing 9).

    Overlap = members of this group already replicated on that partition.
    ``chunk > 1`` scores that many groups against a frozen replica state
    before committing — the scalable approximation used for large inputs
    (Aweto itself partitions greedily over independent subsets).
    """
    if n_parts > 64:
        raise ValueError(
            "greedy partitioner tracks replicas in a uint64 bitmask; "
            f"n_parts={n_parts} > 64. Use hybrid/random for wider meshes "
            "or raise the mask width."
        )
    order = np.argsort(group_ids, kind="stable")
    g_sorted = group_ids[order]
    m_sorted = member_ids[order]
    bounds = np.searchsorted(g_sorted, np.arange(n_groups + 1))

    replica_mask = np.zeros(n_members, np.uint64)  # bit p => replica on p
    load = np.zeros(n_parts, np.float64)
    group_part = np.zeros(n_groups, np.int32)
    bits = (np.uint64(1) << np.arange(n_parts, dtype=np.uint64))

    # Iterate groups in descending size (large groups placed first — they
    # constrain the solution most; same heuristic family as Aweto).
    sizes = bounds[1:] - bounds[:-1]
    visit = np.argsort(-sizes, kind="stable")

    for start in range(0, n_groups, chunk):
        batch = visit[start:start + chunk]
        # Score all groups in the batch against the frozen state.
        for g in batch:
            lo, hi = bounds[g], bounds[g + 1]
            if hi == lo:
                group_part[g] = int(np.argmin(load))
                continue
            members = m_sorted[lo:hi]
            masks = replica_mask[members]
            # popcount per partition: overlap[p] = #members with bit p set
            overlap = (
                (masks[:, None] & bits[None, :]) != 0
            ).sum(axis=0).astype(np.float64)
            score = overlap - np.sqrt(load)
            p = int(np.argmax(score))
            group_part[g] = p
            replica_mask[members] |= bits[p]
            load[p] += hi - lo
    return group_part


def greedy_vertex_cut(
    src, dst, nv, ne, n_parts, chunk: int = 1
) -> PartitionPlan:
    """Assign hyperedges greedily; vertices get cut (Listing 9)."""
    t0 = time.perf_counter()
    he_part = _greedy(dst, src, ne, nv, n_parts, chunk)
    part = he_part[dst]
    return _finish("greedy_vertex_cut", src, dst, nv, ne, part, n_parts, t0)


def greedy_hyperedge_cut(
    src, dst, nv, ne, n_parts, chunk: int = 1
) -> PartitionPlan:
    """Assign vertices greedily; hyperedges get cut."""
    t0 = time.perf_counter()
    v_part = _greedy(src, dst, nv, ne, n_parts, chunk)
    part = v_part[src]
    return _finish("greedy_hyperedge_cut", src, dst, nv, ne, part, n_parts, t0)


STRATEGIES = {
    "random_vertex_cut": random_vertex_cut,
    "random_hyperedge_cut": random_hyperedge_cut,
    "random_both_cut": random_both_cut,
    "hybrid_vertex_cut": hybrid_vertex_cut,
    "hybrid_hyperedge_cut": hybrid_hyperedge_cut,
    "greedy_vertex_cut": greedy_vertex_cut,
    "greedy_hyperedge_cut": greedy_hyperedge_cut,
}


def partition(
    name: str, hg, n_parts: int, **kw
) -> PartitionPlan:
    """Partition a HyperGraph with the named strategy."""
    src = np.asarray(hg.src)
    dst = np.asarray(hg.dst)
    return STRATEGIES[name](
        src, dst, hg.n_vertices, hg.n_hyperedges, n_parts, **kw
    )
