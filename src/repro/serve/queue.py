"""The coalescing batcher: heterogeneous requests -> homogeneous batches.

``CompiledAlgorithm.run_batch`` wants B same-signature queries at once;
real traffic arrives one query at a time, interleaved across algorithms
and hypergraphs.  ``CoalescingBatcher`` bridges the two:

* requests group by an opaque **group key** — the front-end uses
  ``(spec_key, hypergraph identity)``, so only queries that share one
  compiled executable signature ever coalesce;
* each group **admits** up to its capacity (the batch bucket the
  executable was compiled for); an arrival that fills the group makes
  it immediately flushable (reason ``"full"``);
* a group whose **oldest deadline** has passed is flushable with
  whatever it holds (reason ``"deadline"`` — the partial-flush path
  that bounds tail latency);
* ``drain`` flushes everything regardless (reason ``"drain"`` —
  shutdown / test pump).

The batcher is intentionally pure plumbing: no threads, no jax, no wall
clock (callers inject ``now``) — so the coalescing invariants
(every request flushed exactly once, never above capacity, FIFO within
a group) are property-testable in microseconds.  Thread-safety and
execution live in ``repro.serve.frontend``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

FLUSH_REASONS = ("full", "deadline", "drain")


@dataclasses.dataclass
class Request:
    """One in-flight query.

    ``deadline`` is absolute (same clock as ``submit``'s ``now``):
    the latest instant this request may keep waiting for co-batchable
    traffic.  ``future`` is whatever completion handle the caller
    attaches (the front-end uses ``concurrent.futures.Future``; the
    pure tests use plain lists)."""

    group: Any
    query: Any
    arrival: float
    deadline: float
    future: Any = None
    seq: int = 0


@dataclasses.dataclass
class Flush:
    """One batch handed to the executor: FIFO requests of one group."""

    group: Any
    requests: list[Request]
    reason: str
    hg: Any = None


class _Group:
    __slots__ = ("hg", "pending")

    def __init__(self, hg):
        self.hg = hg
        self.pending: list[Request] = []


class CoalescingBatcher:
    """Admission + flush policy over pending request groups.

    ``capacity``: max requests per flush (per group) — the batch bucket.
    May be an int or a ``key -> int`` callable for per-group buckets.
    """

    def __init__(self, capacity: Any = 64):
        self._capacity = capacity
        self._groups: dict[Any, _Group] = {}
        self._seq = itertools.count()

    def capacity(self, group_key: Any) -> int:
        cap = self._capacity
        cap = cap(group_key) if callable(cap) else cap
        if cap < 1:
            raise ValueError(f"capacity for {group_key!r} must be >= 1")
        return int(cap)

    # -- admission ---------------------------------------------------------

    def submit(
        self,
        group_key: Any,
        query: Any,
        *,
        now: float,
        deadline_s: float,
        hg: Any = None,
        future: Any = None,
    ) -> Request:
        """Admit one request; duplicates of an in-flight query are real
        requests (each gets its own slot and future)."""
        req = Request(
            group=group_key,
            query=query,
            arrival=now,
            deadline=now + deadline_s,
            future=future,
            seq=next(self._seq),
        )
        grp = self._groups.get(group_key)
        if grp is None:
            grp = self._groups[group_key] = _Group(hg)
        elif grp.hg is not hg and grp.pending:
            raise ValueError(
                f"group {group_key!r} has pending requests against a "
                "different hypergraph; use a distinct group key per "
                "hypergraph"
            )
        else:
            grp.hg = hg
        grp.pending.append(req)
        return req

    # -- flush policy ------------------------------------------------------

    def pending_count(self) -> int:
        return sum(len(g.pending) for g in self._groups.values())

    def next_deadline(self) -> float | None:
        """Earliest pending deadline, or None when idle — the worker's
        sleep horizon."""
        deadlines = [
            g.pending[0].deadline
            for g in self._groups.values()
            if g.pending
        ]
        return min(deadlines) if deadlines else None

    def poll(self, now: float) -> Flush | None:
        """The next due flush, or None.

        Full groups flush first (they can't improve by waiting); then
        the group with the OLDEST expired deadline (fairness under
        sustained overload).  A full group yields exactly ``capacity``
        requests and keeps the remainder queued with their original
        deadlines."""
        full_key = None
        expired_key, expired_deadline = None, None
        for key, grp in self._groups.items():
            if not grp.pending:
                continue
            if len(grp.pending) >= self.capacity(key):
                full_key = key
                break
            head = grp.pending[0].deadline
            if head <= now and (
                expired_deadline is None or head < expired_deadline
            ):
                expired_key, expired_deadline = key, head
        if full_key is not None:
            return self._take(full_key, "full")
        if expired_key is not None:
            return self._take(expired_key, "deadline")
        return None

    def drain(self) -> list[Flush]:
        """Flush every pending request (capacity-sized chunks), FIFO."""
        flushes = []
        for key in list(self._groups):
            while self._groups[key].pending:
                flushes.append(self._take(key, "drain"))
        return flushes

    def _take(self, key: Any, reason: str) -> Flush:
        grp = self._groups[key]
        cap = self.capacity(key)
        batch, grp.pending = grp.pending[:cap], grp.pending[cap:]
        return Flush(group=key, requests=batch, reason=reason, hg=grp.hg)
