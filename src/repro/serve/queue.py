"""The coalescing batcher: heterogeneous requests -> homogeneous batches.

``CompiledAlgorithm.run_batch`` wants B same-signature queries at once;
real traffic arrives one query at a time, interleaved across algorithms
and hypergraphs.  ``CoalescingBatcher`` bridges the two:

* requests group by an opaque **group key** — the front-end uses
  ``(spec_key, hypergraph identity)``, so only queries that share one
  compiled executable signature ever coalesce;
* each group **admits** up to its capacity (the batch bucket the
  executable was compiled for); an arrival that fills the group makes
  it immediately flushable (reason ``"full"``);
* a group whose **oldest deadline** has passed is flushable with
  whatever it holds (reason ``"deadline"`` — the partial-flush path
  that bounds tail latency);
* ``drain`` flushes everything regardless (reason ``"drain"`` —
  shutdown / test pump).

The batcher is intentionally pure plumbing: no threads, no jax, no wall
clock (callers inject ``now``) — so the coalescing invariants
(every request flushed exactly once, never above capacity, FIFO within
a group) are property-testable in microseconds.  Thread-safety and
execution live in ``repro.serve.frontend``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

FLUSH_REASONS = ("full", "deadline", "drain")


@dataclasses.dataclass
class Request:
    """One in-flight query.

    ``deadline`` is absolute (same clock as ``submit``'s ``now``):
    the latest instant this request may keep waiting for co-batchable
    traffic.  ``expiry`` (also absolute, None = no limit) is the
    request's HARD deadline: past it the front-end resolves the future
    with ``DeadlineExceeded`` instead of serving.  ``future`` is
    whatever completion handle the caller attaches (the front-end uses
    ``concurrent.futures.Future``; the pure tests use plain lists).
    ``requeues`` counts worker-crash requeues (bounded by the
    supervisor so a deterministic crash cannot loop forever)."""

    group: Any
    query: Any
    arrival: float
    deadline: float
    future: Any = None
    seq: int = 0
    expiry: float | None = None
    requeues: int = 0


@dataclasses.dataclass
class Flush:
    """One batch handed to the executor: FIFO requests of one group."""

    group: Any
    requests: list[Request]
    reason: str
    hg: Any = None


class _Group:
    __slots__ = ("hg", "pending")

    def __init__(self, hg):
        self.hg = hg
        self.pending: list[Request] = []


class CoalescingBatcher:
    """Admission + flush policy over pending request groups.

    ``capacity``: max requests per flush (per group) — the batch bucket.
    May be an int or a ``key -> int`` callable for per-group buckets.
    """

    def __init__(self, capacity: Any = 64):
        self._capacity = capacity
        self._groups: dict[Any, _Group] = {}
        self._seq = itertools.count()

    def capacity(self, group_key: Any) -> int:
        cap = self._capacity
        cap = cap(group_key) if callable(cap) else cap
        if cap < 1:
            raise ValueError(f"capacity for {group_key!r} must be >= 1")
        return int(cap)

    # -- admission ---------------------------------------------------------

    def submit(
        self,
        group_key: Any,
        query: Any,
        *,
        now: float,
        deadline_s: float,
        hg: Any = None,
        future: Any = None,
        expiry: float | None = None,
    ) -> Request:
        """Admit one request; duplicates of an in-flight query are real
        requests (each gets its own slot and future)."""
        req = Request(
            group=group_key,
            query=query,
            arrival=now,
            deadline=now + deadline_s,
            future=future,
            seq=next(self._seq),
            expiry=expiry,
        )
        grp = self._groups.get(group_key)
        if grp is None:
            grp = self._groups[group_key] = _Group(hg)
        elif grp.hg is not hg and grp.pending:
            raise ValueError(
                f"group {group_key!r} has pending requests against a "
                "different hypergraph; use a distinct group key per "
                "hypergraph"
            )
        else:
            grp.hg = hg
        grp.pending.append(req)
        return req

    # -- flush policy ------------------------------------------------------

    def pending_count(self) -> int:
        return sum(len(g.pending) for g in self._groups.values())

    def next_deadline(self) -> float | None:
        """Earliest pending deadline, or None when idle — the worker's
        sleep horizon."""
        deadlines = [
            g.pending[0].deadline
            for g in self._groups.values()
            if g.pending
        ]
        return min(deadlines) if deadlines else None

    def poll(self, now: float) -> Flush | None:
        """The next due flush, or None.

        Full groups flush first (they can't improve by waiting); then
        the group with the OLDEST expired deadline (fairness under
        sustained overload).  A full group yields exactly ``capacity``
        requests and keeps the remainder queued with their original
        deadlines."""
        full_key = None
        expired_key, expired_deadline = None, None
        for key, grp in self._groups.items():
            if not grp.pending:
                continue
            if len(grp.pending) >= self.capacity(key):
                full_key = key
                break
            head = grp.pending[0].deadline
            if head <= now and (
                expired_deadline is None or head < expired_deadline
            ):
                expired_key, expired_deadline = key, head
        if full_key is not None:
            return self._take(full_key, "full")
        if expired_key is not None:
            return self._take(expired_key, "deadline")
        return None

    def drain(self) -> list[Flush]:
        """Flush every pending request (capacity-sized chunks), FIFO."""
        flushes = []
        for key in list(self._groups):
            while self._groups[key].pending:
                flushes.append(self._take(key, "drain"))
        return flushes

    def _take(self, key: Any, reason: str) -> Flush:
        grp = self._groups[key]
        cap = self.capacity(key)
        batch, grp.pending = grp.pending[:cap], grp.pending[cap:]
        return Flush(group=key, requests=batch, reason=reason, hg=grp.hg)

    def requeue(self, flush: Flush) -> None:
        """Put a crashed worker's in-flight requests back at the HEAD of
        their group, preserving FIFO order (their original deadlines
        make the group immediately due again)."""
        grp = self._groups.get(flush.group)
        if grp is None:
            grp = self._groups[flush.group] = _Group(flush.hg)
        grp.hg = flush.hg
        grp.pending[:0] = flush.requests


class AdaptiveDelay:
    """Bounded EWMA controller for the coalescing flush deadline.

    The fixed ``max_delay_ms`` is a guess; the right deadline depends
    on traffic, and the wait/execute split ``ServeMetrics`` already
    records says which way it's wrong.  Policy (one signal per flush):

    * reason ``"full"`` — buckets fill before any deadline: waiting
      buys nothing, pull the deadline toward ``lo_s``;
    * reason ``"deadline"`` at LOW occupancy — flushes go out mostly
      empty: waiting longer could coalesce more, pull toward
      ``exec_ratio x EWMA(execute)`` (a request should never wait much
      longer than the batch execute its waiting saves);
    * otherwise (deadline flush, decently full) — hold.

    Every update is one gain-bounded EWMA step clamped to
    ``[lo_s, hi_s]``, so the delay is ALWAYS in bounds and converges
    geometrically under a steady signal — both property-tested.  Pure
    and clock-free (callers pass observed durations), like the batcher;
    OFF by default (``Frontend(adaptive_delay=True)`` opts in).
    """

    def __init__(
        self,
        delay_s: float,
        *,
        lo_s: float = 5e-4,
        hi_s: float = 5e-2,
        gain: float = 0.3,
        exec_alpha: float = 0.3,
        exec_ratio: float = 1.0,
        low_occupancy: float = 0.5,
    ):
        if not 0.0 < lo_s <= hi_s:
            raise ValueError(f"need 0 < lo_s <= hi_s, got {lo_s}, {hi_s}")
        if not 0.0 < gain <= 1.0:
            raise ValueError(f"gain must be in (0, 1], got {gain}")
        self.lo_s, self.hi_s = float(lo_s), float(hi_s)
        self.gain = float(gain)
        self.exec_alpha = float(exec_alpha)
        self.exec_ratio = float(exec_ratio)
        self.low_occupancy = float(low_occupancy)
        self._exec_ewma: float | None = None
        self.delay_s = self._clamp(float(delay_s))
        self.observations = 0

    def _clamp(self, x: float) -> float:
        return min(max(x, self.lo_s), self.hi_s)

    def observe(
        self, *, execute_s: float, occupancy: float, reason: str
    ) -> float:
        """Fold in one flush; returns the updated delay (seconds)."""
        # analysis: ignore[host-sync] — host float in, host float out;
        # no device value crosses this controller
        execute_s = max(float(execute_s), 0.0)
        self._exec_ewma = (
            execute_s
            if self._exec_ewma is None
            else (1.0 - self.exec_alpha) * self._exec_ewma
            + self.exec_alpha * execute_s
        )
        if reason == "full":
            target = self.lo_s
        elif occupancy <= self.low_occupancy:
            target = self._clamp(self.exec_ratio * self._exec_ewma)
        else:
            target = self.delay_s
        self.delay_s = self._clamp(
            self.delay_s + self.gain * (target - self.delay_s)
        )
        self.observations += 1
        return self.delay_s

    def snapshot(self) -> dict:
        return {
            "delay_s": self.delay_s,
            "exec_ewma_s": self._exec_ewma,
            "observations": self.observations,
            "lo_s": self.lo_s,
            "hi_s": self.hi_s,
        }
