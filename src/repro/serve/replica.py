"""One serving replica: a worker *process* booted from the shared store.

The serving tier through PR 9 is one ``Frontend`` owning one Engine in
one process — resilient to thread crashes and poisoned batches, but a
single point of failure at the process level.  This module is the unit
the ``Router`` (``repro.serve.router``) replicates:

* ``ReplicaConfig`` — everything a replica needs to boot, picklable
  across a ``spawn`` boundary: a **builder reference**
  (``"pkg.mod:function"`` resolved by import, never a pickled closure)
  plus its kwargs, the shared ``DiskExecutableCache`` directory, the
  coalescing knobs, and an optional ``FaultPlan`` JSON armed *inside*
  the replica.
* ``replica_main(conn, config)`` — the child-process entry point: build
  the engine, ``serve.warm(..., require_no_retrace=config.
  require_no_retrace)`` from the shared disk store (a respawned replica
  reaches warm q/s with ZERO retraces), then serve a pipe loop — one
  ``Frontend`` coalesces and executes, the loop receives requests and
  streams results + periodic heartbeats back.
* ``ProcessReplica`` — the router-side handle: spawn, non-blocking
  message drain, liveness (pipe EOF / exit code), kill (-9, for chaos
  tests) and stop.

Fault points (armed via ``config.fault_plan``): ``replica.crash`` fires
``os._exit`` — the in-process model of kill -9, losing every in-flight
request exactly like a real crash — and ``replica.hang`` stops
heartbeats without exiting, so the router's missed-heartbeat detector
(not pipe EOF) has to catch it.

Wire protocol (pickled tuples over a ``multiprocessing.Pipe``):
router->replica ``("req", id, spec_key, query, hg_ref, deadline_ms)``
and ``("stop",)``; replica->router ``("ready", boot_report)``,
``("hb", stats)``, ``("res", id, ServedResult)``, ``("err", id, exc)``,
``("fatal", repr)`` on a boot failure, ``("bye", stats)`` on a clean
stop.  At-least-once execution is safe: a failed-over request re-runs
the same compiled executable on a peer, and the compiled paths are
deterministic, so a duplicate execute returns the bitwise-same value.
"""
from __future__ import annotations

import dataclasses
import importlib
import multiprocessing
import os
import pickle
import threading
import time
from functools import partial

_CRASH_EXIT = 13      # replica.crash's exit code: distinguishable from 0


@dataclasses.dataclass(frozen=True)
class ReplicaConfig:
    """Everything one replica process needs to boot, picklable.

    ``builder`` is an import reference ``"package.module:function"``;
    called with ``**kwargs`` in the CHILD process it returns::

        {"specs": {spec_key: AlgorithmSpec},        # required, ordered
         "warm_queries": [example per spec] | None, # for query0-free specs
         "hypergraphs": {hg_ref: HyperGraph} | None}

    so nothing unpicklable (specs close over functions) ever crosses
    the process boundary.  ``require_no_retrace=True`` is the fleet
    contract: the shared store was pre-populated, so a boot that
    compiles anyway raises ``RetraceError`` instead of silently paying
    trace latency on first requests.
    """

    builder: str
    kwargs: dict = dataclasses.field(default_factory=dict)
    cache_dir: str | None = None
    max_batch: int = 16
    max_delay_ms: float = 5.0
    heartbeat_interval_s: float = 0.1
    fault_plan: str | None = None
    seed_offset: int = 0
    require_no_retrace: bool = True
    hang_s: float = 60.0
    index: int = 0


def resolve_builder(ref: str):
    """``"pkg.mod:function"`` -> the callable (child-side import)."""
    mod, _, fn = ref.partition(":")
    if not mod or not fn:
        raise ValueError(
            f"builder reference {ref!r} must be 'package.module:function'"
        )
    return getattr(importlib.import_module(mod), fn)


def _picklable(err: BaseException) -> BaseException:
    """The error as something the pipe can carry; typed errors from the
    taxonomy round-trip as themselves, exotic ones degrade to repr."""
    try:
        pickle.loads(pickle.dumps(err))
        return err
    except Exception:
        return RuntimeError(f"{type(err).__name__}: {err}")


def replica_main(conn, config: ReplicaConfig) -> None:
    """Child-process entry point: boot from the shared store, serve the
    pipe loop until ``("stop",)`` or pipe EOF."""
    try:
        _serve_replica(conn, config)
    except BaseException as err:
        # Boot failures (builder import, warm RetraceError, ...) reach
        # the router as one typed message; the exit code seals it.
        try:
            conn.send(("fatal", f"{type(err).__name__}: {err}"))
        except Exception:
            pass
        raise


def _serve_replica(conn, config: ReplicaConfig) -> None:
    from repro.core import Engine
    from repro.serve.cache import DiskExecutableCache, warm
    from repro.serve.frontend import Frontend

    injector = None
    if config.fault_plan:
        from repro.faults import FaultInjector, FaultPlan

        plan = FaultPlan.from_json(config.fault_plan)
        if config.seed_offset:
            # Each spawned INSTANCE draws a distinct probabilistic fault
            # stream.  Without this a respawned replica re-arms the same
            # seed, replays the same draws against the requeued backlog,
            # and deterministically crashes at the same received-count —
            # a respawn cascade that serves nothing forever.
            plan = FaultPlan(rules=tuple(
                dataclasses.replace(r, seed=r.seed + config.seed_offset)
                if r.trigger == "prob" else r
                for r in plan.rules
            ))
        injector = FaultInjector(plan)
    engine = Engine(
        disk_cache=DiskExecutableCache(config.cache_dir),
        fault_injector=injector,
    )
    built = resolve_builder(config.builder)(**config.kwargs)
    specs = built["specs"]
    hgs = built.get("hypergraphs") or {}
    report = warm(
        engine, list(specs.values()),
        batch_sizes=(config.max_batch,),
        queries=built.get("warm_queries"),
        require_no_retrace=config.require_no_retrace,
    )
    fe = Frontend(
        engine, max_batch=config.max_batch,
        max_delay_ms=config.max_delay_ms,
    )
    for key, spec in specs.items():
        fe.register(key, spec)

    # One pipe, two writers: this loop (heartbeats) and the front-end's
    # worker thread (done callbacks) — Connection is not thread-safe.
    send_lock = threading.Lock()
    counts = {"received": 0, "completed": 0, "errors": 0}

    def _send(msg) -> bool:
        with send_lock:
            try:
                conn.send(msg)
                return True
            except (BrokenPipeError, OSError, ValueError):
                return False   # router gone; the loop will exit

    def _on_done(req_id: int, fut) -> None:
        try:
            served = fut.result()
        except BaseException as err:  # typed FaultError fans back typed
            counts["errors"] += 1
            _send(("err", req_id, _picklable(err)))
        else:
            counts["completed"] += 1
            _send(("res", req_id, served))

    fe.start()
    stop = False
    try:
        _send(("ready", {
            "index": config.index,
            "pid": os.getpid(),
            "boot_s": report["boot_s"],
            "traces": report["traces"],
            "from_disk": report["from_disk"],
            "compiled": report["compiled"],
        }))
        next_hb = time.monotonic() + config.heartbeat_interval_s
        while not stop:
            if conn.poll(max(next_hb - time.monotonic(), 0.0)):
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    break          # router died: no one left to serve
                if msg[0] == "stop":
                    stop = True
                elif msg[0] == "req":
                    _, req_id, spec_key, query, hg_ref, deadline_ms = msg
                    counts["received"] += 1
                    if injector is not None and not _chaos_gate(
                        injector, config
                    ):
                        continue   # hang fired: request lost, as planned
                    try:
                        hg = hgs[hg_ref] if hg_ref is not None else None
                        fut = fe.submit(
                            spec_key, hg=hg, query=query,
                            deadline_ms=deadline_ms,
                        )
                    except Exception as err:   # unknown key / closed
                        counts["errors"] += 1
                        _send(("err", req_id, _picklable(err)))
                    else:
                        fut.add_done_callback(partial(_on_done, req_id))
            now = time.monotonic()
            if now >= next_hb:
                if not _send(("hb", dict(counts))):
                    break
                next_hb = now + config.heartbeat_interval_s
    finally:
        # Graceful stop: requests still queued fail typed
        # (FrontendClosed) and their callbacks stream the errors back
        # before the pipe closes.
        fe.close()
        _send(("bye", dict(counts)))
        try:
            conn.close()
        except Exception:  # analysis: ignore[swallowed-error] — last act
            pass           # of a dying process; no one left to tell


def _chaos_gate(injector, config: ReplicaConfig) -> bool:
    """Fire the per-request replica fault points.  ``replica.crash``
    hard-exits (the kill -9 model: in-flight requests are simply gone);
    ``replica.hang`` sleeps without heartbeating so ONLY the router's
    missed-heartbeat detector can declare this replica dead.  Returns
    False when the current request should be dropped (hang fired)."""
    try:
        injector.maybe_raise("replica.crash", replica=config.index)
    except BaseException:
        os._exit(_CRASH_EXIT)
    try:
        injector.maybe_raise("replica.hang", replica=config.index)
    except BaseException:
        time.sleep(config.hang_s)   # the router will kill us first
        return False
    return True


class ProcessReplica:
    """Router-side handle on one spawned replica process.

    The interface the ``Router`` consumes (and chaos tests fake):
    ``poll_messages`` (non-blocking drain), ``send`` (raises on a
    broken pipe), ``alive`` (pipe + exit-code liveness), ``stop``
    (graceful or forced), ``kill`` (SIGKILL, for chaos tests) and
    ``connection`` (waitable, for the router thread's poll).
    """

    def __init__(self, index: int, config: ReplicaConfig):
        ctx = multiprocessing.get_context("spawn")
        parent, child = ctx.Pipe()
        self.index = index
        self.process = ctx.Process(
            target=replica_main,
            args=(child, dataclasses.replace(config, index=index)),
            name=f"repro-replica-{index}",
            daemon=True,
        )
        self.process.start()
        child.close()
        self.connection = parent
        self._broken = False

    @property
    def pid(self) -> int | None:
        return self.process.pid

    def poll_messages(self) -> list:
        """Drain every message currently in the pipe, non-blocking.
        A broken pipe marks the handle dead instead of raising — the
        messages drained before the break are still delivered."""
        out: list = []
        try:
            while not self._broken and self.connection.poll(0):
                out.append(self.connection.recv())
        except (EOFError, OSError):
            self._broken = True
        return out

    def send(self, msg) -> None:
        if self._broken:
            raise BrokenPipeError(f"replica {self.index} pipe is down")
        try:
            self.connection.send(msg)
        except (BrokenPipeError, OSError, ValueError):
            self._broken = True
            raise

    def alive(self) -> bool:
        return not self._broken and self.process.exitcode is None

    def kill(self) -> None:
        """SIGKILL, no warning — the chaos tests' real kill -9."""
        try:
            self.process.kill()
        except Exception:
            pass

    def stop(self, force: bool = False, join_s: float = 5.0) -> None:
        """Tear the process down.  Graceful sends ``("stop",)`` and
        waits; ``force=True`` (death declaration: the replica missed
        heartbeats or broke its pipe) goes straight to terminate so a
        wedged process can't stall the failover path."""
        if not force:
            try:
                self.send(("stop",))
            except Exception:
                pass
            self.process.join(join_s)
        if self.process.exitcode is None:
            self.process.terminate()
            self.process.join(1.0)
        if self.process.exitcode is None:
            self.process.kill()
            self.process.join(1.0)
        self._broken = True
        try:
            self.connection.close()
        except Exception:
            pass
