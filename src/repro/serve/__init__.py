"""The serving tier: an async request front-end on the compile-once seam.

``Engine.compile`` (PR 3) made one executable serve many queries — but
only for hand-assembled homogeneous batches, with an executable cache
that dies with the process.  This package turns that seam into a
request-serving subsystem, in three layers:

* ``cache``    — a persistent cross-process executable store
  (``DiskExecutableCache``): compiled XLA executables serialized to
  disk keyed by a stable digest of ``serving.signature``, so a fresh
  replica boots to warm-path throughput without recompiling
  (``warm(engine, specs)``).  Falls back to a trace-recipe warmup
  record where the platform can't round-trip serialized executables.
* ``queue``    — the coalescing batcher (``CoalescingBatcher``): groups
  heterogeneous in-flight queries by (compiled path, hypergraph),
  admits per group up to the batch bucket, and flushes on deadline or
  full batch.  Pure, clock-injected, jit-free — property-testable
  without touching jax.
* ``frontend`` — the submission API (``Frontend.submit(spec_key, hg,
  query, deadline_ms) -> Future``): a worker thread drains the batcher
  into ``CompiledAlgorithm.run_batch`` continuously and fans results
  back out to per-request futures, bitwise identical to sequential
  ``CompiledAlgorithm.run`` calls.
* ``metrics``  — latency observability (``ServeMetrics``): p50/p99/p999
  histograms split queue-wait vs execute, per-bucket occupancy, flush
  reasons, cache hit/miss/eviction/disk counters — exposed as
  ``Frontend.stats()`` and a periodic log line.
* ``replica`` / ``router`` — multi-replica serving: N worker
  *processes* (``ProcessReplica``) each booting ``warm(...,
  require_no_retrace=True)`` from the ONE shared disk store, behind a
  ``Router`` doing affinity/least-loaded routing, heartbeat death
  detection, bounded failover (``ReplicaLost`` after ``MAX_FAILOVERS``),
  disk-warmed respawn and ``Overloaded`` load shedding.  The PR 9
  invariant — every request resolves, successes bitwise equal the
  sequential fault-free path — holds across kill -9.

Entry points: ``repro.launch.serve_hypergraph`` (mixed SSSP/PPR replay
loop) and ``benchmarks/bench_serve_tier.py`` (sustained q/s, p99, boot
times -> ``BENCH_serve_tier.json``).
"""
from repro.serve.cache import DiskExecutableCache, stable_digest, warm
from repro.serve.frontend import Frontend, ServedResult
from repro.serve.metrics import LatencyHistogram, ServeMetrics
from repro.serve.queue import AdaptiveDelay, CoalescingBatcher, Flush, Request
from repro.serve.replica import ProcessReplica, ReplicaConfig, replica_main
from repro.serve.router import MAX_FAILOVERS, Router

__all__ = [
    "AdaptiveDelay",
    "CoalescingBatcher",
    "DiskExecutableCache",
    "Flush",
    "Frontend",
    "LatencyHistogram",
    "MAX_FAILOVERS",
    "ProcessReplica",
    "ReplicaConfig",
    "Request",
    "Router",
    "ServedResult",
    "ServeMetrics",
    "replica_main",
    "stable_digest",
    "warm",
]
