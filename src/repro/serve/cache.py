"""Persistent cross-process executable cache + replica-boot warmup.

The Engine's executable LRU (``Engine._exec_cache``) is per-process:
every replica of a serving fleet re-pays the cold compile that
``BENCH_serving.json`` measures at ~144x the warm-path cost.  This
module closes that gap:

* ``stable_digest(key)`` maps a ``repro.core.serving.signature`` tuple —
  which keys programs by *object identity* in memory — onto a digest
  that is stable ACROSS processes running the same code: functions
  contribute their qualified name, bytecode and closure values instead
  of their id.
* ``DiskExecutableCache`` stores serialized XLA executables
  (``jax.experimental.serialize_executable``) under
  ``$REPRO_CACHE_DIR`` (default ``.repro_cache/``), namespaced by
  platform / device count / jax version so a blob is only ever loaded
  into the environment that produced it.  Where the platform cannot
  round-trip a serialized executable, ``store`` degrades to a
  *warmup record* — a marker telling the next boot to re-trace eagerly
  rather than on first request — so ``warm`` keeps its contract.
* ``warm(engine, specs)`` is the replica-boot API: compile every spec
  and materialize its executables — deserializing from disk (ZERO
  retraces, asserted by tests) or AOT-compiling and populating the
  store for the next replica.

The Engine integration is one seam: when ``Engine.disk_cache`` is set,
``Engine._executable_for`` wraps each freshly-built executable in
``_DiskBackedExecutable``, which resolves disk-load vs AOT-compile
lazily on first use (the call site in ``serving._execute`` is unchanged).
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import pickle
import tempfile
import time
import types
import weakref
from functools import partial
from pathlib import Path
from typing import Any, Iterable

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX: publish stays atomic
    fcntl = None

import numpy as np

from repro.obs.metrics import default_registry, weak_provider
from repro.obs.trace import maybe_span

_SCHEMA = 1
_FORMAT_EXECUTABLE = "xla-executable"
_FORMAT_WARMUP = "warmup-record"
DEFAULT_CACHE_DIR = ".repro_cache"


def _checksum(data: bytes) -> str:
    """Content checksum over the serialized executable bytes: detects
    truncation and bit-rot that still unpickle cleanly."""
    return hashlib.sha256(data).hexdigest()


def cache_root(path: str | os.PathLike | None = None) -> Path:
    """The on-disk cache location: explicit path, else ``$REPRO_CACHE_DIR``,
    else ``.repro_cache/`` under the working directory (gitignored)."""
    return Path(
        path or os.environ.get("REPRO_CACHE_DIR") or DEFAULT_CACHE_DIR
    )


# --------------------------------------------------------------------------
# stable signature digests
# --------------------------------------------------------------------------

def _hash_code(code: types.CodeType, h) -> None:
    h.update(code.co_code)
    h.update(repr(code.co_names).encode())
    h.update(repr(code.co_varnames).encode())
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            _hash_code(const, h)
        else:
            h.update(repr(const).encode())


def _hash_function(fn, h) -> None:
    """Qualified name + bytecode + closure values: two processes running
    the same source produce the same token; an edited algorithm (or a
    different closed-over constant, e.g. ``alpha``) changes it."""
    h.update(f"fn:{fn.__module__}:{fn.__qualname__}".encode())
    code = getattr(fn, "__code__", None)
    if code is not None:
        _hash_code(code, h)
    for cell in fn.__closure__ or ():
        try:
            _token(cell.cell_contents, h)
        except ValueError:  # an unhashable self-reference: name only
            h.update(b"cell:opaque")
    defaults = getattr(fn, "__defaults__", None)
    if defaults:
        _token(defaults, h)


def _token(obj: Any, h) -> None:
    """Fold one signature component into the hash, by value."""
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        h.update(f"{type(obj).__name__}:{obj!r}".encode())
    elif isinstance(obj, partial):
        h.update(b"partial")
        _hash_function(obj.func, h)
        _token(obj.args, h)
        _token(tuple(sorted(obj.keywords.items())), h)
    elif isinstance(obj, types.FunctionType) or isinstance(
        obj, types.MethodType
    ):
        _hash_function(
            obj.__func__ if isinstance(obj, types.MethodType) else obj, h
        )
    elif isinstance(obj, dict):
        h.update(b"dict")
        for k in sorted(obj, key=repr):
            _token(k, h)
            _token(obj[k], h)
    elif isinstance(obj, (tuple, list)):
        h.update(f"seq:{len(obj)}".encode())
        for item in obj:
            _token(item, h)
    elif isinstance(obj, np.ndarray):
        h.update(f"nd:{obj.dtype}:{obj.shape}".encode())
        h.update(np.ascontiguousarray(obj).tobytes())
    elif hasattr(obj, "dtype") and hasattr(obj, "shape"):  # jax array
        _token(np.asarray(obj), h)
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        # Program / Monoid / spec-level containers: field-by-field, so
        # function-valued fields hash by bytecode, not memory address.
        h.update(
            f"dc:{type(obj).__module__}.{type(obj).__qualname__}".encode()
        )
        for field in dataclasses.fields(obj):
            h.update(field.name.encode())
            _token(getattr(obj, field.name), h)
    elif callable(obj) and hasattr(obj, "__qualname__"):
        # builtins / callables without python code objects
        h.update(
            f"call:{getattr(obj, '__module__', '?')}:"
            f"{obj.__qualname__}".encode()
        )
    else:
        # treedefs, enums, misc hashables: their repr is stable for the
        # types the serving signature actually contains.
        h.update(
            f"obj:{type(obj).__module__}.{type(obj).__qualname__}:"
            f"{obj!r}".encode()
        )


def stable_digest(key: Any) -> str:
    """A cross-process digest of an executable-cache signature tuple."""
    h = hashlib.sha256()
    _token(key, h)
    return h.hexdigest()


# --------------------------------------------------------------------------
# the disk store
# --------------------------------------------------------------------------

class DiskExecutableCache:
    """Serialize compiled executables to a per-platform on-disk store.

    >>> engine = Engine(disk_cache=DiskExecutableCache())
    >>> warm(engine, [spec], batch_sizes=(8,))   # boot: load or compile
    >>> engine.compile(spec).run_batch(queries)  # zero retraces if warm

    Blobs live under ``<root>/<platform>-<ndev>dev-jax<version>-v<N>/``:
    an executable is only ever deserialized into the environment shape
    that produced it.  Every entry is either a serialized executable or
    a warmup record (the fallback where ``serialize_executable`` cannot
    round-trip this platform's executables); records never satisfy
    ``load`` but tell ``warm`` the compile is expected and intentional.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        import jax

        self.root = cache_root(path)
        self.dir = self.root / (
            f"{jax.default_backend()}-{jax.device_count()}dev-"
            f"jax{jax.__version__}-v{_SCHEMA}"
        )
        self._stats = {
            "disk_hits": 0,
            "disk_misses": 0,
            "disk_stores": 0,
            "disk_errors": 0,
            "warm_records": 0,
            "disk_quarantined": 0,
            "disk_migrated": 0,
            "disk_lock_waits": 0,
        }
        # Duck-typed like Engine.tracer: Engine(fault_injector=...)
        # forwards its injector here so the disk.read / disk.write /
        # disk.deserialize chaos points fire inside the real try blocks.
        self.fault_injector = None
        default_registry().register_provider(
            "serve.disk_cache", weak_provider(self.stats)
        )

    # -- paths -------------------------------------------------------------

    def _path(self, digest: str) -> Path:
        return self.dir / f"{digest}.jexe"

    def _write(self, digest: str, payload: dict) -> None:
        """Atomic publish: a concurrently-booting replica never reads a
        torn blob."""
        self.dir.mkdir(parents=True, exist_ok=True)
        blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._path(digest))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @contextlib.contextmanager
    def lock(self, key: Any):
        """Advisory cross-process claim on one signature.

        Two replicas booting concurrently from one store race the same
        miss: both would pay the AOT compile and rename over each other
        (safe — the publish is atomic — but one whole compile is
        wasted).  Holding the signature's ``flock`` while compiling
        serializes the claim: the loser blocks (counted as a
        ``disk_lock_waits``), then finds the winner's entry on its
        re-check load.  The lock lives next to the entry
        (``<digest>.lock``) and the kernel releases it on process death,
        so a replica killed -9 mid-compile never wedges its peers.
        No-op where ``fcntl`` is unavailable (the atomic publish is the
        only guarantee there)."""
        if fcntl is None:
            yield
            return
        self.dir.mkdir(parents=True, exist_ok=True)
        with open(self.dir / f"{stable_digest(key)}.lock", "ab") as f:
            try:
                fcntl.flock(f, fcntl.LOCK_EX | fcntl.LOCK_NB)
            except OSError:
                self._stats["disk_lock_waits"] += 1
                fcntl.flock(f, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(f, fcntl.LOCK_UN)

    def _quarantine(self, path: Path, err: Exception) -> None:
        """Move a bad entry aside (``<name>.corrupt``, never deleted —
        post-mortem evidence) so the next boot recompiles instead of
        re-tripping over the same blob."""
        try:
            os.replace(path, str(path) + ".corrupt")
            self._stats["disk_quarantined"] += 1
        except OSError:
            pass

    # -- load / store ------------------------------------------------------

    def load(self, key: Any):
        """A loaded ``jax.stages.Compiled`` for ``key``, or ``None``.

        Loading never traces: the deserialized executable answers the
        first request at warm-path cost (the zero-retrace boot
        property the serve-tier tests assert).

        Verification: executable entries carry a sha256 over the
        serialized bytes; a truncated, bit-rotten, or foreign file —
        unpicklable, unknown format, checksum mismatch, or failing
        deserialization — is quarantined (renamed ``.corrupt``) and
        reported as a miss, so the caller recompiles and re-publishes.
        Legacy pre-checksum entries that still round-trip are upgraded
        in place (``disk_migrated``)."""
        from repro.faults.errors import CorruptCacheEntry

        digest = stable_digest(key)
        path = self._path(digest)
        if not path.exists():
            self._stats["disk_misses"] += 1
            return None
        recorded = None
        try:
            if self.fault_injector is not None:
                self.fault_injector.maybe_raise(
                    "disk.read", digest=digest[:16]
                )
            with open(path, "rb") as f:
                payload = pickle.load(f)
            fmt = (
                payload.get("format") if isinstance(payload, dict) else None
            )
            if fmt == _FORMAT_WARMUP:
                self._stats["warm_records"] += 1
                self._stats["disk_misses"] += 1
                return None
            if fmt != _FORMAT_EXECUTABLE:
                raise CorruptCacheEntry(
                    f"unrecognized cache entry format {fmt!r}"
                )
            serialized = payload["serialized"]
            recorded = payload.get("checksum")
            if recorded is not None and _checksum(serialized) != recorded:
                raise CorruptCacheEntry(
                    f"checksum mismatch for {path.name}"
                )
            if self.fault_injector is not None:
                self.fault_injector.maybe_raise(
                    "disk.deserialize", digest=digest[:16]
                )
            from jax.experimental import serialize_executable as se

            compiled = se.deserialize_and_load(
                serialized, payload["in_tree"], payload["out_tree"],
            )
        except Exception as err:  # corrupt blob / incompatible runtime
            self._stats["disk_errors"] += 1
            self._stats["disk_misses"] += 1
            self._quarantine(path, err)
            return None
        if recorded is None:
            # Migration: a pre-checksum entry that round-trips fine is
            # rewritten with its checksum so the next boot verifies it.
            try:
                payload["checksum"] = _checksum(serialized)
                self._write(digest, payload)
                self._stats["disk_migrated"] += 1
            except Exception:
                pass  # upgrade is best-effort; the load itself succeeded
        self._stats["disk_hits"] += 1
        return compiled

    def store(self, key: Any, compiled) -> bool:
        """Serialize ``compiled`` under ``key``; on platforms that cannot
        round-trip executables, degrade to a warmup record so the next
        boot knows to re-trace eagerly.  Returns True on a full store."""
        digest = stable_digest(key)
        try:
            if self.fault_injector is not None:
                self.fault_injector.maybe_raise(
                    "disk.write", digest=digest[:16]
                )
            from jax.experimental import serialize_executable as se

            serialized, in_tree, out_tree = se.serialize(compiled)
            self._write(digest, {
                "format": _FORMAT_EXECUTABLE,
                "schema": _SCHEMA,
                "serialized": serialized,
                "checksum": _checksum(serialized),
                "in_tree": in_tree,
                "out_tree": out_tree,
            })
        except Exception as err:
            self._stats["disk_errors"] += 1
            try:
                self._write(digest, {
                    "format": _FORMAT_WARMUP,
                    "schema": _SCHEMA,
                    "error": repr(err),
                })
            except Exception:
                pass
            return False
        self._stats["disk_stores"] += 1
        return True

    def wrap(self, engine, key: Any, jitted):
        """Engine seam: wrap a freshly-built jitted executable so its
        first use resolves disk-load vs AOT-compile (see
        ``Engine._executable_for``)."""
        return _DiskBackedExecutable(self, key, jitted, engine=engine)

    def stats(self) -> dict:
        entries = 0
        if self.dir.is_dir():
            entries = sum(1 for _ in self.dir.glob("*.jexe"))
        return {**self._stats, "entries": entries, "dir": str(self.dir)}


class _DiskBackedExecutable:
    """An Engine LRU entry backed by the disk store.

    First use resolves, in order: deserialize from disk (no trace, no
    compile), else AOT ``lower().compile()`` + store for the next
    process, else (unloweable args) fall back to the plain jitted
    callable.  ``source`` records which path won, for observability.
    """

    __slots__ = ("cache", "key", "jitted", "compiled", "source",
                 "_engine_ref")

    def __init__(self, cache: DiskExecutableCache, key, jitted, engine=None):
        self.cache = cache
        self.key = key
        self.jitted = jitted
        self.compiled = None
        self.source = None
        # weak: the Engine's LRU owns this object, never the reverse
        self._engine_ref = weakref.ref(engine) if engine is not None else None

    def _tracer(self):
        engine = self._engine_ref() if self._engine_ref is not None else None
        return getattr(engine, "tracer", None)

    def _injector(self):
        engine = self._engine_ref() if self._engine_ref is not None else None
        return getattr(engine, "fault_injector", None)

    def _materialize(self, args: tuple) -> None:
        if self.compiled is not None:
            return
        tracer = self._tracer()
        with maybe_span(tracer, "serve.disk_load", cat="compile") as sp:
            loaded = self.cache.load(self.key)
        if loaded is not None:
            self.compiled, self.source = loaded, "disk"
            if sp is not None:
                sp.args["source"] = "disk"
            return
        # Miss: claim the signature before compiling so concurrently
        # booting replicas don't duplicate the AOT work — the loser of
        # the claim blocks, then finds the winner's entry on re-check.
        with self.cache.lock(self.key):
            with maybe_span(tracer, "serve.disk_load", cat="compile") as sp:
                loaded = self.cache.load(self.key)
            if loaded is not None:
                self.compiled, self.source = loaded, "disk"
                if sp is not None:
                    sp.args["source"] = "disk"
                return
            with maybe_span(tracer, "serve.aot_compile", cat="compile") as sp:
                try:
                    inj = self._injector()
                    if inj is not None:
                        inj.maybe_raise("compile.aot")
                    compiled = self.jitted.lower(*args).compile()
                except Exception:
                    # Can't AOT-lower these args (exotic pytrees,
                    # platform quirks): serve through plain jit, skip
                    # persistence.
                    self.compiled, self.source = self.jitted, "jit"
                    if sp is not None:
                        sp.args["source"] = "jit"
                    return
                self.compiled, self.source = compiled, "aot"
                if sp is not None:
                    sp.args["source"] = "aot"
            self.cache.store(self.key, compiled)

    def warm(self, args: tuple) -> str:
        """Materialize without executing; returns the winning source."""
        self._materialize(args)
        return self.source

    def __call__(self, *args):
        self._materialize(args)
        return self.compiled(*args)


# --------------------------------------------------------------------------
# replica-boot warmup
# --------------------------------------------------------------------------

def warm(
    engine,
    specs: Iterable[Any],
    *,
    batch_sizes: tuple[int, ...] = (),
    queries: list[Any] | None = None,
    hg=None,
    require_no_retrace: bool = False,
) -> dict:
    """Boot-time warmup: bring ``engine`` to warm-path q/s before the
    first request.

    For each spec (an ``AlgorithmSpec``, or an already-compiled
    ``CompiledAlgorithm``) materialize the unbatched executable plus one
    per batch bucket in ``batch_sizes`` — loading from the engine's
    ``disk_cache`` when the store holds the signature (zero retraces)
    and AOT-compiling (and storing) otherwise.

    ``queries``: per-spec example query for specs whose ``query0`` is
    unset (e.g. an unseeded ``random_walk_spec``); ignored where the
    spec carries its own.  Returns a report::

        {"boot_s": ..., "traces": ..., "paths": {name: {path: source}}}

    where each source is ``disk`` (deserialized), ``aot`` (compiled +
    stored), or ``jit`` (no disk cache attached / unloweable).

    ``require_no_retrace=True`` wraps the boot in the analysis-layer
    retrace sentinel: a replica that was expected to come up entirely
    from the disk store raises ``RetraceError`` instead of silently
    paying compile latency on its first requests.
    """
    from repro.analysis.retrace import assert_no_retrace

    if require_no_retrace:
        with assert_no_retrace(engine, label="serve.warm"):
            return warm(
                engine, specs, batch_sizes=batch_sizes, queries=queries,
                hg=hg, require_no_retrace=False,
            )
    t0 = time.perf_counter()
    before = engine.cache_stats()["traces"]
    paths: dict[str, dict] = {}
    for i, item in enumerate(specs):
        compiled = item if hasattr(item, "warmup") else engine.compile(item)
        example = None
        if queries is not None and i < len(queries):
            example = queries[i]
        name = getattr(compiled.spec, "name", f"spec{i}")
        paths[f"{i}:{name}"] = compiled.warmup(
            query=example, batch_sizes=batch_sizes, hg=hg
        )
    sources = [
        rep.get("source") for per in paths.values() for rep in per.values()
    ]
    return {
        "boot_s": time.perf_counter() - t0,
        "traces": engine.cache_stats()["traces"] - before,
        "from_disk": sum(1 for s in sources if s == "disk"),
        "compiled": sum(1 for s in sources if s == "aot"),
        "paths": paths,
    }
