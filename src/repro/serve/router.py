"""``Router``: the replica-pool front-end with heartbeat failover.

PR 9 proved one invariant inside a single process: every submitted
request resolves — value or typed error — no matter what the fault plan
does.  This module extends that invariant across process boundaries.
The router owns N ``ProcessReplica`` handles (``repro.serve.replica``),
all booted from one shared ``DiskExecutableCache``, and guarantees:

* **Routing** — signature-affinity first (a stable ``crc32`` of the
  spec key pins a key to a home replica, keeping that replica's
  executable LRU hot), falling back to least-loaded when the home
  replica is busier than the pool minimum by more than
  ``affinity_slack`` requests, dead, or still booting.
* **Death detection** — a replica is declared dead when its pipe
  breaks/EOFs, its process exits, or it misses heartbeats for
  ``heartbeat_timeout_ms`` (catches the wedged-but-alive case that pipe
  liveness can't).
* **Failover** — a dead replica's in-flight requests re-route to a
  peer.  Re-execution is safe (compiled paths are deterministic: a
  duplicate execute is bitwise-identical) and bounded: after
  ``MAX_FAILOVERS`` re-routes a request resolves with ``ReplicaLost``
  instead of bouncing forever.
* **Respawn** — a dead slot respawns via the factory; the newcomer
  boots from the shared disk store (zero retraces) and rejoins the
  ready set on its ``("ready", ...)`` message.
* **Load shedding** — admission fails fast with ``Overloaded`` once
  pending + in-flight hits ``max_queue_depth``; the pool keeps serving
  what it already accepted.

``submit`` ALWAYS returns a ``Future`` and every future resolves:
shed, route-fault, closed, and replica-lost requests resolve with their
typed error rather than raising at the call site, so a replay loop is
``wait(futures)`` + classify, never try/except around admission.

Testability mirrors the batcher: the clock is injected and ``pump(now)``
is the whole control loop as a pure-ish step — fake-clock unit tests
drive death detection, failover bounding and shedding with fake replica
handles and no processes, threads, or sleeps.  ``start()`` merely runs
``pump`` on a thread against the real clock.
"""
from __future__ import annotations

import itertools
import threading
import time
import zlib
from collections import deque
from concurrent.futures import Future
from typing import Any, Callable

from repro.faults.errors import FrontendClosed, Overloaded, ReplicaLost
from repro.obs.metrics import default_registry, weak_provider

# A request survives this many re-routes before resolving ReplicaLost.
MAX_FAILOVERS = 2

_BOOTING, _READY, _DEAD = "booting", "ready", "dead"


class _Pending:
    """One admitted request: what we need to (re)send it + its future."""

    __slots__ = ("req_id", "spec_key", "query", "hg_ref", "deadline_ms",
                 "future", "failovers")

    def __init__(self, req_id, spec_key, query, hg_ref, deadline_ms):
        self.req_id = req_id
        self.spec_key = spec_key
        self.query = query
        self.hg_ref = hg_ref
        self.deadline_ms = deadline_ms
        self.future: Future = Future()
        self.failovers = 0


class _Slot:
    """One replica position: the handle cycles through boot/ready/dead
    (and back, via respawn) while the slot identity — and its affinity
    hash target — stays fixed."""

    __slots__ = ("index", "handle", "state", "last_seen", "boot_started",
                 "in_flight", "served", "errors", "deaths", "respawns",
                 "boot_report", "hb", "fatal")

    def __init__(self, index: int, handle, now: float):
        self.index = index
        self.handle = handle
        self.state = _BOOTING
        self.last_seen = now
        self.boot_started = now
        self.in_flight: dict[int, _Pending] = {}
        self.served = 0
        self.errors = 0
        self.deaths = 0
        self.respawns = 0
        self.boot_report: dict | None = None
        self.hb: dict | None = None
        self.fatal: str | None = None


class Router:
    """Replica-pool front-end: route / detect / fail over / respawn.

    ``factory(index)`` returns a replica handle exposing the
    ``ProcessReplica`` interface (``poll_messages``/``send``/``alive``/
    ``stop``/``kill``); tests substitute in-memory fakes.
    """

    def __init__(
        self,
        factory: Callable[[int], Any],
        n_replicas: int,
        *,
        heartbeat_timeout_ms: float = 1000.0,
        boot_timeout_s: float = 180.0,
        max_queue_depth: int = 256,
        max_in_flight: int = 32,
        respawn: bool = True,
        max_respawns: int = 3,
        affinity_slack: int = 2,
        clock: Callable[[], float] = time.monotonic,
        poll_interval_s: float = 0.02,
        fault_injector=None,
        registry=None,
    ):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self._factory = factory
        self._hb_timeout_s = heartbeat_timeout_ms / 1000.0
        self._boot_timeout_s = boot_timeout_s
        self._max_queue_depth = max_queue_depth
        self._max_in_flight = max_in_flight
        self._respawn = respawn
        self._max_respawns = max_respawns
        self._affinity_slack = affinity_slack
        self._clock = clock
        self._poll_interval_s = poll_interval_s
        self.fault_injector = fault_injector
        self._lock = threading.RLock()
        self._ids = itertools.count(1)
        self._pending: deque[_Pending] = deque()
        self._closed = False
        self._thread: threading.Thread | None = None
        self._stop_thread = False
        now = self._clock()
        self.slots = [_Slot(i, factory(i), now) for i in range(n_replicas)]

        reg = registry if registry is not None else default_registry()
        self._m_deaths = reg.counter("faults.replica.deaths")
        self._m_respawns = reg.counter("faults.replica.respawns")
        self._m_failovers = reg.counter("faults.replica.failovers")
        self._m_lost = reg.counter("faults.replica.lost")
        self._m_shed = reg.counter("serve.router.shed")
        self._m_route_faults = reg.counter("serve.router.route_faults")
        self._m_closed_failed = reg.counter("serve.router.closed_failed")
        self._provider = reg.register_provider(
            "serve.router", weak_provider(self.stats)
        )

    # -- admission ---------------------------------------------------------

    def submit(
        self,
        spec_key: Any,
        hg_ref: Any = None,
        query: Any = None,
        deadline_ms: float | None = None,
    ) -> Future:
        """Admit one request; the returned future ALWAYS resolves — to a
        ``ServedResult`` or to a typed error (``Overloaded`` at the
        admission edge, ``FrontendClosed`` after ``close``,
        ``ReplicaLost`` past the failover budget, or whatever typed
        error the replica itself fanned back)."""
        req = _Pending(next(self._ids), spec_key, query, hg_ref, deadline_ms)
        resolutions: list = []
        with self._lock:
            if self._closed:
                self._m_closed_failed.inc()
                resolutions.append(
                    (req, FrontendClosed("router is closed"))
                )
            elif not self._admit(req, resolutions):
                pass           # _admit resolved it (shed / route fault)
            else:
                self._dispatch(resolutions)
        self._apply(resolutions)
        return req.future

    def _admit(self, req: _Pending, resolutions: list) -> bool:
        if self.fault_injector is not None:
            try:
                self.fault_injector.maybe_raise(
                    "router.route", spec_key=req.spec_key
                )
            except Exception as err:
                self._m_route_faults.inc()
                resolutions.append((req, err))
                return False
        depth = len(self._pending) + sum(
            len(s.in_flight) for s in self.slots
        )
        if depth >= self._max_queue_depth:
            self._m_shed.inc()
            resolutions.append((req, Overloaded(
                f"queue depth {depth} >= {self._max_queue_depth}; "
                f"back off and retry"
            )))
            return False
        self._pending.append(req)
        return True

    # -- routing -----------------------------------------------------------

    def _route(self, req: _Pending) -> _Slot | None:
        """Pick a ready slot: home-by-affinity unless it lags the
        least-loaded by more than ``affinity_slack``.

        Slots at ``max_in_flight`` don't take more: the surplus stays in
        the router's pending queue.  This bounds the blast radius of one
        crash — a dying replica burns at most ``max_in_flight`` requests'
        failover budget, not the whole backlog."""
        ready = [
            s for s in self.slots
            if s.state == _READY and len(s.in_flight) < self._max_in_flight
        ]
        if not ready:
            return None
        least = min(ready, key=lambda s: (len(s.in_flight), s.index))
        home_idx = zlib.crc32(repr(req.spec_key).encode()) % len(self.slots)
        home = self.slots[home_idx]
        if home.state == _READY and (
            len(home.in_flight) < self._max_in_flight
        ) and (
            len(home.in_flight) <= len(least.in_flight) + self._affinity_slack
        ):
            return home
        return least

    def _dispatch(self, resolutions: list) -> None:
        """Drain pending into ready slots; a send failure is a death
        declaration and its failover path requeues, so this loops until
        pending is empty or no slot is ready."""
        now = self._clock()
        while self._pending:
            slot = self._route(self._pending[0])
            if slot is None:
                break
            req = self._pending.popleft()
            slot.in_flight[req.req_id] = req
            try:
                slot.handle.send((
                    "req", req.req_id, req.spec_key, req.query,
                    req.hg_ref, req.deadline_ms,
                ))
            except Exception as err:
                # Broken pipe at send: the slot is dead; the request we
                # just attached fails over with the rest of its in-flight.
                self._mark_dead(slot, now, f"send failed: {err}",
                                resolutions)
        self._fail_pending_if_hopeless(resolutions)

    def _fail_pending_if_hopeless(self, resolutions: list) -> None:
        """With every slot permanently dead (no respawn budget left),
        queued requests can never execute — resolve them ``ReplicaLost``
        now rather than hang."""
        if self._pending and all(
            s.state == _DEAD for s in self.slots
        ):
            while self._pending:
                req = self._pending.popleft()
                self._m_lost.inc()
                resolutions.append((req, ReplicaLost(
                    f"request {req.req_id}: all {len(self.slots)} replicas "
                    f"dead with no respawn budget left"
                )))

    # -- the control step --------------------------------------------------

    def pump(self, now: float | None = None) -> None:
        """One control step: drain replica messages, detect deaths,
        fail over, respawn, dispatch.  The background thread calls this
        in a loop; fake-clock tests call it directly."""
        resolutions: list = []
        with self._lock:
            if now is None:
                now = self._clock()
            for slot in self.slots:
                if slot.state == _DEAD:
                    continue
                for msg in slot.handle.poll_messages():
                    slot.last_seen = now
                    self._on_message(slot, msg, resolutions)
            for slot in self.slots:
                if slot.state == _DEAD:
                    continue
                if not slot.handle.alive():
                    self._mark_dead(slot, now, "process exited",
                                    resolutions)
                elif slot.state == _READY and (
                    now - slot.last_seen > self._hb_timeout_s
                ):
                    self._mark_dead(slot, now, "missed heartbeats",
                                    resolutions)
                elif slot.state == _BOOTING and (
                    now - slot.boot_started > self._boot_timeout_s
                ):
                    self._mark_dead(slot, now, "boot timeout", resolutions)
            self._dispatch(resolutions)
        self._apply(resolutions)

    def _on_message(self, slot: _Slot, msg, resolutions: list) -> None:
        kind = msg[0]
        if kind == "ready":
            slot.state = _READY
            slot.boot_report = msg[1]
        elif kind == "hb":
            slot.hb = msg[1]
        elif kind == "res":
            req = slot.in_flight.pop(msg[1], None)
            if req is not None:        # None: already failed over, stale
                slot.served += 1
                resolutions.append((req, ("ok", msg[2])))
        elif kind == "err":
            req = slot.in_flight.pop(msg[1], None)
            if req is not None:
                slot.errors += 1
                resolutions.append((req, msg[2]))
        elif kind == "fatal":
            slot.fatal = msg[1]
        elif kind == "bye":
            slot.hb = msg[1]

    # -- death / failover / respawn ----------------------------------------

    def _mark_dead(self, slot: _Slot, now: float, why: str,
                   resolutions: list) -> None:
        slot.state = _DEAD
        slot.deaths += 1
        self._m_deaths.inc()
        slot.handle.stop(force=True)
        # Failover: the dead replica's in-flight requests go back to the
        # FRONT of the queue (they have waited longest), each burning one
        # unit of failover budget.
        for req in reversed(list(slot.in_flight.values())):
            slot.in_flight.pop(req.req_id, None)
            req.failovers += 1
            if req.failovers > MAX_FAILOVERS:
                self._m_lost.inc()
                resolutions.append((req, ReplicaLost(
                    f"request {req.req_id} lost replica {slot.index} "
                    f"({why}); failover budget ({MAX_FAILOVERS}) exhausted"
                )))
            else:
                self._m_failovers.inc()
                self._pending.appendleft(req)
        if self._respawn and slot.respawns < self._max_respawns \
                and not self._closed:
            slot.respawns += 1
            self._m_respawns.inc()
            slot.handle = self._factory(slot.index)
            slot.state = _BOOTING
            slot.boot_started = now
            slot.last_seen = now
            slot.boot_report = None
        self._fail_pending_if_hopeless(resolutions)

    # -- resolution (outside the lock) -------------------------------------

    @staticmethod
    def _apply(resolutions: list) -> None:
        """Resolve futures OUTSIDE the router lock: done-callbacks may
        re-enter ``submit``/``stats`` and must not deadlock."""
        for req, outcome in resolutions:
            if req.future.done():      # failover raced a late result
                continue
            if isinstance(outcome, tuple) and outcome[0] == "ok":
                req.future.set_result(outcome[1])
            else:
                req.future.set_exception(outcome)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "Router":
        if self._thread is None:
            self._stop_thread = False
            self._thread = threading.Thread(
                target=self._loop, name="repro-serve-router", daemon=True,
            )
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop_thread:
            self.pump()
            time.sleep(self._poll_interval_s)

    def wait_ready(self, min_ready: int | None = None,
                   timeout_s: float = 180.0) -> int:
        """Block until ``min_ready`` replicas (default: all) answered
        ``ready``.  Raises on timeout, quoting any ``fatal`` boot
        errors the replicas reported."""
        want = len(self.slots) if min_ready is None else min_ready
        deadline = time.monotonic() + timeout_s
        while True:
            if self._thread is None:
                self.pump()
            with self._lock:
                n = sum(1 for s in self.slots if s.state == _READY)
                fatals = [s.fatal for s in self.slots if s.fatal]
            if n >= want:
                return n
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"{n}/{want} replicas ready after {timeout_s}s; "
                    f"boot errors: {fatals or 'none'}"
                )
            time.sleep(0.02)

    def close(self) -> None:
        """Stop the pool.  A final pump collects results already on the
        wire; everything still unresolved — queued or in flight — fails
        typed with ``FrontendClosed``.  Idempotent; never hangs."""
        self.pump()
        resolutions: list = []
        with self._lock:
            if self._closed:
                return
            self._closed = True
            while self._pending:
                req = self._pending.popleft()
                self._m_closed_failed.inc()
                resolutions.append(
                    (req, FrontendClosed("router closed while queued"))
                )
            for slot in self.slots:
                for req in list(slot.in_flight.values()):
                    slot.in_flight.pop(req.req_id, None)
                    self._m_closed_failed.inc()
                    resolutions.append(
                        (req, FrontendClosed("router closed in flight"))
                    )
        self._apply(resolutions)
        self._stop_thread = True
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None
        for slot in self.slots:
            if slot.state != _DEAD:
                slot.handle.stop()
                slot.state = _DEAD

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- inspection --------------------------------------------------------

    def in_flight(self) -> int:
        with self._lock:
            return sum(len(s.in_flight) for s in self.slots)

    def stats(self) -> dict:
        """Pool totals + per-replica detail; also the registry's
        ``serve.router`` snapshot provider."""
        with self._lock:
            per = []
            for s in self.slots:
                per.append({
                    "index": s.index,
                    "state": s.state,
                    "in_flight": len(s.in_flight),
                    "served": s.served,
                    "errors": s.errors,
                    "deaths": s.deaths,
                    "respawns": s.respawns,
                    "boot": s.boot_report,
                    "replica_counts": s.hb,
                })
            return {
                "replicas": len(self.slots),
                "ready": sum(1 for s in self.slots if s.state == _READY),
                "pending": len(self._pending),
                "in_flight": sum(len(s.in_flight) for s in self.slots),
                "served": sum(s.served for s in self.slots),
                "errors": sum(s.errors for s in self.slots),
                "deaths": self._m_deaths.value,
                "respawns": self._m_respawns.value,
                "failovers": self._m_failovers.value,
                "lost": self._m_lost.value,
                "shed": self._m_shed.value,
                "per_replica": per,
            }
