"""The async request front-end: ``submit`` one query, get a ``Future``.

``Frontend`` is the user-facing layer of the serving tier.  It owns

* a registry of **compiled paths** (``register(spec_key, spec)`` ->
  ``Engine.compile``),
* a ``CoalescingBatcher`` grouping in-flight queries by
  ``(spec_key, hypergraph)``,
* one **worker thread** that continuously drains due batches into
  ``CompiledAlgorithm.run_batch`` and fans the rows back out to
  per-request futures,
* ``ServeMetrics`` for the wait/execute latency split, bucket
  occupancy and flush accounting (``stats()``).

Correctness contract: a request's resolved value is **bitwise identical
to a sequential ``CompiledAlgorithm.run(query=...)``** of the same query
— coalescing, batch padding and fan-out never touch the numbers
(``run_batch``'s own bitwise-vs-sequential guarantee carries through
row slicing).  Asserted by ``tests/test_serve.py`` on the local and
sharded backends.

Determinism for tests: the batcher is pure and the clock injectable;
an unstarted front-end can be driven synchronously with ``pump()``
(no thread, no sleeps), which the jit-free property tests use.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any

import numpy as np

from repro.obs.trace import maybe_span
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import AdaptiveDelay, CoalescingBatcher, Flush

DEFAULT_MAX_BATCH = 32
DEFAULT_MAX_DELAY_MS = 5.0


@dataclasses.dataclass
class ServedResult:
    """What a request's ``Future`` resolves to.

    ``value`` is the spec's extracted output for THIS query (leading
    batch axis already sliced off, leaves as numpy arrays).  The rest is
    per-request observability: how long the query waited for
    co-batchable traffic, how long its batch executed, why and how full
    the batch flushed.
    """

    value: Any
    queue_wait_s: float
    execute_s: float
    flush_reason: str
    batch_size: int
    batch_bucket: int
    group: Any
    supersteps_executed: int | None = None


class _Path:
    """One registered compiled algorithm (a ``spec_key``)."""

    __slots__ = ("key", "compiled", "max_batch")

    def __init__(self, key, compiled, max_batch):
        self.key = key
        self.compiled = compiled
        self.max_batch = max_batch


class Frontend:
    """Coalescing request front-end over one ``Engine``.

    >>> fe = Frontend(engine, max_batch=32, max_delay_ms=5)
    >>> fe.register("sssp", shortest_paths_spec(hg, 0, 32))
    >>> fe.register("ppr", random_walk_spec(hg, iters=20))
    >>> with fe:                      # starts the worker thread
    ...     futs = [fe.submit("sssp", query=s) for s in sources]
    ...     results = [f.result() for f in futs]
    >>> fe.stats()                    # latency split, occupancy, caches

    ``max_batch`` should be the batch bucket the executables were
    warmed at (a power of two): a full flush then runs at occupancy 1.0
    while partial (deadline) flushes pad up to the same bucket set.
    """

    def __init__(
        self,
        engine,
        *,
        max_batch: int = DEFAULT_MAX_BATCH,
        max_delay_ms: float = DEFAULT_MAX_DELAY_MS,
        log_every_s: float | None = None,
        clock=time.monotonic,
        adaptive_delay: bool = False,
        min_delay_ms: float = 0.5,
    ):
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_ms) / 1e3
        self.clock = clock
        self.metrics = ServeMetrics(log_every_s=log_every_s)
        # Off by default: max_delay_ms stays a fixed deadline.  Opted
        # in, it becomes the UPPER bound of an AdaptiveDelay controller
        # fed by the observed flush reason / occupancy / execute time.
        self._adaptive = (
            AdaptiveDelay(
                self.max_delay_s,
                lo_s=float(min_delay_ms) / 1e3,
                hi_s=max(self.max_delay_s, float(min_delay_ms) / 1e3),
            )
            if adaptive_delay
            else None
        )
        self._paths: dict[Any, _Path] = {}
        self._batcher = CoalescingBatcher(
            capacity=lambda group: self._paths[group[0]].max_batch
        )
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._thread: threading.Thread | None = None
        self._stop = False
        self._closed = False

    # -- registration ------------------------------------------------------

    def register(
        self, spec_key: Any, spec, *, max_batch: int | None = None,
        **overrides,
    ):
        """Register a servable path: an ``AlgorithmSpec`` (compiled via
        ``engine.compile(spec, **overrides)``) or anything already
        exposing ``run_batch`` (a ``CompiledAlgorithm``, or a test
        double).  Returns the compiled handle."""
        if hasattr(spec, "run_batch"):
            compiled = spec
        else:
            if getattr(spec, "bind_query", None) is None:
                raise ValueError(
                    f"spec {getattr(spec, 'name', spec)!r} has no "
                    "bind_query: the front-end batches per-request "
                    "queries; declare the query axis"
                )
            compiled = self.engine.compile(spec, **overrides)
        with self._lock:
            if self._closed:
                raise RuntimeError("front-end is closed")
            if spec_key in self._paths:
                raise ValueError(f"spec_key {spec_key!r} already registered")
            self._paths[spec_key] = _Path(
                spec_key, compiled, int(max_batch or self.max_batch)
            )
        return compiled

    def compiled(self, spec_key: Any):
        return self._paths[spec_key].compiled

    # -- submission --------------------------------------------------------

    def submit(
        self,
        spec_key: Any,
        hg=None,
        query: Any = None,
        deadline_ms: float | None = None,
    ) -> Future:
        """Enqueue one query; resolves to a ``ServedResult``.

        ``hg``: serve against this (same-shape-bucket) hypergraph
        instead of the spec's own; queries only coalesce within one
        hypergraph.  ``deadline_ms`` bounds this request's queue wait —
        when it expires the batch flushes with whatever co-arrived
        (default: the front-end's ``max_delay_ms``)."""
        if spec_key not in self._paths:
            raise KeyError(
                f"unknown spec_key {spec_key!r}; register() it first"
            )
        if deadline_ms is not None:
            deadline_s = deadline_ms / 1e3
        elif self._adaptive is not None:
            deadline_s = self._adaptive.delay_s
        else:
            deadline_s = self.max_delay_s
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise RuntimeError("front-end is closed")
            self._batcher.submit(
                (spec_key, id(hg) if hg is not None else 0),
                query,
                now=self.clock(),
                deadline_s=deadline_s,
                hg=hg,
                future=fut,
            )
            self._cond.notify()
        self.metrics.note_submit()
        return fut

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "Frontend":
        if self._thread is None:
            self._stop = False
            self._thread = threading.Thread(
                target=self._worker, name="repro-serve-frontend",
                daemon=True,
            )
            self._thread.start()
        return self

    def close(self) -> None:
        """Stop accepting, drain every pending request, stop the worker."""
        with self._cond:
            self._closed = True
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.pump(drain=True)  # whatever the worker didn't get to

    def __enter__(self) -> "Frontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- execution ---------------------------------------------------------

    def pump(self, *, drain: bool = False) -> int:
        """Synchronously execute every due flush on the caller's thread.

        The single-threaded serving mode: property tests (fake clock,
        no sleeps) and simple replay loops call ``pump`` instead of
        ``start``.  ``drain=True`` also flushes not-yet-due groups."""
        n = 0
        while True:
            with self._lock:
                flush = self._batcher.poll(self.clock())
                due = (
                    [flush] if flush is not None
                    else self._batcher.drain() if drain
                    else []
                )
            if not due:
                return n
            for f in due:
                self._run_flush(f)
                n += 1

    def _worker(self) -> None:
        while True:
            with self._cond:
                flush = None
                while not self._stop:
                    flush = self._batcher.poll(self.clock())
                    if flush is not None:
                        break
                    horizon = self._batcher.next_deadline()
                    self._cond.wait(
                        timeout=None
                        if horizon is None
                        else max(horizon - self.clock(), 0.0)
                    )
                if flush is None and self._stop:
                    flushes = self._batcher.drain()
                    for f in flushes:
                        self._run_flush(f)
                    return
            self._run_flush(flush)
            self.metrics.maybe_log(self.clock())

    def _run_flush(self, flush: Flush) -> None:
        from repro.core.serving import BATCH_FLOOR, bucket_dim

        path = self._paths[flush.group[0]]
        reqs = flush.requests
        dispatch = self.clock()
        waits = [dispatch - r.arrival for r in reqs]
        b = len(reqs)
        bucket = bucket_dim(b, floor=BATCH_FLOOR)
        tracer = getattr(self.engine, "tracer", None)
        try:
            with maybe_span(
                tracer, "serve.flush", cat="serve",
                group=str(flush.group[0]), reason=flush.reason, batch=b,
                bucket=bucket,
            ) as sp:
                queries = _stack([r.query for r in reqs])
                res = path.compiled.run_batch(queries, hg=flush.hg)
                value = res.value
                if sp is not None:
                    tracer.block(sp, value)
                    sp.args["max_wait_s"] = max(waits, default=0.0)
                else:
                    _block(value)
        except Exception as err:  # noqa: BLE001 - fanned out to futures
            self.metrics.note_flush(
                flush.group[0], flush.reason, b, bucket, waits,
                self.clock() - dispatch, error=True,
            )
            for r in reqs:
                if r.future is not None:
                    r.future.set_exception(err)
            return
        execute_s = self.clock() - dispatch
        executed = getattr(res, "supersteps_executed", None)
        # analysis: ignore[host-sync] — one scalar readback per FLUSH
        # (not per request) feeding the occupancy metrics
        executed = int(np.asarray(executed)) if executed is not None else None
        self.metrics.note_flush(
            flush.group[0], flush.reason, b, bucket, waits, execute_s,
        )
        if self._adaptive is not None:
            # Error flushes (above) don't feed the controller: their
            # execute time measures the failure, not the batch.
            self._adaptive.observe(
                execute_s=execute_s,
                occupancy=b / max(path.max_batch, 1),
                reason=flush.reason,
            )
        rows = _unstack(value, b)
        for i, r in enumerate(reqs):
            if r.future is None:
                continue
            r.future.set_result(ServedResult(
                value=rows[i],
                queue_wait_s=waits[i],
                execute_s=execute_s,
                flush_reason=flush.reason,
                batch_size=b,
                batch_bucket=bucket,
                group=flush.group[0],
                supersteps_executed=executed,
            ))

    # -- observability -----------------------------------------------------

    @property
    def current_delay_ms(self) -> float:
        """The flush deadline new submits get (adaptive or fixed)."""
        delay_s = (
            self._adaptive.delay_s if self._adaptive is not None
            else self.max_delay_s
        )
        return delay_s * 1e3

    def stats(self) -> dict:
        """One snapshot across all three layers: front-end latency /
        occupancy, the Engine's executable cache, the disk store — plus
        the unified metrics registry (every provider in one view)."""
        snap = self.metrics.snapshot()
        engine_stats = None
        if hasattr(self.engine, "cache_stats"):
            engine_stats = self.engine.cache_stats()
        snap["engine_cache"] = engine_stats
        disk = getattr(self.engine, "disk_cache", None)
        snap["disk_cache"] = disk.stats() if disk is not None else None
        snap["adaptive_delay"] = (
            self._adaptive.snapshot() if self._adaptive is not None else None
        )
        snap["registry"] = self.metrics.registry.snapshot()
        return snap


# -- pytree batch helpers (no jax import needed for the pure tests) --------

def _stack(queries: list[Any]):
    """Stack B query pytrees into one batched pytree (leading axis B)."""
    import jax

    return jax.tree.map(
        # analysis: ignore[host-sync] — batching host-side queries is the
        # ingest contract (rows are request-sized, not graph-sized)
        lambda *leaves: np.stack([np.asarray(x) for x in leaves]),
        *queries,
    )


def _unstack(value: Any, b: int) -> list[Any]:
    """Split a batched result pytree into B per-request pytrees."""
    import jax

    leaves, treedef = jax.tree.flatten(value)
    # analysis: ignore[host-sync] — fan-out materializes results the
    # futures are about to hand back; the one sync serving requires
    leaves = [np.asarray(leaf) for leaf in leaves]
    return [
        jax.tree.unflatten(treedef, [leaf[i] for leaf in leaves])
        for i in range(b)
    ]


def _block(value: Any) -> None:
    try:
        import jax

        # analysis: ignore[host-sync] — futures resolve to READY values
        # by contract (the tracer path measures this same wait)
        jax.block_until_ready(value)
    except Exception:  # numpy-only test doubles
        pass
